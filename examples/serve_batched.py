"""Serve a small model with batched requests (end-to-end driver per
deliverable b): prefill a batch of prompts, decode with the KV-cache serve
step, compare bf16 vs int8 weight-only quantization.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import RunConfig
from repro.models import LM
from repro.serve.engine import ServeEngine


def main():
    cfg = configs.get_smoke_config("granite-8b").replace(
        n_layers=4, d_model=128, d_ff=256, vocab_size=512)
    run = RunConfig(param_dtype="float32", activation_dtype="float32",
                    attn_block_q=64, attn_block_kv=64)
    params, _ = LM.init(cfg, run, jax.random.PRNGKey(0))

    prompts = jax.random.randint(jax.random.PRNGKey(1), (8, 12), 0,
                                 cfg.vocab_size)
    for quant in (False, True):
        run_q = dataclasses.replace(run, quantize_serving=quant)
        eng = ServeEngine(cfg, run_q, params, max_seq=64)
        t0 = time.time()
        out = eng.generate(prompts, max_new_tokens=32)
        dt = time.time() - t0
        print(f"int8={quant}: batch=8 x 32 new tokens in {dt:.2f}s "
              f"({8 * 32 / dt:.0f} tok/s); sample: "
              f"{list(map(int, out[0, -8:]))}")


if __name__ == "__main__":
    main()
