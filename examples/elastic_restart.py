"""Fault-tolerance walkthrough: train, kill, resume bit-exactly — the
job-level durability MISO's re-partitioning relies on.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticLMData
from repro.models import LM
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.optim import adamw_init
from repro.train.train_step import make_train_step


def main():
    cfg = configs.get_smoke_config("granite-8b")
    run = RunConfig(param_dtype="float32", activation_dtype="float32",
                    attn_block_q=16, attn_block_kv=16, loss_chunk=32)
    data = SyntheticLMData(cfg.vocab_size, 32, 4, seed=0)
    step_fn = jax.jit(make_train_step(cfg, run))
    ckpt = tempfile.mkdtemp()

    def run_steps(params, opt, start, n):
        for s in range(start, start + n):
            t, l = data.batch_at(s)
            params, opt, m = step_fn(params, opt, jnp.asarray(t),
                                     jnp.asarray(l))
        return params, opt, float(m["loss"])

    params, _ = LM.init(cfg, run, jax.random.PRNGKey(0))
    opt = adamw_init(params)

    # uninterrupted run
    pa, _, loss_a = run_steps(params, opt, 0, 10)

    # interrupted run: 6 steps -> "crash" -> restore -> 4 more
    pb, ob, _ = run_steps(params, opt, 0, 6)
    save_checkpoint(ckpt, 6, {"params": pb, "opt": ob})
    print("killed after step 6; restoring from checkpoint...")
    state, step = restore_checkpoint(ckpt)
    pc, _, loss_c = run_steps(state["params"], state["opt"], step, 4)

    diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree_util.tree_leaves(pa),
                   jax.tree_util.tree_leaves(pc)))
    print(f"final loss {loss_a:.4f} vs resumed {loss_c:.4f}; "
          f"max param diff {diff:.2e} (bit-exact resume: {diff < 1e-6})")
    shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
