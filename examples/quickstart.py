"""Quickstart: train a small LM end-to-end on CPU with the public API.

    PYTHONPATH=src python examples/quickstart.py [--steps 200]

Uses the 20M preset (a reduced smollm-family model), the deterministic data
pipeline, AdamW with grad clipping, and checkpoints every 50 steps.  Loss
drops visibly within ~100 steps on the structured synthetic stream.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    sys.exit(train_main([
        "--preset", "20m", "--steps", str(args.steps), "--batch", "8",
        "--seq", "128", "--lr", "3e-3", "--ckpt-dir", "/tmp/repro_quickstart",
        "--log-every", "10",
    ]))
