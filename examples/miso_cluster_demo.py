"""MISO vs the competing schedulers on one cluster trace (paper Fig 10 in
miniature), using the trained U-Net predictor when available.

    PYTHONPATH=src python examples/miso_cluster_demo.py
"""
import os
import sys

sys.path.insert(0, "src")

from repro.core.estimators import OracleEstimator, UNetEstimator
from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.simulator import SimConfig, simulate
from repro.core.traces import generate_trace

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "predictor.npz")


def main():
    space = a100_mig_space()
    pm = PerfModel(space)
    jobs = generate_trace(80, lam_s=45.0, seed=0)
    oracle = OracleEstimator(pm)
    miso_est = (UNetEstimator.from_artifact(pm, ARTIFACT)
                if os.path.exists(ARTIFACT) else oracle)
    print(f"estimator: {'U-Net' if miso_est is not oracle else 'oracle'}; "
          f"{len(jobs)} jobs on 8 GPUs\n")
    print(f"{'policy':10s} {'avgJCT(s)':>10s} {'makespan(s)':>12s} "
          f"{'STP':>6s}  queue/mps/ckpt/run (s)")
    base = None
    for pol in ("nopart", "optsta", "mpsonly", "miso", "miso-frag", "srpt",
                "oracle"):
        est = miso_est if pol in ("miso", "miso-frag", "srpt") else oracle
        m = simulate(jobs, SimConfig(n_gpus=8, policy=pol), space, pm, est)
        if pol == "nopart":
            base = m
        b = m.breakdown
        gain = f" ({100 * (1 - m.avg_jct / base.avg_jct):+.0f}%)" if base else ""
        print(f"{pol:10s} {m.avg_jct:10,.0f} {m.makespan:12,.0f} "
              f"{m.stp:6.3f}  {b['queue']:.0f}/{b['mps']:.0f}/"
              f"{b['ckpt']:.0f}/{b['run']:.0f}{gain}")


if __name__ == "__main__":
    main()
