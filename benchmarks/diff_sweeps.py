"""Diff two sweep reports (``BENCH_sweep_*.json``) and flag regressions.

Compares per-(scenario, policy, placer, objective) summary metrics between
a baseline report and a candidate report, and exits non-zero when any
scenario regresses by more than ``--threshold`` (default 2%):

* ``avg_jct_s_mean`` / ``p90_jct_s_mean`` / ``makespan_s_mean`` — higher is
  worse (a JCT regression);
* ``stp_mean`` — lower is worse (a throughput regression);
* ``energy_j_mean`` / ``energy_per_job_j_mean`` — higher is worse (an
  energy regression; only compared when both reports carry the v3 energy
  columns);
* ``goodput_mean`` — lower is worse and ``work_lost_s_mean`` — higher is
  worse (robustness regressions; only compared when both reports carry the
  v4 robustness columns — the CI gate for the chaos scenarios).

Timing fields (``wall_s``, ``wall_s_total``) and execution details
(``config.workers``, ``config.serial``) are ignored: how a sweep was
scheduled is not a scheduling result.  This is the ROADMAP's "sweep
trajectory tracking" tool; CI runs it against the committed baseline in
``benchmarks/baselines/``.

  PYTHONPATH=src python benchmarks/diff_sweeps.py \\
      benchmarks/baselines/BENCH_sweep_smoke.json BENCH_sweep_smoke.json

The same driver also diffs **component reports** (``BENCH_components.json``,
kind ``miso-components``) — the report kind is auto-detected from the
baseline file.  In that mode the gated metric is ``us_per_call`` on the
``trace_scaling_*`` rows (µs per simulator event at each fleet tier): a row
more than ``--threshold`` slower than the committed baseline (default 10%
for components — wall-clock noise is real even with the harness's min-of-N
timing) fails the gate, and a trace row missing from the candidate is a
coverage regression.  Non-trace rows (optimizer latency, policy walls)
are reported as notes only: they are microbenches, not the event-loop
acceptance curve.

  PYTHONPATH=src:. python benchmarks/diff_sweeps.py \\
      benchmarks/baselines/BENCH_components.json BENCH_components.json

``--exact`` (sweep reports only) switches from threshold gating to
bit-identical comparison of every summary column except wall-clock: any
differing value is a regression.  This is the CI equivalence gate for the
replica-batched engine — the same grid run through ``--engine pool`` and
``--engine batched`` must produce byte-equal scheduling results, because
the batched engine is a re-staging of the scalar tick, not an
approximation of it.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

# rows of a miso-components report whose us_per_call is gated (higher is
# a regression); everything else in that report is informational.
# trace_scaling_* is the scalar engine's µs/event acceptance curve;
# batch_rollout is the replica-batched engine's aggregate µs/event.
GATED_ROW_PREFIX = ("trace_scaling_", "batch_rollout")
THRESHOLD_SWEEP = 0.02
THRESHOLD_COMPONENTS = 0.10

# metric key -> direction: +1 means "higher is a regression"
METRICS = {
    "avg_jct_s_mean": +1,
    "p90_jct_s_mean": +1,
    "makespan_s_mean": +1,
    "stp_mean": -1,
    "energy_j_mean": +1,
    "energy_per_job_j_mean": +1,
    "goodput_mean": -1,
    "work_lost_s_mean": +1,
}


def load_summary(path: str
                 ) -> Dict[Tuple[str, str, str, str], Dict[str, float]]:
    """Cells keyed (scenario, policy, placer, objective).  Schema v1
    reports predate the placer axis (every cell ran the then-hardwired
    least-loaded placement) and v1/v2 predate the objective axis (every
    cell maximized throughput), so older reports normalize to
    placer="least-loaded" / objective="throughput" and stay comparable
    against v3 candidates."""
    with open(path) as f:
        rep = json.load(f)
    if rep.get("kind") != "miso-sweep":
        raise ValueError(f"{path}: not a miso-sweep report "
                         f"(kind={rep.get('kind')!r})")
    ver = rep.get("schema_version", 1)
    out = {}
    for scenario, by_policy in rep.get("summary", {}).items():
        for policy, v in by_policy.items():
            if ver >= 3:
                for placer, by_obj in v.items():
                    for objective, agg in by_obj.items():
                        out[(scenario, policy, placer, objective)] = agg
            elif ver == 2:
                for placer, agg in v.items():
                    out[(scenario, policy, placer, "throughput")] = agg
            else:
                out[(scenario, policy, "least-loaded", "throughput")] = v
    return out


def report_kind(path: str) -> str:
    """``"miso-sweep"`` or ``"miso-components"``; raises on anything else."""
    with open(path) as f:
        kind = json.load(f).get("kind")
    if kind not in ("miso-sweep", "miso-components"):
        raise ValueError(f"{path}: unknown report kind {kind!r}")
    return kind


def load_components(path: str) -> Dict[str, float]:
    """Row name -> us_per_call from a miso-components report."""
    with open(path) as f:
        rep = json.load(f)
    if rep.get("kind") != "miso-components":
        raise ValueError(f"{path}: not a miso-components report "
                         f"(kind={rep.get('kind')!r})")
    return {r["name"]: float(r["us_per_call"])
            for r in rep.get("rows", []) if "us_per_call" in r}


def diff_components(base_path: str, new_path: str,
                    threshold: float) -> Tuple[List[str], List[str]]:
    """Returns (regressions, notes) for two miso-components reports.

    Gates ``us_per_call`` on the ``trace_scaling_*`` rows — the µs/event
    engine acceptance curve — and treats a gated row that vanished from the
    candidate as a regression (same vanishing-coverage rule as the sweep
    differ).  All other rows diff as notes.
    """
    base = load_components(base_path)
    new = load_components(new_path)
    regressions, notes = [], []
    for name in sorted(set(base) | set(new)):
        gated = name.startswith(GATED_ROW_PREFIX)
        if name not in new:
            (regressions if gated else notes).append(
                f"{name}: missing from candidate")
            continue
        if name not in base:
            notes.append(f"{name}: new row (no baseline)")
            continue
        b, n = base[name], new[name]
        if b == 0:
            continue
        rel = (n - b) / abs(b)
        line = f"{name} us_per_call: {b:.4g} -> {n:.4g} ({rel:+.2%})"
        if gated and rel > threshold:
            regressions.append(line)
        elif rel != 0:
            notes.append(line)
    return regressions, notes


def diff_exact(base_path: str, new_path: str) -> Tuple[List[str], List[str]]:
    """Bit-identical comparison of two sweep reports (``--exact``).

    Every summary column except wall-clock timing must match exactly —
    no threshold, no direction.  Used by CI to prove the replica-batched
    engine reproduces the pool engine's scheduling results byte-for-byte.
    """
    base = load_summary(base_path)
    new = load_summary(new_path)
    regressions, notes = [], []
    for cell in sorted(set(base) | set(new)):
        scenario, policy, placer, objective = cell
        label = f"{scenario}/{policy}/{placer}/{objective}"
        if cell not in new:
            regressions.append(f"{label}: missing from candidate")
            continue
        if cell not in base:
            notes.append(f"{label}: new cell (no baseline)")
            continue
        b, n = base[cell], new[cell]
        for k in sorted(set(b) | set(n)):
            if "wall" in k:
                continue
            if b.get(k) != n.get(k):
                regressions.append(
                    f"{label} {k}: {b.get(k)!r} != {n.get(k)!r}")
    return regressions, notes


def diff_reports(base_path: str, new_path: str,
                 threshold: float) -> Tuple[List[str], List[str]]:
    """Returns (regressions, notes): human-readable per-cell findings."""
    base = load_summary(base_path)
    new = load_summary(new_path)
    regressions, notes = [], []
    for cell in sorted(set(base) | set(new)):
        scenario, policy, placer, objective = cell
        label = f"{scenario}/{policy}/{placer}/{objective}"
        if cell not in new:
            # a baseline cell that stopped being measured is itself a
            # regression — the gate must not pass on vanishing coverage
            regressions.append(f"{label}: missing from candidate")
            continue
        if cell not in base:
            notes.append(f"{label}: new cell (no baseline)")
            continue
        for metric, direction in METRICS.items():
            b = base[cell].get(metric)
            n = new[cell].get(metric)
            if b is None or n is None or b == 0:
                continue
            rel = (n - b) / abs(b) * direction
            line = (f"{label} {metric}: "
                    f"{b:.4g} -> {n:.4g} ({rel:+.2%})")
            if rel > threshold:
                regressions.append(line)
            elif rel != 0:
                notes.append(line)
    return regressions, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two benchmark reports (sweep or components; "
                    "kind auto-detected), flag regressions")
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--threshold", type=float, default=None,
                    help="relative regression to flag (default 2%% for "
                         "sweep reports, 10%% for components reports)")
    ap.add_argument("--exact", action="store_true",
                    help="sweep reports only: require every non-timing "
                         "summary column to match bit-for-bit (the "
                         "batched-engine CI equivalence gate)")
    args = ap.parse_args(argv)
    kind = report_kind(args.baseline)
    if args.exact and kind != "miso-sweep":
        ap.error("--exact only applies to miso-sweep reports")
    if args.exact:
        gate = "exact match"
        regressions, notes = diff_exact(args.baseline, args.candidate)
    elif kind == "miso-components":
        threshold = (THRESHOLD_COMPONENTS if args.threshold is None
                     else args.threshold)
        gate = f"{threshold:.0%}"
        regressions, notes = diff_components(args.baseline, args.candidate,
                                             threshold)
    else:
        threshold = (THRESHOLD_SWEEP if args.threshold is None
                     else args.threshold)
        gate = f"{threshold:.0%}"
        regressions, notes = diff_reports(args.baseline, args.candidate,
                                          threshold)
    for line in notes:
        print(f"[diff-sweeps] note: {line}")
    if regressions:
        for line in regressions:
            print(f"[diff-sweeps] REGRESSION: {line}")
        print(f"[diff-sweeps] {len(regressions)} regression(s) "
              f"({gate}) vs {args.baseline}")
        return 1
    print(f"[diff-sweeps] OK: no regression ({gate}) vs {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
