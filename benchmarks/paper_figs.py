"""Reproductions of the paper's motivation + evaluation figures (Figs 2-15).

Each ``fig*`` function returns CSV rows (name, seconds, derived-string); the
derived string carries the figure's actual quantities, normalized the same
way the paper normalizes them.
"""
from __future__ import annotations

import itertools as it
import random
import time

import numpy as np

from benchmarks.common import (ORACLE_EST, PM, SPACE, miso_estimator,
                               row, run_policies, testbed_trace)
from repro.core.estimators import NoisyEstimator, UNetEstimator
from repro.core.jobs import WORKLOADS, Job
from repro.core.optimizer import optimize_partition
from repro.core.perfmodel import MPS_LEVELS
from repro.core.simulator import SimConfig, simulate
from repro.core.traces import generate_trace


def _best_mig(profs):
    est = [{s: PM.slice_speed(p, s) for s in SPACE.sizes} for p in profs]
    return optimize_partition(SPACE, est)


def fig2_takeaway1(fast=True):
    """GPU underutilization: distribution of achievable SM occupancy."""
    t0 = time.time()
    sms = [p.sm_util for p in WORKLOADS]
    return [row("fig2_sm_utilization", time.time() - t0,
                f"mean={np.mean(sms):.2f};p10={np.percentile(sms,10):.2f};"
                f"p90={np.percentile(sms,90):.2f};"
                f"frac_below_half={np.mean(np.array(sms)<0.5):.2f}")]


def fig3_mig_vs_mps(fast=True):
    """3-job mix: MIG (4,2,1) vs MPS equal-share vs MPS proportional."""
    t0 = time.time()
    rng = random.Random(2)
    profs = [sorted(WORKLOADS, key=lambda p: -p.sm_util)[0],
             sorted(WORKLOADS, key=lambda p: p.intensity)[2],
             sorted(WORKLOADS, key=lambda p: p.sm_util)[1]]
    mig = _best_mig(profs)
    mps_eq = sum(PM.mps_speeds(profs, 0.33))
    mps_prop = sum(PM.mps_speeds(profs, 0.57)[:1]) + \
        sum(PM.mps_speeds(profs, 0.29)[1:2]) + sum(PM.mps_speeds(profs, 0.14)[2:])
    return [row("fig3_mig_vs_mps", time.time() - t0,
                f"mig_stp={mig.objective:.3f};mps_equal_stp={mps_eq:.3f};"
                f"partition={'+'.join(map(str, sorted(mig.partition, reverse=True)))}")]


def fig4_optimal_partition_varies(fast=True):
    """Optimal MIG partition changes across job mixes (Takeaway 3)."""
    t0 = time.time()
    rng = random.Random(0)
    from collections import Counter
    cnt = Counter()
    for _ in range(40 if fast else 200):
        profs = rng.sample(list(WORKLOADS), 3)
        cnt[tuple(sorted(_best_mig(profs).partition, reverse=True))] += 1
    top = ";".join(f"{'+'.join(map(str, p))}x{c}" for p, c in
                   cnt.most_common(3))
    return [row("fig4_partition_diversity", time.time() - t0,
                f"distinct={len(cnt)};{top}")]


def fig5_heuristics_suboptimal(fast=True):
    """Cosine-similarity heuristics (mem / sm-util) vs optimal partition."""
    t0 = time.time()
    rng = random.Random(4)
    gaps_mem, gaps_sm = [], []
    for _ in range(30 if fast else 150):
        profs = rng.sample(list(WORKLOADS), 3)
        best = _best_mig(profs).objective

        def heuristic_stp(char):
            cands = SPACE.partitions_of_len(3)
            def cos(p):
                v = np.array(sorted(p, reverse=True), float)
                c = np.array(sorted(char, reverse=True), float)
                return float(v @ c / (np.linalg.norm(v) * np.linalg.norm(c)))
            part = max(cands, key=cos)
            order = np.argsort([-c for c in char])
            sizes = sorted(part, reverse=True)
            stp = 0.0
            for r, i in enumerate(order):
                stp += PM.slice_speed(profs[i], sizes[r])
            return stp

        gaps_mem.append(1 - heuristic_stp([p.mem_gb for p in profs]) / best)
        gaps_sm.append(1 - heuristic_stp([p.sm_util for p in profs]) / best)
    return [row("fig5_heuristic_gap", time.time() - t0,
                f"mem_heuristic_gap={np.mean(gaps_mem):.3f};"
                f"smutil_heuristic_gap={np.mean(gaps_sm):.3f}")]


def fig10_testbed(fast=True):
    """Testbed: 8 GPUs, 100 jobs, lambda=60s. JCT/makespan/STP normalized to
    NoPart (paper: MISO 49%/15%/23% better; within 10% of Oracle)."""
    jobs = testbed_trace(60 if fast else 100)
    res = run_policies(jobs, ("nopart", "optsta", "mpsonly", "miso", "oracle"),
                       estimator=miso_estimator())
    n, _ = res["nopart"]
    rows = []
    total_t = sum(t for _, t in res.values())
    for pol in ("optsta", "mpsonly", "miso", "oracle"):
        m, t = res[pol]
        rows.append(row(
            f"fig10_{pol}", t,
            f"jct_gain={1 - m.avg_jct / n.avg_jct:+.3f};"
            f"makespan_gain={1 - m.makespan / n.makespan:+.3f};"
            f"stp_gain={m.stp / n.stp - 1:+.3f}"))
    m, _ = res["miso"]
    o, _ = res["oracle"]
    rows.append(row("fig10_miso_vs_oracle", total_t,
                    f"jct_ratio={m.avg_jct / o.avg_jct:.3f}"))
    return rows


def fig11_cdf(fast=True):
    """CDF of per-job relative JCT (vs exclusive full-GPU execution)."""
    jobs = testbed_trace(60 if fast else 100)
    res = run_policies(jobs, ("nopart", "miso", "oracle"),
                       estimator=miso_estimator())
    rows = []
    for pol, (m, t) in res.items():
        rel = np.array(m.relative_jcts)
        rows.append(row(
            f"fig11_{pol}", t,
            f"frac_within_1.5x={np.mean(rel <= 1.5):.2f};"
            f"frac_within_2x={np.mean(rel <= 2.0):.2f};"
            f"max={rel.max():.1f}"))
    return rows


def fig12_breakdown(fast=True):
    """Job life-cycle breakdown (queue/MPS/ckpt/run fractions)."""
    jobs = testbed_trace(60 if fast else 100)
    res = run_policies(jobs, ("nopart", "optsta", "miso"),
                       estimator=miso_estimator())
    rows = []
    for pol, (m, t) in res.items():
        b = m.breakdown
        tot = sum(b.values())
        rows.append(row(
            f"fig12_{pol}", t,
            f"queue={b['queue'] / tot:.2f};mps={b['mps'] / tot:.2f};"
            f"ckpt={b['ckpt'] / tot:.2f};run={b['run'] / tot:.2f}"))
    return rows


def fig13_jobcount(fast=True):
    """Single GPU, 1..10 identical-length jobs arriving together."""
    rows = []
    prof_pool = sorted(WORKLOADS, key=lambda p: p.sm_util)
    counts = (1, 3, 5, 7, 10) if fast else tuple(range(1, 11))
    for n in counts:
        jobs = [Job(jid=i, profile=prof_pool[(3 * i) % len(prof_pool)],
                    arrival=0.0, work=600.0) for i in range(n)]
        res = run_policies(jobs, ("nopart", "miso", "oracle"),
                           n_gpus=1, estimator=miso_estimator())
        npart, _ = res["nopart"]
        m, t = res["miso"]
        o, _ = res["oracle"]
        rows.append(row(
            f"fig13_n{n}", t,
            f"jct_vs_nopart={m.avg_jct / npart.avg_jct:.3f};"
            f"miso_vs_oracle={m.avg_jct / o.avg_jct:.3f};"
            f"stp={m.stp:.2f}"))
    return rows


def fig14_mps_time(fast=True):
    """MPS profiling-time sensitivity: shorter window -> noisier measurement
    -> worse prediction; longer window -> diminishing returns + more time in
    MPS (paper: 0.5x much worse, 1.5x no accuracy gain, 4% JCT loss)."""
    est = miso_estimator()
    if not isinstance(est, UNetEstimator):
        return [row("fig14_skipped", 0.0, "no trained predictor artifact")]
    from repro.core.predictor.dataset import mix_to_matrices
    rng = random.Random(0)
    base_sigma = 0.02
    rows = []
    jobs = testbed_trace(40, seed=5, max_duration_s=1500)
    for ratio in (0.5, 1.0, 1.5, 2.0):
        t0 = time.time()
        sigma = base_sigma / np.sqrt(ratio)
        # prediction error on fresh mixes at this noise level
        errs = []
        rng_np = np.random.default_rng(0)
        for _ in range(30 if fast else 100):
            profs = rng.sample(list(WORKLOADS), rng.randint(2, 6))
            mps = est.measure_mps(profs, noise_sigma=sigma, rng=rng_np)
            pred = est.estimate(profs, mps)
            truth = ORACLE_EST.estimate(profs)
            for p, q in zip(pred, truth):
                for s in (4, 3):
                    if q[s] > 0:
                        errs.append(abs(p[s] - q[s]))

        class _E(UNetEstimator):
            def measure_mps(self, profs, noise_sigma=0.0, rng=None):
                return UNetEstimator.measure_mps(self, profs, sigma, rng_np)

        noisy_est = _E(PM, est.net.params, est.heads)
        cfg = SimConfig(n_gpus=4, policy="miso",
                        mps_level_time_s=10.0 * ratio)
        m = simulate(jobs, cfg, SPACE, PM, noisy_est)
        rows.append(row(f"fig14_mps_{ratio}x", time.time() - t0,
                        f"pred_mae={np.mean(errs):.4f};jct={m.avg_jct:.0f}s"))
    return rows


def fig15_mps_only(fast=True):
    """MISO vs MPS-only baseline (paper: 35% better JCT; 80% vs 30% of jobs
    within 2x of exclusive execution)."""
    jobs = testbed_trace(60 if fast else 100)
    res = run_policies(jobs, ("mpsonly", "miso"), estimator=miso_estimator())
    mps, _ = res["mpsonly"]
    m, t = res["miso"]
    rel_m = np.array(m.relative_jcts)
    rel_p = np.array(mps.relative_jcts)
    return [row("fig15_mps_only", t,
                f"jct_gain={1 - m.avg_jct / mps.avg_jct:+.3f};"
                f"miso_frac2x={np.mean(rel_m <= 2):.2f};"
                f"mpsonly_frac2x={np.mean(rel_p <= 2):.2f}")]
