"""Scenario-sweep trajectory rows: drive the parallel sweep engine over the
heterogeneous-fleet scenario suite and reduce its JSON report to CSV rows.

This is the consumer of the ``repro.launch.sweep`` schema — if the schema
version moves, this file is the first thing that should notice.
"""
from __future__ import annotations

import time

from benchmarks.common import row
from repro.launch.sweep import SCHEMA_VERSION, run_sweep


def scenario_sweep(fast=True):
    """Policy x placer x scenario grid on the default mixed a100+h100 fleet.

    The fast pass keeps the paper's least-loaded placement; the full pass
    crosses in the fleet-aware ``hetero-speed`` placer so the trajectory
    rows track both layers."""
    policies = ("miso", "srpt")
    scenarios = ("bursty", "heavy_tail") if fast else (
        "bursty", "diurnal", "heavy_tail", "flash_crowd", "mixed_qos")
    placers = ("least-loaded",) if fast else ("least-loaded", "hetero-speed")
    seeds = list(range(1 if fast else 3))
    n_jobs = 30 if fast else None

    t0 = time.time()
    report = run_sweep(policies, scenarios, seeds=seeds, placers=placers,
                       n_jobs=n_jobs)
    assert report["schema_version"] == SCHEMA_VERSION
    dt = time.time() - t0

    rows = []
    n_cells = max(1, len(report["results"]))
    for sc, by_policy in report["summary"].items():
        for pol, by_placer in by_policy.items():
            for placer, by_obj in by_placer.items():
                for obj, agg in by_obj.items():
                    rows.append(row(
                        f"sweep_{sc}_{pol}_{placer}_{obj}", dt / n_cells,
                        f"avg_jct={agg['avg_jct_s_mean']:.0f}s;"
                        f"p90={agg['p90_jct_s_mean']:.0f}s;"
                        f"stp={agg['stp_mean']:.3f};"
                        f"energy_mj={agg['energy_j_mean'] / 1e6:.2f};"
                        f"fleet={report['results'][0]['fleet']}"))
    rows.append(row("sweep_wallclock", dt,
                    f"runs={len(report['results'])};"
                    f"workers={report['config']['workers']}"))
    return rows
