# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows.  ``--full`` runs paper-scale trials (slow); default is a fast
# pass suitable for CI.
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale trial counts")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    args = ap.parse_args()
    fast = not args.full

    from benchmarks import components, paper_figs, roofline_table, \
        simulation_figs, sweeps

    benches = [
        paper_figs.fig2_takeaway1,
        paper_figs.fig3_mig_vs_mps,
        paper_figs.fig4_optimal_partition_varies,
        paper_figs.fig5_heuristics_suboptimal,
        components.predictor_accuracy,
        components.optimizer_latency,
        components.scheduling_policies,
        paper_figs.fig10_testbed,
        paper_figs.fig11_cdf,
        paper_figs.fig12_breakdown,
        paper_figs.fig13_jobcount,
        paper_figs.fig14_mps_time,
        paper_figs.fig15_mps_only,
        simulation_figs.fig16_simulation,
        simulation_figs.fig17_ckpt_overhead,
        simulation_figs.fig18_pred_error,
        simulation_figs.fig19_arrival_rate,
        simulation_figs.fault_tolerance,
        sweeps.scenario_sweep,
        components.tpu_cluster,
        components.kernel_bench,
        roofline_table.roofline_table,
    ]

    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        if args.only and args.only not in bench.__name__:
            continue
        try:
            for name, us, derived in bench(fast=fast):
                print(f"{name},{us},{derived}", flush=True)
        except Exception as e:  # keep the harness going
            failures += 1
            print(f"{bench.__name__},NaN,ERROR:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
