"""Shared benchmark plumbing."""
from __future__ import annotations

import os
import time

from repro.core.estimators import NoisyEstimator, OracleEstimator, UNetEstimator
from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.simulator import SimConfig, simulate
from repro.core.traces import generate_trace

SPACE = a100_mig_space()
PM = PerfModel(SPACE)
ORACLE_EST = OracleEstimator(PM)

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "predictor.npz")


def unet_estimator():
    if os.path.exists(ARTIFACT):
        return UNetEstimator.from_artifact(PM, ARTIFACT)
    return None


def miso_estimator():
    """The real learned estimator if the artifact exists, else oracle."""
    return unet_estimator() or ORACLE_EST


def testbed_trace(n_jobs=100, lam=60.0, seed=1, **kw):
    return generate_trace(n_jobs, lam_s=lam, seed=seed, **kw)


_BASELINES = ("nopart", "optsta", "mpsonly", "oracle")  # never use the
# learned estimator: baselines don't profile, oracle is ground truth


def run_policies(jobs, policies, n_gpus=8, estimator=None, **simkw):
    out = {}
    for pol in policies:
        est = estimator if (estimator is not None
                            and pol not in _BASELINES) else ORACLE_EST
        cfg = SimConfig(n_gpus=n_gpus, policy=pol, **simkw)
        t0 = time.time()
        m = simulate(jobs, cfg, SPACE, PM, est)
        out[pol] = (m, time.time() - t0)
    return out


def row(name, seconds_per_call, derived):
    return (name, f"{seconds_per_call * 1e6:.1f}", derived)
