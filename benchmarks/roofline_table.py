"""§Roofline: per (arch x shape x mesh) three-term roofline from the dry-run
artifacts (launch/dryrun.py writes them; launch/dryrun_all.sh runs the full
campaign)."""
from __future__ import annotations

import glob
import json
import os
import time

from benchmarks.common import row

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def roofline_table(fast=True):
    t0 = time.time()
    paths = sorted(glob.glob(os.path.join(ART, "*.json")))
    if not paths:
        return [row("roofline_skipped", 0.0,
                    "run launch/dryrun_all.sh first")]
    rows = []
    for p in paths:
        r = json.load(open(p))
        name = f"roofline_{r['arch']}__{r['shape']}__{r.get('tag', 'pod')}"
        if r.get("skipped"):
            rows.append(row(name, 0.0, "SKIP:" + r["reason"][:70]))
            continue
        rl = r["roofline"]
        rows.append(row(
            name, rl["bound_s"],
            f"comp_ms={rl['compute_s']*1e3:.1f};mem_ms={rl['memory_s']*1e3:.1f};"
            f"coll_ms={rl['collective_s']*1e3:.1f};dom={rl['dominant']};"
            f"useful={r['model_flops_ratio']:.2f};compile_s={r['compile_s']}"))
    rows.append(row("roofline_total_cells", time.time() - t0,
                    f"cells={len(paths)}"))
    return rows
