"""Large-scale simulation figures (paper Figs 16-19) + sensitivity sweeps."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (ORACLE_EST, PM, SPACE, miso_estimator, row,
                               run_policies)
from repro.core.estimators import NoisyEstimator
from repro.core.simulator import SimConfig, simulate
from repro.core.traces import generate_trace


def fig16_simulation(fast=True):
    """40 GPUs / 1000 jobs / lambda=10s, repeated trials with fresh seeds
    (paper: ~70%/20%/30% median JCT/makespan/STP gains; violin)."""
    trials = 5 if fast else 60
    n_jobs = 300 if fast else 1000
    gains = {"jct": [], "makespan": [], "stp": []}
    t0 = time.time()
    est = miso_estimator()
    for trial in range(trials):
        jobs = generate_trace(n_jobs, lam_s=10.0, seed=1000 + trial)
        res = run_policies(jobs, ("nopart", "miso"), n_gpus=40,
                           estimator=est)
        n, _ = res["nopart"]
        m, _ = res["miso"]
        gains["jct"].append(1 - m.avg_jct / n.avg_jct)
        gains["makespan"].append(1 - m.makespan / n.makespan)
        gains["stp"].append(m.stp / n.stp - 1)
    dt = time.time() - t0
    out = []
    for k, v in gains.items():
        v = np.array(v)
        out.append(row(
            f"fig16_{k}", dt / trials,
            f"median={np.median(v):+.3f};p10={np.percentile(v, 10):+.3f};"
            f"p90={np.percentile(v, 90):+.3f};trials={trials}"))
    return out


def fig17_ckpt_overhead(fast=True):
    """Checkpoint-overhead sensitivity (paper: robust up to 2x)."""
    jobs = generate_trace(60 if fast else 150, lam_s=30.0, seed=17)
    rows = []
    base = None
    est = miso_estimator()
    for scale in (0.5, 1.0, 2.0, 4.0):
        t0 = time.time()
        cfg = SimConfig(n_gpus=8, policy="miso", overhead_scale=scale)
        m = simulate(jobs, cfg, SPACE, PM, est)
        if scale == 1.0:
            base = m.avg_jct
        rows.append(row(f"fig17_overhead_{scale}x", time.time() - t0,
                        f"jct={m.avg_jct:.0f}s"))
    n = simulate(jobs, SimConfig(n_gpus=8, policy="nopart"), SPACE, PM,
                 ORACLE_EST)
    rows.append(row("fig17_ref_nopart", 0.0, f"jct={n.avg_jct:.0f}s"))
    return rows


def fig18_pred_error(fast=True):
    """Prediction-error sensitivity (paper: 1.7% -> 9% error still fine)."""
    jobs = generate_trace(60 if fast else 150, lam_s=30.0, seed=18)
    n = simulate(jobs, SimConfig(n_gpus=8, policy="nopart"), SPACE, PM,
                 ORACLE_EST)
    rows = []
    for sigma in (0.0, 0.017, 0.05, 0.09, 0.20):
        t0 = time.time()
        est = NoisyEstimator(PM, sigma=sigma, seed=0) if sigma else ORACLE_EST
        m = simulate(jobs, SimConfig(n_gpus=8, policy="miso"), SPACE, PM, est)
        rows.append(row(f"fig18_sigma_{sigma}", time.time() - t0,
                        f"jct_gain_vs_nopart={1 - m.avg_jct / n.avg_jct:+.3f}"))
    return rows


def fig19_arrival_rate(fast=True):
    """Inter-arrival sweep (paper: 30-50% JCT, >15% makespan, >25% STP gains
    across loads)."""
    rows = []
    est = miso_estimator()
    lams = (5.0, 15.0, 30.0, 60.0) if fast else (2.0, 5.0, 10.0, 20.0, 40.0,
                                                 60.0)
    for lam in lams:
        jobs = generate_trace(60 if fast else 200, lam_s=lam, seed=19)
        res = run_policies(jobs, ("nopart", "miso"), estimator=est)
        n, _ = res["nopart"]
        m, t = res["miso"]
        rows.append(row(
            f"fig19_lambda_{int(lam)}s", t,
            f"jct_gain={1 - m.avg_jct / n.avg_jct:+.3f};"
            f"makespan_gain={1 - m.makespan / n.makespan:+.3f};"
            f"stp_gain={m.stp / n.stp - 1:+.3f}"))
    return rows


def fault_tolerance(fast=True):
    """Beyond-paper: MISO under GPU failures (job-level fault tolerance)."""
    jobs = generate_trace(40, lam_s=30.0, seed=23, max_duration_s=1500)
    rows = []
    for mtbf in (0.0, 3600.0, 900.0):
        t0 = time.time()
        cfg = SimConfig(n_gpus=4, policy="miso", gpu_mtbf_s=mtbf,
                        repair_s=300.0, seed=3)
        m = simulate(jobs, cfg, SPACE, PM, ORACLE_EST)
        tag = "none" if mtbf == 0 else f"{int(mtbf)}s"
        rows.append(row(f"fault_mtbf_{tag}", time.time() - t0,
                        f"jct={m.avg_jct:.0f}s;completed={len(m.jcts)}"))
    return rows
