"""Render EXPERIMENTS.md tables from the dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.make_tables > artifacts/roofline_tables.md
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

ORDER = ["smollm-360m", "granite-8b", "qwen3-32b", "command-r-plus-104b",
         "chameleon-34b", "musicgen-large", "mixtral-8x22b",
         "qwen2-moe-a2.7b", "rwkv6-3b", "recurrentgemma-2b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load():
    recs = {}
    for p in glob.glob(os.path.join(ART, "*.json")):
        r = json.load(open(p))
        recs[(r["arch"], r["shape"], r.get("tag", "pod"))] = r
    return recs


def _ms(x):
    return f"{x * 1e3:,.1f}"


def fits(r):
    """Resident state per device (params/opt/cache: args+out-alias) vs the
    16 GiB v5e budget.  XLA's temp high-water on *this CPU backend* includes
    f32-upcast copies a TPU build would not materialize, so temps are
    reported as a separate footnote, not a verdict."""
    ma = r.get("memory_analysis") or {}
    if "argument_size_in_bytes" not in ma:
        return "?"
    resident = ma["argument_size_in_bytes"] + ma.get("output_size_in_bytes", 0) \
        - ma.get("alias_size_in_bytes", 0)
    ok = resident <= 16e9
    return f"{'yes' if ok else 'NO'} ({resident/1e9:.1f}G)"


def baseline_table(recs, tag):
    print(f"| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
          f"dominant | useful (6ND/HLO) | fits 16GB | compile (s) |")
    print("|---|---|---:|---:|---:|---|---:|---|---:|")
    for arch in ORDER:
        for shape in SHAPES:
            r = recs.get((arch, shape, tag))
            if r is None:
                continue
            if r.get("skipped"):
                print(f"| {arch} | {shape} | — | — | — | SKIP (full attention"
                      f" @512k) | — | — | — |")
                continue
            rl = r["roofline"]
            print(f"| {arch} | {shape} | {_ms(rl['compute_s'])} | "
                  f"{_ms(rl['memory_s'])} | {_ms(rl['collective_s'])} | "
                  f"{rl['dominant']} | {r['model_flops_ratio']:.2f} | "
                  f"{fits(r)} | {r['compile_s']:.0f} |")


def collective_detail(recs, cells):
    print("| cell | all-gather | all-reduce | reduce-scatter | all-to-all | "
          "permute |")
    print("|---|---:|---:|---:|---:|---:|")
    for arch, shape, tag in cells:
        r = recs.get((arch, shape, tag))
        if not r or r.get("skipped"):
            continue
        c = r["collectives"]
        g = lambda k: f"{c.get(k, 0) / 1e9:.2f} GB"
        print(f"| {arch} x {shape} ({tag}) | {g('all-gather')} | "
              f"{g('all-reduce')} | {g('reduce-scatter')} | "
              f"{g('all-to-all')} | {g('collective-permute')} |")


def hillclimb_table(recs, arch, shape, tags):
    print(f"| iteration | compute (ms) | memory (ms) | memory-kern (ms) | "
          f"collective (ms) | bound (ms) | useful | fits |")
    print("|---|---:|---:|---:|---:|---:|---:|---|")
    for tag, label in tags:
        r = recs.get((arch, shape, tag))
        if not r or r.get("skipped"):
            print(f"| {label} | (missing) |")
            continue
        rl, rk = r["roofline"], r["roofline_kernelized"]
        print(f"| {label} | {_ms(rl['compute_s'])} | {_ms(rl['memory_s'])} | "
              f"{_ms(rk['memory_s'])} | {_ms(rl['collective_s'])} | "
              f"{_ms(rl['bound_s'])} | {r['model_flops_ratio']:.2f} | "
              f"{fits(r)} |")


def main():
    recs = _load()
    print("## Single-pod (16x16 = 256 chips) baseline\n")
    baseline_table(recs, "pod")
    print("\n## Multi-pod (2x16x16 = 512 chips)\n")
    baseline_table(recs, "multipod")
    print("\n## Collective composition of the hillclimb cells (per device)\n")
    collective_detail(recs, [
        ("rwkv6-3b", "train_4k", "pod"),
        ("qwen2-moe-a2.7b", "train_4k", "pod"),
        ("qwen2-moe-a2.7b", "train_4k", "it_ep4"),
        ("command-r-plus-104b", "decode_32k", "pod"),
        ("command-r-plus-104b", "decode_32k", "it_int8tp"),
    ])
    print("\n## Hillclimb: rwkv6-3b x train_4k\n")
    hillclimb_table(recs, "rwkv6-3b", "train_4k", [
        ("pod", "baseline (16x16)"),
        ("it_bf16streams", "+bf16 r/k/v streams"),
        ("it_chunk128", "+chunk 128 (refuted)"),
    ])
    print("\n## Hillclimb: qwen2-moe-a2.7b x train_4k\n")
    hillclimb_table(recs, "qwen2-moe-a2.7b", "train_4k", [
        ("pod", "baseline (16x16, TP experts)"),
        ("it_ep4", "EP: 64x4 mesh, experts 4-way"),
    ])
    print("\n## Hillclimb: command-r-plus-104b x decode_32k\n")
    hillclimb_table(recs, "command-r-plus-104b", "decode_32k", [
        ("pod", "baseline (FSDP weights)"),
        ("it_tponly", "TP-only weights (no per-layer gather)"),
        ("it_int8tp", "TP-only + int8 weight streams"),
    ])
    print("\n## Bonus: smollm-360m x train_4k (MISO right-sizing)\n")
    hillclimb_table(recs, "smollm-360m", "train_4k", [
        ("pod", "baseline (16x16)"),
        ("it_rightsize64x4", "right-sized 64x4 mesh"),
        ("it_puredp", "pure DP 256x1, microbatches=1"),
    ])


if __name__ == "__main__":
    main()
