"""Measure the scalar-fallback thresholds for the vectorized fleet settle.

Produces the speedup table recorded next to ``_FREE_VEC_MIN`` /
``_OCC_VEC_MIN`` in ``src/repro/core/sim/soa.py`` (the measurement the
MS110 suppressions cite).  On the reference container the verdict is that
the scalar loop wins at every row count — the vector path's gather/apply
attribute traffic costs more than the arithmetic numpy absorbs — which is
why both shipped thresholds are ``None`` (never auto-vectorize).  Re-run
this script before flipping them on a different host.  Two sweeps:

* free rows — resident-free GPUs settled by the masked energy/clock
  vector update vs. the per-GPU scalar ``advance`` loop;
* occupied rows — progressing GPUs (3 residents each, clean watts memo,
  periodic-checkpoint interval armed) settled by the ``(rows, slots)``
  matrix path vs. the scalar loop.

Run:  PYTHONPATH=src python benchmarks/measure_settle.py
"""
from __future__ import annotations

import time
from types import SimpleNamespace

from repro.core.estimators import OracleEstimator
from repro.core.fleet import homogeneous_fleet
from repro.core.jobs import WORKLOADS, Job
from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.sim import soa
from repro.core.sim.gpu import GPU, MIG_RUN

SPACE = a100_mig_space()
PM = PerfModel(SPACE)
SPEC = homogeneous_fleet(SPACE, PM, OracleEstimator(PM), 1)[0]
PROFILE = WORKLOADS[0]


class _Sink:
    def shift(self, d):
        pass


def build(n, occupied):
    sim = SimpleNamespace(cfg=SimpleNamespace(ckpt_interval_s=600.0),
                          work_agg=_Sink())
    gpus = []
    for gid in range(n):
        g = GPU(gid, sim, SPEC)
        g.last_update = 10.0
        g.energy_j = 1000.0
        if occupied:
            g.phase = MIG_RUN
            for k in range(3):
                job = Job(jid=gid * 8 + k, profile=PROFILE, arrival=0.0,
                          work=1e9)
                rj = g._add_resident(job)
                rj.slice_size = 1
                g._spd[k] = 0.5 + 0.1 * k
                g._ckt[k] = 100.0 * k
            g._spd_key = object()
            g._w_key = g._spd_key
            g._w_val = 300.0
    # reset state the build mutated so every timed settle is identical
        gpus.append(g)
    return gpus


def reset(gpus):
    for g in gpus:
        g.last_update = 10.0
        g.energy_j = 1000.0
        for i in range(len(g._ckt)):
            g._ckt[i] = 100.0 * i
            g._ckw[i] = 0.0
            g._rjobs[i].job.remaining = 1e9


def bench(n, occupied, vector, reps=400):
    gpus = build(n, occupied)
    t = 2000.0
    best = float("inf")
    for _ in range(reps):
        reset(gpus)
        t0 = time.perf_counter()
        if vector:
            # force the vector path regardless of the shipped defaults so
            # the measurement is of the path, not the gate
            soa.settle_rows(gpus, t, free_min=1, occ_min=1)
        else:
            for g in gpus:
                g.advance(t)
        best = min(best, time.perf_counter() - t0)
    return best


def sweep(occupied, label):
    print(f"-- {label} rows (scalar us / vector us / speedup)")
    for n in (2, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256, 512):
        v = bench(n, occupied, vector=True)
        s = bench(n, occupied, vector=False)
        print(f"  n={n:4d}  {s*1e6:8.2f}  {v*1e6:8.2f}  {s/v:5.2f}x")


if __name__ == "__main__":
    sweep(False, "free")
    sweep(True, "occupied")
