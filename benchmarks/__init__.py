# One module per paper table/figure; `python -m benchmarks.run` prints
# `name,us_per_call,derived` CSV rows for all of them.
