"""Component benchmarks: predictor accuracy (paper §4.1), Algorithm-1
latency (paper §4.2/§8), kernel microbenches, TPU-pod adaptation."""
from __future__ import annotations

import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (ARTIFACT, ORACLE_EST, PM, SPACE,
                               miso_estimator, row, run_policies,
                               testbed_trace)
from repro.core.optimizer import (clear_memo, memo_stats, optimize_partition,
                                  optimize_partition_bruteforce)


def predictor_accuracy(fast=True):
    """Validation MAE (paper: 0.017) + linreg R^2 (paper: 0.96) + accuracy
    on completely fresh mixes."""
    import os
    if not os.path.exists(ARTIFACT):
        return [row("predictor_skipped", 0.0, "artifact missing")]
    t0 = time.time()
    from repro.core.predictor import dataset as ds
    from repro.core.predictor import unet
    from repro.core.predictor.train import load_artifact
    params, heads, hist = load_artifact(ARTIFACT)
    net = unet.UNet(params)
    fresh = ds.generate_dataset(PM, mixes_per_count=20 if fast else 100,
                                seed=31337)
    pred = np.asarray(net(jnp.asarray(fresh["val_x"])))
    mae = float(np.abs(pred - fresh["val_y"]).mean())
    return [row("predictor_accuracy", time.time() - t0,
                f"val_mae={hist['val_mae'][-1]:.4f};fresh_mix_mae={mae:.4f};"
                f"linreg_r2_2g={heads['r2'][0]:.3f};"
                f"linreg_r2_1g={heads['r2'][1]:.3f}")]


def optimizer_latency(fast=True):
    """Algorithm 1 latency (paper: <=0.5ms; 80ms at 10x combinations), plus
    the memo cache's speedup on repeated repartitions (long traces re-run the
    multiset scan with identical speed vectors over and over)."""
    rng = random.Random(0)
    rows = []
    hits = misses = 0
    for m in (3, 5, 7):
        speeds = []
        for _ in range(m):
            sv = {7: 1.0}
            for s in (4, 3, 2, 1):
                sv[s] = rng.uniform(0.1, 1.0)
            speeds.append(sv)
        reps = 50 if fast else 500
        t0 = time.time()
        for _ in range(reps):
            optimize_partition(SPACE, speeds, memo=False)
        dp = (time.time() - t0) / reps
        t0 = time.time()
        for _ in range(max(reps // 10, 5)):
            optimize_partition_bruteforce(SPACE, speeds)
        bf = (time.time() - t0) / max(reps // 10, 5)
        # memoized repeated repartition: first call fills, the rest hit
        clear_memo()
        t0 = time.time()
        for _ in range(reps):
            optimize_partition(SPACE, speeds)
        memo = (time.time() - t0) / reps
        stats = memo_stats()
        hits += stats["hits"]
        misses += stats["misses"]
        rows.append(row(
            f"optimizer_m{m}", dp,
            f"dp_ms={dp*1e3:.3f};bruteforce_ms={bf*1e3:.3f};"
            f"memo_ms={memo*1e3:.3f};memo_speedup={dp/max(memo, 1e-12):.1f}x"))
    rows.append(row("optimizer_memo_stats", 0.0,
                    f"hits={hits};misses={misses}"))
    return rows


def scheduling_policies(fast=True):
    """All registered policies head-to-head on one trace (the policy layer's
    reachability check: legacy five + miso-frag + srpt)."""
    from repro.core.simulator import available_policies
    jobs = testbed_trace(40 if fast else 100, lam=30.0, seed=13,
                         max_duration_s=1800)
    res = run_policies(jobs, available_policies(), n_gpus=4,
                       estimator=miso_estimator())
    n, _ = res["nopart"]
    rows = []
    for pol in available_policies():
        m, t = res[pol]
        rows.append(row(f"policy_{pol}", t,
                        f"jct_gain_vs_nopart={1 - m.avg_jct / n.avg_jct:+.3f};"
                        f"stp={m.stp:.3f};completed={len(m.jcts)}"))
    return rows


def kernel_bench(fast=True):
    """Pure-JAX flash vs naive attention on CPU (wall time + peak-residual
    note); Pallas kernels run in interpret mode for correctness, so their
    timing is not meaningful off-TPU — FLOPs parity is reported instead."""
    from repro.models import flash, modules
    rows = []
    B, S, H, D = 2, 1024, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    pos = jnp.arange(S, dtype=jnp.int32)

    def loss_flash(q, k, v):
        return flash.flash_attention(q, k, v, q_positions=pos,
                                     kv_positions=pos, causal=True,
                                     block_q=128, block_kv=128).sum()

    def loss_naive(q, k, v):
        return modules.naive_attention(q, k, v, q_positions=pos,
                                       kv_positions=pos, causal=True).sum()

    for name, fn in (("flash", loss_flash), ("naive", loss_naive)):
        g = jax.jit(jax.grad(fn, argnums=(0, 1, 2)))
        g(q, k, v)[0].block_until_ready()  # compile
        reps = 3 if fast else 10
        t0 = time.time()
        for _ in range(reps):
            g(q, k, v)[0].block_until_ready()
        rows.append(row(f"attn_bwd_{name}_S{S}", (time.time() - t0) / reps,
                        "custom-vjp flash vs naive, CPU wall time"))
    return rows


def tpu_cluster(fast=True):
    """MISO over TPU-pod sub-slices (the DESIGN.md adaptation)."""
    from repro.core.estimators import OracleEstimator
    from repro.core.partitions import tpu_pod_space
    from repro.core.perfmodel import PerfModel, TPU_V5E_POD
    from repro.core.simulator import SimConfig, simulate
    from repro.core.traces import generate_trace
    t0 = time.time()
    space = tpu_pod_space()
    pm = PerfModel(space, TPU_V5E_POD)
    jobs = generate_trace(60 if fast else 200, lam_s=20.0, seed=77)
    est = OracleEstimator(pm)
    m = simulate(jobs, SimConfig(n_gpus=4, policy="miso"), space, pm, est)
    n = simulate(jobs, SimConfig(n_gpus=4, policy="nopart"), space, pm, est)
    return [row("tpu_pod_miso", time.time() - t0,
                f"jct_gain={1 - m.avg_jct / n.avg_jct:+.3f};"
                f"slices=2x16..16x16;pods=4")]
