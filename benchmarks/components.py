"""Component benchmarks: predictor accuracy (paper §4.1), Algorithm-1
latency (paper §4.2/§8), kernel microbenches, TPU-pod adaptation."""
from __future__ import annotations

import copy
import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (ARTIFACT, ORACLE_EST, PM, SPACE,
                               miso_estimator, row, run_policies,
                               testbed_trace)
from repro.core.optimizer import (_assign_dp, clear_memo, memo_stats,
                                  optimize_partition,
                                  optimize_partition_batch,
                                  optimize_partition_bruteforce)


def predictor_accuracy(fast=True):
    """Validation MAE (paper: 0.017) + linreg R^2 (paper: 0.96) + accuracy
    on completely fresh mixes."""
    import os
    if not os.path.exists(ARTIFACT):
        return [row("predictor_skipped", 0.0, "artifact missing")]
    t0 = time.time()
    from repro.core.predictor import dataset as ds
    from repro.core.predictor import unet
    from repro.core.predictor.train import load_artifact
    params, heads, hist = load_artifact(ARTIFACT)
    net = unet.UNet(params)
    fresh = ds.generate_dataset(PM, mixes_per_count=20 if fast else 100,
                                seed=31337)
    pred = np.asarray(net(jnp.asarray(fresh["val_x"])))
    mae = float(np.abs(pred - fresh["val_y"]).mean())
    return [row("predictor_accuracy", time.time() - t0,
                f"val_mae={hist['val_mae'][-1]:.4f};fresh_mix_mae={mae:.4f};"
                f"linreg_r2_2g={heads['r2'][0]:.3f};"
                f"linreg_r2_1g={heads['r2'][1]:.3f}")]


def _legacy_scan(space, speeds):
    """The pre-vectorization optimize_partition inner loop (dict DP per
    multiset, first-strict-max scan) — the un-memoized comparison baseline;
    ``_assign_dp`` is kept in-tree as the tie-break oracle."""
    best = None
    m = len(speeds)
    for part in space.partitions_of_len(m):
        obj, perm = _assign_dp(part, speeds)
        feasible = all(speeds[j].get(perm[j], 0.0) > 0.0 for j in range(m))
        if best is None or obj > best[0]:
            best = (obj, perm, feasible)
    return best


def _best_of(fn, reps, repeats=5):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def optimizer_latency(fast=True):
    """Algorithm 1 latency (paper: <=0.5ms; 80ms at 10x combinations).

    Reports, per co-location count m: the legacy scalar scan (dict DP per
    multiset — the pre-vectorization implementation), the vectorized
    single-decision pass, the batched per-decision cost when B same-tick
    decisions solve in one stacked DP (what the engine's same-tick
    coalescing exercises), and the memo cache's speedup on repeated
    repartitions.  The acceptance metric is the un-memoized batched
    speedup aggregated over the m grid (``optimizer_unmemoized_speedup``).
    """
    rng = random.Random(0)
    rows = []
    hits = misses = 0
    B = 16
    legacy_sum = vec_sum = batch_sum = 0.0
    reps = 30 if fast else 200
    for m in (3, 5, 7):
        speeds = []
        for _ in range(m):
            sv = {7: 1.0}
            for s in (4, 3, 2, 1):
                sv[s] = rng.uniform(0.1, 1.0)
            speeds.append(sv)
        mixes = [[{s: (v if s == 7 else rng.uniform(0.1, 1.0))
                   for s, v in sv.items()} for sv in speeds]
                 for _ in range(B)]
        legacy = _best_of(lambda: _legacy_scan(SPACE, speeds), reps)
        vec = _best_of(lambda: optimize_partition(SPACE, speeds, memo=False),
                       reps)
        batch = _best_of(lambda: optimize_partition_batch(SPACE, mixes,
                                                          memo=False),
                         max(reps // 4, 5)) / B
        bf = _best_of(lambda: optimize_partition_bruteforce(SPACE, speeds),
                      max(reps // 10, 5))
        # memoized repeated repartition: first call fills, the rest hit
        clear_memo()
        t0 = time.perf_counter()
        for _ in range(reps):
            optimize_partition(SPACE, speeds)
        memo = (time.perf_counter() - t0) / reps
        stats = memo_stats()
        hits += stats["hits"]
        misses += stats["misses"]
        legacy_sum += legacy
        vec_sum += vec
        batch_sum += batch
        rows.append(row(
            f"optimizer_m{m}", vec,
            f"legacy_ms={legacy*1e3:.3f};vec_ms={vec*1e3:.3f};"
            f"batch{B}_ms_per_decision={batch*1e3:.3f};"
            f"bruteforce_ms={bf*1e3:.3f};memo_ms={memo*1e3:.3f};"
            f"vec_speedup={legacy/max(vec, 1e-12):.1f}x;"
            f"batch_speedup={legacy/max(batch, 1e-12):.1f}x;"
            f"memo_speedup={legacy/max(memo, 1e-12):.1f}x"))
    rows.append(row(
        "optimizer_unmemoized_speedup", 0.0,
        f"single={legacy_sum/max(vec_sum, 1e-12):.1f}x;"
        f"batched_B{B}={legacy_sum/max(batch_sum, 1e-12):.1f}x;"
        f"legacy_total_ms={legacy_sum*1e3:.3f};"
        f"vec_total_ms={vec_sum*1e3:.3f};"
        f"batch_total_ms={batch_sum*1e3:.3f}"))
    rows.append(row("optimizer_memo_stats", 0.0,
                    f"hits={hits};misses={misses}"))
    return rows


def scheduling_policies(fast=True):
    """All registered policies head-to-head on one trace (the policy layer's
    reachability check: legacy five + miso-frag + srpt)."""
    from repro.core.simulator import available_policies
    jobs = testbed_trace(40 if fast else 100, lam=30.0, seed=13,
                         max_duration_s=1800)
    res = run_policies(jobs, available_policies(), n_gpus=4,
                       estimator=miso_estimator())
    n, _ = res["nopart"]
    rows = []
    for pol in available_policies():
        m, t = res[pol]
        rows.append(row(f"policy_{pol}", t,
                        f"jct_gain_vs_nopart={1 - m.avg_jct / n.avg_jct:+.3f};"
                        f"stp={m.stp:.3f};completed={len(m.jcts)}"))
    return rows


def kernel_bench(fast=True):
    """Pure-JAX flash vs naive attention on CPU (wall time + peak-residual
    note); Pallas kernels run in interpret mode for correctness, so their
    timing is not meaningful off-TPU — FLOPs parity is reported instead."""
    from repro.models import flash, modules
    rows = []
    B, S, H, D = 2, 1024, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    pos = jnp.arange(S, dtype=jnp.int32)

    def loss_flash(q, k, v):
        return flash.flash_attention(q, k, v, q_positions=pos,
                                     kv_positions=pos, causal=True,
                                     block_q=128, block_kv=128).sum()

    def loss_naive(q, k, v):
        return modules.naive_attention(q, k, v, q_positions=pos,
                                       kv_positions=pos, causal=True).sum()

    for name, fn in (("flash", loss_flash), ("naive", loss_naive)):
        g = jax.jit(jax.grad(fn, argnums=(0, 1, 2)))
        g(q, k, v)[0].block_until_ready()  # compile
        reps = 3 if fast else 10
        t0 = time.time()
        for _ in range(reps):
            g(q, k, v)[0].block_until_ready()
        rows.append(row(f"attn_bwd_{name}_S{S}", (time.time() - t0) / reps,
                        "custom-vjp flash vs naive, CPU wall time"))
    return rows


def trace_scaling(fast=True):
    """Engine scalability: replay synthetic Alibaba-distribution traces at
    growing fleet sizes (the indexed-hot-path acceptance curve).

    Each cell drives one miso run over a homogeneous a100 fleet of ``n``
    GPUs with ``min(20*n, 100_000)`` jobs; the arrival rate scales with the
    fleet (``load_scale = n/16``) so per-GPU utilization stays roughly
    constant and wall time isolates the engine's per-event cost.  The full
    grid ends at the 5,000-GPU / 100K-job cell, whose wall time must stay
    under 5 minutes single-process."""
    from repro.core.fleet import homogeneous_fleet
    from repro.core.simulator import ClusterSim, SimConfig
    from repro.core.traces_alibaba import synthesize_alibaba_trace
    sizes = (8, 64, 512) if fast else (8, 64, 512, 2048, 5000)
    fleet_proto = homogeneous_fleet(SPACE, PM, ORACLE_EST, 1)[0]
    rows = []
    # the us/event rows feed CI's regression gate (diff_sweeps.py components
    # mode), so take the min over a few identical replays: the sim is
    # deterministic, only the wall clock is noisy, and min-of-N is the
    # standard noise floor estimator for a deterministic workload
    reps = 5 if fast else 1
    for n in sizes:
        n_jobs = min(20 * n, 100_000)
        jobs = synthesize_alibaba_trace(n_jobs, seed=7, load_scale=n / 16.0,
                                        max_duration_s=7200.0)
        cfg = SimConfig(n_gpus=n, policy="miso", profile=True)
        wall = float("inf")
        for _ in range(reps):
            sim = ClusterSim(copy.deepcopy(jobs), cfg,
                             fleet=[fleet_proto] * n)
            t0 = time.perf_counter()
            m = sim.run()
            wall = min(wall, time.perf_counter() - t0)
        p = sim.prof
        rows.append(row(
            f"trace_scaling_n{n}", wall / max(p["events"], 1.0),
            f"gpus={n};jobs={len(jobs)};wall_s={wall:.2f};"
            f"events={int(p['events'])};completed={len(m.jcts)};"
            f"jobs_per_s={len(m.jcts) / max(wall, 1e-9):.0f};"
            f"placement_s={p['placement_s']:.2f};"
            f"alg1_s={p['alg1_s']:.2f};estimator_s={p['estimator_s']:.2f}"))
    return rows


def batch_rollout(fast=True):
    """Replica-batched engine vs the warm-pool path on a B=16 smoke grid.

    The rollout this measures is the sweep driver's: B independent cells,
    same fleet shape, different (policy, seed).  The warm-pool side
    dispatches each cell to its worker pool; the batched side runs all B
    cells in one lockstep ``BatchSim`` in-process.  Event counts come from
    one profiled serial pass (the sim is deterministic, so every engine
    replays the identical event stream).

    Two baselines, because the pool path's cost depends on who is asking:

    * ``pool_wall_s`` — what ``--engine pool`` costs a *fresh driver
      process* (one CLI sweep): worker spawn + import + jit-warm
      initializer + the cells.  This is the cost the in-process batched
      engine eliminates outright, and the >=4x acceptance target is
      measured against it (measured once — it is cold by definition).
    * ``pool_warm_wall_s`` — the amortized per-sweep cost inside a
      long-lived driver that reuses the warm pool (min-of-reps after a
      warm-up sweep).  Recorded so nobody mistakes the headline for the
      amortized regime: against this baseline the batched engine wins
      only the fused-dispatch margin (~2x here), because both engines
      pay the same per-event scalar machinery and the bit-identity
      contract forbids approximating it away.

    The gated column is the batched engine's aggregate us/event (walls
    are min-of-reps); derived records both baselines' events/sec and both
    speedups against the >=4x target."""
    from repro.launch.sweep import run_sweep, shutdown_pool

    B = 16
    kw = dict(policies=["miso", "srpt"], scenarios=["smoke"],
              seeds=list(range(B // 2)))
    # one profiled serial pass for the denominators (not timed)
    prof = run_sweep(serial=True, profile=True, **kw)
    events = sum(r["profile"]["events"] for r in prof["results"])
    reps = 3 if fast else 10
    shutdown_pool()                            # cold-driver baseline
    t0 = time.perf_counter()
    run_sweep(workers=1, **kw)
    pool_wall = time.perf_counter() - t0
    pool_warm = float("inf")                   # amortized baseline
    for _ in range(reps):
        t0 = time.perf_counter()
        run_sweep(workers=1, **kw)
        pool_warm = min(pool_warm, time.perf_counter() - t0)
    shutdown_pool()
    batched_wall = float("inf")
    rep = None
    for _ in range(reps):
        t0 = time.perf_counter()
        rep = run_sweep(serial=True, engine="batched", **kw)
        batched_wall = min(batched_wall, time.perf_counter() - t0)
    assert rep["config"]["batched_cells"] == B, "batched path fell back"
    return [row(
        "batch_rollout", batched_wall / max(events, 1),
        f"B={B};events={events};pool_wall_s={pool_wall:.3f};"
        f"pool_warm_wall_s={pool_warm:.3f};"
        f"batched_wall_s={batched_wall:.3f};"
        f"pool_events_per_s={events / max(pool_wall, 1e-9):.0f};"
        f"pool_warm_events_per_s={events / max(pool_warm, 1e-9):.0f};"
        f"batched_events_per_s={events / max(batched_wall, 1e-9):.0f};"
        f"speedup={pool_wall / batched_wall:.2f}x;"
        f"speedup_warm={pool_warm / batched_wall:.2f}x;target=4.00x")]


def tpu_cluster(fast=True):
    """MISO over TPU-pod sub-slices (the DESIGN.md adaptation)."""
    from repro.core.estimators import OracleEstimator
    from repro.core.partitions import tpu_pod_space
    from repro.core.perfmodel import PerfModel, TPU_V5E_POD
    from repro.core.simulator import SimConfig, simulate
    from repro.core.traces import generate_trace
    t0 = time.time()
    space = tpu_pod_space()
    pm = PerfModel(space, TPU_V5E_POD)
    jobs = generate_trace(60 if fast else 200, lam_s=20.0, seed=77)
    est = OracleEstimator(pm)
    m = simulate(jobs, SimConfig(n_gpus=4, policy="miso"), space, pm, est)
    n = simulate(jobs, SimConfig(n_gpus=4, policy="nopart"), space, pm, est)
    return [row("tpu_pod_miso", time.time() - t0,
                f"jct_gain={1 - m.avg_jct / n.avg_jct:+.3f};"
                f"slices=2x16..16x16;pods=4")]


# --------------------------------------------------------------- reporting


def write_report(path: str, fast: bool = True) -> dict:
    """Write the component-latency JSON report (``BENCH_components.json``,
    schema v1) consumed by CI for perf-trajectory tracking.  Rows mirror the
    CSV harness: (name, us_per_call, derived key=value pairs)."""
    import json
    report = {
        "schema_version": 1,
        "kind": "miso-components",
        "rows": [{"name": n, "us_per_call": float(us), "derived": d}
                 for n, us, d in (optimizer_latency(fast=fast)
                                  + scheduling_policies(fast=fast)
                                  + trace_scaling(fast=fast)
                                  + batch_rollout(fast=fast))],
    }
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
        f.write("\n")
    return report


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="component benchmarks -> BENCH_components.json")
    ap.add_argument("--out", default="BENCH_components.json")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    rep = write_report(args.out, fast=not args.full)
    for r in rep["rows"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    print(f"[components] report -> {args.out}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
