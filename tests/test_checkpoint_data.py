"""Checkpointing (atomic, keep-last, elastic restore) and the deterministic
data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import SyntheticLMData
from repro.models import LM
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.optim import adamw_init


def test_roundtrip_bitexact(tmp_path, run32, key):
    cfg = configs.get_smoke_config("granite-8b")
    params, _ = LM.init(cfg, run32, key)
    opt = adamw_init(params)
    state = {"params": params, "opt": opt, "step": jnp.int32(7)}
    save_checkpoint(str(tmp_path), 7, state)
    restored, step = restore_checkpoint(str(tmp_path))
    assert step == 7
    flat_a = jax.tree_util.tree_leaves(state)
    flat_b = jax.tree_util.tree_leaves(restored)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_gc(tmp_path, run32, key):
    cfg = configs.get_smoke_config("smollm-360m")
    params, _ = LM.init(cfg, run32, key)
    for s in range(5):
        save_checkpoint(str(tmp_path), s, {"params": params}, keep_last=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2
    assert latest_step(str(tmp_path)) == 4


def test_restore_with_shardings(tmp_path, run32, key):
    """Elastic restore: place onto explicit (1-device) NamedShardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg = configs.get_smoke_config("smollm-360m")
    params, _ = LM.init(cfg, run32, key)
    save_checkpoint(str(tmp_path), 0, {"params": params})
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), params)
    restored, _ = restore_checkpoint(str(tmp_path),
                                     shardings={"params": sh})
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_training_is_seamless(tmp_path, run32, key):
    """Train 4 steps; vs train 2, checkpoint, restore, train 2 — identical."""
    from repro.train.train_step import make_train_step
    cfg = configs.get_smoke_config("smollm-360m")
    data = SyntheticLMData(cfg.vocab_size, 16, 4, seed=1)
    step_fn = jax.jit(make_train_step(cfg, run32))

    def train(params, opt, start, n):
        for s in range(start, start + n):
            toks, labs = data.batch_at(s)
            params, opt, _ = step_fn(params, opt, jnp.asarray(toks),
                                     jnp.asarray(labs))
        return params, opt

    params0, _ = LM.init(cfg, run32, key)
    opt0 = adamw_init(params0)
    pa, oa = train(params0, opt0, 0, 4)

    pb, ob = train(params0, opt0, 0, 2)
    save_checkpoint(str(tmp_path), 2, {"params": pb, "opt": ob})
    restored, step = restore_checkpoint(str(tmp_path))
    pc, oc = train(restored["params"], restored["opt"], step, 2)
    for a, b in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ------------------------------------------------------------------- data

def test_data_deterministic():
    d1 = SyntheticLMData(1000, 32, 8, seed=5)
    d2 = SyntheticLMData(1000, 32, 8, seed=5)
    t1, l1 = d1.batch_at(3)
    t2, l2 = d2.batch_at(3)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(l1, l2)


def test_data_labels_are_shifted():
    d = SyntheticLMData(1000, 32, 8, seed=5)
    t, l = d.batch_at(0)
    np.testing.assert_array_equal(t[:, 1:], l[:, :-1])


def test_data_shards_disjoint():
    a = SyntheticLMData(1000, 32, 8, seed=5, n_shards=2, shard=0)
    b = SyntheticLMData(1000, 32, 8, seed=5, n_shards=2, shard=1)
    ta, _ = a.batch_at(0)
    tb, _ = b.batch_at(0)
    assert ta.shape == (4, 32)
    assert not np.array_equal(ta, tb)


def test_data_in_vocab():
    d = SyntheticLMData(257, 64, 4, seed=0)
    t, l = d.batch_at(11)
    assert t.min() >= 0 and t.max() < 257
