"""Ground-truth performance model invariants."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.jobs import WORKLOADS
from repro.core.perfmodel import MPS_LEVELS, A100, PerfModel
from repro.core.partitions import a100_mig_space

SPACE = a100_mig_space()
PM = PerfModel(SPACE)


@pytest.mark.parametrize("prof", WORKLOADS, ids=lambda p: p.name)
def test_slice_speed_monotone(prof):
    """More compute+memory never hurts (full >= 4g >= 3g >= 2g >= 1g),
    modulo OOM zeros."""
    sv = PM.speed_vector(prof)
    assert sv[7] == pytest.approx(1.0)
    order = [sv[7], sv[4], sv[3], sv[2], sv[1]]
    nonzero = [v for v in order if v > 0]
    assert all(a >= b - 1e-9 for a, b in zip(nonzero, nonzero[1:]))
    assert all(0.0 <= v <= 1.0 + 1e-9 for v in order)


@pytest.mark.parametrize("prof", WORKLOADS[::4], ids=lambda p: p.name)
def test_oom_matches_slice_memory(prof):
    sv = PM.speed_vector(prof)
    for s in SPACE.sizes:
        if prof.mem_gb > SPACE.slice_mem_gb(s):
            assert sv[s] == 0.0
        else:
            assert sv[s] > 0.0


def test_mps_speeds_bounded():
    profs = [WORKLOADS[0], WORKLOADS[10], WORKLOADS[20]]
    for lv in MPS_LEVELS:
        speeds = PM.mps_speeds(profs, lv)
        assert all(0.0 < s <= 1.0 + 1e-6 for s in speeds)


def test_mps_solo_at_full_level_near_one():
    """A job alone in MPS at 100% should run at ~solo speed (small mux tax)."""
    for prof in WORKLOADS[::6]:
        s = PM.mps_speeds([prof], 1.0)[0]
        assert s > 0.9


def test_colocation_stp_exceeds_one_for_small_jobs():
    """Takeaway 1/2: co-locating low-occupancy jobs yields STP > 1 on MIG."""
    small = sorted(WORKLOADS, key=lambda p: p.sm_util)[:3]
    from repro.core.optimizer import optimize_partition
    est = [{s: PM.slice_speed(p, s) for s in SPACE.sizes} for p in small]
    choice = optimize_partition(SPACE, est)
    assert choice.objective > 1.2


def test_mig_beats_mps_usually():
    """Paper: 'MIG is expected to outperform MPS in most cases'."""
    import itertools as it
    import random
    rng = random.Random(0)
    from repro.core.optimizer import optimize_partition
    wins = trials = 0
    for _ in range(30):
        profs = rng.sample(list(WORKLOADS), 3)
        est = [{s: PM.slice_speed(p, s) for s in SPACE.sizes} for p in profs]
        mig = optimize_partition(SPACE, est).objective
        mps = max(sum(PM.mps_speeds(profs, lv)) for lv in MPS_LEVELS)
        wins += mig >= mps
        trials += 1
    assert wins / trials > 0.5
