"""Prefill/decode consistency: serving paths must agree with the full
forward for every cache family (full KV, ring/SWA KV, RWKV state, RG-LRU
state), including ring-buffer wraparound over many steps."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import LM

ARCHS = ["smollm-360m", "qwen3-32b", "mixtral-8x22b", "rwkv6-3b",
         "recurrentgemma-2b", "qwen2-moe-a2.7b"]


def _uncapped(cfg):
    if cfg.moe is not None:
        return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                   capacity_factor=32.0))
    return cfg


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_full_forward(arch, run32, key):
    cfg = _uncapped(configs.get_smoke_config(arch))
    params, _ = LM.init(cfg, run32, key)
    S = 21
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, S), 0,
                                cfg.vocab_size)
    full = LM.logits(params, cfg, run32, tokens)
    logits, _ = LM.prefill(params, cfg, run32, tokens, max_seq=48)
    assert float(jnp.max(jnp.abs(logits[:, 0] - full[:, -1]))) < 1e-4


@pytest.mark.parametrize("arch", ARCHS)
def test_multi_step_decode(arch, run32, key):
    """Decode 8 tokens one-by-one; each must match the growing full forward.
    For SWA archs this wraps the ring buffer (window 16 < total length)."""
    cfg = _uncapped(configs.get_smoke_config(arch))
    params, _ = LM.init(cfg, run32, key)
    S0 = 19
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, S0), 0,
                              cfg.vocab_size)
    _, cache = LM.prefill(params, cfg, run32, toks, max_seq=64)
    for i in range(8):
        nxt = jax.random.randint(jax.random.PRNGKey(100 + i), (2, 1), 0,
                                 cfg.vocab_size)
        toks = jnp.concatenate([toks, nxt], axis=1)
        full = LM.logits(params, cfg, run32, toks)
        logits, cache = LM.decode_step(params, cfg, run32, nxt, cache,
                                       jnp.int32(toks.shape[1] - 1))
        err = float(jnp.max(jnp.abs(logits[:, 0] - full[:, -1])))
        assert err < 1e-3, (arch, i, err)


def test_ring_buffer_wraps_exactly(run32, key):
    """Mixtral smoke window=16: decode far past the window."""
    cfg = _uncapped(configs.get_smoke_config("mixtral-8x22b"))
    params, _ = LM.init(cfg, run32, key)
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 40), 0,
                              cfg.vocab_size)
    _, cache = LM.prefill(params, cfg, run32, toks, max_seq=256)
    # ring cache is capped at the window size
    k_leaf = jax.tree_util.tree_leaves(cache)[0]
    for i in range(20):
        nxt = jax.random.randint(jax.random.PRNGKey(200 + i), (1, 1), 0,
                                 cfg.vocab_size)
        toks = jnp.concatenate([toks, nxt], axis=1)
        full = LM.logits(params, cfg, run32, toks)
        logits, cache = LM.decode_step(params, cfg, run32, nxt, cache,
                                       jnp.int32(toks.shape[1] - 1))
        assert float(jnp.max(jnp.abs(logits[:, 0] - full[:, -1]))) < 1e-3, i


def test_cache_shapes_windowed(run32):
    cfg = configs.get_smoke_config("mixtral-8x22b")  # window 16
    cache = LM.cache_shape(cfg, run32, batch=4, max_seq=128)
    k = cache["groups"][0]["kv"]["k"]
    assert k.shape[2] == 16  # (layers, batch, W, kv_heads, head_dim)
