"""MISO partition optimizer (Algorithm 1): the DP assignment must equal the
literal brute-force enumeration; OOM/QoS zeros must steer the choice."""
import random

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.optimizer import (optimize_partition,
                                  optimize_partition_bruteforce)
from repro.core.partitions import (a100_mig_space, h100_mig_space,
                                   tpu_pod_space)

SPACE = a100_mig_space()
ALL_SPACES = (SPACE, h100_mig_space(), tpu_pod_space())


def _random_speeds(rng, m):
    out = []
    for _ in range(m):
        base = rng.uniform(0.2, 1.0)
        sv = {7: 1.0}
        for s, frac in ((4, 4 / 7), (3, 3 / 7), (2, 2 / 7), (1, 1 / 7)):
            sv[s] = min(1.0, base * frac / base * rng.uniform(0.6, 1.4))
        if rng.random() < 0.3:
            sv[1] = 0.0       # OOM on 1g
        if rng.random() < 0.15:
            sv[2] = 0.0
        if rng.random() < 0.08:
            sv = {s: 0.0 for s in sv}   # fully infeasible job (OOM everywhere)
        out.append(sv)
    return out


@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 6), seed=st.integers(0, 10_000))
def test_dp_equals_bruteforce(m, seed):
    rng = random.Random(seed)
    speeds = _random_speeds(rng, m)
    a = optimize_partition(SPACE, speeds)
    b = optimize_partition_bruteforce(SPACE, speeds)
    assert a is not None and b is not None
    assert abs(a.objective - b.objective) < 1e-9
    assert SPACE.is_valid(a.partition)


def _space_speeds(rng, space, m):
    out = []
    for _ in range(m):
        sv = {}
        for s in space.sizes:
            r = rng.random()
            if r < 0.2:
                sv[s] = 0.0
            elif r < 0.3:
                continue                   # missing key == OOM == 0.0
            else:
                sv[s] = rng.uniform(0.05, 1.0)
        if rng.random() < 0.15 and out:
            sv = dict(out[-1])             # identical clone job: forces ties
        out.append(sv)
    return out


@settings(max_examples=60, deadline=None)
@given(space_idx=st.integers(0, 2), m=st.integers(1, 5),
       seed=st.integers(0, 10_000))
def test_vectorized_equals_bruteforce_all_spaces(space_idx, m, seed):
    """Property test for the vectorized Algorithm 1: on random speed
    vectors (zeros, missing keys, cloned jobs) across all three partition
    spaces, the numpy kernel matches the literal-enumeration oracle's
    objective and returns a valid multiset."""
    space = ALL_SPACES[space_idx]
    rng = random.Random(seed)
    speeds = _space_speeds(rng, space, min(m, space.max_jobs))
    a = optimize_partition(space, speeds, memo=False)
    b = optimize_partition_bruteforce(space, speeds)
    assert a is not None and b is not None
    assert abs(a.objective - b.objective) < 1e-9
    assert space.is_valid(a.partition)
    # objective consistency: the reported objective is the sum of the
    # chosen assignment's speeds
    manual = sum(speeds[j].get(a.partition[j], 0.0)
                 for j in range(len(speeds)))
    assert a.objective == pytest.approx(manual, abs=1e-12)


def test_all_zero_speeds_dp_and_bruteforce_agree():
    """All-OOM job mixes: both paths must return the same (infeasible,
    objective-0) choice — the brute-force oracle used to return None while
    the DP path returned a choice."""
    for m in (1, 2, 3):
        speeds = [{7: 0.0, 4: 0.0, 3: 0.0, 2: 0.0, 1: 0.0}] * m
        a = optimize_partition(SPACE, speeds, memo=False)
        b = optimize_partition_bruteforce(SPACE, speeds)
        assert a is not None and b is not None
        assert a.objective == b.objective == 0.0
        assert not a.feasible and not b.feasible
        assert SPACE.is_valid(a.partition) and SPACE.is_valid(b.partition)


def test_single_job_gets_full_gpu():
    choice = optimize_partition(SPACE, [{7: 1.0, 4: 0.6, 3: 0.5, 2: 0.3,
                                         1: 0.2}])
    assert choice.partition == (7,)


def test_oom_jobs_avoid_small_slices():
    # job 0 OOMs below 3g; job 1 and 2 are tiny
    speeds = [
        {7: 1.0, 4: 0.99, 3: 0.98, 2: 0.0, 1: 0.0},
        {7: 1.0, 4: 1.0, 3: 1.0, 2: 1.0, 1: 0.95},
        {7: 1.0, 4: 1.0, 3: 1.0, 2: 1.0, 1: 0.95},
    ]
    choice = optimize_partition(SPACE, speeds, require_feasible=True)
    assert choice.feasible
    assert choice.partition[0] >= 3


def test_objective_is_predicted_stp():
    speeds = [{7: 1.0, 4: 0.9, 3: 0.8, 2: 0.5, 1: 0.25},
              {7: 1.0, 4: 1.0, 3: 1.0, 2: 0.9, 1: 0.6}]
    choice = optimize_partition(SPACE, speeds)
    manual = sum(speeds[i][choice.partition[i]] for i in range(2))
    assert abs(choice.objective - manual) < 1e-12


def test_optimizer_latency_smallish():
    """Paper: <= 0.5 ms/GPU at max co-location; allow slack on this CPU."""
    import time
    rng = random.Random(0)
    speeds = _random_speeds(rng, 7)
    t0 = time.time()
    for _ in range(20):
        optimize_partition(SPACE, speeds)
    dt = (time.time() - t0) / 20
    assert dt < 0.05, f"optimizer took {dt*1e3:.1f} ms"
