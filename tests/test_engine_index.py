"""The indexed placement hot path: the fleet index, the cached up-set, the
max-addable-slice fast path and the remaining-work aggregate must be exact
accelerations — every answer identical to the O(fleet)/O(jobs) recompute
they replaced."""
import numpy as np
import pytest

from repro.core.estimators import OracleEstimator
from repro.core.jobs import Job, WORKLOADS
from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.simulator import ClusterSim, SimConfig
from repro.core.traces import generate_trace

SPACE = a100_mig_space()
PM = PerfModel(SPACE)
EST = OracleEstimator(PM)


def _sim(jobs, **kw):
    import copy
    cfg = SimConfig(**kw)
    return ClusterSim(copy.deepcopy(jobs), cfg, SPACE, PM, EST)


# --------------------------------------------------------- up-set caching


def test_up_gpus_cache_matches_recompute_under_rack_outages():
    """The cached up-set must equal the brute-force recompute at every
    admission decision while racks fail and repair around it."""
    jobs = generate_trace(30, lam_s=15.0, seed=9, max_duration_s=900)
    sim = _sim(jobs, n_gpus=8, policy="miso", rack_size=2,
               rack_mtbf_s=1200.0, repair_s=180.0, ckpt_interval_s=300.0,
               seed=3)
    mismatches = []
    orig_admit = sim.policy.admit

    def checked_admit():
        got = {g.gid for g in sim.up_gpus()}
        want = {g.gid for g in sim.gpus if sim.t >= g.down_until}
        if got != want:
            mismatches.append((sim.t, got, want))
        orig_admit()

    sim.policy.admit = checked_admit
    m = sim.run()
    assert not mismatches
    assert len(m.jcts) == len(jobs)
    # the scenario actually exercised outages: someone was down at some point
    assert any(g.down_until > 0 for g in sim.gpus)


def test_up_gpus_reflects_failure_and_repair_immediately():
    jobs = [Job(jid=0, profile=WORKLOADS[0], arrival=0.0, work=600.0)]
    sim = _sim(jobs, n_gpus=2, policy="miso", repair_s=120.0)
    assert {g.gid for g in sim.up_gpus()} == {0, 1}
    sim._on_failure(sim.gpus[0])
    assert {g.gid for g in sim.up_gpus()} == {1}
    assert not sim.gpus[0]._in_index
    sim.t = sim.gpus[0].down_until          # repair boundary reached
    assert {g.gid for g in sim.up_gpus()} == {0, 1}
    assert sim.gpus[0]._in_index


def test_refailure_while_down_is_absorbed():
    """A failure landing on a GPU already down for repair is absorbed: it
    must not extend the repair clock, push a second live ``(down_until,
    gid)`` heap entry or perturb the cached up-set — the same guard the
    rack-outage path applies (double-failure audit)."""
    jobs = [Job(jid=0, profile=WORKLOADS[0], arrival=0.0, work=600.0)]
    sim = _sim(jobs, n_gpus=1, policy="miso", repair_s=100.0)
    g = sim.gpus[0]
    sim._on_failure(g)
    first_up = g.down_until
    heap_before = list(sim._down_heap)
    sim.t = 50.0
    sim._on_failure(g)                       # failed again while down
    assert g.down_until == first_up          # repair clock untouched
    assert sim._down_heap == heap_before     # no duplicate heap entry
    assert sim.up_gpus() == []
    sim.t = first_up                         # original repair boundary
    assert [x.gid for x in sim.up_gpus()] == [0]
    assert g._in_index


# ---------------------------------------------- max-addable-slice fast path


def _states(seed, n_jobs=24, n_gpus=3):
    """Yield mid-trace GPU states by snapshotting a real run."""
    jobs = generate_trace(n_jobs, lam_s=10.0, seed=seed, max_duration_s=900,
                          qos_frac=0.3, mem_constraint_frac=0.3)
    sim = _sim(jobs, n_gpus=n_gpus, policy="miso")
    sim.run()
    return sim


def test_max_add_equals_exact_spare_slice_check():
    """``min_required_slice(job) <= _max_add`` must agree with the exact
    ``spare_slice_ok`` for every (GPU state, probe job) pair the shipped
    memory-monotone menu can produce."""
    sim = _states(seed=2)
    probes = generate_trace(12, lam_s=1.0, seed=5, qos_frac=0.5,
                            mem_constraint_frac=0.5)
    for g in sim.gpus:
        sim._refresh_feas(g)
        assert g._max_add is not None        # a100 menu is memory-monotone
        for job in probes:
            r = SPACE.min_required_slice(
                max(job.profile.mem_gb, job.min_mem_gb), job.qos_min_slice)
            fast = r is not None and r <= g._max_add \
                and len(g.jobs) < SPACE.max_jobs
            slow = len(g.jobs) < SPACE.max_jobs and sim.spare_slice_ok(g, job)
            assert fast == slow, (g.gid, dict(g.jobs), job.jid, r, g._max_add)


def test_index_buckets_track_resident_sets_through_a_run():
    """After a full run the index's buckets must hold exactly the in-service
    GPUs at their true (count, level) positions."""
    sim = _states(seed=4)
    seen = set()
    for kd in sim.index._kinds.values():
        for count, by_level in enumerate(kd.counts):
            for level, gids in enumerate(by_level):
                for gid in gids:
                    g = sim.gpus[gid]
                    assert g._in_index
                    assert len(g.jobs) == count
                    assert g._idx_pos == (count, level)
                    assert sim.index._level(kd, g) == level
                    seen.add(gid)
    assert seen == {g.gid for g in sim.gpus if g._in_index}


# -------------------------------------------------- remaining-work aggregate


def test_work_aggregate_tracks_exact_remaining_sum():
    """The Kahan aggregate must match the exact queue+resident remaining-work
    sum at every admission decision of a churny trace."""
    jobs = generate_trace(40, lam_s=8.0, seed=11, max_duration_s=600)
    sim = _sim(jobs, n_gpus=3, policy="miso", gpu_mtbf_s=1500.0,
               repair_s=120.0, seed=7)
    worst = [0.0]
    orig_admit = sim.policy.admit

    def checked_admit():
        for g in sim.gpus:
            g.advance(sim.t)                 # settle progress integration
        exact = sum(sim.jobs[j].remaining for j in sim.queue) + sum(
            rj.job.remaining for g in sim.gpus for rj in g.jobs.values())
        n = len(sim.queue) + sim._resident_count
        assert sim.work_agg.count == n
        worst[0] = max(worst[0], abs(sim.work_agg.total - exact))
        orig_admit()

    sim.policy.admit = checked_admit
    m = sim.run()
    assert len(m.jcts) == len(jobs)
    assert worst[0] < 1e-6 * max(1.0, sum(j.work for j in jobs))


def test_split_point_falls_back_on_hand_built_queue():
    """Tests (and tools) assign ``sim.queue`` directly without the arrival
    hook; the O(1) split point must detect the count mismatch and recompute
    exactly."""
    from repro.core.sim.placement import get_placer
    jobs = [Job(jid=i, profile=WORKLOADS[0], arrival=0.0, work=100.0 * (i + 1))
            for i in range(3)]
    sim = _sim(jobs, n_gpus=2, policy="miso", placer="hetero-speed")
    sim.queue = [0, 1, 2]                    # bypasses _enqueue on purpose
    placer = sim.policy.placer
    assert sim.work_agg.count == 0           # aggregate never saw them
    assert placer._split_point() == pytest.approx((100 + 200 + 300) / 3)


# ------------------------------------------------- index == materialized


@pytest.mark.parametrize("policy", ["miso", "nopart", "mpsonly", "srpt"])
@pytest.mark.parametrize("placer", ["least-loaded", "frag-aware",
                                    "best-fit-slice"])
def test_indexed_placement_equals_materialized_scan(policy, placer):
    """Forcing the fallback (materialized placement_candidates scan) must
    reproduce the indexed run decision-for-decision."""
    jobs = generate_trace(25, lam_s=12.0, seed=6, max_duration_s=900,
                          qos_frac=0.25, mem_constraint_frac=0.25)
    fast = _sim(jobs, n_gpus=4, policy=policy, placer=placer)
    slow = _sim(jobs, n_gpus=4, policy=policy, placer=placer)
    assert fast.policy.indexable
    slow.policy.indexable = False            # force the legacy scan
    mf, ms = fast.run(), slow.run()
    assert mf.jcts == ms.jcts
    assert mf.avg_jct == ms.avg_jct
    assert fast.completed == slow.completed


def test_same_tick_arrival_burst_places_like_sequential_fcfs():
    """A burst of identical-timestamp arrivals (integer trace seconds) must
    admit exactly as back-to-back single arrivals would under FCFS."""
    prof = WORKLOADS[0]
    burst = [Job(jid=i, profile=prof, arrival=100.0, work=300.0 + 10 * i)
             for i in range(6)]
    spread = [Job(jid=i, profile=prof, arrival=100.0 + 1e-7 * i,
                  work=300.0 + 10 * i) for i in range(6)]
    mb = _sim(burst, n_gpus=2, policy="miso").run()
    msp = _sim(spread, n_gpus=2, policy="miso").run()
    assert len(mb.jcts) == len(burst)
    assert mb.avg_jct == pytest.approx(msp.avg_jct, rel=1e-6)
