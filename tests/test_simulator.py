"""Cluster simulator: policy ordering, accounting invariants, constraints,
fault injection, and the MISO feature set of paper §4.3."""
import numpy as np
import pytest

from repro.core.estimators import NoisyEstimator, OracleEstimator
from repro.core.jobs import WORKLOADS, Job
from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.simulator import SimConfig, simulate
from repro.core.traces import expand_multi_instance, generate_trace

SPACE = a100_mig_space()
PM = PerfModel(SPACE)
EST = OracleEstimator(PM)


def _run(policy, jobs, **kw):
    cfg = SimConfig(n_gpus=4, policy=policy, **kw)
    return simulate(jobs, cfg, SPACE, PM, EST)


@pytest.fixture(scope="module")
def trace():
    return generate_trace(40, lam_s=45.0, seed=7, max_duration_s=1200)


def test_policy_ordering(trace):
    """Oracle <= MISO < NoPart on JCT (paper Fig 10)."""
    jct = {p: _run(p, trace).avg_jct
           for p in ("nopart", "oracle", "miso", "optsta")}
    assert jct["oracle"] <= jct["miso"] * 1.001
    assert jct["miso"] < jct["nopart"]
    assert jct["oracle"] < jct["optsta"]


def test_all_jobs_complete(trace):
    m = _run("miso", trace)
    assert len(m.jcts) == len(trace)


def test_breakdown_accounts_jct(trace):
    """queue+mps+ckpt+run must equal JCT on average (paper Fig 12)."""
    m = _run("miso", trace)
    total = sum(m.breakdown.values())
    assert abs(total - m.avg_jct) / m.avg_jct < 0.02


def test_nopart_runs_exclusively(trace):
    m = _run("nopart", trace)
    # exclusive execution: run time == work exactly
    works = sorted(j.work for j in trace)
    runs = sorted(m.breakdown["run"] * len(m.jcts) for _ in [0])
    assert abs(np.mean([j.work for j in trace]) - m.breakdown["run"]) < 1e-6


def test_relative_jct_lower_bound(trace):
    """No job can finish faster than its exclusive-GPU time."""
    for pol in ("nopart", "miso", "oracle", "optsta", "mpsonly"):
        m = _run(pol, trace)
        assert min(m.relative_jcts) >= 1.0 - 1e-9, pol


def test_mem_constraint_respected():
    """Jobs with declared min memory only land where a big slice exists."""
    jobs = generate_trace(12, lam_s=5.0, seed=3, max_duration_s=600,
                          mem_constraint_frac=1.0)
    m = _run("miso", jobs)
    assert len(m.jcts) == len(jobs)


def test_qos_constraint():
    big = [j for j in generate_trace(10, lam_s=10.0, seed=4,
                                     max_duration_s=600, qos_frac=1.0)]
    m = _run("miso", big)
    assert len(m.jcts) == len(big)


def test_multi_instance_profiled_once():
    prof = WORKLOADS[0]
    jobs = [Job(jid=0, profile=prof, arrival=0.0, work=300.0, n_instances=3)]
    jobs = expand_multi_instance(jobs)
    assert len(jobs) == 3
    assert all(j.mi_group == 0 for j in jobs)
    m = _run("miso", jobs)
    assert len(m.jcts) == 3
    # clones skip the MPS phase: at most one job paid profiling time
    paid = [j for j in jobs if j.t_mps > 0]
    assert len(paid) <= 1


def test_failure_injection_requeues():
    jobs = generate_trace(10, lam_s=20.0, seed=5, max_duration_s=900)
    cfg = SimConfig(n_gpus=2, policy="miso", gpu_mtbf_s=600.0, repair_s=120.0,
                    seed=11)
    m = simulate(jobs, cfg, SPACE, PM, EST)
    assert len(m.jcts) == len(jobs)          # everything still completes
    base = simulate(jobs, SimConfig(n_gpus=2, policy="miso"), SPACE, PM, EST)
    assert m.avg_jct >= base.avg_jct          # failures cannot help


def test_failure_rollback_is_speed_weighted_and_placement_local():
    """Lost work on a GPU failure is the speed-weighted work done since the
    last checkpoint of the CURRENT placement — not wall-clock seconds, and
    not ``min(ckpt_interval, cumulative t_run)`` across earlier requeues."""
    from repro.core.simulator import ClusterSim
    job = Job(jid=0, profile=WORKLOADS[0], arrival=0.0, work=5000.0)
    cfg = SimConfig(n_gpus=1, policy="nopart", ckpt_interval_s=50.0,
                    repair_s=100.0)
    sim = ClusterSim([job], cfg, SPACE, PM, EST)
    sim._on_arrival(sim.jobs[0])
    g = sim.gpus[0]
    assert g.jobs[0].speed == 1.0            # full slice: exactly 1 work-s/s
    sim.t = 130.0
    sim._on_failure(g)
    # periodic checkpoints passed at t=50 and t=100 -> exactly 30 work-s lost
    assert sim.jobs[0].remaining == pytest.approx(5000.0 - 100.0)
    assert sim.queue == [0]
    # second placement: rollback restarts from THIS placement's checkpoints
    sim.t = g.down_until
    sim.policy.admit()
    assert 0 in g.jobs
    sim.t = g.down_until + 10.0              # 10s < interval: no ckpt yet
    sim._on_failure(g)
    # all 10 fresh work-seconds lost; nothing more (old bug: min(50, t_run
    # =140) would have destroyed 50)
    assert sim.jobs[0].remaining == pytest.approx(5000.0 - 100.0)


def test_failure_mid_checkpoint_discards_unfinished_save():
    """A checkpoint is durable only once its window completes: a failure
    mid-save rolls back to the last *completed* checkpoint, losing all the
    MPS-phase progress the in-flight save was trying to commit."""
    from repro.core.simulator import CKPT, MIG_RUN, MPS_PROF, ClusterSim
    job = Job(jid=0, profile=WORKLOADS[0], arrival=0.0, work=5000.0)
    cfg = SimConfig(n_gpus=1, policy="miso", ckpt_interval_s=100000.0)
    sim = ClusterSim([job], cfg, SPACE, PM, EST)
    sim._on_arrival(sim.jobs[0])
    g = sim.gpus[0]
    assert g.phase == MPS_PROF
    sim.t = g.phase_end                      # MPS sweep ends -> reconfigure
    sim.end_phase(g)
    assert g.phase == CKPT
    done = 5000.0 - sim.jobs[0].remaining
    assert done > 0                          # job progressed during MPS
    assert g.jobs[0].since_ckpt_work == pytest.approx(done)
    sim.t += g.ckpt_duration() / 2           # fail while the save is in flight
    sim._on_failure(g)
    assert sim.jobs[0].remaining == pytest.approx(5000.0)

    # ... whereas a checkpoint that runs to completion commits the progress
    sim2 = ClusterSim([Job(jid=0, profile=WORKLOADS[0], arrival=0.0,
                           work=5000.0)], cfg, SPACE, PM, EST)
    sim2._on_arrival(sim2.jobs[0])
    g2 = sim2.gpus[0]
    sim2.t = g2.phase_end
    sim2.end_phase(g2)                       # MPS -> CKPT
    done2 = 5000.0 - sim2.jobs[0].remaining
    sim2.t = g2.phase_end
    sim2.end_phase(g2)                       # CKPT completes -> MIG_RUN
    assert g2.phase == MIG_RUN
    assert g2.jobs[0].since_ckpt_work == 0.0
    sim2._on_failure(g2)
    assert sim2.jobs[0].remaining == pytest.approx(5000.0 - done2)


def test_failure_requeue_preserves_relative_order():
    """Multiple jobs requeued by one failure keep their placement order at
    the queue head (the old repeated ``insert(0, ...)`` reversed them)."""
    from repro.core.simulator import ClusterSim
    jobs = [Job(jid=i, profile=WORKLOADS[0], arrival=float(i), work=600.0)
            for i in range(3)]
    cfg = SimConfig(n_gpus=1, policy="mpsonly", mps_only_max_jobs=2)
    sim = ClusterSim(jobs, cfg, SPACE, PM, EST)
    for i, t in enumerate((0.0, 1.0, 2.0)):
        sim.t = t
        sim._on_arrival(sim.jobs[i])
    g = sim.gpus[0]
    assert list(g.jobs) == [0, 1] and sim.queue == [2]
    sim.t = 10.0
    sim._on_failure(g)
    assert sim.queue == [0, 1, 2]            # victims first, order preserved


def test_failure_work_conservation():
    """Paper Fig 12 invariant under faults: every second of a completed
    job's life lands in exactly one of {queue, mps, ckpt, run}, across
    failure/repair/requeue cycles."""
    import copy
    from repro.core.simulator import ClusterSim
    jobs = generate_trace(12, lam_s=20.0, seed=9, max_duration_s=900)
    cfg = SimConfig(n_gpus=2, policy="miso", gpu_mtbf_s=700.0, repair_s=150.0,
                    ckpt_interval_s=120.0, seed=4)
    sim = ClusterSim(copy.deepcopy(jobs), cfg, SPACE, PM, EST)
    m = sim.run()
    assert len(m.jcts) == len(jobs)
    assert any(g.down_until > 0 for g in sim.gpus)       # faults did fire
    for j in sim.jobs.values():
        total = j.t_queue + j.t_mps + j.t_ckpt + j.t_run
        assert total == pytest.approx(j.finish_time - j.arrival,
                                      rel=1e-9, abs=1e-6)


def test_noisy_estimator_degrades_gracefully():
    """Paper Fig 18: large prediction error should not break MISO."""
    jobs = generate_trace(30, lam_s=30.0, seed=6, max_duration_s=900)
    clean = simulate(jobs, SimConfig(n_gpus=4, policy="miso"), SPACE, PM,
                     OracleEstimator(PM))
    noisy = simulate(jobs, SimConfig(n_gpus=4, policy="miso"), SPACE, PM,
                     NoisyEstimator(PM, sigma=0.09, seed=0))
    nopart = simulate(jobs, SimConfig(n_gpus=4, policy="nopart"), SPACE, PM,
                      OracleEstimator(PM))
    assert noisy.avg_jct < nopart.avg_jct          # still clearly better
    assert noisy.avg_jct < clean.avg_jct * 1.5


def test_phase_change_reprofiles():
    from repro.core.jobs import job_profile
    p1 = job_profile("smollm-360m", 8)
    p2 = job_profile("granite-dense-700m", 32)
    j = Job(jid=0, profile=p1, arrival=0.0, work=600.0,
            phases=((0.5, p2),))
    assert j.profile_at(0.0).name == p1.name
    assert j.profile_at(0.6).name == p2.name
