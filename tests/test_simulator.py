"""Cluster simulator: policy ordering, accounting invariants, constraints,
fault injection, and the MISO feature set of paper §4.3."""
import numpy as np
import pytest

from repro.core.estimators import NoisyEstimator, OracleEstimator
from repro.core.jobs import WORKLOADS, Job
from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.simulator import SimConfig, simulate
from repro.core.traces import expand_multi_instance, generate_trace

SPACE = a100_mig_space()
PM = PerfModel(SPACE)
EST = OracleEstimator(PM)


def _run(policy, jobs, **kw):
    cfg = SimConfig(n_gpus=4, policy=policy, **kw)
    return simulate(jobs, cfg, SPACE, PM, EST)


@pytest.fixture(scope="module")
def trace():
    return generate_trace(40, lam_s=45.0, seed=7, max_duration_s=1200)


def test_policy_ordering(trace):
    """Oracle <= MISO < NoPart on JCT (paper Fig 10)."""
    jct = {p: _run(p, trace).avg_jct
           for p in ("nopart", "oracle", "miso", "optsta")}
    assert jct["oracle"] <= jct["miso"] * 1.001
    assert jct["miso"] < jct["nopart"]
    assert jct["oracle"] < jct["optsta"]


def test_all_jobs_complete(trace):
    m = _run("miso", trace)
    assert len(m.jcts) == len(trace)


def test_breakdown_accounts_jct(trace):
    """queue+mps+ckpt+run must equal JCT on average (paper Fig 12)."""
    m = _run("miso", trace)
    total = sum(m.breakdown.values())
    assert abs(total - m.avg_jct) / m.avg_jct < 0.02


def test_nopart_runs_exclusively(trace):
    m = _run("nopart", trace)
    # exclusive execution: run time == work exactly
    works = sorted(j.work for j in trace)
    runs = sorted(m.breakdown["run"] * len(m.jcts) for _ in [0])
    assert abs(np.mean([j.work for j in trace]) - m.breakdown["run"]) < 1e-6


def test_relative_jct_lower_bound(trace):
    """No job can finish faster than its exclusive-GPU time."""
    for pol in ("nopart", "miso", "oracle", "optsta", "mpsonly"):
        m = _run(pol, trace)
        assert min(m.relative_jcts) >= 1.0 - 1e-9, pol


def test_mem_constraint_respected():
    """Jobs with declared min memory only land where a big slice exists."""
    jobs = generate_trace(12, lam_s=5.0, seed=3, max_duration_s=600,
                          mem_constraint_frac=1.0)
    m = _run("miso", jobs)
    assert len(m.jcts) == len(jobs)


def test_qos_constraint():
    big = [j for j in generate_trace(10, lam_s=10.0, seed=4,
                                     max_duration_s=600, qos_frac=1.0)]
    m = _run("miso", big)
    assert len(m.jcts) == len(big)


def test_multi_instance_profiled_once():
    prof = WORKLOADS[0]
    jobs = [Job(jid=0, profile=prof, arrival=0.0, work=300.0, n_instances=3)]
    jobs = expand_multi_instance(jobs)
    assert len(jobs) == 3
    assert all(j.mi_group == 0 for j in jobs)
    m = _run("miso", jobs)
    assert len(m.jcts) == 3
    # clones skip the MPS phase: at most one job paid profiling time
    paid = [j for j in jobs if j.t_mps > 0]
    assert len(paid) <= 1


def test_failure_injection_requeues():
    jobs = generate_trace(10, lam_s=20.0, seed=5, max_duration_s=900)
    cfg = SimConfig(n_gpus=2, policy="miso", gpu_mtbf_s=600.0, repair_s=120.0,
                    seed=11)
    m = simulate(jobs, cfg, SPACE, PM, EST)
    assert len(m.jcts) == len(jobs)          # everything still completes
    base = simulate(jobs, SimConfig(n_gpus=2, policy="miso"), SPACE, PM, EST)
    assert m.avg_jct >= base.avg_jct          # failures cannot help


def test_noisy_estimator_degrades_gracefully():
    """Paper Fig 18: large prediction error should not break MISO."""
    jobs = generate_trace(30, lam_s=30.0, seed=6, max_duration_s=900)
    clean = simulate(jobs, SimConfig(n_gpus=4, policy="miso"), SPACE, PM,
                     OracleEstimator(PM))
    noisy = simulate(jobs, SimConfig(n_gpus=4, policy="miso"), SPACE, PM,
                     NoisyEstimator(PM, sigma=0.09, seed=0))
    nopart = simulate(jobs, SimConfig(n_gpus=4, policy="nopart"), SPACE, PM,
                      OracleEstimator(PM))
    assert noisy.avg_jct < nopart.avg_jct          # still clearly better
    assert noisy.avg_jct < clean.avg_jct * 1.5


def test_phase_change_reprofiles():
    from repro.core.jobs import job_profile
    p1 = job_profile("smollm-360m", 8)
    p2 = job_profile("granite-dense-700m", 32)
    j = Job(jid=0, profile=p1, arrival=0.0, work=600.0,
            phases=((0.5, p2),))
    assert j.profile_at(0.0).name == p1.name
    assert j.profile_at(0.6).name == p2.name
