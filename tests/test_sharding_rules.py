"""Divisibility-aware sharding rules: the same table serves every arch."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import logical_to_pspec, make_rules


@pytest.fixture(scope="module")
def rules16():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    # fake 16x16 table without needing 256 devices
    from repro.sharding.rules import ShardingRules
    base = make_rules(mesh)
    return ShardingRules(table=base.table,
                         mesh_axes={"data": 16, "model": 16})


def test_head_tp_when_divisible(rules16):
    spec = logical_to_pspec(("d_model", "heads", "head_dim"),
                            (4096, 32, 128), rules16)
    assert spec == P("data", "model")


def test_head_tp_fallback_smollm(rules16):
    """15 heads don't divide 16 -> heads unsharded, d_model takes FSDP."""
    spec = logical_to_pspec(("d_model", "heads", "head_dim"),
                            (960, 15, 64), rules16)
    assert spec == P("data")


def test_vocab_sharding(rules16):
    spec = logical_to_pspec(("vocab", "d_model"), (151936, 5120), rules16)
    assert spec == P("model", "data")


def test_batch_over_pod_and_data():
    from repro.sharding.rules import ShardingRules
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    base = make_rules(mesh)
    rules = ShardingRules(table=base.table,
                          mesh_axes={"pod": 2, "data": 16, "model": 16})
    spec = logical_to_pspec(("batch", "seq"), (256, 4096), rules)
    assert spec == P(("pod", "data"))
    # batch=1 (long_500k): no divisor -> replicated
    spec = logical_to_pspec(("batch", "seq"), (1, 524288), rules)
    assert spec == P()


def test_no_axis_reuse(rules16):
    """One tensor can't use 'model' twice (heads + d_ff)."""
    spec = logical_to_pspec(("heads", "d_ff"), (32, 4096), rules16)
    assert spec == P("model")       # d_ff candidate blocked by used axis


def test_experts_ep_when_divisible(rules16):
    spec = logical_to_pspec(("experts", "d_model", "d_ff_expert"),
                            (16, 2048, 1408), rules16)
    assert spec == P("model", "data", None) or spec == P("model", "data")
    # 60 experts don't divide 16 -> d_ff_expert takes TP
    spec = logical_to_pspec(("experts", "d_model", "d_ff_expert"),
                            (60, 2048, 1408), rules16)
    assert spec == P(None, "data", "model")
