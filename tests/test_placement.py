"""Placement layer: registry, golden bit-identity of the default placer,
the feasibility guarantee (every placer only ever returns GPUs its policy
offered) on mixed fleets, per-placer ranking behavior, and the per-kind
predictor-artifact routing through ``GPUSpec.estimator``."""
import json
import os
from dataclasses import replace

import numpy as np
import pytest

import repro.core.fleet as fleet_mod
from repro.core.estimators import OracleEstimator
from repro.core.fleet import (GPUSpec, default_artifact_path,
                              homogeneous_fleet, parse_fleet)
from repro.core.jobs import WORKLOADS, Job
from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.simulator import (ClusterSim, Placer, SimConfig,
                                  available_placers, get_placer,
                                  register_placer, simulate)
from repro.core.traces import generate_trace

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # container image ships without it
    HAVE_HYPOTHESIS = False

SPACE = a100_mig_space()
PM = PerfModel(SPACE)
EST = OracleEstimator(PM)
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "simulator_golden.json")

ALL_POLICIES = ("nopart", "optsta", "mpsonly", "miso", "oracle",
                "miso-frag", "srpt")
BUILTIN_PLACERS = ("least-loaded", "hetero-speed", "frag-aware",
                   "best-fit-slice")


# --------------------------------------------------------------- registry

def test_builtin_placers_registered():
    for name in BUILTIN_PLACERS:
        assert name in available_placers()
        assert get_placer(name).name == name


def test_unknown_placer_raises():
    with pytest.raises(ValueError, match="unknown placer"):
        get_placer("does-not-exist")
    # fails fast at construction, like an unknown policy
    with pytest.raises(ValueError, match="unknown placer"):
        ClusterSim([], SimConfig(placer="does-not-exist"), SPACE, PM, EST)


def test_duplicate_placer_registration_raises():
    with pytest.raises(ValueError, match="duplicate"):
        @register_placer
        class Clash(Placer):                       # noqa: F811
            name = "least-loaded"

            def pick(self, job, candidates):
                return None
    assert get_placer("least-loaded").__name__ == "LeastLoadedPlacer"


# ----------------------------------------------------------------- golden

with open(GOLDEN) as f:
    _GOLD = json.load(f)
_GCFG = _GOLD["config"]


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_default_placer_bit_identical_to_golden(policy):
    """An *explicit* least-loaded placer reproduces the recorded
    (pre-placement-layer) simulator bit-for-bit for all seven policies —
    the refactor moved the paper's placement rule, it did not change it."""
    seed = 0
    jobs = generate_trace(_GCFG["n_jobs"], lam_s=_GCFG["lam_s"], seed=seed,
                          max_duration_s=_GCFG["max_duration_s"])
    m = simulate(jobs, SimConfig(n_gpus=_GCFG["n_gpus"], policy=policy,
                                 placer="least-loaded"), SPACE, PM, EST)
    g = _GOLD[f"{policy}/seed{seed}"]
    assert m.avg_jct == g["avg_jct"]
    assert m.makespan == g["makespan"]
    assert m.stp == g["stp"]
    assert list(m.jcts) == g["jcts"]
    assert m.breakdown == g["breakdown"]


# -------------------------------------------------------- ranking behavior

def _sim(fleet_spec, jobs=(), policy="oracle", placer="least-loaded"):
    return ClusterSim(list(jobs), SimConfig(policy=policy, placer=placer),
                      fleet=parse_fleet(fleet_spec))


def _job(jid, mem_gb, work=300.0, qos=0):
    prof = replace(WORKLOADS[0], name=f"j{jid}", mem_gb=mem_gb)
    return Job(jid=jid, profile=prof, arrival=0.0, work=work,
               qos_min_slice=qos)


def test_hetero_speed_splits_long_and_short_jobs():
    """Long jobs (above the in-system mean remaining work) go to the fast
    GPU, short ones pack on the slow GPU."""
    long_j, short_j = _job(0, 5.0, work=10_000.0), _job(1, 5.0, work=10.0)
    sim = _sim("a100:1+h100:1", [long_j, short_j], placer="hetero-speed")
    sim.queue = [0, 1]                   # both in the system, nothing placed
    placer = sim.policy.placer
    cands = sim.policy.placement_candidates(long_j)
    assert len(cands) == 2
    assert placer.pick(long_j, cands).speed_scale == 2.0     # h100
    assert placer.pick(short_j, cands).speed_scale == 1.0    # a100


def test_hetero_speed_degenerates_to_least_loaded_when_homogeneous():
    job = _job(0, 5.0)
    sim = _sim("a100:3", [job], placer="hetero-speed")
    sim.queue = [0]
    cands = sim.policy.placement_candidates(job)
    assert sim.policy.placer.pick(job, cands) is \
        get_placer("least-loaded")(sim).pick(job, cands)


def test_frag_aware_keeps_contiguous_slices_free():
    """GPU0's resident forces the packed (3g,3g) partition; GPU1's covering
    partition keeps a 2g slice free.  least-loaded ties to GPU0 (lower gid),
    frag-aware must prefer GPU1."""
    new = _job(2, 11.0)                          # needs a 3g.20gb slice
    sim = _sim("a100:2", [_job(0, 20.0), _job(1, 4.0), new],
               placer="frag-aware")
    sim.place(sim.gpus[0], sim.jobs[0])          # req 3g resident
    sim.place(sim.gpus[1], sim.jobs[1])          # req 1g resident
    cands = sim.policy.placement_candidates(new)
    assert [g.gid for g in cands] == [0, 1]
    assert get_placer("least-loaded")(sim).pick(new, cands).gid == 0
    assert sim.policy.placer.pick(new, cands).gid == 1


def test_best_fit_slice_packs_tightest():
    """A 1g job fits tightest next to the existing 1g resident; least-loaded
    would start a fresh GPU instead."""
    new = _job(2, 4.0)                           # needs only a 1g.5gb slice
    sim = _sim("a100:2", [_job(1, 4.0), new], placer="best-fit-slice")
    sim.place(sim.gpus[1], sim.jobs[1])
    cands = sim.policy.placement_candidates(new)
    assert [g.gid for g in cands] == [0, 1]
    assert get_placer("least-loaded")(sim).pick(new, cands).gid == 0
    assert sim.policy.placer.pick(new, cands).gid == 1


# ------------------------------------------- feasibility on mixed fleets

def _assert_placer_feasible(placer_name, jobs, fleet_spec="a100:2+h100:1"):
    """Place ``jobs`` one by one: the placer must only ever return a GPU the
    policy offered (which implies the engine's feasibility checks held)."""
    sim = _sim(fleet_spec, jobs, policy="oracle", placer=placer_name)
    sim.queue = [j.jid for j in jobs]
    placed = 0
    for job in jobs:
        cands = sim.policy.placement_candidates(job)
        g = sim.policy.placer.pick(job, cands)
        assert g is None or g in cands
        if g is not None:
            assert sim.mem_ok(g, job) and sim.spare_slice_ok(g, job)
            sim.queue.remove(job.jid)
            sim.place(g, job)
            placed += 1
    return placed


_QOS_SIZES = (0, 1, 2, 3, 4, 7)


def _jobs_from_params(params):
    return [Job(jid=i,
                profile=replace(WORKLOADS[0], name=f"h{i}", mem_gb=mem),
                arrival=0.0, work=work, qos_min_slice=qos)
            for i, (mem, qos, work) in enumerate(params)]


if HAVE_HYPOTHESIS:
    @pytest.mark.parametrize("placer", BUILTIN_PLACERS)
    @settings(max_examples=30, deadline=None)
    @given(params=st.lists(
        st.tuples(st.floats(0.5, 90.0, allow_nan=False),
                  st.sampled_from(_QOS_SIZES),
                  st.floats(10.0, 5_000.0, allow_nan=False)),
        min_size=1, max_size=10))
    def test_placers_only_return_feasible_gpus(placer, params):
        """Property: on a mixed a100+h100 fleet, every registered placer
        only ever returns feasible GPUs, whatever the (mem, QoS, work)
        mix — including jobs no GPU can take (placer returns None)."""
        _assert_placer_feasible(placer, _jobs_from_params(params))


@pytest.mark.parametrize("placer", BUILTIN_PLACERS)
def test_placers_only_return_feasible_gpus_seeded(placer):
    """Seeded variant of the feasibility property (runs where hypothesis is
    not installed)."""
    rng = np.random.default_rng(0)
    some_placed = 0
    for _ in range(15):
        n = int(rng.integers(1, 11))
        params = [(float(rng.uniform(0.5, 90.0)),
                   int(rng.choice(_QOS_SIZES)),
                   float(rng.uniform(10.0, 5_000.0))) for _ in range(n)]
        some_placed += _assert_placer_feasible(placer,
                                               _jobs_from_params(params))
    assert some_placed > 0                       # the property isn't vacuous


@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("placer", BUILTIN_PLACERS)
def test_every_policy_completes_under_every_placer(policy, placer):
    """Full policy x placer grid on a mixed fleet: every combination drains
    the trace (placers respect each policy's own candidate rules)."""
    jobs = generate_trace(10, lam_s=25.0, seed=4, max_duration_s=900)
    m = simulate(jobs, SimConfig(policy=policy, placer=placer),
                 fleet=parse_fleet("a100:2+h100:2"))
    assert len(m.jcts) == len(jobs)


def test_cluster_cli_lists_all_placers():
    from repro.launch.cluster import build_parser
    action = next(a for a in build_parser()._actions
                  if "--placer" in a.option_strings)
    assert set(BUILTIN_PLACERS) <= set(action.choices)


# ------------------------------------------------ estimator routing (fleet)

def test_explicit_estimator_never_clobbered():
    sentinel = object()
    spec = GPUSpec("a100", SPACE, PM, estimator=sentinel)
    assert spec.estimator is sentinel
    fleet = homogeneous_fleet(SPACE, PM, sentinel, 3)
    assert all(s.estimator is sentinel for s in fleet)
    # dataclasses.replace re-runs __post_init__; the estimator must survive
    assert replace(spec, speed_scale=2.0).estimator is sentinel


def test_unknown_artifact_path_raises_clearly():
    with pytest.raises(FileNotFoundError, match="h100"):
        GPUSpec("h100", SPACE, PM, artifact="/does/not/exist.npz")
    # ... and an explicit estimator wins over a bogus artifact path
    sentinel = object()
    spec = GPUSpec("h100", SPACE, PM, estimator=sentinel,
                   artifact="/does/not/exist.npz")
    assert spec.estimator is sentinel


def test_default_artifact_path_per_kind(tmp_path, monkeypatch):
    monkeypatch.setattr(fleet_mod, "ARTIFACT_DIR", str(tmp_path))
    assert default_artifact_path("h100") is None
    (tmp_path / "predictor_h100.npz").write_bytes(b"")
    assert default_artifact_path("h100") == str(tmp_path / "predictor_h100.npz")
    # a100 falls back to the legacy un-suffixed artifact
    assert default_artifact_path("a100") is None
    (tmp_path / "predictor.npz").write_bytes(b"")
    assert default_artifact_path("a100") == str(tmp_path / "predictor.npz")
    (tmp_path / "predictor_a100.npz").write_bytes(b"")
    assert default_artifact_path("a100") == str(tmp_path / "predictor_a100.npz")
    assert default_artifact_path("tpu") is None


def test_fleet_kinds_default_to_oracle_without_artifacts():
    """Without shipped artifacts the per-kind factories stay on the oracle
    estimator (never a silent half-configured U-Net)."""
    for spec in parse_fleet("a100:1+h100:1+tpu:1"):
        if spec.artifact is None:
            assert isinstance(spec.estimator, OracleEstimator)
