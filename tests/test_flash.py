"""Flash attention (custom VJP, pure JAX): forward and gradients vs the
naive reference over shape/window sweeps + hypothesis-generated cases."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import flash, modules


def _run_case(B, S, Hq, Hkv, D, win, bq, bkv, tol=5e-5):
    ks = jax.random.split(jax.random.PRNGKey(S * 7 + Hq), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.arange(S, dtype=jnp.int32)

    def f_flash(q, k, v):
        return flash.flash_attention(
            q, k, v, q_positions=pos, kv_positions=pos, causal=True,
            window=win, block_q=bq, block_kv=bkv).sum()

    def f_ref(q, k, v):
        return modules.naive_attention(
            q, k, v, q_positions=pos, kv_positions=pos, causal=True,
            window=win).sum()

    o1 = flash.flash_attention(q, k, v, q_positions=pos, kv_positions=pos,
                               causal=True, window=win, block_q=bq,
                               block_kv=bkv)
    o2 = modules.naive_attention(q, k, v, q_positions=pos, kv_positions=pos,
                                 causal=True, window=win)
    assert float(jnp.max(jnp.abs(o1 - o2))) < tol
    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b))) < tol * 20


@pytest.mark.parametrize("case", [
    (2, 37, 4, 2, 16, None, 8, 8),
    (2, 64, 6, 2, 8, 16, 16, 8),
    (1, 33, 3, 3, 8, None, 8, 16),
    (2, 40, 4, 1, 16, 12, 8, 8),      # MQA + window
    (1, 128, 2, 2, 4, None, 64, 32),
])
def test_flash_matches_reference(case):
    _run_case(*case)


@settings(max_examples=12, deadline=None)
@given(
    S=st.integers(9, 70),
    g=st.integers(1, 3),
    hkv=st.integers(1, 3),
    win=st.one_of(st.none(), st.integers(4, 32)),
    bq=st.sampled_from([8, 16]),
    bkv=st.sampled_from([8, 16]),
)
def test_flash_hypothesis(S, g, hkv, win, bq, bkv):
    _run_case(1, S, g * hkv, hkv, 8, win, bq, bkv)


def test_band_skip_equals_masked():
    """The banded SWA fast path must equal the masked path exactly."""
    B, S, H, D, W = 2, 96, 4, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    pos = jnp.arange(S, dtype=jnp.int32)
    kw = dict(q_positions=pos, kv_positions=pos, causal=True, window=W,
              block_q=16, block_kv=16)
    o_band = flash.flash_attention(q, k, v, window_block_skip=True, **kw)
    o_mask = flash.flash_attention(q, k, v, window_block_skip=False, **kw)
    assert float(jnp.max(jnp.abs(o_band - o_mask))) < 1e-5
