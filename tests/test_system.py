"""End-to-end behaviour of the paper's system: the full MISO pipeline
(trace -> MPS profiling -> U-Net prediction -> Algorithm 1 -> dynamic MIG
partitions) on a simulated cluster, plus the paper's headline claims at
reduced scale (full-scale reproduction lives in benchmarks/ and
EXPERIMENTS.md)."""
import os

import numpy as np
import pytest

from repro.core.estimators import OracleEstimator, UNetEstimator
from repro.core.partitions import a100_mig_space, tpu_pod_space
from repro.core.perfmodel import PerfModel, TPU_V5E_POD
from repro.core.simulator import SimConfig, simulate
from repro.core.traces import generate_trace

SPACE = a100_mig_space()
PM = PerfModel(SPACE)
ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "predictor.npz")


@pytest.fixture(scope="module")
def trace100():
    # paper testbed scale: 100 jobs, lambda=60s, <=2h durations
    return generate_trace(100, lam_s=60.0, seed=1)


@pytest.mark.slow
def test_paper_headline_claims(trace100):
    """MISO ~half the JCT of NoPart; within ~15% of Oracle; better makespan
    and STP than NoPart (paper Fig 10 bands, tolerance widened for our
    synthetic perf model)."""
    res = {p: simulate(trace100, SimConfig(n_gpus=8, policy=p), SPACE, PM,
                       OracleEstimator(PM))
           for p in ("nopart", "optsta", "miso", "oracle")}
    n = res["nopart"]
    gain = 1 - res["miso"].avg_jct / n.avg_jct
    assert 0.30 < gain < 0.75                      # paper: 49%
    assert res["miso"].avg_jct <= res["oracle"].avg_jct * 1.20  # paper: <10%
    assert res["miso"].makespan < n.makespan * 1.05
    assert res["miso"].stp > n.stp * 0.95
    # OptSta between NoPart and MISO on JCT (paper: MISO beats OptSta by 16%)
    assert res["miso"].avg_jct < res["optsta"].avg_jct < n.avg_jct


@pytest.mark.skipif(not os.path.exists(ARTIFACT),
                    reason="trained predictor artifact missing")
def test_full_miso_pipeline_with_unet():
    """The real learned pipeline end-to-end: measured MPS matrices -> U-Net
    -> linreg heads -> optimizer, inside the cluster simulator."""
    jobs = generate_trace(40, lam_s=45.0, seed=9, max_duration_s=1500)
    unet_est = UNetEstimator.from_artifact(PM, ARTIFACT)
    m_unet = simulate(jobs, SimConfig(n_gpus=4, policy="miso"), SPACE, PM,
                      unet_est)
    m_nopart = simulate(jobs, SimConfig(n_gpus=4, policy="nopart"), SPACE,
                        PM, OracleEstimator(PM))
    m_oracle = simulate(jobs, SimConfig(n_gpus=4, policy="oracle"), SPACE,
                        PM, OracleEstimator(PM))
    assert m_unet.avg_jct < m_nopart.avg_jct          # clearly beats NoPart
    assert m_unet.avg_jct < m_oracle.avg_jct * 1.35   # close to Oracle


def test_tpu_pod_space_end_to_end():
    """DESIGN.md §2 adaptation: MISO scheduling over TPU pod sub-slices."""
    space = tpu_pod_space()
    pm = PerfModel(space, TPU_V5E_POD)
    jobs = generate_trace(25, lam_s=40.0, seed=3, max_duration_s=1200)
    cfg = SimConfig(n_gpus=2, policy="miso")          # 2 pods
    m = simulate(jobs, cfg, space, pm, OracleEstimator(pm))
    n = simulate(jobs, SimConfig(n_gpus=2, policy="nopart"), space, pm,
                 OracleEstimator(pm))
    assert len(m.jcts) == len(jobs)
    assert m.avg_jct <= n.avg_jct
