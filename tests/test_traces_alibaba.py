"""Alibaba trace loader: row accounting, QoS mapping, window determinism,
oversize handling and the synthetic twin's distributions."""
import pytest

from repro.core import traces_alibaba as ta
from repro.core.partitions import a100_mig_space

SPACE = a100_mig_space()

HEADER = ("job_name,task_name,inst_num,status,start_time,end_time,"
          "plan_cpu,plan_mem,plan_gpu,gpu_type\n")


def _write(tmp_path, rows, header=HEADER):
    p = tmp_path / "trace.csv"
    p.write_text(header + "".join(r + "\n" for r in rows))
    return str(p)


def test_sample_csv_loads_and_accounts(tmp_path):
    stats = ta.TraceStats()
    jobs = ta.load_alibaba_trace(stats_out=stats)
    assert jobs, "committed sample must yield jobs"
    assert stats.rows_total == (stats.rows_used + stats.rows_malformed
                                + stats.rows_zero_duration
                                + stats.rows_no_gpu)
    assert jobs[0].arrival == 0.0                      # normalized to t=0
    assert all(j.work >= ta._MIN_WORK_S for j in jobs)
    assert all(j.qos_min_slice in (0,) + SPACE.sizes for j in jobs)


def test_malformed_short_and_unparseable_rows_counted(tmp_path):
    path = _write(tmp_path, [
        "a,worker,1,Terminated,0,100,600,29,50,V100",
        "b,worker,1,Terminated",                    # short row
        "c,worker,1,Terminated,zero,100,600,29,50,V100",  # bad number
        "d,worker,1,Terminated,10,110,600,29,25,V100",
    ])
    stats = ta.TraceStats()
    jobs = ta.load_alibaba_trace(path, stats_out=stats)
    assert stats.rows_malformed == 2
    assert stats.rows_used == 2
    assert len(jobs) == 2


def test_strict_raises_with_line_number(tmp_path):
    path = _write(tmp_path, [
        "a,worker,1,Terminated,0,100,600,29,50,V100",
        "b,worker,1,Terminated",
    ])
    with pytest.raises(ValueError, match=r"trace\.csv:3: malformed"):
        ta.load_alibaba_trace(path, strict=True)


def test_zero_duration_and_cpu_only_rows_dropped(tmp_path):
    path = _write(tmp_path, [
        "a,worker,1,Terminated,100,100,600,29,50,V100",   # end == start
        "b,worker,1,Failed,100,90,600,29,50,V100",        # end < start
        "c,worker,1,Terminated,0,50,600,29,0,CPU",        # no GPU
        "d,worker,1,Terminated,0,50,600,29,,CPU",         # blank plan_gpu
        "e,worker,1,Terminated,0,100,600,29,100,V100",
    ])
    stats = ta.TraceStats()
    jobs = ta.load_alibaba_trace(path, stats_out=stats)
    assert stats.rows_zero_duration == 2
    assert stats.rows_no_gpu == 2
    assert len(jobs) == 1 and jobs[0].work == pytest.approx(100.0)


def test_out_of_order_submissions_sorted_and_rebased(tmp_path):
    path = _write(tmp_path, [
        "late,worker,1,Terminated,500,600,600,29,50,V100",
        "early,worker,1,Terminated,100,400,600,29,50,V100",
        "mid,worker,1,Terminated,300,350,600,29,50,V100",
    ])
    stats = ta.TraceStats()
    jobs = ta.load_alibaba_trace(path, stats_out=stats)
    arrivals = [j.arrival for j in jobs]
    assert arrivals == sorted(arrivals)
    assert arrivals[0] == 0.0 and stats.t0 == 100.0
    assert arrivals == [0.0, 200.0, 400.0]


def test_oversize_clamps_by_default_and_rejects_on_error(tmp_path):
    path = _write(tmp_path, [
        "big,worker,1,Terminated,0,100,600,29,200,V100",  # 2 GPUs
    ])
    stats = ta.TraceStats()
    jobs = ta.load_alibaba_trace(path, stats_out=stats)
    assert stats.rows_clamped == 1
    # work = duration * min(share, 1): clamped to one full GPU
    assert jobs[0].work == pytest.approx(100.0)
    assert jobs[0].qos_min_slice == SPACE.full_size
    with pytest.raises(ValueError, match="plan_gpu=200%"):
        ta.load_alibaba_trace(path, oversize="error")
    with pytest.raises(ValueError, match="oversize"):
        ta.load_alibaba_trace(path, oversize="maybe")


def test_qos_mapping_share_and_task_floor(tmp_path):
    path = _write(tmp_path, [
        "tiny,worker,1,Terminated,0,100,600,29,10,V100",
        "half,worker,1,Terminated,1,100,600,29,50,V100",
        "coord,chief,1,Terminated,2,100,600,29,10,V100",
        "param,ps,1,Terminated,3,100,600,29,10,V100",
    ])
    jobs = ta.load_alibaba_trace(path)
    tiny, half, coord, param = jobs
    assert tiny.qos_min_slice == min(SPACE.sizes)
    # 50% share -> smallest slice with compute_frac >= 0.5
    assert SPACE.compute_frac(half.qos_min_slice) >= 0.5
    # chief floor lifts a tiny request to a 2-slice
    assert coord.qos_min_slice >= ta.TASK_QOS_FLOOR["chief"]
    assert param.qos_min_slice >= ta.TASK_QOS_FLOOR["ps"]


def test_window_slicing_is_deterministic_and_rebased(tmp_path):
    path = _write(tmp_path, [
        f"j{i},worker,1,Terminated,{i * 100},{i * 100 + 50},600,29,50,V100"
        for i in range(10)
    ])
    full = ta.load_alibaba_trace(path)
    win = ta.load_alibaba_trace(path, t_start=200.0, t_end=600.0)
    win2 = ta.load_alibaba_trace(path, t_start=200.0, t_end=600.0)
    key = lambda js: [(j.jid, j.arrival, j.work, j.profile.name) for j in js]
    assert key(win) == key(win2)                       # deterministic
    assert len(win) == 4                               # t in {200,300,400,500}
    assert win[0].arrival == 0.0                       # re-based to window
    assert len(full) == 10
    lim = ta.load_alibaba_trace(path, limit_jobs=3)
    assert key(lim) == key(full[:3])


def test_multi_instance_expansion_capped_and_grouped(tmp_path):
    path = _write(tmp_path, [
        "grp,worker,100,Terminated,0,100,600,29,50,V100",
    ])
    jobs = ta.load_alibaba_trace(path)
    assert len(jobs) == ta._INSTANCE_CAP               # 100 workers capped
    groups = {j.mi_group for j in jobs}
    assert groups == {jobs[0].jid}                     # one shared group


def test_profile_assignment_is_stable_across_loads(tmp_path):
    path = _write(tmp_path, [
        f"job-{i},worker,1,Terminated,{i},{i + 100},600,29,50,V100"
        for i in range(8)
    ])
    a = [j.profile.name for j in ta.load_alibaba_trace(path)]
    b = [j.profile.name for j in ta.load_alibaba_trace(path)]
    assert a == b                                      # sha-hash, not hash()
    assert len(set(a)) > 1                             # pool actually used


def test_synthesize_matches_sample_support_and_scales_load():
    jobs = ta.synthesize_alibaba_trace(300, seed=3)
    assert len(jobs) >= 300                            # mi-expansion only adds
    assert jobs[0].arrival == 0.0
    key = lambda js: [(j.jid, j.arrival, j.work) for j in js]
    assert key(jobs) == key(ta.synthesize_alibaba_trace(300, seed=3))
    assert key(jobs) != key(ta.synthesize_alibaba_trace(300, seed=4))
    base_rows, _ = ta.parse_alibaba_csv(ta.SAMPLE_CSV)
    qos_support = {ta._qos_for(SPACE, min(r.gpu_share, 1.0), r.task_name)
                   for r in base_rows}
    assert {j.qos_min_slice for j in jobs} <= qos_support
    fast = ta.synthesize_alibaba_trace(300, seed=3, load_scale=4.0)
    span = lambda js: max(j.arrival for j in js)
    assert span(fast) == pytest.approx(span(jobs) / 4.0)
    with pytest.raises(ValueError, match="load_scale"):
        ta.synthesize_alibaba_trace(10, load_scale=0.0)
    assert ta.synthesize_alibaba_trace(0) == []
