"""Property tests for the struct-of-arrays fleet settle.

The contract under test (soa.py's "masked-update contract"): for any fleet
state — arbitrary accounting clocks, repair deadlines, phases, resident
mixes, checkpoint marks — ``FleetState.settle_all(t)`` leaves every GPU and
every resident job in exactly (bit-for-bit) the state the scalar oracle
``settle_scalar`` (per-GPU ``GPU.advance`` in gid order) produces, and
issues the same work-aggregate shifts in the same order.

Fleets are built twice from one parameter set instead of deep-copied, so
both sides start from independently-constructed but bit-identical state.
The randomized check runs under hypothesis when the environment has it
(the container image ships without it) and always under a seeded
numpy fallback sweep, so the property is exercised in CI either way.
"""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.estimators import OracleEstimator
from repro.core.fleet import homogeneous_fleet
from repro.core.jobs import WORKLOADS, Job
from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.sim.gpu import CKPT, GPU, IDLE, MIG_RUN, MPS_PROF
from repro.core.sim.soa import FleetState, settle_scalar

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # container image ships without it
    HAVE_HYPOTHESIS = False

SPACE = a100_mig_space()
PM = PerfModel(SPACE)
SPEC = homogeneous_fleet(SPACE, PM, OracleEstimator(PM), 1)[0]
PROFILE = WORKLOADS[0]
PHASES = (IDLE, CKPT, MPS_PROF, MIG_RUN)
SLICES = (0,) + tuple(SPACE.sizes)


class _ShiftLog:
    """Stands in for the engine's Kahan WorkAggregate: records the shifts
    ``GPU.advance`` issues so both settle paths can be compared on them."""

    def __init__(self):
        self.shifts = []

    def shift(self, d):
        self.shifts.append(d)


def make_fleet(params, interval):
    """Deterministically build a fleet from plain-value parameters; calling
    twice with the same params yields bit-identical independent fleets."""
    sim = SimpleNamespace(cfg=SimpleNamespace(ckpt_interval_s=interval),
                          work_agg=_ShiftLog())
    gpus = []
    for gid, p in enumerate(params):
        g = GPU(gid, sim, SPEC)
        g.last_update = p["last_update"]
        g.down_until = p["down_until"]
        g.energy_j = p["energy"]
        g.phase = PHASES[p["phase"]]
        for k, r in enumerate(p["residents"]):
            job = Job(jid=gid * 8 + k, profile=PROFILE, arrival=0.0,
                      work=max(r["remaining"], 1.0))
            job.remaining = r["remaining"]
            rj = g._add_resident(job)
            rj.slice_size = SLICES[r["slice"]]
            g._spd[k] = r["speed"]
            g._ckt[k] = r["since_t"]
            g._ckw[k] = r["since_w"]
        if p.get("clean_watts"):
            # simulate a refresh_speeds + advance having memoized the wall
            # watts: a clean identity chain is what makes an occupied row
            # eligible for the vectorized settle path
            g._spd_key = object()
            g._w_key = g._spd_key
            g._w_val = p.get("wall_w", 275.0)
        gpus.append(g)
    return gpus, sim


def fleet_state(gpus):
    """Bit-exact snapshot: float repr round-trips exactly (and tells -0.0
    from 0.0), so tuple equality here IS bitwise state equality."""
    out = []
    for g in gpus:
        out.append((
            repr(g.last_update), repr(g.energy_j), repr(g.down_until),
            [repr(x) for x in g._spd],
            [repr(x) for x in g._ckt],
            [repr(x) for x in g._ckw],
            [(rj.job.jid, rj.slice_size, repr(rj.job.remaining),
              repr(rj.job.t_run), repr(rj.job.t_mps), repr(rj.job.t_ckpt),
              repr(rj.job.t_queue)) for rj in g._rjobs],
        ))
    return out


def check_settle_matches(params, t, interval, free_min=1, occ_min=1):
    """Bit-identity of the thresholded settle against the scalar oracle.
    Defaults force the masked vector path wherever a row is eligible (the
    shipped module defaults are None = always-scalar, which would make the
    property vacuous); explicit thresholds exercise the gating itself."""
    vec_gpus, vec_sim = make_fleet(params, interval)
    ref_gpus, ref_sim = make_fleet(params, interval)
    assert fleet_state(vec_gpus) == fleet_state(ref_gpus)  # build is stable
    FleetState(vec_gpus).settle_all(t, free_min=free_min, occ_min=occ_min)
    settle_scalar(ref_gpus, t)
    assert fleet_state(vec_gpus) == fleet_state(ref_gpus)
    assert ([repr(s) for s in vec_sim.work_agg.shifts]
            == [repr(s) for s in ref_sim.work_agg.shifts])


def random_params(rng, n=None, occupied_p=0.4, clean_p=0.5):
    """One fleet parameter set; mixes free/occupied GPUs, live/dead/
    straddling repair windows, all four phases, and clean/dirty wall-watts
    memos (a clean memo on a progressing occupied GPU is what routes it
    onto the vectorized settle path)."""
    if n is None:
        n = int(rng.integers(1, 41))
    params = []
    for _ in range(n):
        occupied = rng.random() < occupied_p
        residents = []
        if occupied:
            for _ in range(int(rng.integers(1, 5))):
                residents.append({
                    "speed": float(rng.uniform(0.0, 2.0)),
                    "remaining": float(rng.uniform(0.0, 500.0)),
                    "since_t": float(rng.uniform(0.0, 150.0)),
                    "since_w": float(rng.uniform(0.0, 150.0)),
                    "slice": int(rng.integers(0, len(SLICES))),
                })
        params.append({
            "last_update": float(rng.uniform(0.0, 1000.0)),
            # 0.0 = never repaired; otherwise the deadline can fall before,
            # inside, or after the settle window
            "down_until": (0.0 if rng.random() < 0.5
                           else float(rng.uniform(0.0, 2000.0))),
            "energy": float(rng.uniform(0.0, 1e7)),
            "phase": int(rng.integers(0, len(PHASES))),
            "residents": residents,
            "clean_watts": bool(occupied and rng.random() < clean_p),
            "wall_w": float(rng.uniform(60.0, 500.0)),
        })
    return params


@pytest.mark.parametrize("seed", range(30))
def test_settle_all_matches_scalar_seeded(seed):
    """Seeded randomized sweep — the always-on property check (hypothesis
    is not in the container image).  Each fleet runs under three threshold
    regimes: vector forced everywhere, mid thresholds (so free/occupied
    classes cross their gates from both sides), and the shipped all-scalar
    defaults (trivially identical — guards the gate wiring)."""
    rng = np.random.default_rng(0xA15E + seed)
    params = random_params(rng)
    t = float(rng.uniform(0.0, 1500.0))          # sometimes before clocks
    interval = float(rng.choice([0.0, 45.0, 300.0]))
    check_settle_matches(params, t, interval, free_min=1, occ_min=1)
    check_settle_matches(params, t, interval, free_min=4, occ_min=8)
    check_settle_matches(params, t, interval,
                         free_min=None, occ_min=None)


@pytest.mark.parametrize("seed", range(15))
def test_settle_all_matches_scalar_occupied_vector(seed):
    """Dense occupied fleets with mostly-clean watts memos: the
    (rows, slots) matrix path — progress drain, repeated-subtraction
    checkpoint boundaries, gid-ordered Kahan shifts — is exercised against
    the scalar oracle, not just the free-row path."""
    rng = np.random.default_rng(0x0CC0 + seed)
    n = int(rng.integers(4, 65))
    params = random_params(rng, n=n, occupied_p=0.85, clean_p=0.85)
    t = float(rng.uniform(0.0, 1500.0))
    interval = float(rng.choice([0.0, 45.0, 300.0]))
    check_settle_matches(params, t, interval)


def test_settle_all_matches_scalar_edges():
    """Hand-picked boundaries: dt == 0, whole window dead, repair ending
    exactly at t, empty fleet — all eight rows on the forced free-row
    vector path."""
    base = {"energy": 100.0, "phase": 3, "residents": []}
    params = [
        dict(base, last_update=50.0, down_until=0.0),     # plain live
        dict(base, last_update=50.0, down_until=200.0),   # dead past t
        dict(base, last_update=50.0, down_until=100.0),   # ends exactly at t
        dict(base, last_update=100.0, down_until=0.0),    # dt == 0
        dict(base, last_update=150.0, down_until=0.0),    # clock ahead of t
        dict(base, last_update=0.0, down_until=60.0),     # straddling repair
        dict(base, last_update=50.0, down_until=50.0),    # boundary equality
        dict(base, last_update=0.0, down_until=0.0),      # from epoch
    ]
    check_settle_matches(params, 100.0, 0.0)
    check_settle_matches([], 100.0, 0.0)


def test_settle_all_matches_scalar_occupied_edges():
    """Hand-picked occupied-row boundaries at an explicit 16-row gate:
    checkpoint boundary landing exactly on the interval, many boundaries
    inside one window, zero-speed residents, a dead-then-live straddle,
    and mixed-in ineligible rows (dirty memo, CKPT phase, dt == 0) that
    must stay on the scalar path."""
    run = {"speed": 1.25, "remaining": 400.0, "since_t": 10.0,
           "since_w": 12.5, "slice": 1}
    eligible = {
        "last_update": 50.0, "down_until": 0.0, "energy": 100.0,
        "phase": 3, "clean_watts": True, "wall_w": 300.0,
        "residents": [dict(run), dict(run, speed=0.0),
                      dict(run, since_t=149.0)],
    }
    params = [dict(eligible) for _ in range(16)]
    # exactly-on-the-boundary since_t: 149 + dt(=250) crosses at 45*k
    params[0] = dict(eligible, residents=[dict(run, since_t=35.0)])
    # repair straddle: dead from 100 to 180, still progresses (scalar
    # advance charges progress over the whole dt — the contract to match)
    params[1] = dict(eligible, down_until=180.0, last_update=100.0)
    # ineligible rows interleaved: dirty memo / CKPT phase / clock at t
    params.append(dict(eligible, clean_watts=False))
    params.append(dict(eligible, phase=1))
    params.append(dict(eligible, last_update=300.0))
    check_settle_matches(params, 300.0, 45.0, occ_min=16)
    # one row short of the 16-row gate: everything scalar, still identical
    check_settle_matches(params[:15], 300.0, 45.0, occ_min=16)


if HAVE_HYPOTHESIS:
    finite = {"allow_nan": False, "allow_infinity": False}

    resident_st = st.fixed_dictionaries({
        "speed": st.floats(0.0, 2.0, **finite),
        "remaining": st.floats(0.0, 500.0, **finite),
        "since_t": st.floats(0.0, 150.0, **finite),
        "since_w": st.floats(0.0, 150.0, **finite),
        "slice": st.integers(0, len(SLICES) - 1),
    })
    gpu_st = st.fixed_dictionaries({
        "last_update": st.floats(0.0, 1000.0, **finite),
        "down_until": st.floats(0.0, 2000.0, **finite),
        "energy": st.floats(0.0, 1e7, **finite),
        "phase": st.integers(0, len(PHASES) - 1),
        "residents": st.lists(resident_st, max_size=4),
    })

    @settings(max_examples=60, deadline=None)
    @given(params=st.lists(gpu_st, max_size=40),
           t=st.floats(0.0, 1500.0, **finite),
           interval=st.sampled_from([0.0, 45.0, 300.0]))
    def test_settle_all_matches_scalar_hypothesis(params, t, interval):
        check_settle_matches(params, t, interval)


# ----------------------------------------------------- resident_matrix view

def test_resident_matrix_export():
    """The (G, S) export mirrors the columns exactly and never aliases
    simulation state (mutating the export must not touch the fleet)."""
    rng = np.random.default_rng(7)
    params = random_params(rng, n=12)
    gpus, _ = make_fleet(params, 0.0)
    fs = FleetState(gpus)
    mat = fs.resident_matrix()
    widest = max((len(g._rjobs) for g in gpus), default=0)
    assert mat["speed"].shape == (12, max(widest, 1))
    for i, g in enumerate(gpus):
        k = len(g._rjobs)
        assert mat["mask"][i, :k].all() and not mat["mask"][i, k:].any()
        assert mat["speed"][i, :k].tolist() == g._spd
        assert mat["since_ckpt_t"][i, :k].tolist() == g._ckt
        assert mat["since_ckpt_work"][i, :k].tolist() == g._ckw
        assert (mat["remaining"][i, :k].tolist()
                == [rj.job.remaining for rj in g._rjobs])
    before = fleet_state(gpus)
    mat["speed"][:] = -1.0
    mat["mask"][:] = False
    assert fleet_state(gpus) == before
