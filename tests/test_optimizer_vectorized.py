"""Vectorized Algorithm-1 kernel: bit-identity with the scalar reference.

The batched numpy DP must reproduce the historical per-partition dict DP
(``_assign_dp``) *exactly* — objective bits, chosen multiset, job->slice
permutation and feasibility flag, tie-breaks included — across all three
partition spaces.  These tests are seeded-random (no hypothesis dependency);
``test_optimizer.py`` carries the hypothesis property-test variant.
"""
import random

import numpy as np
import pytest

from repro.core.optimizer import (_assign_dp, assign_multisets, clear_memo,
                                  memo_stats, optimize_partition,
                                  optimize_partition_batch,
                                  optimize_partition_bruteforce,
                                  solve_all_partitions)
from repro.core.partitions import (a100_mig_space, h100_mig_space,
                                   tpu_pod_space)

SPACES = {
    "a100": a100_mig_space(),
    "h100": h100_mig_space(),
    "tpu": tpu_pod_space(),
}


def random_speeds(rng, space, m):
    """Speed dicts with zeros, missing keys and exact duplicates (clone
    jobs) — the tie-heavy cases where replication could break."""
    out = []
    for _ in range(m):
        sv = {}
        for s in space.sizes:
            r = rng.random()
            if r < 0.15:
                sv[s] = 0.0
            elif r < 0.25:
                pass                      # missing key == 0.0
            else:
                sv[s] = rng.uniform(0.05, 1.0)
        if rng.random() < 0.15 and out:
            sv = dict(out[-1])            # identical clone job
        out.append(sv)
    return out


def reference_scan(space, speeds, require_feasible):
    """The pre-vectorization optimize_partition: dict DP per multiset,
    first-strict-max scan in partition order."""
    m = len(speeds)
    best = None
    for part in space.partitions_of_len(m):
        obj, perm = _assign_dp(part, speeds)
        feasible = all(speeds[j].get(perm[j], 0.0) > 0.0 for j in range(m))
        if require_feasible and not feasible:
            continue
        if best is None or obj > best[0]:
            best = (obj, perm, feasible)
    return best


@pytest.mark.parametrize("space_name", sorted(SPACES))
def test_vectorized_equals_scalar_reference(space_name):
    space = SPACES[space_name]
    rng = random.Random(hash(space_name) & 0xFFFF)
    for trial in range(300):
        m = rng.randint(1, space.max_jobs)
        speeds = random_speeds(rng, space, m)
        for rf in (False, True):
            ref = reference_scan(space, speeds, rf)
            got = optimize_partition(space, speeds, require_feasible=rf,
                                     memo=False)
            if ref is None:
                assert got is None
            else:
                assert (got.objective, got.partition, got.feasible) == ref


@pytest.mark.parametrize("space_name", sorted(SPACES))
def test_solve_all_partitions_rows_match_dict_dp(space_name):
    space = SPACES[space_name]
    rng = random.Random(99)
    for trial in range(60):
        m = rng.randint(2, space.max_jobs)
        speeds = random_speeds(rng, space, m)
        objs, perms, feas = solve_all_partitions(space, speeds)
        for i, part in enumerate(space.partitions_of_len(m)):
            obj, perm = _assign_dp(part, speeds)
            fe = all(speeds[j].get(perm[j], 0.0) > 0.0 for j in range(m))
            assert objs[i] == obj
            assert tuple(int(x) for x in perms[i]) == perm
            assert bool(feas[i]) == fe


@pytest.mark.parametrize("space_name", sorted(SPACES))
def test_vectorized_equals_bruteforce(space_name):
    """The literal Algorithm-1 oracle agrees on objective and validity."""
    space = SPACES[space_name]
    rng = random.Random(7)
    for trial in range(40):
        m = rng.randint(1, min(5, space.max_jobs))   # m! enumeration cost
        speeds = random_speeds(rng, space, m)
        a = optimize_partition(space, speeds, memo=False)
        b = optimize_partition_bruteforce(space, speeds)
        assert a is not None and b is not None
        assert abs(a.objective - b.objective) < 1e-9
        assert space.is_valid(a.partition)


def test_batch_equals_singles_mixed_lengths():
    rng = random.Random(11)
    for space in SPACES.values():
        for rf in (False, True):
            mixes = [random_speeds(rng, space, rng.randint(1, space.max_jobs))
                     for _ in range(40)]
            got = optimize_partition_batch(space, mixes, require_feasible=rf,
                                           memo=False)
            for i, sp in enumerate(mixes):
                assert got[i] == optimize_partition(space, sp,
                                                    require_feasible=rf,
                                                    memo=False)


def test_batch_fills_and_reads_memo_like_singles():
    space = SPACES["a100"]
    rng = random.Random(13)
    mixes = [random_speeds(rng, space, 4) for _ in range(6)]
    clear_memo()
    a = optimize_partition_batch(space, mixes + mixes)    # second half hits
    assert memo_stats()["hits"] == 6 and memo_stats()["misses"] == 6
    b = [optimize_partition(space, sp) for sp in mixes]
    assert a[:6] == b and a[6:] == b


def test_assign_multisets_matches_dict_dp():
    import itertools
    space = SPACES["a100"]
    rng = random.Random(17)
    for _ in range(50):
        part = space.partitions[rng.randrange(len(space.partitions))]
        k = rng.randint(1, len(part))
        subs = list(set(itertools.combinations(part, k)))
        speeds = random_speeds(rng, space, k)
        objs, perms, feas = assign_multisets(space, subs, speeds)
        for i, sub in enumerate(subs):
            obj, perm = _assign_dp(sub, speeds)
            fe = all(speeds[j].get(perm[j], 0.0) > 0.0 for j in range(k))
            assert objs[i] == obj
            assert tuple(int(x) for x in perms[i]) == perm
            assert bool(feas[i]) == fe


def test_all_zero_speeds_still_agree():
    for space in SPACES.values():
        for m in (1, 2, 3):
            speeds = [{s: 0.0 for s in space.sizes}] * m
            a = optimize_partition(space, speeds, memo=False)
            b = optimize_partition_bruteforce(space, speeds)
            assert a.objective == b.objective == 0.0
            assert not a.feasible and not b.feasible
            assert space.is_valid(a.partition) and space.is_valid(b.partition)
