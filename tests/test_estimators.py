"""Estimator regression tests: the profiling-noise RNG must advance across
measurement windows (the old ``default_rng(0)``-per-call bug froze it), an
external RNG must thread through reproducibly, and oversized job mixes must
fail loudly instead of building a wrong-shaped matrix."""
import numpy as np
import pytest

from repro.core.estimators import UNetEstimator
from repro.core.jobs import WORKLOADS
from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel

PM = PerfModel(a100_mig_space())


@pytest.fixture(scope="module")
def unet_est():
    jax = pytest.importorskip("jax")
    from repro.core.predictor import unet
    net = unet.UNet.create(jax.random.PRNGKey(0))
    # heads are unused by measure_mps; estimate() is exercised elsewhere
    return UNetEstimator(PM, net.params, heads=None)


def test_noise_differs_across_windows(unet_est):
    """The old bug: re-seeding to 0 per call made every profiling window's
    'measurement noise' identical, degenerating Fig 14 sensitivity."""
    profs = list(WORKLOADS[:3])
    m1 = unet_est.measure_mps(profs, noise_sigma=0.05)
    m2 = unet_est.measure_mps(profs, noise_sigma=0.05)
    assert m1.shape == m2.shape
    assert not np.allclose(m1, m2)


def test_external_rng_threads_through(unet_est):
    profs = list(WORKLOADS[:2])
    a = unet_est.measure_mps(profs, noise_sigma=0.05,
                             rng=np.random.default_rng(7))
    b = unet_est.measure_mps(profs, noise_sigma=0.05,
                             rng=np.random.default_rng(7))
    assert np.allclose(a, b)                 # same stream -> reproducible


def test_noiseless_measurement_is_deterministic(unet_est):
    profs = list(WORKLOADS[:2])
    a = unet_est.measure_mps(profs)
    b = unet_est.measure_mps(profs)
    assert np.allclose(a, b)


def test_oversized_mix_raises(unet_est):
    with pytest.raises(ValueError, match="at most 7"):
        unet_est.measure_mps(list(WORKLOADS[:8]))
