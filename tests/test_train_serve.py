"""Training-step semantics (microbatch accumulation, grad clip) and the
serving engine (generation correctness, int8 weight-only quantization)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import LM
from repro.serve.engine import ServeEngine, dequantize_params, quantize_params
from repro.train.optim import adamw_init
from repro.train.train_step import make_train_step


def test_microbatch_equals_full_batch(run32, key):
    """Grad accumulation over 4 microbatches == single big batch (same data,
    mean-of-means holds because microbatches are equal-sized)."""
    cfg = configs.get_smoke_config("granite-8b")
    params, _ = LM.init(cfg, run32, key)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                cfg.vocab_size)
    labels = jnp.roll(tokens, -1, 1)

    run_mb = dataclasses.replace(run32, microbatches=4)
    p1, o1, m1 = jax.jit(make_train_step(cfg, run32))(
        params, adamw_init(params), tokens, labels)
    p2, o2, m2 = jax.jit(make_train_step(cfg, run_mb))(
        params, adamw_init(params), tokens, labels)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-4
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        assert float(jnp.max(jnp.abs(a - b))) < 5e-4


def test_loss_decreases_over_steps(run32, key):
    from repro.data.pipeline import SyntheticLMData
    cfg = configs.get_smoke_config("smollm-360m")
    params, _ = LM.init(cfg, run32, key)
    opt = adamw_init(params)
    run = dataclasses.replace(run32, learning_rate=1e-2)
    step = jax.jit(make_train_step(cfg, run))
    data = SyntheticLMData(cfg.vocab_size, 32, 8, seed=0)
    losses = []
    for s in range(30):
        t, l = data.batch_at(s)
        params, opt, m = step(params, opt, jnp.asarray(t), jnp.asarray(l))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_grad_clip_caps_update(run32, key):
    cfg = configs.get_smoke_config("smollm-360m")
    params, _ = LM.init(cfg, run32, key)
    run = dataclasses.replace(run32, grad_clip=1e-9)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                cfg.vocab_size)
    p2, _, m = jax.jit(make_train_step(cfg, run))(
        params, adamw_init(params), tokens, jnp.roll(tokens, -1, 1))
    assert bool(jnp.isfinite(m["grad_norm"]))


# ----------------------------------------------------------------- serving

def test_generate_matches_stepwise_argmax(run32, key):
    cfg = configs.get_smoke_config("qwen3-32b")
    params, _ = LM.init(cfg, run32, key)
    eng = ServeEngine(cfg, run32, params, max_seq=64)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (3, 9), 0,
                                 cfg.vocab_size)
    out = eng.generate(prompts, max_new_tokens=6)
    assert out.shape == (3, 15)
    # reference: greedy decode via repeated full forward
    toks = prompts
    for _ in range(6):
        logits = LM.logits(params, cfg, run32, toks)
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks = jnp.concatenate([toks, nxt], 1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(toks))


def test_quantize_roundtrip_small_error(run32, key):
    cfg = configs.get_smoke_config("granite-8b")
    params, _ = LM.init(cfg, run32, key)
    deq = dequantize_params(quantize_params(params), jnp.float32)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(deq)):
        if a.ndim >= 2 and a.size >= 4096:
            rel = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
            assert rel < 0.02


def test_quantized_serving_close(run32, key):
    cfg = configs.get_smoke_config("granite-8b")
    params, _ = LM.init(cfg, run32, key)
    run_q = dataclasses.replace(run32, quantize_serving=True)
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0,
                                 cfg.vocab_size)
    e1 = ServeEngine(cfg, run32, params, max_seq=32)
    e2 = ServeEngine(cfg, run_q, params, max_seq=32)
    o1 = e1.generate(prompts, max_new_tokens=4)
    o2 = e2.generate(prompts, max_new_tokens=4)
    # int8 weight-only: generations may differ on ties, but mostly agree
    agree = float((np.asarray(o1) == np.asarray(o2)).mean())
    assert agree > 0.7
