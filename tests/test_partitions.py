"""Partition-space invariants (paper Table 1 / appendix semantics)."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.partitions import a100_mig_space, tpu_pod_space

SPACE = a100_mig_space()
TPU = tpu_pod_space()


def test_table1_profiles():
    assert SPACE.slices[7].memory_gb == 40.0
    assert SPACE.slices[4].memory_gb == 20.0
    assert SPACE.slices[3].memory_gb == 20.0     # the 3g/4-memory-slot quirk
    assert SPACE.slices[2].memory_gb == 10.0
    assert SPACE.slices[1].memory_gb == 5.0
    assert SPACE.slices[3].mem_slots == 4
    assert SPACE.max_jobs == 7


def test_paper_exclusion_4g_3g():
    assert not SPACE.is_valid((4, 3))
    assert SPACE.is_valid((4, 2, 1))
    assert SPACE.is_valid((3, 3))
    assert SPACE.is_valid((2, 2, 3))
    assert SPACE.is_valid((7,))


def test_full_gpu_configs_present():
    """All of the paper's named configurations must be enumerated."""
    for p in [(7,), (4, 2, 1), (3, 3), (3, 2, 2), (4, 1, 1, 1),
              (1, 1, 1, 1, 1, 1, 1)]:
        assert SPACE.is_valid(p), p


def test_maximal_partitions_cannot_extend():
    for p in SPACE.maximal_partitions:
        compute = sum(SPACE.slices[s].compute_slots for s in p)
        mem = sum(SPACE.slices[s].mem_slots for s in p)
        for size, sl in SPACE.slices.items():
            extended = tuple(sorted(list(p) + [size], reverse=True))
            if (compute + sl.compute_slots <= 7 and mem + sl.mem_slots <= 8
                    and list(p).count(size) < sl.max_count
                    and not (4 in extended and 3 in extended)):
                pytest.fail(f"{p} can be extended by {size}g")


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from([1, 2, 3, 4, 7]), min_size=1, max_size=8))
def test_validity_is_arithmetic(sizes):
    """is_valid <=> compute/mem/caps/exclusion constraints hold."""
    p = tuple(sorted(sizes, reverse=True))
    compute = sum(SPACE.slices[s].compute_slots for s in p)
    mem = sum(SPACE.slices[s].mem_slots for s in p)
    caps_ok = all(p.count(s) <= SPACE.slices[s].max_count for s in set(p))
    excl_ok = not (4 in p and 3 in p)
    expected = compute <= 7 and mem <= 8 and caps_ok and excl_ok
    assert SPACE.is_valid(p) == expected


def test_partitions_of_len_cover_scheduling():
    """Eq.4: for every m <= 7 there must be at least one valid partition."""
    for m in range(1, 8):
        assert len(SPACE.partitions_of_len(m)) >= 1


def test_tpu_space_shapes():
    assert TPU.max_jobs == 8
    assert TPU.is_valid((4, 4))
    assert TPU.is_valid((4, 3, 1))       # no MIG exclusion on TPU
    full = TPU.slices[TPU.full_size]
    assert full.chips == 256 and full.mesh_shape == (16, 16)
    assert TPU.slices[1].chips == 32 and TPU.slices[1].mesh_shape == (2, 16)
