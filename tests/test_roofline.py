"""Roofline machinery: loop-aware HLO walk (flops under scan), analytic cost
model invariants, and dry-run artifact well-formedness."""
import glob
import json
import os

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro import configs
from repro.roofline.costs import model_flops, step_costs
from repro.roofline.hlo_analysis import analyze_hlo

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def test_scan_trip_count_multiplied():
    def scanned(x):
        def body(c, _):
            return c @ c, None
        y, _ = lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(scanned).lower(x).compile().as_text()
    r = analyze_hlo(txt)
    expect = 10 * 2 * 64 ** 3
    assert abs(r["flops"] - expect) / expect < 0.01


def test_single_dot_flops():
    def mm(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    txt = jax.jit(mm).lower(a, b).compile().as_text()
    r = analyze_hlo(txt)
    assert abs(r["flops"] - 2 * 128 * 256 * 64) / (2 * 128 * 256 * 64) < 0.01


def test_nested_scan_multiplies():
    def nested(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            d, _ = lax.scan(inner, c, None, length=3)
            return d, None
        y, _ = lax.scan(outer, x, None, length=5)
        return y
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    txt = jax.jit(nested).lower(x).compile().as_text()
    r = analyze_hlo(txt)
    expect = 15 * 2 * 32 ** 3
    assert abs(r["flops"] - expect) / expect < 0.01


# ------------------------------------------------------------ cost model

def test_model_flops_scaling():
    cfg = configs.get_config("granite-8b")
    f1 = model_flops(cfg, 4096, 256, "train")
    f2 = model_flops(cfg, 4096, 512, "train")
    assert abs(f2 / f1 - 2.0) < 0.05
    # train ~= 3x prefill at same tokens
    ftrain = model_flops(cfg, 4096, 256, "train")
    fpre = model_flops(cfg, 4096, 256, "prefill")
    assert 2.5 < ftrain / fpre < 3.5


def test_decode_costs_weight_bound():
    cfg = configs.get_config("command-r-plus-104b")
    c = step_costs(cfg, 32768, 128, "decode")
    assert c.hbm_bytes > cfg.param_count() * 2 * 0.9  # reads all weights
    assert c.flops < model_flops(cfg, 4096, 256, "train") / 100


def test_window_reduces_attention_flops():
    full = configs.get_config("mixtral-8x22b").replace(sliding_window=None)
    swa = configs.get_config("mixtral-8x22b")
    f_full = model_flops(full, 32768, 32, "prefill")
    f_swa = model_flops(swa, 32768, 32, "prefill")
    assert f_swa < f_full


# --------------------------------------------------- dry-run artifacts

@pytest.mark.skipif(not glob.glob(os.path.join(ART, "*.json")),
                    reason="dry-run artifacts not generated")
def test_dryrun_artifacts_wellformed():
    recs = [json.load(open(p)) for p in glob.glob(os.path.join(ART, "*.json"))]
    ran = [r for r in recs if not r.get("skipped")]
    skipped = [r for r in recs if r.get("skipped")]
    assert len(ran) + len(skipped) == len(recs)
    for r in ran:
        assert r["roofline"]["dominant"] in ("compute", "memory", "collective")
        assert r["flops_per_device"] > 0
        assert r["compile_s"] > 0
    # every skip is a long_500k on a full-attention arch
    for r in skipped:
        assert r["shape"] == "long_500k"


@pytest.mark.skipif(not glob.glob(os.path.join(ART, "*__multipod.json")),
                    reason="multi-pod artifacts not generated")
def test_multipod_cells_present():
    pods = glob.glob(os.path.join(ART, "*__pod.json"))
    multis = glob.glob(os.path.join(ART, "*__multipod.json"))
    assert len(multis) == len(pods)
    for p in multis:
        r = json.load(open(p))
        if not r.get("skipped"):
            assert r["chips"] == 512
            assert r["mesh_axes"] == ["pod", "data", "model"]


@pytest.mark.slow
def test_dryrun_cell_fresh_compile():
    """Actually lower+compile one cell in a subprocess (512 fake devices)."""
    import subprocess, sys
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-360m",
         "--shape", "decode_32k"],
        capture_output=True, text=True, timeout=900,
        env={**env, "PYTHONPATH": "src"},
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "FAIL" not in out.stdout, out.stdout + out.stderr
    assert "decode_32k" in out.stdout
