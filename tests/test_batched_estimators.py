"""Fused estimator service: estimate_batch contract and the engine's
same-tick phase-end coalescing (batched runs must be bit-identical to
sequential processing, including estimator RNG draw order)."""
import jax
import numpy as np
import pytest

import repro.core.sim.engine as eng
from repro.core.estimators import (NoisyEstimator, OracleEstimator,
                                   UNetEstimator)
from repro.core.jobs import WORKLOADS
from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.predictor import linreg, unet
from repro.core.simulator import SimConfig, simulate
from repro.core.traces import generate_trace

SPACE = a100_mig_space()
PM = PerfModel(SPACE)


# ------------------------------------------------------------ estimate_batch


def _mixes(rng, n=5):
    out = []
    for _ in range(n):
        k = int(rng.integers(1, 7))
        profs = [WORKLOADS[int(i)]
                 for i in rng.integers(0, len(WORKLOADS), k)]
        out.append((profs, None, [0] * k))
    return out


def test_oracle_estimate_batch_equals_singles():
    est = OracleEstimator(PM)
    reqs = _mixes(np.random.default_rng(0))
    batched = est.estimate_batch(reqs)
    for (profs, mat, qos), got in zip(reqs, batched):
        assert got == est.estimate(profs, mat, qos=qos)


def test_noisy_estimate_batch_consumes_rng_in_request_order():
    reqs = _mixes(np.random.default_rng(1))
    a = NoisyEstimator(PM, 0.1, seed=3).estimate_batch(reqs)
    b_est = NoisyEstimator(PM, 0.1, seed=3)
    b = [b_est.estimate(profs, mat, qos=qos) for profs, mat, qos in reqs]
    assert a == b


@pytest.fixture(scope="module")
def unet_est():
    net = unet.UNet.create(jax.random.PRNGKey(0))
    X = np.random.default_rng(0).random((64, 3))
    Y = np.random.default_rng(1).random((64, 2))
    heads = linreg.fit_linreg(X, Y)
    return UNetEstimator(PM, net.params, heads)


def test_unet_estimate_batch_single_request_bit_identical(unet_est):
    profs = list(WORKLOADS[:4])
    mat = unet_est.measure_mps(profs)
    assert unet_est.estimate_batch([(profs, mat, [0] * 4)])[0] == \
        unet_est.estimate(profs, mat, qos=[0] * 4)


def test_unet_estimate_batch_matches_singles_allclose(unet_est):
    """A stacked (B, 3, J) forward equals per-request forwards up to XLA
    batch reassociation (float32 last-ulp; see estimators module doc)."""
    rng = np.random.default_rng(2)
    reqs = []
    for profs, _, qos in _mixes(rng, n=5):
        reqs.append((profs, unet_est.measure_mps(profs), qos))
    batched = unet_est.estimate_batch(reqs)
    for (profs, mat, qos), got in zip(reqs, batched):
        single = unet_est.estimate(profs, mat, qos=qos)
        assert len(got) == len(single)
        for a, b in zip(single, got):
            assert set(a) == set(b)
            for s in a:
                assert a[s] == pytest.approx(b[s], abs=1e-5)


def test_unet_batch_bucketing_pads_and_crops(unet_est):
    mats = np.stack([np.asarray(unet_est.measure_mps([p]), np.float32)
                     for p in WORKLOADS[:3]])
    out = np.asarray(unet_est.net(mats))     # B=3 -> bucket 4 -> cropped
    assert out.shape == (3, 3, 7)


# -------------------------------------------------- same-tick coalescing


def _run(policy, seed, coalesce, estimator=None, n_gpus=8):
    jobs = generate_trace(30, lam_s=2.0, seed=seed, max_duration_s=1800)
    cfg = SimConfig(n_gpus=n_gpus, policy=policy)
    est = estimator or OracleEstimator(PM)
    if coalesce:
        m = simulate(jobs, cfg, SPACE, PM, est)
    else:
        orig = eng.ClusterSim._drain_same_tick_timers
        eng.ClusterSim._drain_same_tick_timers = lambda self, t, g: None
        try:
            m = simulate(jobs, cfg, SPACE, PM, est)
        finally:
            eng.ClusterSim._drain_same_tick_timers = orig
    return (m.avg_jct, m.makespan, m.stp, tuple(m.jcts),
            tuple(sorted(m.breakdown.items())))


@pytest.mark.parametrize("policy", ["miso", "miso-frag", "srpt"])
def test_coalesced_phase_ends_bit_identical(policy):
    for seed in (0, 1):
        assert _run(policy, seed, True) == _run(policy, seed, False)


def test_coalesced_noisy_estimator_preserves_rng_stream():
    for seed in (0, 1):
        a = _run("miso", seed, True, NoisyEstimator(PM, 0.1, seed=7))
        b = _run("miso", seed, False, NoisyEstimator(PM, 0.1, seed=7))
        assert a == b
