"""int8 cross-pod gradient compression: exactness bounds + shard_map psum."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.compression import (_quantize, compressed_psum_tree,
                                     compression_error)


def test_quantize_bounded_error():
    g = jax.random.normal(jax.random.PRNGKey(0), (256, 128)) * 0.01
    q, scale = _quantize(g, None)
    rec = np.asarray(q, np.float32) * float(scale)
    rel = np.abs(rec - np.asarray(g)).max() / np.abs(np.asarray(g)).max()
    assert rel < 1.0 / 127 + 1e-3


def test_tree_error_small():
    grads = {"a": jax.random.normal(jax.random.PRNGKey(1), (64, 64)),
             "b": jax.random.normal(jax.random.PRNGKey(2), (128,)) * 10}
    err = compression_error(grads)
    assert err < 0.01


def test_stochastic_rounding_unbiased():
    g = jnp.full((4096,), 0.3e-3)
    key = jax.random.PRNGKey(3)
    recs = []
    for i in range(20):
        q, s = _quantize(g, jax.random.fold_in(key, i))
        recs.append(np.asarray(q, np.float32) * float(s))
    mean = np.stack(recs).mean()
    assert abs(mean - 0.3e-3) / 0.3e-3 < 0.02


def test_shard_map_psum_matches_exact():
    """compressed_psum under shard_map on a 1-device 'pod' axis equals the
    plain mean to quantization accuracy (multi-device case runs in the
    dry-run environment)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = jax.random.normal(jax.random.PRNGKey(4), (64, 64))

    def f(x):
        return compressed_psum_tree({"g": x}, "pod")["g"]

    out = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P())(g)
    rel = float(jnp.max(jnp.abs(out - g)) / jnp.max(jnp.abs(g)))
    assert rel < 1.5 / 127
