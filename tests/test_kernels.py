"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru.ops import linear_scan
from repro.kernels.rglru.ref import linear_scan_ref
from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref


# ---------------------------------------------------------------- flash

@pytest.mark.parametrize("B,S,Hq,Hkv,D,win,bq,bkv,dtype", [
    (2, 64, 4, 2, 16, None, 16, 16, "float32"),
    (1, 100, 6, 2, 32, None, 32, 16, "float32"),
    (2, 128, 4, 1, 16, 32, 32, 32, "float32"),
    (1, 64, 4, 4, 16, None, 16, 16, "bfloat16"),
    (1, 48, 8, 2, 8, 16, 16, 8, "bfloat16"),
])
def test_flash_kernel(B, S, Hq, Hkv, D, win, bq, bkv, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype=dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype=dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype=dtype)
    o1 = flash_attention(q, k, v, causal=True, window=win, block_q=bq,
                         block_kv=bkv, interpret=True)
    o2 = attention_ref(q, k, v, causal=True, window=win)
    tol = 5e-6 if dtype == "float32" else 2e-2
    assert float(jnp.max(jnp.abs(o1.astype(jnp.float32)
                                 - o2.astype(jnp.float32)))) < tol


# ---------------------------------------------------------------- wkv6

@pytest.mark.parametrize("B,H,S,N,chunk,nonzero_s0", [
    (2, 3, 37, 16, 16, False),
    (1, 2, 64, 32, 32, True),
    (2, 2, 100, 8, 64, True),
    (1, 1, 16, 64, 64, True),
])
def test_wkv6_kernel(B, H, S, N, chunk, nonzero_s0):
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    r = jax.random.normal(ks[0], (B, H, S, N)) * 0.5
    k = jax.random.normal(ks[1], (B, H, S, N)) * 0.5
    v = jax.random.normal(ks[2], (B, H, S, N))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, S, N)) * 0.5)
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    s0 = (jax.random.normal(ks[5], (B, H, N, N)) * 0.2 if nonzero_s0
          else jnp.zeros((B, H, N, N)))
    y1, st1 = wkv6(r, k, v, logw, u, s0, chunk=chunk, interpret=True)
    y2, st2 = wkv6_ref(r, k, v, logw, u, s0)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 5e-5
    assert float(jnp.max(jnp.abs(st1 - st2))) < 5e-5


@settings(max_examples=8, deadline=None)
@given(S=st.integers(5, 80), chunk=st.sampled_from([8, 16, 64]),
       N=st.sampled_from([8, 16]))
def test_wkv6_hypothesis(S, chunk, N):
    ks = jax.random.split(jax.random.PRNGKey(S * 31 + N), 6)
    B, H = 1, 2
    r = jax.random.normal(ks[0], (B, H, S, N)) * 0.5
    k = jax.random.normal(ks[1], (B, H, S, N)) * 0.5
    v = jax.random.normal(ks[2], (B, H, S, N))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, S, N)) * 0.5)
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, N, N)) * 0.2
    y1, st1 = wkv6(r, k, v, logw, u, s0, chunk=chunk, interpret=True)
    y2, st2 = wkv6_ref(r, k, v, logw, u, s0)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 5e-5
    assert float(jnp.max(jnp.abs(st1 - st2))) < 5e-5


# ---------------------------------------------------------------- rglru

@pytest.mark.parametrize("B,S,D,bs,bd", [
    (2, 37, 16, 8, 8),
    (1, 64, 40, 16, 16),
    (2, 100, 24, 128, 128),
    (1, 17, 8, 4, 8),
])
def test_rglru_kernel(B, S, D, bs, bd):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, D)))
    b = jax.random.normal(ks[1], (B, S, D))
    h0 = jax.random.normal(ks[2], (B, D))
    y1, h1 = linear_scan(a, b, h0, block_s=bs, block_d=bd, interpret=True)
    y2, h2 = linear_scan_ref(a, b, h0)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-5
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-5


# ------------------------------------------------- model-path equivalence

@pytest.mark.parametrize("arch", ["smollm-360m", "mixtral-8x22b", "rwkv6-3b",
                                  "recurrentgemma-2b"])
def test_model_pallas_path_matches_pure(arch, run32, key):
    import dataclasses
    from repro import configs
    from repro.models import LM
    cfg = configs.get_smoke_config(arch)
    run_pl = dataclasses.replace(run32, use_pallas=True)
    params, _ = LM.init(cfg, run32, key)
    tokens = jax.random.randint(jax.random.PRNGKey(7), (2, 24), 0,
                                cfg.vocab_size)
    l_ref = LM.logits(params, cfg, run32, tokens)
    l_pl = LM.logits(params, cfg, run_pl, tokens)
    assert float(jnp.max(jnp.abs(l_ref - l_pl))) < 5e-4
