"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import LM
from repro.train.optim import adamw_init
from repro.train.train_step import make_train_step


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_loss(arch, run32, key):
    cfg = configs.get_smoke_config(arch)
    params, specs = LM.init(cfg, run32, key)
    # specs mirror params
    assert jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda *_: 0, params)) is not None
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    logits = LM.logits(params, cfg, run32, tokens)
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = LM.loss(params, cfg, run32, tokens, labels)
    assert bool(jnp.isfinite(loss))
    # at init, loss should be near ln(vocab)
    import math
    assert abs(float(metrics["ce"]) - math.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_one_train_step(arch, run32, key):
    cfg = configs.get_smoke_config(arch)
    params, _ = LM.init(cfg, run32, key)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, run32))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    new_params, new_opt, metrics = step(params, opt, tokens, labels)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters actually moved
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0.0
    assert int(new_opt["step"]) == 1


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_config_exact_numbers(arch):
    """The full configs carry the exact published hyperparameters."""
    cfg = configs.get_config(arch)
    published = {
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == published


def test_param_counts_plausible():
    """Sanity: abstract tree param counts are in the advertised ballpark."""
    from repro.configs.base import RunConfig
    run = RunConfig()
    expected_b = {"command-r-plus-104b": (95, 115), "qwen3-32b": (30, 36),
                  "chameleon-34b": (30, 38), "granite-8b": (7.5, 9),
                  "mixtral-8x22b": (130, 150), "smollm-360m": (0.3, 0.45),
                  "rwkv6-3b": (2.6, 3.6), "recurrentgemma-2b": (2.4, 3.4),
                  "qwen2-moe-a2.7b": (13, 16),
                  "musicgen-large": (2.2, 3.4)}
    for arch, (lo, hi) in expected_b.items():
        n = LM.param_count(configs.get_config(arch), run) / 1e9
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    cfg = configs.get_config("mixtral-8x22b")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()
