import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

from repro.configs.base import RunConfig


@pytest.fixture(scope="session")
def run32():
    """Small-shape fp32 run config for CPU tests."""
    return RunConfig(param_dtype="float32", activation_dtype="float32",
                     attn_block_q=8, attn_block_kv=8, loss_chunk=16)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
