"""Parallel sweep engine: schema stability (v3: the objective axis and
energy columns), deterministic serial/parallel equivalence,
fleet/placer/objective overrides, the report differ's v1/v2/v3
compatibility, and the CLI entry point."""
import importlib.util
import json
import os

import pytest

from repro.launch.sweep import SCHEMA_VERSION, run_sweep, run_task

RESULT_KEYS = {"policy", "placer", "objective", "scenario", "seed", "fleet",
               "n_jobs", "n_completed", "metrics", "wall_s"}
METRIC_KEYS = {"avg_jct_s", "p50_jct_s", "p90_jct_s", "makespan_s", "stp",
               "energy_j", "avg_power_w", "energy_per_job_j",
               "jct_per_joule", "breakdown_s",
               # v4 robustness columns
               "goodput", "gross_stp", "work_lost_s", "n_fault_events",
               "blast_jobs", "blast_radius_max", "mean_recover_s",
               "quarantine_occupancy", "n_quarantines", "n_migrations"}
SUMMARY_KEYS = {"avg_jct_s_mean", "p90_jct_s_mean", "stp_mean",
                "makespan_s_mean", "energy_j_mean", "energy_per_job_j_mean",
                "goodput_mean", "work_lost_s_mean"}


def test_run_task_schema():
    r = run_task({"policy": "miso", "scenario": "smoke", "seed": 0})
    assert set(r) == RESULT_KEYS
    assert set(r["metrics"]) == METRIC_KEYS
    assert r["n_completed"] == r["n_jobs"] > 0
    assert r["fleet"] == "a100:2"            # smoke's default fleet
    assert r["placer"] == "least-loaded"     # smoke's default placer
    assert r["objective"] == "throughput"    # smoke's default objective
    assert r["metrics"]["energy_j"] > 0.0    # energy integration is live
    json.dumps(r)                            # JSON-serializable end to end


def test_run_sweep_serial_grid():
    rep = run_sweep(["miso", "srpt"], ["smoke"], seeds=[0, 1], serial=True)
    assert rep["schema_version"] == SCHEMA_VERSION
    assert rep["kind"] == "miso-sweep"
    assert len(rep["results"]) == 4
    keys = [(r["scenario"], r["policy"], r["placer"], r["objective"],
             r["seed"]) for r in rep["results"]]
    assert keys == sorted(keys)              # stable result ordering
    assert set(rep["summary"]["smoke"]) == {"miso", "srpt"}
    for by_placer in rep["summary"]["smoke"].values():
        assert set(by_placer) == {"least-loaded"}
        for by_obj in by_placer.values():
            assert set(by_obj) == {"throughput"}
            for agg in by_obj.values():
                assert set(agg) == SUMMARY_KEYS


def test_placer_axis_crosses_grid():
    rep = run_sweep(["miso"], ["smoke"], seeds=[0],
                    placers=["least-loaded", "hetero-speed"], serial=True)
    assert len(rep["results"]) == 2
    assert {r["placer"] for r in rep["results"]} == {"least-loaded",
                                                     "hetero-speed"}
    assert set(rep["summary"]["smoke"]["miso"]) == {"least-loaded",
                                                    "hetero-speed"}
    assert rep["config"]["placers"] == ["least-loaded", "hetero-speed"]
    # smoke's a100-only fleet has one speed class: hetero-speed degenerates
    # to least-loaded, so both cells carry identical metrics
    a, b = rep["results"]
    assert a["metrics"] == b["metrics"]


def test_objective_axis_crosses_grid():
    rep = run_sweep(["miso"], ["smoke"], seeds=[0],
                    objectives=["throughput", "energy", "edp"], serial=True)
    assert len(rep["results"]) == 3
    assert {r["objective"] for r in rep["results"]} == {"throughput",
                                                        "energy", "edp"}
    by_obj = rep["summary"]["smoke"]["miso"]["least-loaded"]
    assert set(by_obj) == {"throughput", "energy", "edp"}
    assert rep["config"]["objectives"] == ["throughput", "energy", "edp"]
    for agg in by_obj.values():
        assert agg["energy_j_mean"] > 0.0


def test_parallel_matches_serial():
    strip = lambda rep: [(r["policy"], r["scenario"], r["seed"], r["metrics"])
                         for r in rep["results"]]
    a = run_sweep(["miso"], ["smoke"], seeds=[0, 1], serial=True)
    b = run_sweep(["miso"], ["smoke"], seeds=[0, 1], workers=2)
    assert strip(a) == strip(b)
    assert b["config"]["workers"] == 2 and not b["config"]["serial"]


def test_fleet_and_jobs_override():
    rep = run_sweep(["miso"], ["smoke"], seeds=[0], fleet="a100:1+h100:1",
                    n_jobs=6, serial=True)
    (r,) = rep["results"]
    assert r["fleet"] == "a100:1+h100:1"
    assert r["n_jobs"] == 6
    assert rep["config"]["fleet"] == "a100:1+h100:1"


@pytest.mark.slow
def test_sweep_cli_writes_report(tmp_path):
    from repro.launch import sweep
    out = tmp_path / "report.json"
    rc = sweep.main(["--scenarios", "smoke", "--seeds", "1",
                     "--policies", "miso", "--serial", "--out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["schema_version"] == SCHEMA_VERSION
    assert rep["results"]


def test_cli_rejects_unknown_names():
    from repro.launch import sweep
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        sweep.main(["--policies", "nope", "--scenarios", "smoke",
                    "--seeds", "1"])
    with pytest.raises(ValueError, match="unknown scenario"):
        sweep.main(["--policies", "miso", "--scenarios", "nope",
                    "--seeds", "1"])


# ------------------------------------------------------------- hardening

def test_error_cell_isolated_not_fatal(monkeypatch):
    """A cell whose simulation raises lands in report["errors"] with the
    failure recorded; the rest of the grid still produces results."""
    from repro.launch import sweep

    real = sweep.run_task

    def flaky(task):
        if task["seed"] == 1:
            raise RuntimeError("boom")
        return real(task)

    monkeypatch.setattr(sweep, "run_task", flaky)
    rep = sweep.run_sweep(["miso"], ["smoke"], seeds=[0, 1], serial=True,
                          retries=2)
    assert len(rep["results"]) == 1
    assert rep["results"][0]["seed"] == 0
    (err,) = rep["errors"]
    assert err["seed"] == 1 and err["attempts"] == 2
    assert "RuntimeError: boom" in err["error"]
    # error cells carry resolved identity keys and never reach the summary
    assert err["placer"] == "least-loaded"
    assert set(rep["summary"]["smoke"]["miso"]["least-loaded"]
               ["throughput"]) == SUMMARY_KEYS
    json.dumps(rep)


def test_cell_timeout_records_error(monkeypatch):
    """A cell that exceeds its wall-clock budget is killed by the SIGALRM
    guard and recorded, not hung forever."""
    import signal as _signal

    import pytest as _pytest

    if not hasattr(_signal, "SIGALRM"):
        _pytest.skip("no SIGALRM on this platform")
    from repro.launch import sweep

    def hang(task):
        import time as _t
        _t.sleep(30.0)

    monkeypatch.setattr(sweep, "run_task", hang)
    rep = sweep.run_sweep(["miso"], ["smoke"], seeds=[0], serial=True,
                          cell_timeout=0.2)
    assert rep["results"] == []
    (err,) = rep["errors"]
    assert "CellTimeout" in err["error"]
    assert rep["config"]["cell_timeout_s"] == 0.2


def test_resume_skips_completed_cells(tmp_path, monkeypatch):
    """--resume carries successful cells of a partial same-schema report
    over verbatim and only runs the missing ones."""
    from repro.launch import sweep

    partial = sweep.run_sweep(["miso"], ["smoke"], seeds=[0], serial=True)
    p = tmp_path / "partial.json"
    p.write_text(json.dumps(partial))

    ran = []
    real = sweep.run_task

    def spy(task):
        ran.append(task["seed"])
        return real(task)

    monkeypatch.setattr(sweep, "run_task", spy)
    rep = sweep.run_sweep(["miso"], ["smoke"], seeds=[0, 1], serial=True,
                          resume=str(p))
    assert ran == [1]                    # seed 0 came from the partial
    assert len(rep["results"]) == 2
    assert rep["config"]["resumed_cells"] == 1
    assert rep["results"][0]["metrics"] == partial["results"][0]["metrics"]


def test_resume_ignores_other_schema_versions(tmp_path):
    """A partial report from a different schema version resumes nothing
    (its metric columns would not line up), and a non-sweep JSON is
    rejected outright."""
    from repro.launch import sweep

    old = {"schema_version": SCHEMA_VERSION - 1, "kind": "miso-sweep",
           "results": [{"scenario": "smoke", "policy": "miso",
                        "placer": "least-loaded",
                        "objective": "throughput", "seed": 0}]}
    p = tmp_path / "old.json"
    p.write_text(json.dumps(old))
    rep = sweep.run_sweep(["miso"], ["smoke"], seeds=[0], serial=True,
                          resume=str(p))
    assert rep["config"]["resumed_cells"] == 0
    assert len(rep["results"]) == 1
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"kind": "something-else"}))
    with pytest.raises(ValueError, match="not a miso-sweep report"):
        sweep.run_sweep(["miso"], ["smoke"], seeds=[0], serial=True,
                        resume=str(bad))


# ------------------------------------------------------------ diff_sweeps

def _load_diff_sweeps():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "diff_sweeps.py")
    spec = importlib.util.spec_from_file_location("diff_sweeps", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_diff_sweeps_reads_v1_v2_and_v3_summaries(tmp_path):
    """v1 (pre-placer) and v2 (pre-objective) reports normalize to
    placer=least-loaded / objective=throughput and compare cleanly against
    v3 candidates."""
    ds = _load_diff_sweeps()
    agg = {"avg_jct_s_mean": 100.0, "p90_jct_s_mean": 200.0,
           "stp_mean": 1.5, "makespan_s_mean": 400.0}
    v1 = {"schema_version": 1, "kind": "miso-sweep",
          "summary": {"smoke": {"miso": agg}}}
    v2 = {"schema_version": 2, "kind": "miso-sweep",
          "summary": {"smoke": {"miso": {"least-loaded": agg}}}}
    v3 = {"schema_version": 3, "kind": "miso-sweep",
          "summary": {"smoke": {"miso": {"least-loaded":
                                         {"throughput": agg}}}}}
    p1, p2, p3 = tmp_path / "v1.json", tmp_path / "v2.json", \
        tmp_path / "v3.json"
    p1.write_text(json.dumps(v1))
    p2.write_text(json.dumps(v2))
    p3.write_text(json.dumps(v3))
    key = ("smoke", "miso", "least-loaded", "throughput")
    assert ds.load_summary(str(p1)) == {key: agg}
    assert ds.load_summary(str(p2)) == {key: agg}
    assert ds.load_summary(str(p3)) == {key: agg}
    for old in (p1, p2):
        regressions, notes = ds.diff_reports(str(old), str(p3),
                                             threshold=0.02)
        assert regressions == [] and notes == []


def test_diff_sweeps_flags_regressions_per_placer(tmp_path):
    ds = _load_diff_sweeps()
    base_agg = {"avg_jct_s_mean": 100.0, "stp_mean": 1.5}
    bad_agg = {"avg_jct_s_mean": 150.0, "stp_mean": 1.5}
    base = {"schema_version": 2, "kind": "miso-sweep",
            "summary": {"smoke": {"miso": {"least-loaded": base_agg,
                                           "hetero-speed": base_agg}}}}
    cand = {"schema_version": 2, "kind": "miso-sweep",
            "summary": {"smoke": {"miso": {"least-loaded": base_agg,
                                           "hetero-speed": bad_agg}}}}
    pb, pc = tmp_path / "base.json", tmp_path / "cand.json"
    pb.write_text(json.dumps(base))
    pc.write_text(json.dumps(cand))
    regressions, _ = ds.diff_reports(str(pb), str(pc), threshold=0.02)
    assert len(regressions) == 1
    assert "smoke/miso/hetero-speed/throughput" in regressions[0]


def test_diff_sweeps_flags_energy_regressions(tmp_path):
    """The v3 energy columns gate exactly like the JCT ones: more joules
    than baseline (beyond threshold) fails."""
    ds = _load_diff_sweeps()
    base_agg = {"avg_jct_s_mean": 100.0, "energy_j_mean": 1.0e6}
    bad_agg = {"avg_jct_s_mean": 100.0, "energy_j_mean": 1.1e6}
    mk = lambda agg: {"schema_version": 3, "kind": "miso-sweep",
                      "summary": {"smoke": {"miso": {"least-loaded":
                                                     {"energy": agg}}}}}
    pb, pc = tmp_path / "base.json", tmp_path / "cand.json"
    pb.write_text(json.dumps(mk(base_agg)))
    pc.write_text(json.dumps(mk(bad_agg)))
    regressions, _ = ds.diff_reports(str(pb), str(pc), threshold=0.02)
    assert len(regressions) == 1
    assert "energy_j_mean" in regressions[0]
    assert "smoke/miso/least-loaded/energy" in regressions[0]


def test_diff_sweeps_flags_robustness_regressions(tmp_path):
    """The v4 robustness columns gate: losing goodput or destroying more
    work than baseline (beyond threshold) fails the diff."""
    ds = _load_diff_sweeps()
    base_agg = {"goodput_mean": 1.0, "work_lost_s_mean": 100.0}
    mk = lambda agg: {"schema_version": 4, "kind": "miso-sweep",
                      "summary": {"flaky_fleet": {"miso": {"least-loaded":
                                                  {"throughput": agg}}}}}
    pb = tmp_path / "base.json"
    pb.write_text(json.dumps(mk(base_agg)))
    for bad, metric in (({"goodput_mean": 0.9, "work_lost_s_mean": 100.0},
                         "goodput_mean"),
                        ({"goodput_mean": 1.0, "work_lost_s_mean": 150.0},
                         "work_lost_s_mean")):
        pc = tmp_path / "cand.json"
        pc.write_text(json.dumps(mk(bad)))
        regressions, _ = ds.diff_reports(str(pb), str(pc), threshold=0.02)
        assert len(regressions) == 1
        assert metric in regressions[0]
    # improvement in either direction is a note, not a regression
    pc = tmp_path / "good.json"
    pc.write_text(json.dumps(mk({"goodput_mean": 1.1,
                                 "work_lost_s_mean": 50.0})))
    regressions, notes = ds.diff_reports(str(pb), str(pc), threshold=0.02)
    assert regressions == [] and len(notes) == 2


def test_v3_report_round_trip(tmp_path):
    """A freshly-generated v3 report JSON-round-trips through the differ:
    same report on both sides -> zero regressions, objective-keyed cells."""
    ds = _load_diff_sweeps()
    rep = run_sweep(["miso"], ["smoke"], seeds=[0],
                    objectives=["throughput", "energy"], serial=True)
    p = tmp_path / "rep.json"
    p.write_text(json.dumps(rep))
    cells = ds.load_summary(str(p))
    assert ("smoke", "miso", "least-loaded", "throughput") in cells
    assert ("smoke", "miso", "least-loaded", "energy") in cells
    for agg in cells.values():
        assert agg["energy_j_mean"] > 0.0
    regressions, notes = ds.diff_reports(str(p), str(p), threshold=0.02)
    assert regressions == [] and notes == []


def _components_report(rows):
    return {"schema_version": 1, "kind": "miso-components",
            "rows": [{"name": n, "us_per_call": v, "derived": ""}
                     for n, v in rows.items()]}


def test_diff_components_gates_trace_rows_only(tmp_path):
    """The us/event gate: a trace_scaling row >threshold slower fails; a
    microbench row slowing down is a note; a vanished trace row is a
    coverage regression; improvements are notes."""
    ds = _load_diff_sweeps()
    pb = tmp_path / "base.json"
    pb.write_text(json.dumps(_components_report(
        {"trace_scaling_n8": 50.0, "trace_scaling_n512": 20.0,
         "optimizer_latency": 100.0})))
    # 50% slower trace tier -> regression; 50% slower microbench -> note
    pc = tmp_path / "cand.json"
    pc.write_text(json.dumps(_components_report(
        {"trace_scaling_n8": 75.0, "trace_scaling_n512": 20.0,
         "optimizer_latency": 150.0})))
    regressions, notes = ds.diff_components(str(pb), str(pc), threshold=0.10)
    assert len(regressions) == 1 and "trace_scaling_n8" in regressions[0]
    assert any("optimizer_latency" in n for n in notes)
    # within threshold -> note, not regression
    pc.write_text(json.dumps(_components_report(
        {"trace_scaling_n8": 52.0, "trace_scaling_n512": 18.0,
         "optimizer_latency": 100.0})))
    regressions, notes = ds.diff_components(str(pb), str(pc), threshold=0.10)
    assert regressions == []
    assert any("trace_scaling_n8" in n for n in notes)
    # a gated row missing from the candidate fails the gate
    pc.write_text(json.dumps(_components_report(
        {"trace_scaling_n8": 50.0, "optimizer_latency": 100.0})))
    regressions, _ = ds.diff_components(str(pb), str(pc), threshold=0.10)
    assert len(regressions) == 1
    assert "trace_scaling_n512" in regressions[0]
    assert "missing" in regressions[0]


def test_diff_main_autodetects_components_kind(tmp_path):
    """``main`` routes on the baseline's kind field: components reports get
    the 10% default threshold, so an 8% trace slowdown passes while a 12%
    one fails."""
    ds = _load_diff_sweeps()
    pb = tmp_path / "base.json"
    pb.write_text(json.dumps(_components_report({"trace_scaling_n8": 50.0})))
    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps(_components_report({"trace_scaling_n8": 54.0})))
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(_components_report({"trace_scaling_n8": 56.0})))
    assert ds.main([str(pb), str(ok)]) == 0
    assert ds.main([str(pb), str(bad)]) == 1
    # explicit threshold still wins
    assert ds.main([str(pb), str(bad), "--threshold", "0.2"]) == 0
    # and a sweep baseline still routes to the sweep differ (2% default)
    sb = tmp_path / "sweep.json"
    sb.write_text(json.dumps(
        {"schema_version": 4, "kind": "miso-sweep",
         "summary": {"smoke": {"miso": {"least-loaded":
                     {"throughput": {"stp_mean": 1.0}}}}}}))
    assert ds.main([str(sb), str(sb)]) == 0


def test_diff_components_gates_batch_rollout_row(tmp_path):
    """batch_rollout is a gated row like the trace tiers: slower than
    threshold fails, and vanishing from the candidate fails coverage."""
    ds = _load_diff_sweeps()
    pb = tmp_path / "base.json"
    pb.write_text(json.dumps(_components_report(
        {"batch_rollout": 60.0, "optimizer_latency": 100.0})))
    pc = tmp_path / "cand.json"
    pc.write_text(json.dumps(_components_report(
        {"batch_rollout": 90.0, "optimizer_latency": 100.0})))
    regressions, _ = ds.diff_components(str(pb), str(pc), threshold=0.10)
    assert len(regressions) == 1 and "batch_rollout" in regressions[0]
    pc.write_text(json.dumps(_components_report(
        {"optimizer_latency": 100.0})))
    regressions, _ = ds.diff_components(str(pb), str(pc), threshold=0.10)
    assert any("batch_rollout" in r and "missing" in r for r in regressions)


def test_diff_exact_flags_any_metric_drift(tmp_path):
    """``--exact`` turns sub-threshold drift into a regression: the
    batched-equivalence CI gate accepts byte-equal results only (timing
    columns stay exempt, and components reports reject the flag)."""
    ds = _load_diff_sweeps()

    def mk(stp, wall=1.0):
        return {"schema_version": 4, "kind": "miso-sweep",
                "summary": {"smoke": {"miso": {"least-loaded":
                            {"throughput": {"stp_mean": stp,
                                            "wall_s_mean": wall}}}}}}

    pb, pc = tmp_path / "b.json", tmp_path / "c.json"
    pb.write_text(json.dumps(mk(1.0)))
    pc.write_text(json.dumps(mk(1.0 + 1e-12)))
    regressions, _ = ds.diff_exact(str(pb), str(pc))
    assert len(regressions) == 1 and "stp_mean" in regressions[0]
    # drift far below 2% passes the threshold differ but fails --exact
    assert ds.main([str(pb), str(pc)]) == 0
    assert ds.main([str(pb), str(pc), "--exact"]) == 1
    # identical metrics with different wall-clock: exact passes
    pc.write_text(json.dumps(mk(1.0, wall=9.9)))
    assert ds.main([str(pb), str(pc), "--exact"]) == 0
    comp = tmp_path / "comp.json"
    comp.write_text(json.dumps(_components_report({"batch_rollout": 60.0})))
    with pytest.raises(SystemExit):
        ds.main([str(comp), str(comp), "--exact"])


def test_diff_exact_pool_vs_batched_end_to_end(tmp_path):
    """The CI equivalence gate end-to-end: the same grid through both
    engines summarizes byte-equal, so ``--exact`` returns 0."""
    ds = _load_diff_sweeps()
    kw = dict(policies=["miso", "srpt"], scenarios=["smoke"], seeds=[0])
    pa, pb = tmp_path / "pool.json", tmp_path / "batched.json"
    pa.write_text(json.dumps(run_sweep(serial=True, **kw)))
    rep = run_sweep(serial=True, engine="batched", **kw)
    assert rep["config"]["batched_cells"] == 2
    pb.write_text(json.dumps(rep))
    assert ds.main([str(pa), str(pb), "--exact"]) == 0


def test_profile_stamps_lint_version():
    """``--profile`` reports carry the misolint rule-set hash so archived
    numbers record which determinism contract the tree was clean under."""
    from misolint import ruleset_hash
    rep = run_sweep(["miso"], ["smoke"], seeds=[0], serial=True,
                    profile=True)
    assert rep["lint_version"] == ruleset_hash()
    assert len(rep["lint_version"]) == 12
    # and only --profile reports pay for the stamp
    bare = run_sweep(["miso"], ["smoke"], seeds=[0], serial=True)
    assert "lint_version" not in bare


# ------------------------------------------------------------ trace cache


def _cache_task(seed=0, n_jobs=None, trace_cache=None):
    return {"policy": "miso", "scenario": "smoke", "seed": seed,
            "n_jobs": n_jobs, "trace_cache": trace_cache}


def test_trace_memo_fifo_eviction_bounds_memory(monkeypatch):
    """The in-process trace memo is FIFO-bounded: a long rollout loop over
    many distinct cells must not accumulate every trace it ever generated."""
    from repro.core.scenarios import get_scenario
    from repro.launch import sweep as sw

    monkeypatch.setattr(sw, "_TRACE_CACHE", {})
    monkeypatch.setattr(sw, "_TRACE_CACHE_MAX", 4)
    sc = get_scenario("smoke")
    for seed in range(10):                     # 10 distinct keys
        sw._get_jobs(_cache_task(seed=seed), sc)
    assert len(sw._TRACE_CACHE) == 4
    # FIFO: the four *newest* survive, and a surviving key is a memo hit
    jobs, _, src = sw._get_jobs(_cache_task(seed=9), sc)
    assert src == "memo"
    _, _, src0 = sw._get_jobs(_cache_task(seed=0), sc)
    assert src0 == "fresh"                     # evicted long ago


def test_trace_cache_corrupt_pickle_regenerates(tmp_path, monkeypatch):
    """A truncated/corrupt on-disk trace entry regenerates (and heals the
    file) instead of crashing the cell."""
    import hashlib

    from repro.core.scenarios import get_scenario
    from repro.launch import sweep as sw

    monkeypatch.setattr(sw, "_TRACE_CACHE", {})
    sc = get_scenario("smoke")
    task = _cache_task(trace_cache=str(tmp_path))
    key = sw._trace_key(task, sc)
    h = hashlib.sha256(repr(key).encode()).hexdigest()[:24]
    path = tmp_path / f"trace_{h}.pkl"

    # cold write, then destroy the entry two ways
    jobs, _, src = sw._get_jobs(task, sc)
    assert src == "fresh" and path.exists()
    good = path.read_bytes()

    for corrupt in (good[: len(good) // 2], b"\x80garbage"):
        path.write_bytes(corrupt)
        monkeypatch.setattr(sw, "_TRACE_CACHE", {})   # force the disk tier
        jobs2, _, src2 = sw._get_jobs(task, sc)
        assert src2 == "fresh"                 # fell through, regenerated
        assert [j.jid for j in jobs2] == [j.jid for j in jobs]
        assert path.read_bytes() == good       # healed atomically
    monkeypatch.setattr(sw, "_TRACE_CACHE", {})
    _, _, src3 = sw._get_jobs(task, sc)
    assert src3 == "disk"                      # healthy entry serves again


# --------------------------------------------------------- batched engine


def _strip(rep):
    return [(r["policy"], r["scenario"], r["seed"], r["placer"],
             r["metrics"]) for r in rep["results"]]


def test_batched_engine_bit_identical_to_pool():
    """`--engine batched` coalesces same-fleet cells into one lockstep
    replica batch; every cell's metrics stay bit-identical to the scalar
    per-process path."""
    kw = dict(policies=["miso", "srpt"], scenarios=["smoke"],
              seeds=[0, 1], serial=True)
    a = run_sweep(engine="pool", **kw)
    b = run_sweep(engine="batched", **kw)
    assert _strip(a) == _strip(b)
    assert b["config"]["engine"] == "batched"
    assert b["config"]["batched_cells"] == 4
    assert not b["errors"]


def test_batched_engine_coalesces_by_fleet():
    """Cells with different fleet shapes land in different lockstep groups
    (hetero_smoke: a100+h100 vs smoke: a100-only) — all still run batched,
    none fall back."""
    rep = run_sweep(["miso"], ["smoke", "hetero_smoke"], seeds=[0],
                    serial=True, engine="batched")
    assert rep["config"]["batched_cells"] == 2
    fleets = {r["scenario"]: r["fleet"] for r in rep["results"]}
    assert fleets["smoke"] != fleets["hetero_smoke"]


def test_batched_engine_group_failure_falls_back(monkeypatch):
    """A group whose lockstep run dies falls back to the per-cell scalar
    path: the sweep still returns every cell, with batched_cells == 0."""
    from repro.core.sim import batch as batch_mod

    def boom(self):
        raise RuntimeError("injected lockstep failure")

    monkeypatch.setattr(batch_mod.BatchSim, "run", boom)
    rep = run_sweep(["miso"], ["smoke"], seeds=[0, 1], serial=True,
                    engine="batched")
    assert rep["config"]["batched_cells"] == 0
    assert len(rep["results"]) == 2 and not rep["errors"]


def test_batched_engine_profile_falls_back():
    """--profile keeps the scalar path (per-component clocks are not
    accumulated through the collect pipeline) but still completes."""
    rep = run_sweep(["miso"], ["smoke"], seeds=[0], serial=True,
                    profile=True, engine="batched")
    assert rep["config"]["batched_cells"] == 0
    (r,) = rep["results"]
    assert "profile" in r and r["profile"]["events"] > 0
