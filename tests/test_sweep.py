"""Parallel sweep engine: schema stability (v2: the placer axis),
deterministic serial/parallel equivalence, fleet/placer overrides, the
report differ's v1/v2 compatibility, and the CLI entry point."""
import importlib.util
import json
import os

import pytest

from repro.launch.sweep import SCHEMA_VERSION, run_sweep, run_task

RESULT_KEYS = {"policy", "placer", "scenario", "seed", "fleet", "n_jobs",
               "n_completed", "metrics", "wall_s"}
METRIC_KEYS = {"avg_jct_s", "p50_jct_s", "p90_jct_s", "makespan_s", "stp",
               "breakdown_s"}


def test_run_task_schema():
    r = run_task({"policy": "miso", "scenario": "smoke", "seed": 0})
    assert set(r) == RESULT_KEYS
    assert set(r["metrics"]) == METRIC_KEYS
    assert r["n_completed"] == r["n_jobs"] > 0
    assert r["fleet"] == "a100:2"            # smoke's default fleet
    assert r["placer"] == "least-loaded"     # smoke's default placer
    json.dumps(r)                            # JSON-serializable end to end


def test_run_sweep_serial_grid():
    rep = run_sweep(["miso", "srpt"], ["smoke"], seeds=[0, 1], serial=True)
    assert rep["schema_version"] == SCHEMA_VERSION
    assert rep["kind"] == "miso-sweep"
    assert len(rep["results"]) == 4
    keys = [(r["scenario"], r["policy"], r["placer"], r["seed"])
            for r in rep["results"]]
    assert keys == sorted(keys)              # stable result ordering
    assert set(rep["summary"]["smoke"]) == {"miso", "srpt"}
    for by_placer in rep["summary"]["smoke"].values():
        assert set(by_placer) == {"least-loaded"}
        for agg in by_placer.values():
            assert set(agg) == {"avg_jct_s_mean", "p90_jct_s_mean",
                                "stp_mean", "makespan_s_mean"}


def test_placer_axis_crosses_grid():
    rep = run_sweep(["miso"], ["smoke"], seeds=[0],
                    placers=["least-loaded", "hetero-speed"], serial=True)
    assert len(rep["results"]) == 2
    assert {r["placer"] for r in rep["results"]} == {"least-loaded",
                                                     "hetero-speed"}
    assert set(rep["summary"]["smoke"]["miso"]) == {"least-loaded",
                                                    "hetero-speed"}
    assert rep["config"]["placers"] == ["least-loaded", "hetero-speed"]
    # smoke's a100-only fleet has one speed class: hetero-speed degenerates
    # to least-loaded, so both cells carry identical metrics
    a, b = rep["results"]
    assert a["metrics"] == b["metrics"]


def test_parallel_matches_serial():
    strip = lambda rep: [(r["policy"], r["scenario"], r["seed"], r["metrics"])
                         for r in rep["results"]]
    a = run_sweep(["miso"], ["smoke"], seeds=[0, 1], serial=True)
    b = run_sweep(["miso"], ["smoke"], seeds=[0, 1], workers=2)
    assert strip(a) == strip(b)
    assert b["config"]["workers"] == 2 and not b["config"]["serial"]


def test_fleet_and_jobs_override():
    rep = run_sweep(["miso"], ["smoke"], seeds=[0], fleet="a100:1+h100:1",
                    n_jobs=6, serial=True)
    (r,) = rep["results"]
    assert r["fleet"] == "a100:1+h100:1"
    assert r["n_jobs"] == 6
    assert rep["config"]["fleet"] == "a100:1+h100:1"


@pytest.mark.slow
def test_sweep_cli_writes_report(tmp_path):
    from repro.launch import sweep
    out = tmp_path / "report.json"
    rc = sweep.main(["--scenarios", "smoke", "--seeds", "1",
                     "--policies", "miso", "--serial", "--out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["schema_version"] == SCHEMA_VERSION
    assert rep["results"]


def test_cli_rejects_unknown_names():
    from repro.launch import sweep
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        sweep.main(["--policies", "nope", "--scenarios", "smoke",
                    "--seeds", "1"])
    with pytest.raises(ValueError, match="unknown scenario"):
        sweep.main(["--policies", "miso", "--scenarios", "nope",
                    "--seeds", "1"])


# ------------------------------------------------------------ diff_sweeps

def _load_diff_sweeps():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "diff_sweeps.py")
    spec = importlib.util.spec_from_file_location("diff_sweeps", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_diff_sweeps_reads_v1_and_v2_summaries(tmp_path):
    """v1 reports (pre-placer) normalize to placer=least-loaded and compare
    cleanly against v2 candidates."""
    ds = _load_diff_sweeps()
    agg = {"avg_jct_s_mean": 100.0, "p90_jct_s_mean": 200.0,
           "stp_mean": 1.5, "makespan_s_mean": 400.0}
    v1 = {"schema_version": 1, "kind": "miso-sweep",
          "summary": {"smoke": {"miso": agg}}}
    v2 = {"schema_version": 2, "kind": "miso-sweep",
          "summary": {"smoke": {"miso": {"least-loaded": agg}}}}
    p1, p2 = tmp_path / "v1.json", tmp_path / "v2.json"
    p1.write_text(json.dumps(v1))
    p2.write_text(json.dumps(v2))
    key = ("smoke", "miso", "least-loaded")
    assert ds.load_summary(str(p1)) == {key: agg}
    assert ds.load_summary(str(p2)) == {key: agg}
    regressions, notes = ds.diff_reports(str(p1), str(p2), threshold=0.02)
    assert regressions == [] and notes == []


def test_diff_sweeps_flags_regressions_per_placer(tmp_path):
    ds = _load_diff_sweeps()
    base_agg = {"avg_jct_s_mean": 100.0, "stp_mean": 1.5}
    bad_agg = {"avg_jct_s_mean": 150.0, "stp_mean": 1.5}
    base = {"schema_version": 2, "kind": "miso-sweep",
            "summary": {"smoke": {"miso": {"least-loaded": base_agg,
                                           "hetero-speed": base_agg}}}}
    cand = {"schema_version": 2, "kind": "miso-sweep",
            "summary": {"smoke": {"miso": {"least-loaded": base_agg,
                                           "hetero-speed": bad_agg}}}}
    pb, pc = tmp_path / "base.json", tmp_path / "cand.json"
    pb.write_text(json.dumps(base))
    pc.write_text(json.dumps(cand))
    regressions, _ = ds.diff_reports(str(pb), str(pc), threshold=0.02)
    assert len(regressions) == 1
    assert "smoke/miso/hetero-speed" in regressions[0]
