"""Parallel sweep engine: schema stability, deterministic serial/parallel
equivalence, fleet override, and the CLI entry point."""
import json

import pytest

from repro.launch.sweep import SCHEMA_VERSION, run_sweep, run_task

RESULT_KEYS = {"policy", "scenario", "seed", "fleet", "n_jobs",
               "n_completed", "metrics", "wall_s"}
METRIC_KEYS = {"avg_jct_s", "p50_jct_s", "p90_jct_s", "makespan_s", "stp",
               "breakdown_s"}


def test_run_task_schema():
    r = run_task({"policy": "miso", "scenario": "smoke", "seed": 0})
    assert set(r) == RESULT_KEYS
    assert set(r["metrics"]) == METRIC_KEYS
    assert r["n_completed"] == r["n_jobs"] > 0
    assert r["fleet"] == "a100:2"            # smoke's default fleet
    json.dumps(r)                            # JSON-serializable end to end


def test_run_sweep_serial_grid():
    rep = run_sweep(["miso", "srpt"], ["smoke"], seeds=[0, 1], serial=True)
    assert rep["schema_version"] == SCHEMA_VERSION
    assert rep["kind"] == "miso-sweep"
    assert len(rep["results"]) == 4
    keys = [(r["scenario"], r["policy"], r["seed"]) for r in rep["results"]]
    assert keys == sorted(keys)              # stable result ordering
    assert set(rep["summary"]["smoke"]) == {"miso", "srpt"}
    for agg in rep["summary"]["smoke"].values():
        assert set(agg) == {"avg_jct_s_mean", "p90_jct_s_mean", "stp_mean",
                            "makespan_s_mean"}


def test_parallel_matches_serial():
    strip = lambda rep: [(r["policy"], r["scenario"], r["seed"], r["metrics"])
                         for r in rep["results"]]
    a = run_sweep(["miso"], ["smoke"], seeds=[0, 1], serial=True)
    b = run_sweep(["miso"], ["smoke"], seeds=[0, 1], workers=2)
    assert strip(a) == strip(b)
    assert b["config"]["workers"] == 2 and not b["config"]["serial"]


def test_fleet_and_jobs_override():
    rep = run_sweep(["miso"], ["smoke"], seeds=[0], fleet="a100:1+h100:1",
                    n_jobs=6, serial=True)
    (r,) = rep["results"]
    assert r["fleet"] == "a100:1+h100:1"
    assert r["n_jobs"] == 6
    assert rep["config"]["fleet"] == "a100:1+h100:1"


@pytest.mark.slow
def test_sweep_cli_writes_report(tmp_path):
    from repro.launch import sweep
    out = tmp_path / "report.json"
    rc = sweep.main(["--scenarios", "smoke", "--seeds", "1",
                     "--policies", "miso", "--serial", "--out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["schema_version"] == SCHEMA_VERSION
    assert rep["results"]


def test_cli_rejects_unknown_names():
    from repro.launch import sweep
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        sweep.main(["--policies", "nope", "--scenarios", "smoke",
                    "--seeds", "1"])
    with pytest.raises(ValueError, match="unknown scenario"):
        sweep.main(["--policies", "miso", "--scenarios", "nope",
                    "--seeds", "1"])
