"""Replica-batched engine: B=1 bit-identity against the committed golden
traces, mixed-batch bit-identity against scalar runs, fused-service
contracts (estimator grouping, stacked Algorithm-1), and the step/observe
vectorized-environment surface."""
import json
import os

import numpy as np
import pytest

from repro.core.estimators import NoisyEstimator, OracleEstimator
from repro.core.jobs import WORKLOADS
from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.sim.batch import BatchFleetState, BatchSim
from repro.core.simulator import ClusterSim, SimConfig
from repro.core.traces import generate_trace

SPACE = a100_mig_space()
PM = PerfModel(SPACE)
EST = OracleEstimator(PM)
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "simulator_golden.json")

with open(GOLDEN) as f:
    _GOLD = json.load(f)
_GCFG = _GOLD["config"]

ALL_POLICIES = ("nopart", "optsta", "mpsonly", "miso", "oracle",
                "miso-frag", "srpt")
PLACERS = ("least-loaded", "hetero-speed", "frag-aware", "best-fit-slice")


def _golden_jobs(seed):
    return generate_trace(_GCFG["n_jobs"], lam_s=_GCFG["lam_s"], seed=seed,
                          max_duration_s=_GCFG["max_duration_s"])


def _sim(policy, seed, *, placer=None, estimator=None, n_gpus=None,
         jobs=None, **cfg_kw):
    cfg = SimConfig(n_gpus=n_gpus or _GCFG["n_gpus"], policy=policy,
                    seed=seed, **({"placer": placer} if placer else {}),
                    **cfg_kw)
    return ClusterSim(jobs if jobs is not None else _golden_jobs(seed),
                      cfg, SPACE, PM, estimator or EST)


def _key(m):
    return (m.avg_jct, m.makespan, m.stp, m.p50_jct, m.p90_jct,
            tuple(m.jcts), tuple(sorted(m.breakdown.items())))


# ------------------------------------------------------- B=1 bit-identity


@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_b1_bit_identical_to_golden(policy, seed):
    """Every committed golden trace, replayed through BatchSim([replica]),
    reproduces the recorded scalar-engine metrics bit-for-bit: the
    collect/fuse/apply pipeline is an exact re-staging of the inline tick,
    not an approximation of it."""
    (m,) = BatchSim([_sim(policy, seed)]).run()
    g = _GOLD[f"{policy}/seed{seed}"]
    assert m.avg_jct == g["avg_jct"]
    assert m.makespan == g["makespan"]
    assert m.stp == g["stp"]
    assert m.p50_jct == g["p50_jct"]
    assert m.p90_jct == g["p90_jct"]
    assert list(m.jcts) == g["jcts"]
    assert m.breakdown == g["breakdown"]


@pytest.mark.parametrize("placer", PLACERS)
def test_b1_bit_identical_per_placer(placer):
    """Placement goldens: each built-in placer runs bit-identically batched
    (placement happens inside the replica's own arrival tick — the batch
    layer never touches it)."""
    scalar = _sim("miso", 0, placer=placer).run()
    (batched,) = BatchSim([_sim("miso", 0, placer=placer)]).run()
    assert _key(batched) == _key(scalar)


# ------------------------------------------------- mixed-batch bit-identity


def test_mixed_b8_bit_identical_to_scalar():
    """A B=8 batch mixing policies, seeds and placers: every replica's
    metrics stay bit-identical to running it alone, even though estimator
    and Algorithm-1 work fused across replicas mid-flight."""
    specs = [("miso", 0, None), ("miso", 1, None), ("oracle", 0, None),
             ("srpt", 2, None), ("miso-frag", 1, None),
             ("mpsonly", 0, None), ("miso", 2, "frag-aware"),
             ("srpt", 0, "best-fit-slice")]
    scalar = [_key(_sim(p, s, placer=pl).run()) for p, s, pl in specs]
    batched = BatchSim([_sim(p, s, placer=pl) for p, s, pl in specs]).run()
    assert [_key(m) for m in batched] == scalar


def test_mixed_batch_with_noise_and_faults_bit_identical():
    """Replica RNG streams (measurement noise + failure schedule) stay
    per-replica under lockstep interleaving."""
    kw = dict(mps_noise_sigma=0.1, gpu_mtbf_s=2000.0)
    specs = [("miso", 0), ("miso", 1), ("srpt", 0), ("oracle", 1)]
    scalar = [_key(_sim(p, s, estimator=NoisyEstimator(PM, 0.1, seed=7),
                        **kw).run())
              for p, s in specs]
    batched = BatchSim(
        [_sim(p, s, estimator=NoisyEstimator(PM, 0.1, seed=7), **kw)
         for p, s in specs]).run()
    assert [_key(m) for m in batched] == scalar


# ------------------------------------------------------- fused services


def test_fused_estimates_match_singles():
    """Stage A groups by estimator object and fills ``ests`` exactly as
    per-work ``estimate`` calls would (oracle: bit-identical)."""
    from repro.core.sim.policies.base import EstimateWork

    class _G:
        def __init__(self, est):
            self.estimator = est

    rng = np.random.default_rng(0)
    works = []
    for _ in range(6):
        k = int(rng.integers(1, 6))
        profs = [WORKLOADS[int(i)]
                 for i in rng.integers(0, len(WORKLOADS), k)]
        works.append(EstimateWork(_G(EST), tuple(range(k)), profs,
                                  [0] * k, None))
    BatchSim._fuse_estimates(works)
    for w in works:
        assert w.ests == EST.estimate(w.profs, w.mat, qos=w.qos)


def test_fused_estimates_unet_allclose():
    """A cross-replica U-Net group runs one stacked (sum B, 3, J) forward;
    per-request results match the scalar forward up to XLA batch
    reassociation (float32 last-ulp — same contract the scalar engine's
    same-tick coalescing already accepts)."""
    jax = pytest.importorskip("jax")
    from repro.core.estimators import UNetEstimator
    from repro.core.predictor import linreg, unet
    from repro.core.sim.policies.base import EstimateWork

    net = unet.UNet.create(jax.random.PRNGKey(0))
    X = np.random.default_rng(0).random((64, 3))
    Y = np.random.default_rng(1).random((64, 2))
    est = UNetEstimator(PM, net.params, linreg.fit_linreg(X, Y))

    class _G:
        estimator = est

    rng = np.random.default_rng(3)
    works = []
    for _ in range(4):
        k = int(rng.integers(1, 5))
        profs = [WORKLOADS[int(i)]
                 for i in rng.integers(0, len(WORKLOADS), k)]
        works.append(EstimateWork(_G(), tuple(range(k)), profs, [0] * k,
                                  est.measure_mps(profs)))
    BatchSim._fuse_estimates(works)
    for w in works:
        single = est.estimate(w.profs, w.mat, qos=w.qos)
        assert len(w.ests) == len(single)
        for a, b in zip(single, w.ests):
            assert set(a) == set(b)
            for s in a:
                assert a[s] == pytest.approx(b[s], abs=1e-5)


def test_solve_decisions_matches_scalar_chooser():
    """Stage C fills every decision with exactly what the policy's own
    ``choose_partition`` would pick, across mixed objectives (distinct
    memo keys must not cross-contaminate groups)."""
    from repro.core.sim.policies.base import RepartDecision

    miso = _sim("miso", 0, n_gpus=1, jobs=[]).policy
    frag = _sim("miso-frag", 0, n_gpus=1, jobs=[]).policy  # own chooser

    class _G:
        pass

    g = _G()
    g.space = SPACE
    g.power = _sim("miso", 0, n_gpus=1, jobs=[]).gpus[0].power
    speeds_a = [{7: 1.0, 4: 0.7, 3: 0.6, 2: 0.4, 1: 0.2},
                {7: 1.0, 4: 0.5, 3: 0.45, 2: 0.3, 1: 0.15}]
    speeds_b = [{7: 1.0, 4: 0.6, 3: 0.6, 2: 0.57, 1: 0.2},
                {7: 1.0, 4: 0.6, 3: 0.6, 2: 0.57, 1: 0.2}]
    ds = [RepartDecision(miso, g, (0, 1), speeds_a, False),
          RepartDecision(miso, g, (2, 3), speeds_b, False),
          RepartDecision(frag, g, (4, 5), speeds_b, False)]
    BatchSim._solve_decisions(ds)
    for d in ds:
        want = d.policy.choose_partition(d.speeds, space=SPACE, power=g.power)
        assert d.choice.partition == want.partition
        assert d.choice.objective == want.objective


# --------------------------------------------------- step/observe surface


def test_step_observe_shapes_and_termination():
    sims = [_sim("miso", s, n_gpus=2,
                 jobs=generate_trace(6, lam_s=30.0, seed=s,
                                     max_duration_s=900))
            for s in range(3)]
    bs = BatchSim(sims)
    obs = bs.observe()
    assert obs["t"].shape == (3,)
    assert obs["last_update"].shape == (3, 2)
    assert obs["speed"].shape[:2] == (3, 2)
    assert obs["mask"].shape == obs["speed"].shape
    assert not obs["done"].any()
    rounds = 0
    while bs.step():
        rounds += 1
        assert rounds < 10_000
    bs.settle()
    obs = bs.observe()
    assert obs["done"].all()
    assert (obs["completed"] == 6).all()
    assert not obs["mask"].any()          # everything drained
    # run() after manual stepping just finishes: metrics still well-formed
    ms = [s.finish(settle=False) for s in sims]
    assert all(len(m.jcts) == 6 for m in ms)


def test_observe_resident_matrix_mid_flight():
    """Mid-run the resident export reflects live occupancy and never
    mutates simulation state (observe twice -> identical)."""
    bs = BatchSim([_sim("miso", 0, n_gpus=2,
                        jobs=generate_trace(8, lam_s=5.0, seed=0,
                                            max_duration_s=900))])
    seen_resident = False
    for _ in range(500):
        live = bs.step()
        a = bs.observe()
        b = bs.observe()
        for k in a:
            assert np.array_equal(a[k], b[k])
        if a["mask"].any():
            seen_resident = True
            assert a["speed"][a["mask"]].min() >= 0.0
        if not live:
            break
    assert seen_resident


def test_shape_mismatch_rejected():
    with pytest.raises(ValueError, match="fleet shapes differ"):
        BatchSim([_sim("miso", 0, n_gpus=2, jobs=[]),
                  _sim("miso", 0, n_gpus=3, jobs=[])])


def test_empty_batch_rejected():
    with pytest.raises(ValueError, match="at least one replica"):
        BatchFleetState([])


def test_batch_settle_matches_per_replica():
    """The (B*G)-row batched settle with per-replica clocks lands the same
    numbers as each replica settling alone."""
    mk = lambda: [_sim("miso", s, n_gpus=2,
                       jobs=generate_trace(6, lam_s=10.0, seed=s,
                                           max_duration_s=900))
                  for s in range(3)]
    a, b = BatchSim(mk()), BatchSim(mk())
    for _ in range(40):
        a.step()
        b.step()
    a.fleet_state.settle_all()
    for s in b.sims:
        s.fleet_state.settle_all(s.t)
    for ga, gb in zip(a.fleet_state.gpus, b.fleet_state.gpus):
        assert ga.energy_j == gb.energy_j
        assert ga.last_update == gb.last_update
        assert [rj.job.remaining for rj in ga._rjobs] == \
            [rj.job.remaining for rj in gb._rjobs]
