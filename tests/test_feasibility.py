"""Precomputed admission feasibility: exactness of the scalar-requirement
collapse, the Pareto-frontier scan, and the spare_slice_ok QoS/memory
regression the greedy check used to miss."""
import itertools
import random

import pytest

from repro.core.estimators import OracleEstimator
from repro.core.jobs import WORKLOADS, Job
from repro.core.partitions import (a100_mig_space, h100_mig_space,
                                   tpu_pod_space)
from repro.core.perfmodel import PerfModel
from repro.core.simulator import ClusterSim, SimConfig

SPACES = {
    "a100": a100_mig_space(),
    "h100": h100_mig_space(),
    "tpu": tpu_pod_space(),
}


def brute_force_feasible(space, mems, qoss):
    """Ground truth: try every partition x every job->slot assignment."""
    m = len(mems)
    for part in space.partitions_of_len(m):
        for perm in set(itertools.permutations(part)):
            if all(space.slice_mem_gb(perm[i]) >= mems[i]
                   and perm[i] >= qoss[i] for i in range(m)):
                return True
    return False


@pytest.mark.parametrize("space_name", sorted(SPACES))
def test_feasible_exact_matches_bruteforce(space_name):
    space = SPACES[space_name]
    mem_menu = sorted({st.memory_gb for st in space.slices.values()})
    rng = random.Random(42)
    for trial in range(400):
        m = rng.randint(1, min(5, space.max_jobs))
        mems, qoss = [], []
        for _ in range(m):
            # memory around slice boundaries, including infeasible overshoot
            base = rng.choice(mem_menu)
            mems.append(max(0.1, base * rng.choice((0.3, 0.9, 1.0, 1.1))))
            qoss.append(rng.choice((0, 0) + space.sizes))
        assert space.feasible_exact(mems, qoss) == \
            brute_force_feasible(space, mems, qoss), (trial, mems, qoss)


@pytest.mark.parametrize("space_name", sorted(SPACES))
def test_min_required_slice_threshold_semantics(space_name):
    """A slice satisfies (mem, qos) iff its size >= min_required_slice —
    the collapse is valid because slice memory is monotone in size."""
    space = SPACES[space_name]
    assert space._mem_monotone
    for mem in (0.1, 4.9, 5.0, 5.1, 19.0, 21.0, 39.0, 41.0, 100.0, 1e5):
        for qos in (0,) + space.sizes:
            req = space.min_required_slice(mem, qos)
            for size in space.sizes:
                ok = (space.slice_mem_gb(size) >= mem and size >= qos)
                if req is None:
                    assert not ok
                else:
                    assert ok == (size >= req)


def test_placeable_pareto_frontier_is_exact():
    for space in SPACES.values():
        rng = random.Random(5)
        for _ in range(300):
            m = rng.randint(1, space.max_jobs)
            reqs = [rng.choice(space.sizes + (space.full_size + 1,))
                    for _ in range(m)]
            expected = any(
                all(a >= b for a, b in
                    zip(p, sorted(reqs, reverse=True)))
                for p in space.partitions_of_len(m))
            assert space.placeable(reqs) == expected


def test_largest_free_slice_cached_consistent():
    space = a100_mig_space()
    for p in space.partitions:
        assert space.largest_free_slice(p) == space._largest_free(p)
    # non-canonical orderings go through the same cache keyed per tuple
    assert space.largest_free_slice((2, 4)) == space.largest_free_slice((4, 2))


def test_is_valid_uses_precomputed_set():
    space = a100_mig_space()
    assert space.is_valid((4, 2, 1))
    assert space.is_valid((1, 2, 4))          # any order
    assert not space.is_valid((4, 3))
    assert isinstance(space._partition_set, frozenset)    # built once
    assert space._partition_set == frozenset(space.partitions)


# ------------------------------------------------- spare_slice_ok regression


def _sim(n_gpus=1):
    space = a100_mig_space()
    pm = PerfModel(space)
    return ClusterSim([], SimConfig(n_gpus=n_gpus, policy="miso"), space, pm,
                      OracleEstimator(pm))


def test_spare_slice_ok_qos_vs_memory_conflict():
    """The satellite regression: job A (mem=1 GB, qos_min_slice=4) + job B
    (mem=10 GB, qos=0) fit on partition (4, 2) — A on the 4g (QoS), B on the
    2g (10 GB).  The historical biggest-memory-first greedy gave the 4g to
    B and then failed A's QoS floor, rejecting a feasible admission."""
    sim = _sim()
    g = sim.gpus[0]
    small = [p for p in WORKLOADS if p.mem_gb <= 5.0]
    big = [p for p in WORKLOADS if 5.0 < p.mem_gb <= 10.0]
    assert small and big, "workload pool no longer spans the menu"
    resident = Job(jid=0, profile=small[0], arrival=0.0, work=100.0,
                   qos_min_slice=4)
    sim.place(g, resident)
    incoming = Job(jid=1, profile=big[0], arrival=0.0, work=100.0)
    assert sim.spare_slice_ok(g, incoming), \
        "exact assignment must admit (A->4g for QoS, B->2g for memory)"


def test_spare_slice_ok_still_rejects_infeasible():
    sim = _sim()
    g = sim.gpus[0]
    small = [p for p in WORKLOADS if p.mem_gb <= 5.0]
    # seven QoS-7 jobs can never share one GPU
    sim.place(g, Job(jid=0, profile=small[0], arrival=0.0, work=100.0,
                     qos_min_slice=7))
    assert not sim.spare_slice_ok(
        g, Job(jid=1, profile=small[0], arrival=0.0, work=100.0,
               qos_min_slice=7))
    # memory above every slice is infeasible outright
    assert not sim.spare_slice_ok(
        g, Job(jid=2, profile=small[0], arrival=0.0, work=100.0,
               min_mem_gb=64.0))


def test_spare_slice_ok_exclude_what_if():
    sim = _sim()
    g = sim.gpus[0]
    small = [p for p in WORKLOADS if p.mem_gb <= 5.0]
    a = Job(jid=0, profile=small[0], arrival=0.0, work=100.0, qos_min_slice=7)
    sim.place(g, a)
    b = Job(jid=1, profile=small[0], arrival=0.0, work=100.0, qos_min_slice=7)
    assert not sim.spare_slice_ok(g, b)
    assert sim.spare_slice_ok(g, b, exclude=0)   # if A were evicted
