"""misolint: per-rule positive/negative fixtures, suppressions, baseline
filtering, --fix rewrites, the CLI, and the meta-test that keeps the lint
honest — the live tree must stay clean modulo the committed baseline.

Fixture snippets are linted as *strings* (never executed), with the path
argument chosen to land inside each rule's scope.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from misolint import lint_source, ruleset_hash
from misolint.api import lint_paths
from misolint.baseline import Baseline, fingerprint, make_entries
from misolint.context import build_context
from misolint.fixes import fix_source
from misolint.rules import all_rules

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CORE = "src/repro/core/x.py"          # inside MS101 scope
SIM = "src/repro/core/sim/x.py"       # inside MS107/MS108 scope
ANY = "src/repro/anywhere.py"


def ids(findings, *, include_suppressed=False):
    return [f.rule for f in findings
            if include_suppressed or not f.suppressed]


def lint(src, path=ANY, **kw):
    return lint_source(textwrap.dedent(src), path, **kw)


# ------------------------------------------------------------------ MS101

def test_ms101_positive_numpy_global():
    fs = lint("""
        import numpy as np
        x = np.random.rand(3)
        np.random.seed(0)
    """, CORE)
    assert ids(fs) == ["MS101", "MS101"]


def test_ms101_positive_stdlib_random():
    fs = lint("""
        import random
        v = random.randint(0, 7)
    """, CORE)
    assert ids(fs) == ["MS101"]


def test_ms101_negative_generator_and_annotations():
    fs = lint("""
        import numpy as np

        def draw(rng: np.random.Generator) -> float:
            return rng.random()

        RNG = np.random.default_rng(0)
        SS = np.random.SeedSequence(42)
    """, CORE)
    assert ids(fs) == []


def test_ms101_out_of_scope_path():
    fs = lint("""
        import numpy as np
        x = np.random.rand(3)
    """, "src/repro/launch/cli_tool.py")
    assert ids(fs) == []


# ------------------------------------------------------------------ MS102

def test_ms102_positive_seed_and_const_prngkey():
    fs = lint("""
        import jax

        def measure(self):
            self.rng.seed(0)
            k = jax.random.PRNGKey(0)
            return k
    """)
    assert ids(fs) == ["MS102", "MS102"]


def test_ms102_negative_variable_key_module_level_and_main():
    fs = lint("""
        import jax
        K = jax.random.PRNGKey(0)          # module top level: fine

        def step(seed):
            return jax.random.PRNGKey(seed)   # threaded seed: fine

        def main():
            return jax.random.PRNGKey(1)      # CLI entry point: fine
    """)
    assert ids(fs) == []


def test_ms102_exempts_test_files():
    fs = lint("""
        import jax

        def test_thing():
            k = jax.random.PRNGKey(0)
            return k
    """, "tests/test_thing.py")
    assert ids(fs) == []


# ------------------------------------------------------------------ MS103

def test_ms103_positive_forms():
    fs = lint("""
        s = {1, 2, 3}
        for x in set(range(4)):
            pass
        xs = list({4, 5} | s)
        ys = [y for y in frozenset((6, 7))]
        zs = tuple({4, 5}.union(s))
    """)
    assert ids(fs) == ["MS103"] * 4


def test_ms103_no_dataflow_on_bare_names():
    # a set bound to a name is invisible to the syntactic check (no
    # dataflow) — the rule is deliberately local to keep zero false
    # positives on list/tuple variables
    fs = lint("""
        s = set()
        for x in s:
            pass
    """)
    assert ids(fs) == []


def test_ms103_negative_order_free_sinks():
    fs = lint("""
        s = {3, 1, 2}
        n = len(set(s))
        lo = min({1, 2})
        ok = 2 in {1, 2}
        canon = sorted({9, 8})
        total = sum(x for x in {1, 2, 3})
        for x in sorted(set(s)):
            pass
    """)
    assert ids(fs) == []


def test_ms103_keys_iteration_flagged():
    fs = lint("""
        d = {"a": 1}
        for k in d.keys():
            pass
    """)
    assert ids(fs) == ["MS103"]


# ------------------------------------------------------------------ MS104

def test_ms104_positive_name_mismatch_and_multiple():
    fs = lint("""
        from repro.core.sim.policies.base import Policy, register_policy

        @register_policy
        class A(Policy):
            name = "not-the-module"

        @register_policy
        class B(Policy):
            name = "other"
    """, "src/repro/core/sim/policies/my_policy.py")
    rules = ids(fs)
    # one 2-policies-per-module finding + a name mismatch per class
    assert rules.count("MS104") == 3


def test_ms104_positive_missing_and_duplicate_names():
    fs = lint("""
        from repro.core.sim.placement import Placer, register_placer

        @register_placer
        class NoName(Placer):
            pass

        @register_placer
        class P1(Placer):
            name = "dup"

        @register_placer
        class P2(Placer):
            name = "dup"
    """, "src/repro/core/sim/placement_extra.py")
    assert ids(fs) == ["MS104", "MS104"]  # missing literal name + duplicate


def test_ms104_negative_well_formed_policy_module():
    fs = lint("""
        from repro.core.sim.policies.base import Policy, register_policy

        @register_policy
        class MyFragPolicy(Policy):
            name = "my-frag"
    """, "src/repro/core/sim/policies/my_frag.py")
    assert ids(fs) == []


# ------------------------------------------------------------------ MS105

def test_ms105_positive_variants():
    fs = lint("""
        def f(a, b=[], c={}, *, d=set()):
            return a, b, c, d
    """)
    assert ids(fs) == ["MS105"] * 3


def test_ms105_negative_none_and_immutable():
    fs = lint("""
        def f(a, b=None, c=(), d="x", e=0):
            if b is None:
                b = []
            return a, b, c, d, e
    """)
    assert ids(fs) == []


# ------------------------------------------------------------------ MS106

def test_ms106_positive_default_context():
    fs = lint("""
        import jax
        from concurrent.futures import ProcessPoolExecutor

        def sweep(tasks):
            with ProcessPoolExecutor(max_workers=2) as pool:
                return list(pool.map(str, tasks))
    """)
    msgs = [f for f in fs if not f.suppressed and f.rule == "MS106"]
    assert len(msgs) == 1
    assert "imports jax" in msgs[0].message


def test_ms106_positive_fork_context_and_bare_pool():
    fs = lint("""
        import multiprocessing
        from concurrent.futures import ProcessPoolExecutor

        def bad(tasks):
            ctx = multiprocessing.get_context("fork")
            pool = multiprocessing.Pool(2)
            with ProcessPoolExecutor(
                    mp_context=multiprocessing.get_context("fork")) as p:
                pass
    """)
    # fork get_context (x2, one nested in the executor call), bare Pool,
    # and the executor configured with a fork context
    assert ids(fs) == ["MS106"] * 4


def test_ms106_negative_spawn():
    fs = lint("""
        import multiprocessing
        from concurrent.futures import ProcessPoolExecutor

        def sweep(tasks, run):
            with ProcessPoolExecutor(
                    max_workers=2,
                    mp_context=multiprocessing.get_context("spawn")) as pool:
                return list(pool.map(run, tasks))
    """)
    assert ids(fs) == []


# ------------------------------------------------------------------ MS107

def test_ms107_positive_accumulator():
    fs = lint("""
        def advance(self, windows):
            total = 0.0
            for dt in windows:
                total += dt * self.speed
            return total
    """, SIM)
    assert ids(fs) == ["MS107"]


def test_ms107_negative_counters_and_per_item():
    fs = lint("""
        def advance(self, rjobs, dt):
            n = 0
            events = 0.0
            for rj in rjobs:
                rj.since_ckpt_t += dt      # per-item update off the loop var
                n += 1                     # int counter
                events += 1.0              # integral-step counter, exact
    """, SIM)
    assert ids(fs) == []


def test_ms107_out_of_scope():
    fs = lint("""
        def outside(xs):
            t = 0.0
            for x in xs:
                t += x
            return t
    """, ANY)
    assert ids(fs) == []


# ------------------------------------------------------------------ MS108

def test_ms108_positive_wall_clock():
    fs = lint("""
        import time
        from datetime import datetime

        def stamp(self):
            self.t0 = time.time()
            return datetime.now()
    """, SIM)
    assert ids(fs) == ["MS108", "MS108"]


def test_ms108_negative_perf_counter_and_scope():
    fs = lint("""
        import time

        def profile(self, prof):
            t0 = time.perf_counter()      # designated profiling clock
            return t0
    """, SIM)
    assert ids(fs) == []
    # same wall-clock call outside the engine scope is not MS108's business
    fs = lint("""
        import time
        t0 = time.time()
    """, "src/repro/launch/sweep.py")
    assert ids(fs) == []


# ------------------------------------------------------------------ MS109

def test_ms109_positive_bare_except():
    fs = lint("""
        def load(path):
            try:
                return open(path).read()
            except:
                return None
    """, "src/repro/launch/x.py")
    assert ids(fs) == ["MS109"]


def test_ms109_positive_broad_swallow():
    fs = lint("""
        def run(task):
            try:
                work(task)
            except Exception:
                pass
            try:
                work(task)
            except (ValueError, BaseException):
                ...
    """, CORE)
    assert ids(fs) == ["MS109", "MS109"]


def test_ms109_negative_narrow_and_handled():
    # narrow optional-dependency gates and broad handlers that *act* on
    # the failure (record / re-raise / fall back) are the contract
    fs = lint("""
        def gated():
            try:
                import fancy_dep
            except ImportError:
                fancy_dep = None
            errors = []
            try:
                risky()
            except Exception as e:
                errors.append(str(e))
            try:
                risky()
            except Exception:
                raise RuntimeError("context")
    """, CORE)
    assert ids(fs) == []
    # and outside core/ + launch/ the rule does not apply
    fs = lint("""
        try:
            risky()
        except:
            pass
    """, ANY)
    assert ids(fs) == []


# ------------------------------------------------------------------ MS110

def test_ms110_positive_direct_and_wrapped():
    fs = lint("""
        def advance(self, dt):
            for rj in self._rjobs:
                rj.job.t_run += dt
            for i, rj in enumerate(self._rjobs):
                use(i, rj)
    """, SIM)
    assert ids(fs) == ["MS110", "MS110"]


def test_ms110_positive_alias_subscript_and_comprehension():
    fs = lint("""
        def refresh(self):
            rjs = self._rjobs
            for rj in rjs:                      # alias of a column
                touch(rj)
            for r in self._rjobs[2:]:           # subscripted column slice
                r.slot -= 1
            profs = [rj.job.profile for rj in self._spd]
    """, SIM)
    assert ids(fs) == ["MS110", "MS110", "MS110"]


def test_ms110_negative_non_column_loops_and_scope():
    # ordinary loops (the fleet, the event heap) are not per-resident
    fs = lint("""
        def settle(self, gpus, t):
            for g in gpus:
                g.advance(t)
            for jid in sorted(self.jobs):
                use(jid)
    """, SIM)
    assert ids(fs) == []
    # the same column walk outside core/sim/ is out of scope
    fs = lint("""
        def export(g):
            for rj in g._rjobs:
                yield rj
    """, ANY)
    assert ids(fs) == []


def test_ms110_replica_major_gather_recognized_in_batch_module():
    """batch.py's (B, G, S) export scatter — a comprehension over a column
    stored straight into a subscripted row — is the vectorization boundary
    itself: recognized without a suppression, but only in batch.py."""
    gather = """
        def resident_matrix(self):
            for i, g in enumerate(self.gpus):
                k = len(g._rjobs)
                remaining[b, gg, :k] = [rj.job.remaining for rj in g._rjobs]
    """
    assert ids(lint(gather, "src/repro/core/sim/batch.py")) == []
    # the identical gather elsewhere in core/sim/ still needs a suppression
    assert ids(lint(gather, SIM)) == ["MS110"]


def test_ms110_batch_module_plain_walks_still_fire():
    """Recognition is surgical: a column walk in batch.py that is not a
    subscript-store gather is still a flagged scalar loop."""
    fs = lint("""
        def walk(self, g):
            for rj in g._rjobs:
                touch(rj)
            xs = [rj.job.remaining for rj in g._rjobs]
    """, "src/repro/core/sim/batch.py")
    assert ids(fs) == ["MS110", "MS110"]


def test_ms110_suppression_with_reason_is_clean():
    fs = lint("""
        def advance(self, dt):
            # misolint: disable=MS110 -- measured: <=7 slots, scalar wins
            for rj in self._rjobs:
                rj.job.t_run += dt
    """, SIM)
    assert ids(fs) == []


def test_ms107_skips_loop_var_aliases_and_indexed_slots():
    """`job = rj.job; job.t_run += dt` and `ckw[i] += done` are per-item
    updates, not cross-iteration sums — the SoA column walks in GPU.advance
    rely on this."""
    fs = lint("""
        def advance(self, rjobs, spd, ckw, dt):
            for i, rj in enumerate(rjobs):
                job = rj.job
                job.t_run += dt
                ckw[i] += spd[i] * dt
    """, SIM)
    assert ids(fs) == []


# ------------------------------------------- suppressions & MS000 hygiene

def test_inline_suppression_with_reason():
    fs = lint("""
        def f(xs, acc=[]):  # misolint: disable=MS105 -- fixture: shared accumulator is the point
            return acc
    """)
    assert ids(fs) == []
    sup = [f for f in fs if f.suppressed]
    assert len(sup) == 1 and "shared accumulator" in sup[0].suppress_reason


def test_standalone_suppression_covers_next_statement_through_comments():
    fs = lint("""
        # misolint: disable=MS103 -- fixture: order provably cannot matter
        # here because the loop body is commutative
        for x in {1, 2, 3}:
            pass
    """)
    assert ids(fs) == []
    assert any(f.suppressed for f in fs)


def test_suppression_without_reason_is_flagged():
    fs = lint("""
        def f(xs, acc=[]):  # misolint: disable=MS105
            return acc
    """)
    assert ids(fs) == ["MS000"]


def test_unused_suppression_is_flagged():
    fs = lint("""
        x = 1  # misolint: disable=MS103 -- nothing fires here
    """)
    assert ids(fs) == ["MS000"]


def test_suppression_only_covers_named_rule():
    fs = lint("""
        def f(xs, acc=[]):  # misolint: disable=MS103 -- wrong rule id
            return acc
    """)
    # MS105 still fires; the MS103 suppression is unused -> MS000 too
    assert sorted(ids(fs)) == ["MS000", "MS105"]


# ----------------------------------------------------------- baseline

def test_baseline_filters_known_findings(tmp_path):
    src = textwrap.dedent("""
        def f(xs, acc=[]):
            return acc
    """)
    path = tmp_path / "mod.py"
    path.write_text(src)
    pairs, errors = lint_paths([str(path)], root=str(tmp_path))
    assert not errors
    active = [(f, fingerprint(f, ctx.lines)) for f, ctx in pairs
              if not f.suppressed]
    assert [f.rule for f, _ in active] == ["MS105"]

    bl_path = tmp_path / "baseline.json"
    Baseline().save(str(bl_path), make_entries(active), ruleset_hash())
    bl = Baseline.load(str(bl_path))
    tagged = bl.filter(active)
    assert all(base for _, base in tagged)          # grandfathered

    # a *new* finding (different line content) is not filtered
    path.write_text(src.replace("acc=[]", "acc=[], extra={}"))
    pairs, _ = lint_paths([str(path)], root=str(tmp_path))
    active = [(f, fingerprint(f, ctx.lines)) for f, ctx in pairs
              if not f.suppressed]
    tagged = bl.filter(active)
    assert [base for _, base in tagged] == [False, False]


def test_baseline_count_budget(tmp_path):
    src = "def f(a=[], b=[]):\n    return a, b\n"
    path = tmp_path / "mod.py"
    path.write_text(src)
    pairs, _ = lint_paths([str(path)], root=str(tmp_path))
    active = [(f, fingerprint(f, ctx.lines)) for f, ctx in pairs]
    assert len(active) == 2
    # both findings share one fingerprint (same line content); a baseline
    # recording count=1 only absorbs one of them
    fp = active[0][1]
    assert active[1][1] == fp
    bl = Baseline({fp: 1})
    tagged = bl.filter(active)
    assert sorted(base for _, base in tagged) == [False, True]


# ----------------------------------------------------------------- --fix

def test_fix_mutable_default_and_set_iteration(tmp_path):
    src = textwrap.dedent("""
        def f(xs, acc=[], *, m={}):
            "doc"
            for x in set(xs):
                acc.append(x)
            return acc, m
    """)
    ctx = build_context("mod.py", src)
    fixed, n = fix_source(ctx)
    assert n == 3
    compiled = compile(fixed, "mod.py", "exec")     # still valid python
    assert "acc=None" in fixed and "m=None" in fixed
    assert "if acc is None:" in fixed and "acc = []" in fixed
    assert "if m is None:" in fixed and "m = {}" in fixed
    assert "sorted(set(xs))" in fixed
    # the fixed source lints clean
    assert ids(lint_source(fixed, ANY)) == []
    # behavior: fresh default per call now
    ns = {}
    exec(compiled, ns)
    assert ns["f"]([2, 1]) == ([1, 2], {})
    assert ns["f"]([3]) == ([3], {})                # no shared-state leak


def test_fix_respects_suppressions():
    src = ("def f(xs, acc=[]):  "
           "# misolint: disable=MS105 -- fixture: intentional cache\n"
           "    return acc\n")
    ctx = build_context("mod.py", src)
    fixed, n = fix_source(ctx)
    assert n == 0 and fixed == src


# ------------------------------------------------------------------- CLI

def _run_cli(args, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", "misolint", *args],
                          capture_output=True, text=True, cwd=cwd, env=env)


def test_cli_json_output(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def f(a=[]):\n    return a\n")
    proc = _run_cli(["--format", "json", "--no-baseline", str(bad)])
    assert proc.returncode == 1
    doc = json.loads(proc.stdout)
    assert doc["counts"]["new"] == 1
    assert doc["findings"][0]["rule"] == "MS105"
    assert doc["ruleset"] == ruleset_hash()


def test_cli_exit_codes(tmp_path):
    good = tmp_path / "good.py"
    good.write_text("def f(a=None):\n    return a\n")
    assert _run_cli(["--no-baseline", str(good)]).returncode == 0
    broken = tmp_path / "broken.py"
    broken.write_text("def f(:\n")
    assert _run_cli(["--no-baseline", str(broken)]).returncode == 2


# ------------------------------------------------------------- meta-tests

def test_rule_table_is_complete():
    rules = all_rules()
    assert [r.id for r in rules] == ([f"MS10{i}" for i in range(1, 10)]
                                     + ["MS110"])
    assert all(r.title for r in rules)
    assert {r.id for r in rules if r.fixable} == {"MS103", "MS105"}


def test_ruleset_hash_is_stable():
    h = ruleset_hash()
    assert h == ruleset_hash()
    assert len(h) == 12 and int(h, 16) >= 0


def test_live_tree_is_clean_modulo_baseline():
    """The lint can never silently rot: src/ and tests/ must produce zero
    NEW findings under the committed baseline.  If this fails you either
    fix the finding, suppress it with a reason, or (deliberately!)
    regenerate the baseline — see README 'Static analysis'."""
    proc = _run_cli(["src", "tests"])
    assert proc.returncode == 0, (
        f"misolint found new violations:\n{proc.stdout}\n{proc.stderr}")


def test_live_tree_baseline_is_current_ruleset():
    with open(os.path.join(REPO, "tools", "lint",
                           "misolint_baseline.json")) as fh:
        doc = json.load(fh)
    assert doc["ruleset"] == ruleset_hash(), (
        "baseline was generated under a different rule set; re-triage and "
        "run: PYTHONPATH=src python -m misolint --write-baseline src/ tests/")
