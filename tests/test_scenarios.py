"""Scenario layer: every registered scenario yields valid, deterministic
traces, and each arrival process has its advertised shape."""
import numpy as np
import pytest

from repro.core import scenarios as sc


def test_registry_has_the_suite():
    names = sc.available_scenarios()
    for n in ("smoke", "poisson", "bursty", "diurnal", "heavy_tail",
              "flash_crowd", "mixed_qos"):
        assert n in names
    with pytest.raises(ValueError, match="unknown scenario"):
        sc.get_scenario("nope")
    with pytest.raises(ValueError, match="duplicate"):
        sc.register_scenario(sc.get_scenario("smoke"))


@pytest.mark.parametrize("name", sorted(sc.available_scenarios()))
def test_scenarios_generate_valid_deterministic_traces(name):
    s = sc.get_scenario(name)
    jobs = s.make_jobs(seed=0)
    assert len(jobs) >= s.n_jobs             # multi-instance may expand
    assert all(j.arrival >= 0 for j in jobs)
    assert all(j.work > 0 for j in jobs)
    key = lambda js: [(j.jid, j.arrival, j.work, j.profile.name) for j in js]
    assert key(jobs) == key(s.make_jobs(seed=0))          # deterministic
    if s.seed_sensitive:
        assert key(jobs) != key(s.make_jobs(seed=1))      # seed-sensitive
    else:
        # fixed-trace replay: every seed replays the identical workload
        assert key(jobs) == key(s.make_jobs(seed=1))
    short = s.make_jobs(seed=0, n_jobs=5)
    assert len(short) >= 5


def test_bursty_has_higher_variability_than_poisson():
    b = sc.bursty_arrivals(np.random.default_rng(0), 400, 60.0)
    p = sc.poisson_arrivals(np.random.default_rng(0), 400, 60.0)
    cv = lambda a: (np.std(np.diff(np.r_[0.0, a]))
                    / np.mean(np.diff(np.r_[0.0, a])))
    assert cv(p) == pytest.approx(1.0, abs=0.25)   # Poisson CV ~ 1
    assert cv(b) > 1.2 * cv(p)


def test_diurnal_modulates_rate():
    period = 14400.0
    a = sc.diurnal_arrivals(np.random.default_rng(0), 400, 45.0,
                            period_s=period, amplitude=0.8)
    peak = np.sum((a % period) < period / 2)       # sin > 0 half
    trough = np.sum((a % period) >= period / 2)
    assert peak > 1.3 * trough


def test_heavy_tail_has_extreme_gaps():
    a = sc.heavy_tail_arrivals(np.random.default_rng(0), 500, 60.0)
    iat = np.diff(np.r_[0.0, a])
    assert np.max(iat) > 20 * np.median(iat)


def test_flash_crowd_spike_is_dense():
    a = sc.flash_crowd_arrivals(np.random.default_rng(0), 200, 45.0)
    assert len(a) == 200 and np.all(np.diff(a) >= 0)
    # somewhere, 60 consecutive arrivals land within a tiny window — far
    # denser than Poisson at 45s mean (which would need ~2700s)
    win = min(a[i + 60] - a[i] for i in range(len(a) - 60))
    assert win < 300.0


def test_mixed_qos_populates_constraints():
    jobs = sc.get_scenario("mixed_qos").make_jobs(seed=0)
    assert any(j.qos_min_slice > 0 for j in jobs)
    assert any(j.min_mem_gb > 0 for j in jobs)
    assert any(j.mi_group is not None for j in jobs)


def test_scenarios_carry_fleet_specs():
    from repro.core.fleet import parse_fleet
    for name in sc.available_scenarios():
        fleet = parse_fleet(sc.get_scenario(name).fleet)
        assert len(fleet) >= 1
