"""Objective layer: the registry, golden bit-identity of the default
throughput objective across all 7 policies, power-model shapes, the
energy/edp selection semantics (QoS-floor + feasibility), bruteforce-oracle
agreement, batch/single equivalence, and the engine's energy integration
(including correlated rack failures)."""
import json
import os

import numpy as np
import pytest

from repro.core.estimators import OracleEstimator
from repro.core.fleet import (A100_POWER, H100_POWER, PowerModel,
                              parse_fleet)
from repro.core.jobs import WORKLOADS, Job
from repro.core.optimizer import (clear_memo, optimize_partition,
                                  optimize_partition_batch,
                                  optimize_partition_bruteforce)
from repro.core.partitions import a100_mig_space, h100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.sim.objectives import (EnergyObjective, Objective,
                                       available_objectives, get_objective,
                                       partition_watts, register_objective)
from repro.core.simulator import ClusterSim, SimConfig, simulate
from repro.core.traces import generate_trace

SPACE = a100_mig_space()
PM = PerfModel(SPACE)
EST = OracleEstimator(PM)
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "simulator_golden.json")

ALL_POLICIES = ("nopart", "optsta", "mpsonly", "miso", "oracle",
                "miso-frag", "srpt")


# --------------------------------------------------------------- registry

def test_registry_has_builtins():
    names = available_objectives()
    for n in ("throughput", "energy", "edp"):
        assert n in names
        assert get_objective(n).name == n


def test_unknown_objective_raises():
    with pytest.raises(ValueError, match="unknown objective"):
        get_objective("does-not-exist")
    with pytest.raises(ValueError, match="unknown objective"):
        ClusterSim([], SimConfig(objective="does-not-exist"), SPACE, PM, EST)


def test_duplicate_registration_raises():
    with pytest.raises(ValueError, match="duplicate"):
        @register_objective
        class Clash(Objective):                    # noqa: F811
            name = "energy"

            def score_rows(self, objs, watts):
                return objs
    assert get_objective("energy") is EnergyObjective   # unchanged


# ------------------------------------------------- golden (default = paper)

with open(GOLDEN) as f:
    _GOLD = json.load(f)
_GCFG = _GOLD["config"]


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_throughput_objective_bit_identical_to_golden(policy):
    """Explicitly threading objective="throughput" through the whole stack
    (SimConfig -> Policy -> optimizer) reproduces the recorded golden
    traces bit-for-bit for every policy: the objective refactor did not
    move the default behavior."""
    jobs = generate_trace(_GCFG["n_jobs"], lam_s=_GCFG["lam_s"], seed=0,
                          max_duration_s=_GCFG["max_duration_s"])
    m = simulate(jobs, SimConfig(n_gpus=_GCFG["n_gpus"], policy=policy,
                                 objective="throughput"), SPACE, PM, EST)
    g = _GOLD[f"{policy}/seed0"]
    assert m.avg_jct == g["avg_jct"]
    assert m.makespan == g["makespan"]
    assert m.stp == g["stp"]
    assert list(m.jcts) == g["jcts"]
    assert m.breakdown == g["breakdown"]


# ------------------------------------------------------------ power model

def test_power_model_sublinear_per_slice():
    """The power-partitioning paper's shape: a small slice draws more than
    its compute share of the full active power."""
    full = A100_POWER.active_w(1.0)
    one_g = A100_POWER.active_w(1 / 7)
    assert full == A100_POWER.max_active_w
    assert one_g > full / 7                 # disproportionate small-slice draw
    assert one_g < full                     # ... but still less than the whole
    # seven 1g slices burn more than one 7g slice: consolidation saves power
    assert 7 * one_g > full


def test_fleet_specs_carry_per_kind_power():
    a100, h100 = parse_fleet("a100:1+h100:1")
    assert a100.power is A100_POWER
    assert h100.power is H100_POWER
    assert h100.power.idle_w > a100.power.idle_w
    assert h100.power.max_active_w > a100.power.max_active_w


def test_partition_watts_matches_power_model():
    for m in (1, 2, 3):
        watts = partition_watts(SPACE, A100_POWER, m)
        rows = SPACE.partitions_of_len(m)
        assert watts.shape == (len(rows),)
        for w, part in zip(watts, rows):
            assert w == pytest.approx(A100_POWER.partition_w(SPACE, part))
        assert (watts > A100_POWER.idle_w).all()


# ------------------------------------------------- selection semantics

def test_energy_picks_cheaper_slice_above_floor():
    """A lone job running at ~full speed on 3g (a small job that can't use
    the whole GPU): energy takes the cheap slice; throughput keeps the
    full GPU."""
    sv = {7: 1.0, 4: 0.97, 3: 0.96, 2: 0.5, 1: 0.2}
    t = optimize_partition(SPACE, [sv], memo=False)
    e = optimize_partition(SPACE, [sv], memo=False, objective="energy",
                           power=A100_POWER)
    assert t.partition == (7,)
    assert e.partition == (3,)              # cheapest watts above the floor
    # 2g (speed 0.5) is cheaper still but violates the QoS floor
    assert EnergyObjective.qos_floor > 0.5
    w = lambda c: A100_POWER.partition_w(SPACE, c.partition)
    assert w(e) < w(t)


def test_energy_floor_rejects_slow_cheap_slices():
    """A job whose small-slice speeds fall below the floor stays on the
    full GPU: the floor is what keeps 'save watts' from starving jobs."""
    sv = {7: 1.0, 4: 0.9, 3: 0.85, 2: 0.5, 1: 0.2}
    e = optimize_partition(SPACE, [sv], memo=False, objective="energy",
                           power=A100_POWER)
    assert e.partition == (7,)              # nothing else clears 0.95


def test_edp_balances_speed_and_power():
    """EDP sits between throughput (speed-greedy) and energy (watt-greedy):
    with a shallow speed curve it drops to a cheap slice, with a steep one
    it keeps the full GPU."""
    shallow = {7: 1.0, 4: 0.97, 3: 0.96, 2: 0.6, 1: 0.25}
    d = optimize_partition(SPACE, [shallow], memo=False, objective="edp",
                           power=A100_POWER)
    assert d.partition != (7,)
    steep = {7: 1.0, 4: 0.55, 3: 0.5, 2: 0.3, 1: 0.1}
    d2 = optimize_partition(SPACE, [steep], memo=False, objective="edp",
                            power=A100_POWER)
    assert d2.partition == (7,)
    # within the shared floor, edp leans toward faster rows than energy:
    # for two jobs where (4, 3) clears the floor, energy takes the cheaper
    # watts while edp's T^2 term can prefer the faster multiset
    from repro.core.sim.objectives import EdpObjective
    assert EdpObjective.qos_floor == EnergyObjective.qos_floor


def test_objectives_memoize_independently():
    """The shared optimizer memo keys on objective identity: asking for
    throughput then energy with identical speeds must not alias."""
    sv = {7: 1.0, 4: 0.97, 3: 0.96, 2: 0.5, 1: 0.2}
    clear_memo()
    t1 = optimize_partition(SPACE, [sv])
    e1 = optimize_partition(SPACE, [sv], objective="energy", power=A100_POWER)
    t2 = optimize_partition(SPACE, [sv])
    e2 = optimize_partition(SPACE, [sv], objective="energy", power=A100_POWER)
    assert t1 == t2 and e1 == e2
    assert t1.partition != e1.partition


def test_miso_frag_honors_energy_floor():
    """miso-frag's tolerance scan must restrict to the objective's eligible
    rows: under energy, a watt-cheap slice below the QoS floor (here 3g at
    0.6 speed, whose T/W ratio beats the full GPU's) must not win."""
    jobs = [Job(jid=0, profile=WORKLOADS[0], arrival=0.0, work=300.0)]
    sim = ClusterSim(jobs, SimConfig(n_gpus=1, policy="miso-frag",
                                     objective="energy"), SPACE, PM, EST)
    sv = {7: 1.0, 4: 0.62, 3: 0.6, 2: 0.3, 1: 0.1}
    choice = sim.policy.choose_partition([sv], power=A100_POWER)
    assert choice.partition == (7,)
    # ... while a near-full-speed cheap slice is still taken
    sv2 = {7: 1.0, 4: 0.97, 3: 0.96, 2: 0.3, 1: 0.1}
    choice2 = sim.policy.choose_partition([sv2], power=A100_POWER)
    assert choice2.partition != (7,)


# ------------------------------------------- oracle / batch equivalence

def _random_speeds(rng, m, zero_frac=0.25):
    out = []
    for _ in range(m):
        sv = {}
        for s in SPACE.sizes:
            sv[s] = 0.0 if rng.random() < zero_frac else float(rng.random())
        out.append(sv)
    return out


def _score(space, power, objective, choice):
    w = power.partition_w(space, choice.partition)
    if objective == "energy":
        return choice.objective / w
    if objective == "edp":
        return choice.objective ** 2 / w
    return choice.objective


@pytest.mark.parametrize("objective", ["energy", "edp"])
@pytest.mark.parametrize("space", [a100_mig_space(), h100_mig_space()])
def test_objective_agrees_with_bruteforce(objective, space):
    """The vectorized objective path attains exactly the bruteforce
    oracle's score (choices may differ only on exact ties)."""
    rng = np.random.default_rng(42)
    pm_pow = A100_POWER
    for m in (1, 2, 3):
        for _ in range(20):
            speeds = _random_speeds(rng, m)
            fast = optimize_partition(space, speeds, memo=False,
                                      objective=objective, power=pm_pow)
            slow = optimize_partition_bruteforce(space, speeds,
                                                 objective=objective,
                                                 power=pm_pow)
            assert fast is not None and slow is not None
            assert _score(space, pm_pow, objective, fast) == \
                pytest.approx(_score(space, pm_pow, objective, slow))


@pytest.mark.parametrize("objective", ["energy", "edp"])
def test_batch_matches_singles(objective):
    rng = np.random.default_rng(7)
    mixes = [_random_speeds(rng, m) for m in (1, 2, 2, 3, 3, 3, 1)]
    clear_memo()
    singles = [optimize_partition(SPACE, sp, memo=False, objective=objective,
                                  power=A100_POWER) for sp in mixes]
    batched = optimize_partition_batch(SPACE, mixes, memo=False,
                                       objective=objective, power=A100_POWER)
    assert batched == singles
    # and with require_feasible + memo, as the policy layer calls it
    clear_memo()
    singles = [optimize_partition(SPACE, sp, require_feasible=True,
                                  objective=objective, power=A100_POWER)
               for sp in mixes]
    clear_memo()
    batched = optimize_partition_batch(SPACE, mixes, require_feasible=True,
                                       objective=objective, power=A100_POWER)
    assert batched == singles


# --------------------------------------- QoS safety (never violate floors)

def _assert_qos_safe(speeds, objective):
    """If any feasible row exists (throughput path finds one), the
    energy/edp choice must also be feasible: every job's assigned slice
    carries non-zero speed (zero encodes OOM / QoS-floor violation)."""
    ref = optimize_partition(SPACE, speeds, require_feasible=True,
                             memo=False)
    got = optimize_partition(SPACE, speeds, require_feasible=True,
                             memo=False, objective=objective,
                             power=A100_POWER)
    assert (ref is None) == (got is None)
    if got is not None:
        assert got.feasible
        for j, sv in enumerate(speeds):
            assert sv.get(got.partition[j], 0.0) > 0.0


@pytest.mark.parametrize("objective", ["energy", "edp"])
def test_energy_edp_never_pick_qos_violating_partition_seeded(objective):
    rng = np.random.default_rng(123)
    for m in (1, 2, 3, 4):
        for _ in range(25):
            _assert_qos_safe(_random_speeds(rng, m, zero_frac=0.4), objective)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @st.composite
    def _speed_mixes(draw):
        m = draw(st.integers(min_value=1, max_value=4))
        return [
            {s: draw(st.one_of(st.just(0.0),
                               st.floats(min_value=0.0, max_value=1.0)))
             for s in SPACE.sizes}
            for _ in range(m)]

    @settings(max_examples=60, deadline=None)
    @given(_speed_mixes(), st.sampled_from(["energy", "edp"]))
    def test_energy_edp_never_pick_qos_violating_partition(mix, objective):
        _assert_qos_safe(mix, objective)
except ImportError:                        # pragma: no cover
    pass


# -------------------------------------------------- engine integration

def test_simulation_integrates_energy():
    jobs = generate_trace(12, lam_s=30.0, seed=4, max_duration_s=900)
    m = simulate(jobs, SimConfig(n_gpus=2, policy="miso"), SPACE, PM, EST)
    assert len(m.jcts) == len(jobs)
    assert m.energy_j > 0.0
    # the idle floor alone over the makespan is a lower bound; 2 GPUs at
    # full tilt an upper one
    assert m.energy_j >= 2 * A100_POWER.idle_w * m.makespan * 0.5
    # per-GPU ceiling: idle + seven 1g slices (sublinearity makes that the
    # most power-hungry full partition, above max_active_w)
    ceiling = A100_POWER.idle_w + 7 * A100_POWER.active_w(1 / 7)
    assert m.avg_power_w <= 2 * ceiling * 1.05
    assert m.energy_per_job_j == pytest.approx(m.energy_j / len(jobs))
    assert m.jct_per_joule == pytest.approx(m.avg_jct / m.energy_j)


@pytest.mark.parametrize("objective", ["energy", "edp"])
def test_energy_objectives_complete_all_jobs(objective):
    jobs = generate_trace(15, lam_s=30.0, seed=9, max_duration_s=900)
    m = simulate(jobs, SimConfig(n_gpus=2, policy="miso",
                                 objective=objective), SPACE, PM, EST)
    assert len(m.jcts) == len(jobs)
    assert min(m.relative_jcts) >= 1.0 - 1e-9


def test_energy_objective_saves_joules_on_hetero_fleet():
    """The headline trade-off: on the mixed fleet, optimizing for energy
    spends fewer joules than optimizing for throughput."""
    jobs = generate_trace(20, lam_s=20.0, seed=11, max_duration_s=1200)
    fleet = parse_fleet("a100:2+h100:2")
    t = simulate(jobs, SimConfig(policy="miso", objective="throughput"),
                 fleet=fleet)
    e = simulate(jobs, SimConfig(policy="miso", objective="energy"),
                 fleet=fleet)
    assert len(t.jcts) == len(e.jcts) == len(jobs)
    assert e.energy_j < t.energy_j


def test_downtime_draws_no_power():
    """A GPU under repair is powered off: its energy integral excludes the
    repair window."""
    job = Job(jid=0, profile=WORKLOADS[0], arrival=0.0, work=100.0)
    sim = ClusterSim([job], SimConfig(n_gpus=1, policy="nopart"),
                     SPACE, PM, EST)
    g = sim.gpus[0]
    sim.t = 100.0
    g.advance(100.0)
    e0 = g.energy_j
    assert e0 == pytest.approx(A100_POWER.idle_w * 100.0)
    g.down_until = 200.0                    # down for [100, 200]
    sim.t = 250.0
    g.advance(250.0)
    # only the [200, 250] tail draws idle power
    assert g.energy_j - e0 == pytest.approx(A100_POWER.idle_w * 50.0)


# ------------------------------------------------ correlated rack faults

def test_rack_failure_takes_down_whole_rack():
    jobs = generate_trace(4, lam_s=5.0, seed=0, max_duration_s=600)
    cfg = SimConfig(n_gpus=4, policy="miso", rack_size=2, rack_mtbf_s=1e9,
                    repair_s=100.0)
    sim = ClusterSim(jobs, cfg, SPACE, PM, EST)
    sim.t = 50.0
    sim._on_rack_failure(0)
    assert sim.gpus[0].down_until == 150.0
    assert sim.gpus[1].down_until == 150.0
    assert sim.gpus[2].down_until == 0.0    # other rack untouched
    assert sim.gpus[3].down_until == 0.0
    # the next failure event for this rack was rescheduled
    assert any(ev[2] == "rack_failure" and ev[3] == 0 for ev in sim.events)


def test_rack_outage_scenario_completes():
    from repro.core.scenarios import get_scenario
    sc = get_scenario("rack_outage")
    assert sc.sim_kwargs["rack_size"] == 2
    assert sc.sim_kwargs["rack_mtbf_s"] > 0
    jobs = sc.make_jobs(seed=0)
    fleet = parse_fleet(sc.fleet)
    cfg = SimConfig(n_gpus=len(fleet), policy="miso", seed=0,
                    **sc.sim_kwargs)
    m = simulate(jobs, cfg, fleet=fleet)
    assert len(m.jcts) == len(jobs)         # everything survives the outages


def test_rack_failures_requeue_and_recover():
    """Force a mid-run rack outage and check both victims roll back and the
    trace still completes."""
    jobs = [Job(jid=i, profile=WORKLOADS[0], arrival=0.0, work=400.0)
            for i in range(2)]
    cfg = SimConfig(n_gpus=2, policy="miso", rack_size=2, rack_mtbf_s=900.0,
                    repair_s=120.0, ckpt_interval_s=200.0, seed=3)
    m = simulate(jobs, cfg, SPACE, PM, EST)
    assert len(m.jcts) == 2
