"""The fault-injection layer and the GPU health state machine.

Three contracts, in order of importance:

* **Zero-overhead guarantee** — with ``SimConfig.faults=()`` no injector
  exists and no fault RNG is drawn; golden traces stay bit-identical.
  Enabling injectors with all their rates at zero must also change nothing.
* **Blast-radius semantics** — the paper's §2 containment asymmetry: an MPS
  window has no error containment (every co-resident dies), MIG isolates
  the kill to one slice, checkpoint/idle windows absorb the shock.
* **Graceful degradation** — repeated soft faults quarantine the GPU and
  migrate residents off; repairs are full repairs; garbage estimates
  degrade to last-known-good/oracle instead of crashing Algorithm 1.
"""
import copy

import numpy as np
import pytest

from repro.core.estimators import OracleEstimator
from repro.core.jobs import WORKLOADS, Job
from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.scenarios import get_scenario
from repro.core.simulator import (DEGRADED, HEALTHY, QUARANTINED, CKPT,
                                  MIG_RUN, MPS_PROF, ClusterSim, SimConfig,
                                  available_fault_injectors,
                                  get_fault_injector, simulate)
from repro.core.traces import generate_trace

SPACE = a100_mig_space()
PM = PerfModel(SPACE)
EST = OracleEstimator(PM)


def _sim(jobs, **kw):
    cfg = SimConfig(**kw)
    return ClusterSim(copy.deepcopy(jobs), cfg, SPACE, PM, EST)


def _run_scenario(name, policy, seed, **over):
    from repro.core.fleet import parse_fleet
    sc = get_scenario(name)
    jobs = sc.make_jobs(seed)
    fleet = parse_fleet(sc.fleet)
    kw = dict(sc.sim_kwargs)
    kw.update(over)
    cfg = SimConfig(n_gpus=len(fleet), policy=policy, placer=sc.placer,
                    objective=sc.objective, seed=seed, **kw)
    return simulate(jobs, cfg, fleet=fleet)


class _ScriptedRng:
    """Stand-in fault RNG returning a scripted sequence of uniforms."""

    def __init__(self, vals):
        self.vals = list(vals)

    def random(self):
        return self.vals.pop(0)


# ----------------------------------------------------------- registry


def test_registry_lists_the_builtin_injectors():
    names = available_fault_injectors()
    assert names == sorted(names)
    for n in ("mps_blast", "flaky_reconfig", "straggler",
              "estimator_garbage"):
        assert n in names
        assert get_fault_injector(n).name == n
    with pytest.raises(ValueError, match="unknown fault injector"):
        get_fault_injector("definitely_not_a_fault")


# ------------------------------------------------- zero-overhead guarantee


def test_injectors_off_builds_no_hooks():
    sim = _sim([], n_gpus=2, policy="miso")
    assert sim.fault_injectors == {}
    assert sim._reconfig_hooks == [] and sim._est_hooks == []


def test_enabled_injectors_with_zero_rates_are_bit_identical():
    """All four injectors enabled but every rate at zero: no fault event is
    scheduled, no fault RNG is drawn, and the trace is bit-identical to the
    injectors-off golden run (the zero-overhead guarantee)."""
    jobs = generate_trace(24, lam_s=20.0, seed=3, max_duration_s=900)
    cfg = dict(n_gpus=4, policy="miso", seed=1, ckpt_interval_s=240.0)
    base = simulate(jobs, SimConfig(**cfg), SPACE, PM, EST)
    zero = simulate(jobs, SimConfig(
        faults=tuple(available_fault_injectors()),
        mps_crash_mtbf_s=0.0, reconfig_fail_p=0.0, straggler_mtbf_s=0.0,
        estimator_fault_p=0.0, **cfg), SPACE, PM, EST)
    assert np.array_equal(np.asarray(base.jcts), np.asarray(zero.jcts))
    assert base.stp == zero.stp and base.makespan == zero.makespan
    assert zero.n_fault_events == 0 and zero.work_lost_s == 0.0
    assert zero.goodput == zero.stp


def test_fault_stream_is_isolated_from_the_failure_schedule():
    """Injectors draw only from the dedicated ``fault_rng`` stream: arming
    chaos must not advance the main failure RNG or the MPS noise RNG."""
    jobs = generate_trace(6, lam_s=20.0, seed=0, max_duration_s=600)
    kw = dict(n_gpus=2, policy="miso", seed=7, gpu_mtbf_s=5000.0)
    plain = _sim(jobs, **kw)
    chaos = _sim(jobs, faults=("mps_blast", "straggler"),
                 mps_crash_mtbf_s=300.0, straggler_mtbf_s=400.0, **kw)
    # schedule_initial drew twice from chaos.fault_rng; the other streams
    # must still be at the same point in their sequences
    assert np.array_equal(plain.rng.random(8), chaos.rng.random(8))
    assert np.array_equal(plain.noise_rng.random(8), chaos.noise_rng.random(8))


def test_chaos_runs_are_deterministic():
    a = _run_scenario("flaky_fleet", "miso", seed=1)
    b = _run_scenario("flaky_fleet", "miso", seed=1)
    assert np.array_equal(np.asarray(a.jcts), np.asarray(b.jcts))
    assert a.goodput == b.goodput and a.n_fault_events == b.n_fault_events
    assert a.work_lost_s == b.work_lost_s


def test_metrics_robustness_fields_default_clean():
    jobs = generate_trace(8, lam_s=20.0, seed=2, max_duration_s=600)
    m = simulate(jobs, SimConfig(n_gpus=2, policy="miso"), SPACE, PM, EST)
    assert m.goodput == m.stp and m.gross_stp == m.stp
    assert m.work_lost_s == 0.0 and m.n_fault_events == 0
    assert m.n_quarantines == 0 and m.n_migrations == 0
    assert m.quarantine_occupancy == 0.0


# -------------------------------------------------- blast-radius asymmetry


def test_mps_blast_kills_every_coresident():
    """No error containment during an MPS window: all residents die, each
    rolled back to its last checkpoint and restarted."""
    jobs = [Job(jid=i, profile=WORKLOADS[0], arrival=0.0, work=5000.0)
            for i in range(3)]
    sim = _sim(jobs, n_gpus=1, policy="mpsonly", mps_only_max_jobs=3,
               ckpt_interval_s=1e9, faults=("mps_blast",),
               mps_crash_mtbf_s=1e9)
    for i in range(3):
        sim._on_arrival(sim.jobs[i])
    g = sim.gpus[0]
    assert g.phase == MPS_PROF and len(g.jobs) == 3
    sim.t = 50.0
    sim.fault_injectors["mps_blast"].on_event(None)
    fs = sim.fstats
    assert fs["n_blasts"] == 1 and fs["blast_jobs"] == 3
    assert fs["blast_radius_max"] == 3 and fs["n_faults"] == 1
    assert g.health == DEGRADED
    # no checkpoint ever completed: every victim lost all its progress
    for j in sim.jobs.values():
        assert j.remaining == pytest.approx(5000.0)
    assert sim.lost_agg.total > 0.0
    # the GPU stayed in service, so the eager re-admit already re-placed
    # the victims (time-to-recover 0); none of them vanished
    assert sim.recover_agg.count == 3
    assert len(g.jobs) + len(sim.queue) == 3


def test_mig_blast_kills_exactly_one_slice():
    """Hardware isolation under MIG: one random sliced job dies, its
    slice-mates keep running untouched."""
    jobs = [Job(jid=i, profile=WORKLOADS[0], arrival=0.0, work=5000.0)
            for i in range(2)]
    sim = _sim(jobs, n_gpus=1, policy="miso", ckpt_interval_s=1e9,
               faults=("mps_blast",), mps_crash_mtbf_s=1e9)
    for i in range(2):
        sim._on_arrival(sim.jobs[i])
    g = sim.gpus[0]
    sim.t = g.phase_end
    sim.end_phase(g)                        # MPS sweep -> CKPT
    sim.t = g.phase_end
    sim.end_phase(g)                        # CKPT -> MIG_RUN
    assert g.phase == MIG_RUN and len(g.jobs) == 2
    before = {jid: sim.jobs[jid].remaining for jid in g.jobs}
    sim.t += 10.0
    sim.fault_injectors["mps_blast"].on_event(None)
    assert sim.fstats["n_faults"] == 1
    assert sim.fstats["n_blasts"] == 0      # MIG kills are not blasts
    # exactly one victim rolled back to its last durable checkpoint (the
    # CKPT that just completed); the survivor kept its 10s of progress
    rolled = [jid for jid in before
              if sim.jobs[jid].remaining >= before[jid] - 1e-9]
    assert len(rolled) == 1
    survivor = next(jid for jid in before if jid not in rolled)
    assert sim.jobs[survivor].remaining < before[survivor]


def test_blast_is_absorbed_while_checkpointing():
    jobs = [Job(jid=0, profile=WORKLOADS[0], arrival=0.0, work=5000.0)]
    sim = _sim(jobs, n_gpus=1, policy="miso", ckpt_interval_s=1e9,
               faults=("mps_blast",), mps_crash_mtbf_s=1e9)
    sim._on_arrival(sim.jobs[0])
    g = sim.gpus[0]
    sim.t = g.phase_end
    sim.end_phase(g)
    assert g.phase == CKPT
    sim.fault_injectors["mps_blast"].on_event(None)
    assert sim.fstats["n_faults"] == 0 and g.health == HEALTHY
    assert 0 in g.jobs and sim.queue == []


def test_blast_asymmetry_end_to_end():
    """Same chaos scenario: a policy living in MPS windows takes multi-job
    blasts; MISO's short probe windows + MIG isolation keep the radius at
    (at most) one."""
    mps = _run_scenario("mps_blast", "mpsonly", seed=1)
    mig = _run_scenario("mps_blast", "miso", seed=1)
    assert mps.blast_radius_max >= 2
    assert mig.blast_radius_max <= 1


# ----------------------------------------------------- flaky reconfigures


def _flaky_sim():
    jobs = [Job(jid=0, profile=WORKLOADS[0], arrival=0.0, work=5000.0)]
    sim = _sim(jobs, n_gpus=1, policy="miso", ckpt_interval_s=1e9,
               faults=("flaky_reconfig",), reconfig_fail_p=0.5,
               reconfig_retry_s=10.0, reconfig_max_retries=2,
               repair_s=300.0)
    sim._on_arrival(sim.jobs[0])
    g = sim.gpus[0]
    sim.t = g.phase_end
    sim.end_phase(g)                        # MPS sweep -> CKPT
    assert g.phase == CKPT
    return sim, g


def test_flaky_reconfig_retries_with_exponential_backoff():
    sim, g = _flaky_sim()
    sim.fault_rng = _ScriptedRng([0.0, 0.0, 0.99])   # fail, fail, succeed
    t0 = g.phase_end
    sim.t = t0
    sim.end_phase(g)                        # attempt 1 fails
    assert g.phase == CKPT and g.phase_end == pytest.approx(t0 + 10.0)
    assert not g.sched_ok and not g._in_index
    assert sim.fstats["n_reconfig_retries"] == 1
    sim.t = g.phase_end
    sim.end_phase(g)                        # attempt 2 fails: backoff doubles
    assert g.phase_end == pytest.approx(sim.t + 20.0)
    sim.t = g.phase_end
    sim.end_phase(g)                        # attempt 3 lands
    assert g.phase == MIG_RUN
    assert g.sched_ok and g.reconfig_tries == 0 and g._in_index
    # a retried checkpoint is only durable once the op lands
    assert g.jobs[0].since_ckpt_work == 0.0


def test_flaky_reconfig_exhaustion_escalates_to_gpu_fault():
    sim, g = _flaky_sim()
    sim.fault_rng = _ScriptedRng([0.0, 0.0, 0.0])    # never lands
    for _ in range(3):                      # retries 1, 2, then escalation
        sim.t = g.phase_end
        sim.end_phase(g)
    assert sim.fstats["n_faults"] == 1 and g.health == DEGRADED
    assert g.down_until == pytest.approx(sim.t + 300.0)
    assert sim.queue == [0]                 # resident evicted and requeued
    assert g.sched_ok and g.reconfig_tries == 0   # repairs are full repairs


def test_quarantine_during_inflight_reconfig_retry_resets_cleanly():
    """The interaction case: a GPU mid-backoff (unschedulable, retries
    pending) gets quarantined by an unrelated fault — the hardware swap
    must clear the retry state and the repair must restore service."""
    sim, g = _flaky_sim()
    sim.fault_rng = _ScriptedRng([0.0])
    sim.cfg.quarantine_faults = 2
    sim.cfg.quarantine_window_s = 1e9
    sim.cfg.quarantine_repair_s = 100.0
    sim.t = g.phase_end
    sim.end_phase(g)                        # attempt 1 fails: mid-backoff
    assert not g.sched_ok and g.reconfig_tries == 1
    sim.t += 1.0
    assert not sim.record_fault(g)          # first soft fault: degraded
    assert g.health == DEGRADED and not g.sched_ok   # retry state survives
    sim.t += 1.0
    assert sim.record_fault(g)              # second soft fault -> quarantine
    assert g.health == QUARANTINED
    assert g.sched_ok and g.reconfig_tries == 0 and g.speed_fault == 1.0
    assert not g._in_index and g.fault_times == []
    assert sim.queue == [0]                 # resident migrated off
    assert sim.fstats["n_quarantines"] == 1 and sim.fstats["n_migrations"] == 1
    sim.t = g.down_until
    sim._sync_up()                          # repair promotion
    assert g.health == HEALTHY and g._in_index


# ------------------------------------------------------------- stragglers


def test_straggler_degrades_speed_then_recovers():
    jobs = [Job(jid=0, profile=WORKLOADS[0], arrival=0.0, work=5000.0)]
    sim = _sim(jobs, n_gpus=1, policy="nopart", faults=("straggler",),
               straggler_mtbf_s=1e9, straggler_factor=0.25,
               straggler_recover_s=100.0)
    sim._on_arrival(sim.jobs[0])
    g = sim.gpus[0]
    assert g.jobs[0].speed == 1.0
    inj = sim.fault_injectors["straggler"]
    sim.t = 10.0
    inj.on_event(None)                      # onset
    assert g.speed_fault == 0.25 and g.health == DEGRADED
    assert g.jobs[0].speed == pytest.approx(0.25)
    assert sim.fstats["n_faults"] == 1
    sim.t = 110.0
    inj.on_event(g.gid)                     # recovery event
    assert g.speed_fault == 1.0 and g.health == HEALTHY
    assert g.jobs[0].speed == pytest.approx(1.0)


# ------------------------------------------------------ estimator garbage


def test_garbage_estimates_degrade_to_a_safe_fallback():
    jobs = [Job(jid=0, profile=WORKLOADS[0], arrival=0.0, work=5000.0)]
    sim = _sim(jobs, n_gpus=1, policy="miso", faults=("estimator_garbage",),
               estimator_fault_p=1.0)
    sim._on_arrival(sim.jobs[0])
    g = sim.gpus[0]
    menu = {s: 0.5 for s in SPACE.slices}
    for garbage in ({s: float("nan") for s in menu},
                    {s: -3.0 for s in menu},
                    {s: 0.0 for s in menu}):
        safe = sim.policy.sanitize_estimate(g, 0, dict(garbage))
        vals = list(safe.values())
        assert all(np.isfinite(v) and 0.0 <= v <= 1.5 for v in vals)
        assert max(vals) > 0.0
    # a valid estimate passes through untouched
    assert sim.policy.sanitize_estimate(g, 0, dict(menu)) == menu


def test_estimator_garbage_run_survives_end_to_end():
    jobs = generate_trace(10, lam_s=20.0, seed=5, max_duration_s=600)
    m = simulate(jobs, SimConfig(n_gpus=2, policy="miso", seed=5,
                                 faults=("estimator_garbage",),
                                 estimator_fault_p=1.0), SPACE, PM, EST)
    assert len(m.jcts) == len(jobs)
    assert np.isfinite(m.jcts).all() and m.stp > 0.0
    assert m.n_fault_events == 0            # corrupted estimates, no kills


# ------------------------------------------------- health state machine


def test_health_window_prunes_old_faults():
    sim = _sim([], n_gpus=1, policy="miso", quarantine_faults=2,
               quarantine_window_s=100.0, quarantine_repair_s=50.0)
    g = sim.gpus[0]
    sim.t = 0.0
    assert not sim.record_fault(g)
    assert g.health == DEGRADED and g.fault_times == [0.0]
    sim.t = 200.0                           # first fault aged out
    assert not sim.record_fault(g)
    assert g.fault_times == [200.0]
    sim.t = 250.0                           # two faults inside the window
    assert sim.record_fault(g)
    assert g.health == QUARANTINED
    assert g.down_until == pytest.approx(300.0)
    assert sim.fstats["quarantine_gpu_s"] == pytest.approx(50.0)
    sim.t = 300.0
    sim._sync_up()
    assert g.health == HEALTHY and g._in_index


def test_hard_faults_never_feed_the_quarantine_tracker():
    sim = _sim([], n_gpus=1, policy="miso", quarantine_faults=1)
    g = sim.gpus[0]
    for t in (10.0, 20.0, 30.0):
        sim.t = t
        assert not sim.record_fault(g, hard=True)
    assert g.fault_times == [] and g.health == HEALTHY
    assert sim.fstats["n_faults"] == 3 and sim.fstats["n_quarantines"] == 0


def test_rack_outage_during_mps_window_is_a_hard_fault():
    """A rack power event mid-MPS takes the whole block down (everything
    rolled back) but never trips quarantine: hard faults already pay a full
    repair window."""
    jobs = [Job(jid=i, profile=WORKLOADS[0], arrival=0.0, work=5000.0)
            for i in range(2)]
    sim = _sim(jobs, n_gpus=2, policy="mpsonly", rack_size=2,
               rack_mtbf_s=1e9, repair_s=100.0, quarantine_faults=1)
    for i in range(2):
        sim._on_arrival(sim.jobs[i])
    assert all(g.phase == MPS_PROF for g in sim.gpus if g.jobs)
    sim.t = 40.0
    sim._on_rack_failure(0)
    assert all(sim.t < g.down_until for g in sim.gpus)
    assert sorted(sim.queue) == [0, 1]
    assert sim.fstats["n_faults"] == 2
    assert sim.fstats["n_quarantines"] == 0   # hard, despite threshold 1
    assert all(g.health == HEALTHY for g in sim.gpus)


def test_migration_lands_then_destination_fails():
    """The interaction case: a quarantine migrates the resident onto the
    other GPU, which then fails — the job survives both hops with only its
    since-checkpoint work destroyed each time."""
    jobs = [Job(jid=0, profile=WORKLOADS[0], arrival=0.0, work=5000.0)]
    sim = _sim(jobs, n_gpus=2, policy="nopart", quarantine_faults=1,
               quarantine_repair_s=500.0, repair_s=100.0,
               ckpt_interval_s=1e9)
    sim._on_arrival(sim.jobs[0])
    g0, g1 = sim.gpus
    assert 0 in g0.jobs
    sim.t = 30.0
    assert sim.record_fault(g0)             # quarantine g0: migrate + re-place
    assert g0.health == QUARANTINED and 0 in g1.jobs
    assert sim.fstats["n_migrations"] == 1
    sim.t = 60.0
    sim._on_failure(g1)                     # destination dies too
    assert sim.queue == [0] and sim.t < g1.down_until
    assert sim.jobs[0].remaining == pytest.approx(5000.0)  # no ckpt yet
    sim.t = max(g0.down_until, g1.down_until)
    sim.policy.admit()                      # both repaired: placed again
    assert sum(0 in g.jobs for g in sim.gpus) == 1
    assert all(g.health == HEALTHY for g in sim.gpus)
    assert sim.recover_agg.count == 2       # one wait per fault eviction


# --------------------------------------------------- chaos scenarios e2e


def test_chaos_scenarios_are_seed_sensitive():
    for name in ("mps_blast", "flaky_fleet", "flaky_fleet_noq"):
        assert get_scenario(name).seed_sensitive
    a = _run_scenario("flaky_fleet", "miso", seed=0)
    b = _run_scenario("flaky_fleet", "miso", seed=1)
    assert (a.n_fault_events, a.goodput) != (b.n_fault_events, b.goodput)


def test_flaky_fleet_completes_and_accounts_for_lost_work():
    m = _run_scenario("flaky_fleet", "miso", seed=1)
    sc = get_scenario("flaky_fleet")
    assert len(m.jcts) == sc.n_jobs and np.isfinite(m.jcts).all()
    assert m.n_fault_events > 0
    assert m.work_lost_s > 0.0
    assert m.gross_stp == pytest.approx(
        m.goodput + m.work_lost_s / (m.makespan * 4))
    assert 0.0 <= m.quarantine_occupancy < 1.0


def test_quarantine_and_migration_recover_goodput():
    """The headline graceful-degradation claim: on the flaky fleet, turning
    the health machine ON (quarantine + migration) beats leaving faulty
    GPUs in service, in mean goodput over seeds."""
    on, off = [], []
    for seed in range(3):
        on.append(_run_scenario("flaky_fleet", "miso", seed).goodput)
        off.append(_run_scenario("flaky_fleet_noq", "miso", seed).goodput)
    assert float(np.mean(on)) > float(np.mean(off))
