"""Heterogeneous fleets: spec parsing, per-GPU space/perf routing, and the
golden guarantee that homogeneous runs are bit-identical through the fleet
code path."""
import copy
import os
from dataclasses import replace

import pytest

from repro.core.estimators import OracleEstimator, UNetEstimator
from repro.core.fleet import (available_kinds, describe_fleet,
                              homogeneous_fleet, parse_fleet)
from repro.core.jobs import WORKLOADS, Job
from repro.core.partitions import a100_mig_space, h100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.simulator import ClusterSim, SimConfig, simulate
from repro.core.traces import generate_trace

SPACE = a100_mig_space()
PM = PerfModel(SPACE)
EST = OracleEstimator(PM)


# ------------------------------------------------------------- fleet specs

def test_parse_fleet():
    fleet = parse_fleet("a100:2+h100:3")
    assert [s.kind for s in fleet] == ["a100"] * 2 + ["h100"] * 3
    assert fleet[0] is fleet[1]              # one shared spec per kind
    assert fleet[2] is fleet[4]
    assert describe_fleet(fleet) == "a100:2+h100:3"
    assert parse_fleet("h100")[0].kind == "h100"
    assert len(parse_fleet("a100:1,h100:1")) == 2   # comma also accepted
    assert set(available_kinds()) >= {"a100", "h100", "tpu"}


def test_parse_fleet_rejects_garbage():
    with pytest.raises(ValueError, match="unknown accelerator kind"):
        parse_fleet("b200:4")
    with pytest.raises(ValueError, match="count"):
        parse_fleet("a100:0")
    with pytest.raises(ValueError, match="count"):
        parse_fleet("a100:x")
    with pytest.raises(ValueError, match="empty"):
        parse_fleet("")


def test_h100_space_doubles_memory():
    h = h100_mig_space()
    assert h.sizes == SPACE.sizes            # same GPC slice menu
    assert h.name != SPACE.name              # distinct optimizer memo key
    for s in h.sizes:
        assert h.slice_mem_gb(s) == 2 * SPACE.slice_mem_gb(s)
    assert len(h.partitions) == len(SPACE.partitions)   # same 4g/3g exclusion


def test_per_kind_predictor_artifacts_ship():
    """The trained per-kind artifacts are committed and route through
    ``GPUSpec.estimator`` as U-Net estimators for every GPU kind we train
    for — heterogeneous sweeps no longer silently run the oracle
    (ROADMAP's per-type-predictor item)."""
    for spec in parse_fleet("a100:1+h100:1"):
        assert spec.artifact is not None, \
            f"no predictor artifact shipped for {spec.kind}"
        assert os.path.exists(spec.artifact)
        assert isinstance(spec.estimator, UNetEstimator)
        # the estimator is bound to the kind's own space/perf model
        assert spec.estimator.pm is spec.pm


def test_gpu_carries_own_spec():
    fleet = parse_fleet("a100:1+h100:1")
    cfg = SimConfig(policy="miso")          # default n_gpus=8
    sim = ClusterSim([], cfg, fleet=fleet)
    assert sim.cfg.n_gpus == 2
    assert cfg.n_gpus == 8                  # caller's config not mutated
    a, h = sim.gpus
    assert a.space.name == "a100-mig" and h.space.name == "h100-mig"
    assert a.pm.hw.mem_gb == 40.0 and h.pm.hw.mem_gb == 80.0
    assert a.estimator is not h.estimator
    assert h.speed_scale > a.speed_scale == 1.0


# --------------------------------------------------- homogeneous identity

@pytest.mark.parametrize("policy",
                         ["miso", "oracle", "mpsonly", "nopart", "optsta",
                          "miso-frag", "srpt"])
def test_homogeneous_fleet_bit_identical(policy):
    """The fleet code path reproduces the legacy (space, pm) call exactly."""
    jobs = generate_trace(20, lam_s=30.0, seed=3, max_duration_s=900)
    legacy = simulate(jobs, SimConfig(n_gpus=3, policy=policy), SPACE, PM, EST)
    via_fleet = simulate(jobs, SimConfig(n_gpus=3, policy=policy),
                         fleet=homogeneous_fleet(SPACE, PM, EST, 3))
    assert legacy.avg_jct == via_fleet.avg_jct
    assert legacy.makespan == via_fleet.makespan
    assert list(legacy.jcts) == list(via_fleet.jcts)
    assert legacy.breakdown == via_fleet.breakdown


# --------------------------------------------------------- mixed fleets

@pytest.mark.parametrize("policy",
                         ["miso", "oracle", "mpsonly", "nopart", "optsta",
                          "miso-frag", "srpt"])
def test_mixed_fleet_completes_all_jobs(policy):
    jobs = generate_trace(25, lam_s=25.0, seed=5, max_duration_s=1200)
    m = simulate(jobs, SimConfig(policy=policy),
                 fleet=parse_fleet("a100:2+h100:2"))
    assert len(m.jcts) == len(jobs)


def test_h100_fleet_faster_than_a100():
    """speed_scale routes into job progress: the same trace finishes faster
    on an h100-only fleet than on an a100-only one."""
    jobs = generate_trace(20, lam_s=20.0, seed=6, max_duration_s=900)
    a = simulate(jobs, SimConfig(policy="oracle"), fleet=parse_fleet("a100:2"))
    h = simulate(jobs, SimConfig(policy="oracle"), fleet=parse_fleet("h100:2"))
    assert h.avg_jct < a.avg_jct


def test_memory_constraint_routes_to_h100():
    """A 45GB job overflows every a100 slice (40GB max) but fits h100
    7g.80gb — per-GPU mem_ok / spare_slice_ok must see the right capacity."""
    big = replace(WORKLOADS[0], name="big45", mem_gb=45.0)
    jobs = [Job(jid=0, profile=big, arrival=0.0, work=300.0)]
    m = simulate(jobs, SimConfig(policy="miso"),
                 fleet=parse_fleet("a100:1+h100:1"))
    assert len(m.jcts) == 1
    with pytest.raises(ValueError, match="no completed jobs"):
        simulate(jobs, SimConfig(policy="miso"), fleet=parse_fleet("a100:2"))


def test_mixed_fleet_with_failures_completes():
    jobs = generate_trace(15, lam_s=25.0, seed=7, max_duration_s=900)
    m = simulate(jobs, SimConfig(policy="miso", gpu_mtbf_s=1200.0,
                                 repair_s=150.0, seed=1),
                 fleet=parse_fleet("a100:2+h100:2"))
    assert len(m.jcts) == len(jobs)
