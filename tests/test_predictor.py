"""U-Net predictor: shapes, learnability, permutation augmentation, and the
trained-artifact accuracy band (paper: val MAE ~= 0.017)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.predictor import dataset as ds
from repro.core.predictor import linreg, unet
from repro.core.predictor.train import fit_heads, train_predictor

PM = PerfModel(a100_mig_space())
ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "predictor.npz")


def test_unet_shapes():
    net = unet.UNet.create(jax.random.PRNGKey(0))
    x = jnp.ones((5, 3, 7))
    y = net(x)
    assert y.shape == (5, 3, 7)
    assert bool(jnp.all((y > 0) & (y <= 1)))
    single = net(jnp.ones((3, 7)))
    assert single.shape == (3, 7)


def test_dataset_shapes_and_normalization():
    data = ds.generate_dataset(PM, mixes_per_count=3, seed=0)
    x = data["train_x"]
    y = data["train_y"]
    assert x.shape[1:] == (3, 7) and y.shape[1:] == (3, 7)
    # per-column max normalization -> column max == 1
    assert np.allclose(x.max(axis=1), 1.0, atol=1e-5)
    assert np.allclose(y.max(axis=1), 1.0, atol=1e-5)
    # paper counts: mixes * 5 permutation variants
    total = len(data["train_x"]) + len(data["val_x"])
    assert total == 3 * 7 * 5


def test_permutation_augmentation_consistency():
    """Permuting job columns permutes predictions accordingly (approximately
    — conv padding breaks exact equivariance, the augmentation teaches it)."""
    profs = [PM and w for w in []]  # noqa
    from repro.core.jobs import WORKLOADS
    mps, mig, lin, m = ds.mix_to_matrices(PM, list(WORKLOADS[:4]))
    perm = np.array([3, 1, 0, 2, 4, 5, 6])
    mps_p, mig_p, lin_p, _ = ds.mix_to_matrices(PM, [WORKLOADS[i] for i in
                                                     [3, 1, 0, 2]])
    assert np.allclose(mps[:, perm][:, :4], mps_p[:, :4], atol=1e-5)
    assert np.allclose(mig[:, perm][:, :4], mig_p[:, :4], atol=1e-5)


def test_training_beats_mean_predictor():
    data = ds.generate_dataset(PM, mixes_per_count=25, seed=1)
    baseline = float(np.abs(data["val_y"] - data["train_y"].mean(0,
                     keepdims=True)).mean())
    params, hist = train_predictor(data, epochs=30, lr=8e-4, verbose=False)
    assert hist["val_mae"][-1] < 0.9 * baseline


def test_linreg_heads_fit():
    data = ds.generate_dataset(PM, mixes_per_count=40, seed=2)
    heads = fit_heads(data)
    assert heads["r2"].min() > 0.5
    pred = linreg.apply_linreg(heads, data["val_y"].transpose(0, 2, 1)
                               .reshape(-1, 3))
    assert pred.shape[-1] == 2
    assert pred.min() >= 0.0 and pred.max() <= 1.0


@pytest.mark.skipif(not os.path.exists(ARTIFACT),
                    reason="trained artifact not present")
def test_trained_artifact_accuracy():
    """The shipped predictor must be within 2x of the paper's 1.7% MAE."""
    from repro.core.predictor.train import load_artifact
    params, heads, hist = load_artifact(ARTIFACT)
    assert hist["val_mae"][-1] < 0.035
    net = unet.UNet(params)
    data = ds.generate_dataset(PM, mixes_per_count=10, seed=123)  # fresh mixes
    pred = np.asarray(net(jnp.asarray(data["val_x"])))
    mae = float(np.abs(pred - data["val_y"]).mean())
    assert mae < 0.05


@pytest.mark.parametrize("kind", ["a100", "h100"])
def test_per_kind_artifact_accuracy(kind):
    """Each committed per-kind artifact holds the same accuracy band on
    fresh mixes drawn from its *own* kind's ground truth."""
    from repro.core.fleet import default_artifact_path
    from repro.core.predictor.train import kind_perfmodel, load_artifact
    path = default_artifact_path(kind)
    assert path is not None, f"artifacts/predictor_{kind}.npz not committed"
    params, heads, hist = load_artifact(path)
    assert hist["val_mae"][-1] < 0.035
    net = unet.UNet(params)
    pm = kind_perfmodel(kind)
    data = ds.generate_dataset(pm, mixes_per_count=10, seed=123)
    pred = np.asarray(net(jnp.asarray(data["val_x"])))
    assert float(np.abs(pred - data["val_y"]).mean()) < 0.05


def test_kind_perfmodel_rejects_unknown():
    from repro.core.predictor.train import kind_perfmodel
    with pytest.raises(ValueError, match="no trainable predictor"):
        kind_perfmodel("tpu")
