"""Policy layer: golden-trace equivalence with the seed simulator, the
registry, the optimizer memo cache, the zero-dead-time profiling path, and
the two post-refactor policies (miso-frag / srpt)."""
import json
import os

import pytest

from repro.core.estimators import OracleEstimator
from repro.core.jobs import WORKLOADS, Job
from repro.core.optimizer import (clear_memo, memo_stats, optimize_partition)
from repro.core.partitions import a100_mig_space
from repro.core.perfmodel import PerfModel
from repro.core.simulator import (ClusterSim, MPS_PROF, Policy, SimConfig,
                                  available_policies, get_policy,
                                  register_policy, simulate)
from repro.core.traces import generate_trace

SPACE = a100_mig_space()
PM = PerfModel(SPACE)
EST = OracleEstimator(PM)
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "simulator_golden.json")

LEGACY = ("nopart", "optsta", "mpsonly", "miso", "oracle")
NEW = ("miso-frag", "srpt")


# ---------------------------------------------------------------- golden

with open(GOLDEN) as f:
    _GOLD = json.load(f)
_GCFG = _GOLD["config"]


@pytest.mark.parametrize("policy", LEGACY + NEW)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_golden_trace_equivalence(policy, seed):
    """All seven policies reproduce the recorded (pre-vectorization)
    simulator's metrics bit-for-bit: the legacy five against the original
    seed goldens, miso-frag and srpt against goldens recorded just before
    the scheduler hot paths were vectorized."""
    jobs = generate_trace(_GCFG["n_jobs"], lam_s=_GCFG["lam_s"], seed=seed,
                          max_duration_s=_GCFG["max_duration_s"])
    m = simulate(jobs, SimConfig(n_gpus=_GCFG["n_gpus"], policy=policy),
                 SPACE, PM, EST)
    g = _GOLD[f"{policy}/seed{seed}"]
    assert m.avg_jct == g["avg_jct"]
    assert m.makespan == g["makespan"]
    assert m.stp == g["stp"]
    assert m.p50_jct == g["p50_jct"]
    assert m.p90_jct == g["p90_jct"]
    assert list(m.jcts) == g["jcts"]
    assert m.breakdown == g["breakdown"]


# --------------------------------------------------------------- registry

def test_all_policies_registered():
    for name in LEGACY + NEW:
        assert name in available_policies()
        assert get_policy(name).name == name


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        get_policy("does-not-exist")
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        ClusterSim([], SimConfig(policy="does-not-exist"), SPACE, PM, EST)


def test_duplicate_registration_raises():
    with pytest.raises(ValueError, match="duplicate"):
        @register_policy
        class Clash(Policy):                       # noqa: F811
            name = "miso"

            def pick_gpu(self, job):
                return None

            def on_place(self, g, job):
                pass

            def on_completion(self, g, job):
                pass
    assert get_policy("miso").__name__ == "MisoPolicy"   # unchanged


def test_cluster_cli_lists_all_policies():
    """`--policy` choices (and therefore --help) include the new policies."""
    from repro.launch.cluster import build_parser
    action = next(a for a in build_parser()._actions
                  if "--policy" in a.option_strings)
    assert set(LEGACY + NEW) <= set(action.choices)


# ----------------------------------------------------------- memo cache

def test_optimizer_memo_identical_and_hits():
    speeds = [{7: 1.0, 4: 0.7, 3: 0.6, 2: 0.4, 1: 0.2},
              {7: 1.0, 4: 0.5, 3: 0.45, 2: 0.3, 1: 0.15}]
    clear_memo()
    cold = optimize_partition(SPACE, speeds)
    warm = optimize_partition(SPACE, speeds)
    plain = optimize_partition(SPACE, speeds, memo=False)
    assert cold == warm == plain
    stats = memo_stats()
    assert stats["hits"] == 1 and stats["misses"] == 1


# --------------------------------------------- zero-dead-time regression

def _single_job_sim(policy="miso", n_jobs=1, **jobkw):
    jobs = [Job(jid=i, profile=WORKLOADS[0], arrival=0.0, work=300.0, **jobkw)
            for i in range(n_jobs)]
    return ClusterSim(jobs, SimConfig(n_gpus=1, policy=policy), SPACE, PM,
                      OracleEstimator(PM))


def test_first_placement_has_zero_ckpt_dead_time():
    """A job landing on a fresh GPU goes straight to MPS profiling: the
    initial checkpoint window has zero duration and charges no ckpt time."""
    sim = _single_job_sim()
    sim._on_arrival(sim.jobs[0])
    g = sim.gpus[0]
    assert g.phase == MPS_PROF
    assert g.phase_end == pytest.approx(3 * sim.cfg.mps_level_time_s)
    assert sim.jobs[0].t_ckpt == 0.0


def test_end_phase_schedule_flag_suppresses_events():
    """`end_phase(schedule=False)` must not push GPU events — the caller
    finalizes once afterwards (the seed simulator's `schedule=False` flag
    was dead code that re-scheduled anyway)."""
    sim = _single_job_sim()
    sim._on_arrival(sim.jobs[0])
    g = sim.gpus[0]
    sim.t = g.phase_end                     # MPS window expires
    stamp, nev = g.stamp, len(sim.events)
    sim.end_phase(g, schedule=False)
    assert g.stamp == stamp
    assert len(sim.events) == nev
    # default path does schedule (stamp bump invalidates stale events)
    sim.t = g.phase_end
    sim.end_phase(g)
    assert g.stamp == stamp + 1


# ------------------------------------------------------- new policies

def test_largest_free_slice():
    assert SPACE.largest_free_slice(()) == 7
    assert SPACE.largest_free_slice((7,)) == 0
    assert SPACE.largest_free_slice((4,)) == 2     # 4g excludes 3g
    assert SPACE.largest_free_slice((3, 3)) == 0   # 3g's 4 mem slots fill it
    assert SPACE.largest_free_slice((4, 2)) == 1


def test_miso_frag_prefers_spare_contiguous_slices():
    """Within the throughput tolerance, miso-frag trades a hair of STP for a
    partition that keeps a slice free; plain MISO takes the raw optimum."""
    speeds = [{7: 1.0, 4: 0.6, 3: 0.6, 2: 0.57, 1: 0.2},
              {7: 1.0, 4: 0.6, 3: 0.6, 2: 0.57, 1: 0.2}]
    plain = _single_job_sim("miso").policy.choose_partition(speeds)
    frag = _single_job_sim("miso-frag").policy.choose_partition(speeds)
    assert sorted(plain.partition, reverse=True) == [3, 3]      # obj 1.20
    # (3,3) packs the GPU solid; every near-optimal alternative keeps room
    assert SPACE.largest_free_slice(plain.partition) == 0
    assert SPACE.largest_free_slice(frag.partition) > 0
    assert frag.objective >= (1 - 0.05) * plain.objective


@pytest.mark.parametrize("policy", NEW)
def test_new_policies_complete_all_jobs(policy):
    jobs = generate_trace(25, lam_s=30.0, seed=8, max_duration_s=1200)
    m = simulate(jobs, SimConfig(n_gpus=2, policy=policy), SPACE, PM, EST)
    assert len(m.jcts) == len(jobs)
    assert min(m.relative_jcts) >= 1.0 - 1e-9


def _run_direct(policy, jobs):
    """Run without the deepcopy in simulate() so per-jid times are readable."""
    sim = ClusterSim(jobs, SimConfig(n_gpus=1, policy=policy), SPACE, PM,
                     OracleEstimator(PM))
    sim.run()
    return sim


def test_srpt_avoids_head_of_line_blocking():
    """A queue-head job that needs the full GPU must not stall a short job
    behind it.  FCFS MISO blocks; SRPT lets the short one jump."""
    prof = WORKLOADS[0]
    def mk():
        return [Job(jid=0, profile=prof, arrival=0.0, work=2000.0),
                Job(jid=1, profile=prof, arrival=1.0, work=2000.0,
                    qos_min_slice=7),                # full GPU only
                Job(jid=2, profile=prof, arrival=2.0, work=100.0)]
    fcfs = _run_direct("miso", mk())
    srpt = _run_direct("srpt", mk())
    jct = lambda sim, jid: sim.jobs[jid].finish_time - sim.jobs[jid].arrival
    assert len(srpt.completed) == 3
    assert jct(srpt, 2) < jct(fcfs, 2) * 0.5         # jid 2 jumped the queue


def test_srpt_preempts_long_running_job():
    """A short full-GPU job evicts a freshly-started giant instead of
    waiting behind it; everything still completes."""
    prof = WORKLOADS[0]
    jobs = [Job(jid=0, profile=prof, arrival=0.0, work=20000.0),
            Job(jid=1, profile=prof, arrival=500.0, work=100.0,
                qos_min_slice=7)]
    sim = _run_direct("srpt", jobs)
    assert len(sim.completed) == 2
    # the short job finished long before the giant's exclusive time was up
    assert sim.jobs[1].finish_time - sim.jobs[1].arrival < 2000.0
    assert sim.jobs[1].finish_time < sim.jobs[0].finish_time
