"""Import shim: the real misolint package lives in ``tools/lint/misolint``
(lint tooling stays out of the runtime tree), but ``PYTHONPATH=src`` is
this repo's standard import root — so this package redirects its search
path there, making ``python -m misolint src/ tests/`` and
``from misolint import ruleset_hash`` (the sweep's ``lint_version`` stamp)
work with no extra configuration.
"""
import os as _os

__path__ = [_os.path.abspath(_os.path.join(
    _os.path.dirname(__file__), _os.pardir, _os.pardir,
    "tools", "lint", "misolint"))]

# resolves inside tools/lint/misolint thanks to the __path__ redirect
from misolint.api import (Finding, lint_paths, lint_source,  # noqa: E402
                          ruleset_hash, __version__)

__all__ = ["Finding", "lint_paths", "lint_source", "ruleset_hash",
           "__version__"]
