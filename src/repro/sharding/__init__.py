from repro.sharding.rules import (ShardingRules, make_rules, specs_to_shardings,
                                  logical_to_pspec)
