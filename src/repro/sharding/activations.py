"""Activation sharding constraints.

Model code is mesh-agnostic; these helpers read the mesh from the ambient
``with mesh:`` context and emit ``with_sharding_constraint`` anchors at block
boundaries.  Without them GSPMD is free to propagate *weight* layouts onto
activations (e.g. d_model-sharded-over-"data" activations from FSDP weights),
which manifests as involuntary full rematerialization and ~100x inflated
per-device FLOPs.  With a single batch anchor per block, propagation settles
into the intended DP x TP pattern.  No-ops when no mesh is active (CPU smoke
tests) or when a dim does not divide.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

# logical activation dims -> candidate mesh axes (in priority order)
_ACT_RULES = {
    "batch": (("pod", "data"), ("data",)),
    "seq": (),
    "d_model": (),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "d_ff": (("model",),),
    "vocab": (("model",),),
    "kv_seq": (("model",),),
    "experts": (("model",),),
    None: (),
}


def current_mesh():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is None or m.empty:
            return None
        return m
    except Exception:
        return None


def shard_activation(x, *logical):
    """x with dims named by ``logical`` (None = unsharded). Returns x with a
    with_sharding_constraint if a mesh is active."""
    mesh = current_mesh()
    if mesh is None:
        return x
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = set()
    spec = []
    for name, dim in zip(logical, x.shape):
        placed = None
        for cand in _ACT_RULES.get(name, ()):
            cand = tuple(a for a in cand if a in axes)
            if not cand or any(a in used for a in cand):
                continue
            size = 1
            for a in cand:
                size *= axes[a]
            if dim % size == 0 and dim > 0:
                placed = cand
                used.update(cand)
                break
        if placed is None:
            spec.append(None)
        elif len(placed) == 1:
            spec.append(placed[0])
        else:
            spec.append(placed)
    while spec and spec[-1] is None:
        spec.pop()
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
