"""Divisibility-aware logical-axis -> mesh-axis sharding rules.

Every parameter / cache tensor carries a tuple of logical dim names (built by
ParamBuilder).  A ``ShardingRules`` table maps each logical name to an ordered
list of *candidate* mesh axes; at spec-build time a candidate is accepted only
if (a) the dim size divides the remaining mesh-axis size and (b) the axis is
not already used by another dim of the same tensor.  This is what lets one
rule table serve all 10 architectures: smollm's 15 heads simply fail the
divisibility check on a 16-way "model" axis and the d_ff/vocab shardings
carry the TP load instead (DESIGN.md §Arch-applicability).

Default placement (training):
  batch        -> ("pod", "data")      pure DP across pods, DP within pod
  vocab/heads/kv_heads/d_ff/d_ff_expert/experts/d_rnn -> "model"   (TP / EP)
  d_model      -> "data"               FSDP: weights gathered per layer
  kv_seq       -> "model"              SP for long decode caches
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.tree import map_with_spec

Candidate = Tuple[str, ...]  # mesh axes, possibly compound e.g. ("pod","data")


@dataclass(frozen=True)
class ShardingRules:
    table: Dict[str, Tuple[Candidate, ...]]
    mesh_axes: Dict[str, int]

    def pspec(self, logical_axes: Sequence[str], dims: Sequence[int]) -> P:
        return logical_to_pspec(logical_axes, dims, self)


def make_rules(mesh: Mesh, *, fsdp: bool = True, seq_shard: bool = True,
               expert_parallel: bool = True) -> ShardingRules:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp: Candidate = tuple(a for a in ("pod", "data") if a in axes)
    tp: Candidate = ("model",) if "model" in axes else ()
    t: Dict[str, Tuple[Candidate, ...]] = {
        # activations / caches
        "batch": (dp,),
        "seq": (),
        "kv_seq": ((("model",),) if (seq_shard and tp) else ()),
        # params
        "vocab": (tp,) if tp else (),
        "heads": (tp,) if tp else (),
        "kv_heads": (tp,) if tp else (),
        "d_ff": (tp,) if tp else (),
        "d_ff_expert": (tp,) if tp else (),
        "d_rnn": (tp,) if tp else (),
        "d_rnn_out": (tp,) if tp else (),
        "experts": ((tp,) if expert_parallel and tp else ()),
        "d_model": ((("data",),) if fsdp and "data" in axes else ()),
        "d_model_out": ((("data",),) if fsdp and "data" in axes else ()),
        # never sharded
        "layers": (), "head_dim": (), "one": (), "lora": (), "conv_w": (),
        "rwkv_n": (), "rwkv_n2": (), "experts_r": (),
        "kh": (), "kw": (), "cin": (), "cout": (),
    }
    return ShardingRules(table=t, mesh_axes=axes)


def logical_to_pspec(logical_axes: Sequence[str], dims: Sequence[int],
                     rules: ShardingRules) -> P:
    used: set = set()
    out = []
    for name, dim in zip(logical_axes, dims):
        placed: Optional[Candidate] = None
        for cand in rules.table.get(name, ()):
            axes = tuple(a for a in cand if a in rules.mesh_axes)
            if not axes or any(a in used for a in axes):
                continue
            size = 1
            for a in axes:
                size *= rules.mesh_axes[a]
            if dim % size == 0:
                placed = axes
                used.update(axes)
                break
        if placed is None:
            out.append(None)
        elif len(placed) == 1:
            out.append(placed[0])
        else:
            out.append(placed)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def specs_to_shardings(tree, specs, mesh: Mesh, rules: Optional[ShardingRules]
                       = None, overrides: Optional[Dict[str, Tuple]] = None):
    """Map a (params/cache) tree + logical-spec tree to NamedShardings.

    ``overrides``: logical-name -> candidate tuple replacing the rule table
    entry (used by the perf hillclimb to flip sharding strategies).
    """
    rules = rules or make_rules(mesh)
    if overrides:
        table = dict(rules.table)
        table.update(overrides)
        rules = ShardingRules(table=table, mesh_axes=rules.mesh_axes)

    def one(leaf, axes):
        pspec = logical_to_pspec(axes, leaf.shape, rules)
        return NamedSharding(mesh, pspec)

    return map_with_spec(one, tree, specs)
