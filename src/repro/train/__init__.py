from repro.train.optim import adam_init, adam_update, adamw_init, adamw_update
