"""Optimizers: Adam (predictor training) and AdamW (LM training).

Pure-pytree implementations: state is {"m": tree, "v": tree, "step": scalar}
with fp32 moments regardless of parameter dtype (mixed-precision training
keeps bf16 params + fp32 Adam state; see train/train_step.py for the ZeRO
sharding of this state over the "data" mesh axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _zeros_like_f32(tree):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), tree)


def adam_init(params):
    return {"m": _zeros_like_f32(params), "v": _zeros_like_f32(params),
            "step": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, *, lr, b1=0.9, b2=0.999, eps=1e-8):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    corr1 = 1.0 - b1 ** t
    corr2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        update = (m_new / corr1) / (jnp.sqrt(v_new / corr2) + eps)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


adamw_init = adam_init


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.0, grad_clip=0.0):
    if grad_clip and grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    corr1 = 1.0 - b1 ** t
    corr2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        update = (m_new / corr1) / (jnp.sqrt(v_new / corr2) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = weight_decay if p.ndim >= 2 else 0.0
        p32 = p.astype(jnp.float32)
        return (p32 - lr * (update + wd * p32)).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
