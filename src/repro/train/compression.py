"""int8-compressed cross-pod gradient all-reduce.

The "pod" mesh axis is pure data parallelism over the slowest link
(inter-pod DCN/ICI-superpod), so its gradient all-reduce is the natural
compression target (DESIGN.md §5).  ``compressed_psum_tree`` runs under
``shard_map``: each pod quantizes its local gradient shard to int8 with a
per-tensor scale, all-reduces the int8 payload and the scales separately,
and dequantizes — 4x less cross-pod traffic than an f32 psum at <0.4 %
relative error (stochastic rounding keeps the estimator unbiased).

Intra-pod reductions stay full precision: compression is applied only on
the named axis you pass (usually "pod").

Usage (opt-in via RunConfig.grad_compression in a shard_map training loop):

    grads_global = compressed_psum_tree(grads_local, axis_name="pod",
                                        key=step_key)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _quantize(g, key):
    g32 = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.maximum(amax, 1e-20) / 127.0
    scaled = g32 / scale
    if key is not None:
        # stochastic rounding: unbiased under averaging across pods/steps
        noise = jax.random.uniform(key, g.shape, minval=-0.5, maxval=0.5)
        q = jnp.clip(jnp.round(scaled + noise), -127, 127)
    else:
        q = jnp.clip(jnp.round(scaled), -127, 127)
    return q.astype(jnp.int8), scale


def compressed_psum(g, axis_name: str, key=None):
    """All-reduce-mean one gradient tensor over ``axis_name`` with int8
    payload.  Must be called inside shard_map/vmap with that axis bound."""
    q, scale = _quantize(g, key)
    # int8 payloads summed in int32 (n_pods <= 2^24 safe); scales are tiny
    total = lax.psum(q.astype(jnp.int32), axis_name)
    # each pod contributed (q_i * scale_i); using the mean scale keeps the
    # estimator exact when scales agree and unbiased otherwise
    scale_sum = lax.psum(scale, axis_name)
    n = lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (total.astype(jnp.float32) * (scale_sum / n) / n).astype(g.dtype)


def compressed_psum_tree(grads, axis_name: str, key=None):
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))
    out = [compressed_psum(g, axis_name, k) for g, k in zip(leaves, keys)]
    return treedef.unflatten(out)


def compression_error(grads, n_pods: int = 2, seed: int = 0):
    """Offline estimate of the relative L2 error the compression introduces
    (used by tests and the benchmark)."""
    key = jax.random.PRNGKey(seed)
    leaves = jax.tree_util.tree_leaves(grads)
    num = den = 0.0
    for i, g in enumerate(leaves):
        q, scale = _quantize(g, jax.random.fold_in(key, i))
        rec = q.astype(jnp.float32) * scale
        num += float(jnp.sum((rec - g.astype(jnp.float32)) ** 2))
        den += float(jnp.sum(g.astype(jnp.float32) ** 2))
    return (num / max(den, 1e-20)) ** 0.5
