"""Training step: loss -> grads -> AdamW, with microbatch gradient
accumulation, remat (inside the layer scan), and activation sharding
constraints at the step boundary.

The same ``train_step`` lowers on 1 CPU device (smoke tests / examples) and
on the 512-device production mesh (dry-run): sharding is injected purely via
``in_shardings``/``out_shardings`` on ``jax.jit``, never inside the step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.model import LM
from repro.train.optim import adamw_init, adamw_update


def make_train_step(cfg, run):
    """Returns train_step(params, opt_state, tokens, labels) ->
    (params, opt_state, metrics)."""

    def loss_fn(params, tokens, labels):
        loss, metrics = LM.loss(params, cfg, run, tokens, labels)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, tokens, labels):
        B = tokens.shape[0]
        n_micro = run.microbatches
        if n_micro > 1 and B % n_micro == 0:
            mb = B // n_micro
            toks = tokens.reshape(n_micro, mb, *tokens.shape[1:])
            labs = labels.reshape(n_micro, mb, *labels.shape[1:])

            def micro(acc, xs):
                tk, lb = xs
                (loss, metrics), grads = grad_fn(params, tk, lb)
                grads = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc[0], grads)
                return (grads, acc[1] + loss), metrics

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = lax.scan(micro, (zero, jnp.zeros((), jnp.float32)),
                                            (toks, labs))
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32),
                       "tokens": jnp.float32(tokens.size)}
        else:
            (loss, metrics), grads = grad_fn(params, tokens, labels)

        params, opt_state, gnorm = adamw_update(
            params, grads, opt_state, lr=run.learning_rate,
            weight_decay=run.weight_decay, grad_clip=run.grad_clip)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


def init_train_state(cfg, run, key=None, abstract: bool = False):
    """Returns (params, opt_state, specs, opt_specs)."""
    params, specs = LM.init(cfg, run, key, abstract=abstract)
    if abstract:
        opt_state = {
            "m": jax.tree_util.tree_map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
            "v": jax.tree_util.tree_map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
    else:
        opt_state = adamw_init(params)
    opt_specs = {"m": specs, "v": specs, "step": ()}
    return params, opt_state, specs, opt_specs


def batch_pspec(mesh_axes) -> P:
    dp = tuple(a for a in ("pod", "data") if a in mesh_axes)
    return P(dp if len(dp) > 1 else (dp[0] if dp else None))
