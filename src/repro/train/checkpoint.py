"""Fault-tolerant checkpointing with elastic (re-mesh) restore.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``manifest.msgpack`` holding the tree
structure, shapes, dtypes and the step.  Writes are atomic (tmp dir +
rename), ``keep_last`` old checkpoints are retained, and restore places
arrays onto *any* mesh via ``jax.device_put`` with freshly computed
NamedShardings — a checkpoint written on an N-device mesh restores onto an
M-device mesh (elastic scaling; exercised by tests/test_checkpoint.py).

This is the job-level durability layer that MISO's scheduler relies on: a
pre-empted / failed / re-partitioned job resumes from its last step on a
slice of a different size.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat):
    root: Any = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for i, p in enumerate(parts[:-1]):
            nxt_is_list = parts[i + 1].startswith("#") if i + 1 < len(parts) else False
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("#") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
            return tuple(fix(v) for _, v in items)
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save_checkpoint(ckpt_dir: str, step: int, state: dict, *,
                    keep_last: int = 3) -> str:
    """state: arbitrary pytree of arrays (params/opt/rng/step...)."""
    flat = _flatten(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    manifest = {
        "step": int(step),
        "keys": list(arrays),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "format": 1,
    }
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "|"): a for k, a in arrays.items()})
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1][5:]) if steps else None


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None, *,
                       shardings=None):
    """Returns (state, step).  ``shardings``: optional pytree (same structure)
    of NamedShardings for elastic placement on the current mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    z = np.load(os.path.join(d, "arrays.npz"))
    flat = {k: z[k.replace("/", "|")] for k in manifest["keys"]}
    state = _unflatten(flat)
    if shardings is not None:
        flat_sh = _flatten(shardings)
        placed = {k: jax.device_put(v, flat_sh[k]) if k in flat_sh
                  else jnp.asarray(v)
                  for k, v in flat.items()}
        state = _unflatten(placed)
    else:
        state = jax.tree_util.tree_map(jnp.asarray, state)
    return state, manifest["step"]
