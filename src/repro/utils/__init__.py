from repro.utils.tree import ParamBuilder, tree_bytes, tree_count, map_with_spec
