"""Parameter-tree utilities.

The model substrate uses plain nested dicts of jnp arrays as parameter trees.
Each parameter carries a parallel *logical axis spec*: a tuple of logical dim
names (e.g. ``("layers", "d_model", "d_ff")``).  ``sharding/rules.py`` maps
logical names to mesh axes; keeping specs out of the arrays keeps everything a
vanilla pytree (checkpointable, donate-able, scannable).

``ParamBuilder`` builds the two trees (params + specs) in lock-step so they can
never drift.  Builders compose: ``pb.child("attn")`` namespaces a sub-module.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def _normal_init(scale: float) -> Callable:
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)

    return init


def fan_in_init(fan_in: int) -> Callable:
    return _normal_init(1.0 / math.sqrt(max(fan_in, 1)))


class ParamBuilder:
    """Accumulates (params, specs) trees with a split PRNG key per leaf.

    In ``abstract`` mode no arrays are materialized — leaves are
    ``jax.ShapeDtypeStruct``.  This is what the 512-device dry-run uses: we can
    build the full 104B-parameter tree without allocating a byte.
    """

    def __init__(self, key, dtype=jnp.float32, abstract: bool = False):
        self._key = key
        self.dtype = dtype
        self.abstract = abstract
        self.params: dict = {}
        self.specs: dict = {}

    def _next_key(self):
        if self.abstract:
            return None
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, name: str, shape, axes, init: Callable | None = None,
              dtype=None, scale: float | None = None):
        """Create one parameter; ``axes`` is a tuple of logical dim names."""
        shape = tuple(int(s) for s in shape)
        assert len(shape) == len(axes), (name, shape, axes)
        assert name not in self.params, f"duplicate param {name}"
        dtype = dtype or self.dtype
        if self.abstract:
            leaf = jax.ShapeDtypeStruct(shape, dtype)
        else:
            if init is None:
                init = _normal_init(scale if scale is not None else 0.02)
            leaf = init(self._next_key(), shape, dtype)
        self.params[name] = leaf
        self.specs[name] = tuple(axes)
        return leaf

    def child(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(None, dtype=self.dtype, abstract=self.abstract)
        sub._next_key = self._next_key  # share the parent's key stream
        assert name not in self.params, f"duplicate child {name}"
        self.params[name] = sub.params
        self.specs[name] = sub.specs
        return sub

    def build(self):
        return self.params, self.specs


def tree_count(tree) -> int:
    """Total number of scalar parameters (works on abstract trees too)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) for l in leaves)


def tree_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize for l in leaves)


def map_with_spec(fn, params, specs):
    """tree_map over (param_leaf, spec_tuple) pairs.

    ``specs`` has tuples where ``params`` has array leaves; treat tuples as
    leaves of the spec tree.
    """
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_s = treedef.flatten_up_to(specs)
    return treedef.unflatten([fn(p, s) for p, s in zip(flat_p, flat_s)])


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if hasattr(x, "astype") else x, tree)
