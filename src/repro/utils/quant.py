"""int8 weight-only quantization (serving): q = round(w/s) with a
per-out-channel scale.  Quantized leaves are {"__q": int8, "__s": f32}
dicts; model code reads them transparently via maybe_dequant (weights stream
from HBM as int8 and dequantize in-register, once per consumer)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _quantizable(leaf) -> bool:
    return len(leaf.shape) >= 2 and int(np.prod(leaf.shape)) >= 4096


def is_quantized_leaf(leaf):
    return isinstance(leaf, dict) and "__q" in leaf


def _reduce_axes(ndim):
    """Scale granularity: per-out-channel (last dim), and per-layer for
    stacked scan parameters (keep the leading dim when ndim >= 3)."""
    start = 1 if ndim >= 3 else 0
    return tuple(range(start, ndim - 1))


def quantize_params(params):
    """bf16/f32 matrices -> (int8, scale) pairs; small tensors left alone."""
    def q(leaf):
        if _quantizable(leaf):
            amax = jnp.max(jnp.abs(leaf.astype(jnp.float32)),
                           axis=_reduce_axes(leaf.ndim), keepdims=True)
            scale = jnp.maximum(amax, 1e-8) / 127.0
            qv = jnp.clip(jnp.round(leaf.astype(jnp.float32) / scale),
                          -127, 127).astype(jnp.int8)
            return {"__q": qv, "__s": scale.astype(jnp.float32)}
        return leaf
    return jax.tree_util.tree_map(q, params)


def maybe_dequant(leaf, dtype):
    """Transparent read of a possibly-quantized parameter leaf."""
    if is_quantized_leaf(leaf):
        return (leaf["__q"].astype(jnp.float32) * leaf["__s"]).astype(dtype)
    return leaf


def dequantize_params(qparams, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(
        lambda l: maybe_dequant(l, dtype), qparams,
        is_leaf=is_quantized_leaf)


def abstract_quantize(params, specs):
    """ShapeDtypeStruct tree -> quantized SDS tree (+ matching spec tree)."""
    from repro.utils.tree import map_with_spec

    def q(leaf, axes):
        # stacked (scan) 1-D-per-layer tensors (norm scales etc.) are tiny:
        # quantizing them would give layer-less scales that break the scan
        if _quantizable(leaf) and not (axes and axes[0] == "layers"
                                       and len(leaf.shape) < 3):
            keep_first = len(leaf.shape) >= 3
            sshape = ((leaf.shape[0],) if keep_first else (1,)) \
                + tuple(1 for _ in leaf.shape[1:-1]) + (leaf.shape[-1],)
            return {"__q": jax.ShapeDtypeStruct(leaf.shape, jnp.int8),
                    "__s": jax.ShapeDtypeStruct(sshape, jnp.float32)}
        return leaf

    def qspec(leaf, axes):
        if _quantizable(leaf) and not (axes and axes[0] == "layers"
                                       and len(leaf.shape) < 3):
            return {"__q": tuple(axes), "__s": tuple(axes)}
        return tuple(axes)

    return map_with_spec(q, params, specs), map_with_spec(qspec, params, specs)
