"""GQA attention block: qk-norm, rope, sliding-window, full/ring KV caches.

Three execution paths share one parameter layout:
  * train/prefill  -> blockwise (flash-style) pure-JAX attention, or the
                      Pallas kernel when ``run.use_pallas``;
  * decode         -> naive attention over the cache (Sq == 1, linear cost);
  * ring decode    -> sliding-window archs keep a ring buffer of size W.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import flash, modules
from repro.utils.tree import ParamBuilder, fan_in_init


def init(pb: ParamBuilder, cfg):
    M, Hq, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    D = cfg.resolved_head_dim
    pb.param("wq", (M, Hq, D), ("d_model", "heads", "head_dim"), init=fan_in_init(M))
    pb.param("wk", (M, Hkv, D), ("d_model", "kv_heads", "head_dim"), init=fan_in_init(M))
    pb.param("wv", (M, Hkv, D), ("d_model", "kv_heads", "head_dim"), init=fan_in_init(M))
    pb.param("wo", (Hq, D, M), ("heads", "head_dim", "d_model"), init=fan_in_init(Hq * D))
    if cfg.qk_norm:
        pb.param("q_norm", (D,), ("head_dim",), init=lambda k, s, d: jnp.zeros(s, d))
        pb.param("k_norm", (D,), ("head_dim",), init=lambda k, s, d: jnp.zeros(s, d))


def _project_qkv(p, cfg, x, positions):
    q = jnp.einsum("bsm,mhd->bshd", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsm,mhd->bshd", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsm,mhd->bshd", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = modules.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = modules.rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = modules.rope_angles(positions, cfg.resolved_head_dim, cfg.rope_theta)
    q = modules.apply_rope(q, cos, sin)
    k = modules.apply_rope(k, cos, sin)
    return q, k, v


def apply(p, cfg, run, x, positions, window=None):
    """Full-sequence forward (train / prefill). x: (B, S, M)."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    if run.use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(
            q, k, v, causal=True, window=window,
            block_q=run.attn_block_q, block_kv=run.attn_block_kv,
            interpret=True)
    else:
        o = flash.flash_attention(
            q, k, v, q_positions=positions, kv_positions=positions,
            causal=True, window=window,
            block_q=run.attn_block_q, block_kv=run.attn_block_kv,
            window_block_skip=run.swa_block_skip)
    return jnp.einsum("bshd,hdm->bsm", o, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# KV cache (full or ring)
# ---------------------------------------------------------------------------


def cache_shape(cfg, batch: int, max_seq: int, window=None, dtype=jnp.bfloat16):
    S = min(max_seq, window) if window else max_seq
    Hkv, D = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, S, Hkv, D), dtype),
        "v": jax.ShapeDtypeStruct((batch, S, Hkv, D), dtype),
    }


def cache_specs(window_or_none):
    return {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
            "v": ("batch", "kv_seq", "kv_heads", "head_dim")}


def init_cache(cfg, batch, max_seq, window=None, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype),
        cache_shape(cfg, batch, max_seq, window, dtype))


def prefill_cache(p, cfg, run, x, positions, cache, window=None):
    """Fill the KV cache from a full prefix. x: (B, Sp, M) (already normed).

    For ring-buffer (window) caches only the last W tokens are kept, laid out
    so that entry i holds the token with absolute position ``pos % W == i`` —
    the same invariant ``decode`` maintains.
    """
    _, k, v = _project_qkv(p, cfg, x, positions)
    S = cache["k"].shape[1]
    Sp = k.shape[1]
    if Sp >= S:
        k_keep, v_keep = k[:, -S:], v[:, -S:]
        if window:
            # roll so that absolute position p sits at slot p % S
            shift = Sp % S
            k_keep = jnp.roll(k_keep, shift, axis=1)
            v_keep = jnp.roll(v_keep, shift, axis=1)
        return {"k": k_keep.astype(cache["k"].dtype),
                "v": v_keep.astype(cache["v"].dtype)}
    k_full = lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
    v_full = lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
    return {"k": k_full, "v": v_full}


def decode(p, cfg, run, x, cache, pos, window=None):
    """One-token decode. x: (B, 1, M); pos: () int32 tokens already cached.

    Returns (y, new_cache).  With ``window`` the cache is a ring buffer of
    size W and writes wrap; positions are tracked absolutely for rope/mask.
    """
    B = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)

    S = cache["k"].shape[1]
    slot = (pos % S) if window else pos
    k = lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)

    idx = jnp.arange(S)
    if window:
        # ring buffer: entry i holds absolute position with (abs % S == i) and
        # abs in (pos - S, pos]; reconstruct absolute positions for the mask.
        n_wraps = (pos // S) + 1
        abs_pos = idx + jnp.where(idx <= slot, (pos // S) * S, ((pos // S) - 1) * S)
        # entries never written yet (pos < S) are invalid -> future-dated
        abs_pos = jnp.where(abs_pos < 0, jnp.iinfo(jnp.int32).max // 2, abs_pos)
        abs_pos = jnp.where((idx > pos) & (n_wraps == 1),
                            jnp.iinfo(jnp.int32).max // 2, abs_pos)
        kv_positions = abs_pos.astype(jnp.int32)
    else:
        valid = idx <= pos
        kv_positions = jnp.where(valid, idx,
                                 jnp.iinfo(jnp.int32).max // 2).astype(jnp.int32)

    o = modules.naive_attention(
        q, k, v, q_positions=positions, kv_positions=kv_positions,
        causal=True, window=window)
    y = jnp.einsum("bshd,hdm->bsm", o, p["wo"].astype(x.dtype))
    return y, {"k": k, "v": v}
