"""Flash attention in pure JAX with a custom VJP.

Why this exists: differentiating a streaming-softmax scan with plain JAX AD
stacks the per-block probability matrices as scan residuals — O(S^2) memory
and traffic, which defeats the point of blockwise attention (the dry-run HLO
walk showed f32[nq, nkv, B, H, bq, bkv] buffers dominating the memory term).
This custom VJP saves only (q, k, v, o, lse) and recomputes probabilities
blockwise in the backward pass, exactly like the Pallas/CUDA flash kernels:

  forward : one pass over kv blocks per q block (streaming max/sum)
  backward: pass A (q outer, kv inner)  -> dq
            pass B (kv outer, q inner)  -> dk, dv

Sliding-window support: with ``window`` set and ``band_skip``, both passes
restrict to a kv/q *band* via dynamic_slice — a real FLOPs reduction, not
just masking.  GQA: q is grouped (B, Hkv, G, S, D); k/v stay (B, Hkv, S, D).
Positions are 1-D (shared across batch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _group_q(q, Hkv):
    B, S, H, D = q.shape
    return q.transpose(0, 2, 1, 3).reshape(B, Hkv, H // Hkv, S, D)


def _ungroup_q(qg):
    B, Hkv, G, S, D = qg.shape
    return qg.reshape(B, Hkv * G, S, D).transpose(0, 2, 1, 3)


def _to_heads(x):           # (B, S, H, D) -> (B, H, S, D)
    return x.transpose(0, 2, 1, 3)


def _pad_axis(x, size, axis):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pad_pos(p, size):
    if p.shape[0] >= size:
        return p
    fill = jnp.full((size - p.shape[0],), jnp.iinfo(jnp.int32).max // 2,
                    jnp.int32)
    return jnp.concatenate([p, fill])


def _mask_bias(qp, kp, causal, window):
    m = jnp.ones((qp.shape[0], kp.shape[0]), bool)
    if causal:
        m &= kp[None, :] <= qp[:, None]
    if window is not None:
        m &= (qp[:, None] - kp[None, :]) < window
    return jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)


@functools.lru_cache(maxsize=64)
def _make_flash(causal, window, block_q, block_kv, band_skip):
    """Returns flash(q, k, v, qpos, kvpos) -> o with custom VJP."""

    def geom(Sq, Skv):
        bq = min(block_q, Sq)
        nq = -(-Sq // bq)
        bkv = min(block_kv, Skv)
        nkv = -(-Skv // bkv)
        return bq, nq * bq, bkv, nkv * bkv

    def kv_band(Skv_p, bkv):
        if not (band_skip and window is not None and window < Skv_p):
            return None
        w = int(window)
        return min(Skv_p, (-(-w // bkv) + -(-block_q // bkv)) * bkv)

    def prep(q, k, v, qpos, kvpos):
        Hkv = k.shape[2]
        B, Sq, Hq, D = q.shape
        Skv = k.shape[1]
        bq, Sq_p, bkv, Skv_p = geom(Sq, Skv)
        qg = _group_q(_pad_axis(q, Sq_p, 1), Hkv)
        kh = _to_heads(_pad_axis(k, Skv_p, 1))
        vh = _to_heads(_pad_axis(v, Skv_p, 1))
        qp = _pad_pos(qpos, Sq_p)
        kp = _pad_pos(kvpos, Skv_p)
        return qg, kh, vh, qp, kp, (bq, Sq_p, bkv, Skv_p, D ** -0.5)

    # ------------------------------------------------------------- forward

    def forward(q, k, v, qpos, kvpos):
        qg, kh, vh, qp, kp, (bq, Sq_p, bkv, Skv_p, scale) = prep(
            q, k, v, qpos, kvpos)
        B, Hkv, G, _, D = qg.shape
        nq = Sq_p // bq
        band = kv_band(Skv_p, bkv)

        def per_q(i):
            qb = lax.dynamic_slice_in_dim(qg, i * bq, bq, 3)
            qpb = lax.dynamic_slice_in_dim(qp, i * bq, bq, 0)
            if band is not None:
                start = jnp.clip(i * bq + bq - band, 0, Skv_p - band)
                kr = lax.dynamic_slice_in_dim(kh, start, band, 2)
                vr = lax.dynamic_slice_in_dim(vh, start, band, 2)
                kpr = lax.dynamic_slice_in_dim(kp, start, band, 0)
            else:
                kr, vr, kpr = kh, vh, kp
            nb = kr.shape[2] // bkv

            @jax.named_scope("flash_kernel_region")
            def kv_step(carry, j):
                m, l, acc = carry
                kb = lax.dynamic_slice_in_dim(kr, j * bkv, bkv, 2)
                vb = lax.dynamic_slice_in_dim(vr, j * bkv, bkv, 2)
                kpb = lax.dynamic_slice_in_dim(kpr, j * bkv, bkv, 0)
                s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb,
                               preferred_element_type=jnp.float32) * scale
                s = s + _mask_bias(qpb, kpb, causal, window)[None, None, None]
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                pv = jnp.einsum("bhgqk,bhkd->bhgqd", p, vb,
                                preferred_element_type=jnp.float32)
                return (m_new, l_new, acc * corr[..., None] + pv), None

            m0 = jnp.full((B, Hkv, G, bq), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, Hkv, G, bq), jnp.float32)
            a0 = jnp.zeros((B, Hkv, G, bq, D), jnp.float32)
            (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nb))
            l = jnp.maximum(l, 1e-30)
            return acc / l[..., None], m + jnp.log(l)

        o_b, lse_b = lax.map(per_q, jnp.arange(nq))
        o = o_b.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, G, Sq_p, D)
        lse = lse_b.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, G, Sq_p)
        out = _ungroup_q(o)[:, : q.shape[1]].astype(v.dtype)
        return out, lse

    # ------------------------------------------------------------ backward

    def backward(q, k, v, qpos, kvpos, out, lse, g):
        qg, kh, vh, qp, kp, (bq, Sq_p, bkv, Skv_p, scale) = prep(
            q, k, v, qpos, kvpos)
        B, Hkv, G, _, D = qg.shape
        Sq, Skv = q.shape[1], k.shape[1]
        nq, nkv = Sq_p // bq, Skv_p // bkv
        band = kv_band(Skv_p, bkv)

        dog = _group_q(_pad_axis(g.astype(jnp.float32), Sq_p, 1), Hkv)
        delta_u = jnp.sum(g.astype(jnp.float32) * out.astype(jnp.float32), -1)
        delta = _group_q(_pad_axis(delta_u[..., None], Sq_p, 1), Hkv)[..., 0]
        og = _group_q(_pad_axis(out.astype(jnp.float32), Sq_p, 1), Hkv)
        del og  # o itself is not needed: delta carries sum(do*o)

        def p_block(qb, qpb, kb, kpb, lse_b):
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            s = s + _mask_bias(qpb, kpb, causal, window)[None, None, None]
            return jnp.exp(s - lse_b[..., None])

        # pass A: dq
        def per_q(i):
            qb = lax.dynamic_slice_in_dim(qg, i * bq, bq, 3)
            qpb = lax.dynamic_slice_in_dim(qp, i * bq, bq, 0)
            lse_b = lax.dynamic_slice_in_dim(lse, i * bq, bq, 3)
            dob = lax.dynamic_slice_in_dim(dog, i * bq, bq, 3)
            dlt = lax.dynamic_slice_in_dim(delta, i * bq, bq, 3)
            if band is not None:
                start = jnp.clip(i * bq + bq - band, 0, Skv_p - band)
                kr = lax.dynamic_slice_in_dim(kh, start, band, 2)
                vr = lax.dynamic_slice_in_dim(vh, start, band, 2)
                kpr = lax.dynamic_slice_in_dim(kp, start, band, 0)
            else:
                kr, vr, kpr = kh, vh, kp
            nb = kr.shape[2] // bkv

            @jax.named_scope("flash_kernel_region")
            def kv_step(dq_acc, j):
                kb = lax.dynamic_slice_in_dim(kr, j * bkv, bkv, 2)
                vb = lax.dynamic_slice_in_dim(vr, j * bkv, bkv, 2)
                kpb = lax.dynamic_slice_in_dim(kpr, j * bkv, bkv, 0)
                p = p_block(qb, qpb, kb, kpb, lse_b)
                dp = jnp.einsum("bhgqd,bhkd->bhgqk", dob, vb)
                ds = p * (dp - dlt[..., None])
                return dq_acc + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kb) * scale, None

            dq0 = jnp.zeros((B, Hkv, G, bq, D), jnp.float32)
            dq_b, _ = lax.scan(kv_step, dq0, jnp.arange(nb))
            return dq_b

        dq_b = lax.map(per_q, jnp.arange(nq))
        dq = dq_b.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, G, Sq_p, D)

        # pass B: dk, dv; with a window only q in [j*bkv, j*bkv+window+bq)
        qband = None
        if band is not None:
            w = int(window)
            qband = min(Sq_p, (-(-w // bq) + -(-bkv // bq)) * bq)

        def per_kv(j):
            kb = lax.dynamic_slice_in_dim(kh, j * bkv, bkv, 2)
            vb = lax.dynamic_slice_in_dim(vh, j * bkv, bkv, 2)
            kpb = lax.dynamic_slice_in_dim(kp, j * bkv, bkv, 0)
            if qband is not None:
                qstart = jnp.clip(j * bkv, 0, Sq_p - qband)
                q_r = lax.dynamic_slice_in_dim(qg, qstart, qband, 3)
                qp_r = lax.dynamic_slice_in_dim(qp, qstart, qband, 0)
                lse_r = lax.dynamic_slice_in_dim(lse, qstart, qband, 3)
                do_r = lax.dynamic_slice_in_dim(dog, qstart, qband, 3)
                dl_r = lax.dynamic_slice_in_dim(delta, qstart, qband, 3)
            else:
                q_r, qp_r, lse_r, do_r, dl_r = qg, qp, lse, dog, delta
            nb = q_r.shape[3] // bq

            @jax.named_scope("flash_kernel_region")
            def q_step(carry, i):
                dk_acc, dv_acc = carry
                qb = lax.dynamic_slice_in_dim(q_r, i * bq, bq, 3)
                qpb = lax.dynamic_slice_in_dim(qp_r, i * bq, bq, 0)
                lse_b = lax.dynamic_slice_in_dim(lse_r, i * bq, bq, 3)
                dob = lax.dynamic_slice_in_dim(do_r, i * bq, bq, 3)
                dlt = lax.dynamic_slice_in_dim(dl_r, i * bq, bq, 3)
                p = p_block(qb, qpb, kb, kpb, lse_b)
                dv_acc = dv_acc + jnp.einsum("bhgqk,bhgqd->bhkd", p, dob)
                dp = jnp.einsum("bhgqd,bhkd->bhgqk", dob, vb)
                ds = p * (dp - dlt[..., None])
                dk_acc = dk_acc + jnp.einsum("bhgqk,bhgqd->bhkd", ds, qb) * scale
                return (dk_acc, dv_acc), None

            z = jnp.zeros((B, Hkv, bkv, D), jnp.float32)
            (dk_j, dv_j), _ = lax.scan(q_step, (z, z), jnp.arange(nb))
            return dk_j, dv_j

        dk_b, dv_b = lax.map(per_kv, jnp.arange(nkv))
        dk = dk_b.transpose(1, 2, 0, 3, 4).reshape(B, Hkv, Skv_p, D)
        dv = dv_b.transpose(1, 2, 0, 3, 4).reshape(B, Hkv, Skv_p, D)

        dq_out = _ungroup_q(dq)[:, :Sq].astype(q.dtype)
        dk_out = dk.transpose(0, 2, 1, 3)[:, :Skv].astype(k.dtype)
        dv_out = dv.transpose(0, 2, 1, 3)[:, :Skv].astype(v.dtype)
        return dq_out, dk_out, dv_out

    # ----------------------------------------------------------- custom vjp

    @jax.custom_vjp
    def flash(q, k, v, qpos, kvpos):
        out, _ = forward(q, k, v, qpos, kvpos)
        return out

    def fwd_rule(q, k, v, qpos, kvpos):
        out, lse = forward(q, k, v, qpos, kvpos)
        return out, (q, k, v, qpos, kvpos, out, lse)

    def bwd_rule(res, g):
        q, k, v, qpos, kvpos, out, lse = res
        dq, dk, dv = backward(q, k, v, qpos, kvpos, out, lse, g)
        return (dq, dk, dv, None, None)

    flash.defvjp(fwd_rule, bwd_rule)
    return flash


def flash_attention(q, k, v, *, q_positions, kv_positions, causal=True,
                    window=None, block_q=512, block_kv=1024,
                    window_block_skip=True):
    """q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D); positions 1-D int32."""
    f = _make_flash(bool(causal), None if window is None else int(window),
                    int(block_q), int(block_kv), bool(window_block_skip))
    return f(q, k, v, q_positions.astype(jnp.int32),
             kv_positions.astype(jnp.int32))
