"""Top-level LM facade: embedding, stack, loss, prefill/decode.

`LM` is a thin namespace of pure functions over (params, cfg, run); params are
plain pytrees so pjit/scan/checkpointing compose without a module framework.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.modules import chunked_cross_entropy, rms_norm
from repro.utils.quant import maybe_dequant
from repro.sharding.activations import shard_activation
from repro.utils.tree import ParamBuilder, fan_in_init, tree_count


class LM:
    # ----------------------------------------------------------------- init

    @staticmethod
    def init(cfg, run, key=None, abstract: bool = False):
        """Returns (params, specs). ``abstract=True`` -> ShapeDtypeStructs."""
        dtype = jnp.dtype(run.param_dtype)
        pb = ParamBuilder(key, dtype=dtype, abstract=abstract)
        pb.param("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "d_model"),
                 init=fan_in_init(cfg.d_model))
        pb.param("final_norm", (cfg.d_model,), ("d_model",),
                 init=lambda k, s, d: jnp.zeros(s, d))
        if not cfg.tie_embeddings:
            pb.param("unembed", (cfg.d_model, cfg.vocab_size),
                     ("d_model", "vocab"), init=fan_in_init(cfg.d_model))
        sub_key = None if abstract else jax.random.fold_in(key, 1)
        stack_params, stack_specs = transformer.init_stack(
            cfg, run, sub_key, dtype, abstract=abstract)
        params, specs = pb.build()
        params["stack"] = stack_params
        specs["stack"] = stack_specs
        return params, specs

    @staticmethod
    def param_count(cfg, run) -> int:
        params, _ = LM.init(cfg, run, abstract=True)
        return tree_count(params)

    # -------------------------------------------------------------- forward

    @staticmethod
    def _unembed(params, cfg, dtype=jnp.float32):
        if cfg.tie_embeddings:
            return maybe_dequant(params["embed"], dtype).T
        return maybe_dequant(params["unembed"], dtype)

    @staticmethod
    def hidden(params, cfg, run, tokens, mode="train", cache=None, pos=None):
        """tokens: (B, S) int32 -> (h, new_cache, aux)."""
        B, S = tokens.shape
        adt = jnp.dtype(run.activation_dtype)
        embed = maybe_dequant(params["embed"], adt)
        x = jnp.take(embed, tokens, axis=0).astype(adt)
        x = shard_activation(x, "batch", "seq", "d_model")
        if mode == "decode":
            positions = None
        else:
            positions = jnp.arange(S, dtype=jnp.int32)   # shared across batch
        x, new_cache, aux = transformer.apply_stack(
            params["stack"], cfg, run, x, positions, mode=mode,
            cache=cache, pos=pos)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, new_cache, aux

    @staticmethod
    def loss(params, cfg, run, tokens, labels, label_mask=None):
        """Next-token cross-entropy + MoE aux. Returns (loss, metrics)."""
        h, _, aux = LM.hidden(params, cfg, run, tokens, mode="train")
        ce, count = chunked_cross_entropy(
            h, LM._unembed(params, cfg).astype(h.dtype), labels,
            chunk=run.loss_chunk, label_mask=label_mask)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux, "tokens": count}

    @staticmethod
    def logits(params, cfg, run, tokens):
        """Full logits (small-model paths only: examples, tests)."""
        h, _, _ = LM.hidden(params, cfg, run, tokens, mode="train")
        return jnp.einsum("bsm,mv->bsv", h,
                          LM._unembed(params, cfg).astype(h.dtype),
                          preferred_element_type=jnp.float32)

    # ------------------------------------------------------------- serving

    @staticmethod
    def prefill(params, cfg, run, tokens, max_seq):
        """Process the prompt; returns (last_logits, cache)."""
        adt = jnp.dtype(run.activation_dtype)
        cache = transformer.init_cache(cfg, run, tokens.shape[0], max_seq, adt)
        h, cache, _ = LM.hidden(params, cfg, run, tokens, mode="prefill",
                                cache=cache)
        last = h[:, -1:, :]
        logits = jnp.einsum("bsm,mv->bsv", last,
                            LM._unembed(params, cfg).astype(last.dtype),
                            preferred_element_type=jnp.float32)
        return logits, cache

    @staticmethod
    def decode_step(params, cfg, run, tokens, cache, pos):
        """tokens: (B, 1); pos: () int32 = number of tokens already cached.
        Returns (logits (B,1,V), new_cache)."""
        h, cache, _ = LM.hidden(params, cfg, run, tokens, mode="decode",
                                cache=cache, pos=pos)
        logits = jnp.einsum("bsm,mv->bsv", h,
                            LM._unembed(params, cfg).astype(h.dtype),
                            preferred_element_type=jnp.float32)
        return logits, cache

    # ------------------------------------------------------------ cache api

    @staticmethod
    def init_cache(cfg, run, batch, max_seq, dtype=jnp.bfloat16):
        return transformer.init_cache(cfg, run, batch, max_seq, dtype)

    @staticmethod
    def cache_shape(cfg, run, batch, max_seq, dtype=jnp.bfloat16):
        return transformer.cache_shape(cfg, run, batch, max_seq, dtype)

    @staticmethod
    def cache_specs(cfg, run):
        return transformer.cache_specs(cfg, run)
