"""Decoder stack: heterogeneous layer patterns under a single group-scan.

``layer_kinds`` (from the config) is split into ``n_groups`` repetitions of the
block pattern plus an unrolled tail, e.g. recurrentgemma-2b's 26 layers =
8 x (rglru, rglru, attn) + (rglru, rglru).  Homogeneous archs degenerate to a
pattern of length 1.  All three modes (train / prefill / decode) scan over the
same stacked parameter trees, which keeps the lowered HLO small enough that a
512-device AOT compile of a 104B-parameter model is tractable on one CPU core.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention, ffn, rglru, rwkv6
from repro.models.modules import rms_norm
from repro.utils.quant import dequantize_params
from repro.sharding.activations import shard_activation
from repro.utils.tree import ParamBuilder

# ---------------------------------------------------------------------------
# pattern / grouping helpers
# ---------------------------------------------------------------------------


def pattern_of(cfg):
    if cfg.block_pattern is not None:
        return tuple(cfg.block_pattern)
    return ("rwkv",) if cfg.family == "ssm" else ("attn",)


def grouping(cfg):
    pat = pattern_of(cfg)
    n_groups = cfg.n_layers // len(pat)
    tail = cfg.layer_kinds[n_groups * len(pat):]
    return pat, n_groups, tail


def kind_window(cfg, kind: str) -> Optional[int]:
    if kind != "attn":
        return None
    if cfg.family == "hybrid":
        return cfg.local_window
    return cfg.sliding_window


# ---------------------------------------------------------------------------
# per-layer block init / apply
# ---------------------------------------------------------------------------


def _init_block(pb: ParamBuilder, cfg, kind: str):
    zeros = lambda k, s, d: jnp.zeros(s, d)
    if kind == "attn":
        pb.param("norm1", (cfg.d_model,), ("d_model",), init=zeros)
        pb.param("norm2", (cfg.d_model,), ("d_model",), init=zeros)
        attention.init(pb.child("attn"), cfg)
        if cfg.moe is not None:
            ffn.init_moe(pb.child("moe"), cfg)
        else:
            ffn.init_mlp(pb.child("mlp"), cfg.d_model, cfg.d_ff, cfg.mlp_variant)
    elif kind == "rglru":
        pb.param("norm1", (cfg.d_model,), ("d_model",), init=zeros)
        pb.param("norm2", (cfg.d_model,), ("d_model",), init=zeros)
        rglru.init(pb.child("rec"), cfg)
        ffn.init_mlp(pb.child("mlp"), cfg.d_model, cfg.d_ff)
    elif kind == "rwkv":
        rwkv6.init_block(pb, cfg)
    else:
        raise ValueError(kind)


def layer_init_fn(cfg, run, kind: str, dtype):
    def f(key):
        pb = ParamBuilder(key, dtype=dtype)
        _init_block(pb, cfg, kind)
        return pb.params
    return f


def layer_specs(cfg, kind: str, dtype):
    pb = ParamBuilder(None, dtype=dtype, abstract=True)
    _init_block(pb, cfg, kind)
    return pb.params, pb.specs


def block_forward(p, cfg, run, kind, x, positions, cache, mode, pos=None):
    """Returns (x, new_cache, aux).  cache may be None in train mode."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        window = kind_window(cfg, kind)
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if mode == "decode":
            a, new_kv = attention.decode(p["attn"], cfg, run, h, cache["kv"], pos,
                                         window=window)
            new_cache = {"kv": new_kv}
        elif mode == "prefill":
            a = attention.apply(p["attn"], cfg, run, h, positions, window=window)
            new_cache = {"kv": attention.prefill_cache(
                p["attn"], cfg, run, h, positions, cache["kv"], window=window)}
        else:
            a = attention.apply(p["attn"], cfg, run, h, positions, window=window)
            new_cache = None
        x = x + a
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.moe is not None:
            f, aux = ffn.apply_moe(p["moe"], cfg, h)
        else:
            f = ffn.apply_mlp(p["mlp"], h)
        x = x + f
        return x, new_cache, aux

    if kind == "rglru":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if mode == "decode":
            r, new_rc = rglru.decode(p["rec"], cfg, run, h, cache["rec"])
        else:
            r, new_rc = rglru.apply(p["rec"], cfg, run, h,
                                    cache["rec"] if cache else None,
                                    use_pallas=run.use_pallas)
        x = x + r
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + ffn.apply_mlp(p["mlp"], h)
        return x, ({"rec": new_rc} if mode != "train" else None), aux

    if kind == "rwkv":
        if mode == "decode":
            x, new_c = rwkv6.decode(p, cfg, run, x, cache)
        else:
            x, new_c = rwkv6.apply(p, cfg, run, x, cache if cache else None,
                                   use_pallas=run.use_pallas)
        return x, (new_c if mode != "train" else None), aux

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


def _block_cache_shape(cfg, run, kind, batch, max_seq, dtype):
    if kind == "attn":
        return {"kv": attention.cache_shape(cfg, batch, max_seq,
                                            window=kind_window(cfg, kind),
                                            dtype=dtype)}
    if kind == "rglru":
        return {"rec": rglru.cache_shape(cfg, batch, dtype)}
    if kind == "rwkv":
        return rwkv6.cache_shape(cfg, batch, dtype)
    raise ValueError(kind)


def _block_cache_specs(cfg, kind):
    if kind == "attn":
        return {"kv": attention.cache_specs(kind_window(cfg, kind))}
    if kind == "rglru":
        return {"rec": rglru.cache_specs()}
    if kind == "rwkv":
        return rwkv6.cache_specs()
    raise ValueError(kind)


def _stack_shape(tree, n):
    return jax.tree_util.tree_map(
        lambda sd: jax.ShapeDtypeStruct((n,) + sd.shape, sd.dtype), tree)


def _prepend_spec(specs, name):
    return jax.tree_util.tree_map(lambda t: (name,) + t, specs,
                                  is_leaf=lambda t: isinstance(t, tuple))


def cache_shape(cfg, run, batch, max_seq, dtype=jnp.bfloat16):
    """Abstract cache pytree: {"groups": (per-slot stacked,), "tail": (...,)}."""
    pat, n_groups, tail = grouping(cfg)
    groups = tuple(
        _stack_shape(_block_cache_shape(cfg, run, kind, batch, max_seq, dtype),
                     n_groups)
        for kind in pat)
    tail_caches = tuple(
        _block_cache_shape(cfg, run, kind, batch, max_seq, dtype) for kind in tail)
    return {"groups": groups, "tail": tail_caches}


def cache_specs(cfg, run):
    pat, n_groups, tail = grouping(cfg)
    groups = tuple(
        _prepend_spec(_block_cache_specs(cfg, kind), "layers") for kind in pat)
    tail_specs = tuple(_block_cache_specs(cfg, kind) for kind in tail)
    return {"groups": groups, "tail": tail_specs}


def init_cache(cfg, run, batch, max_seq, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype),
        cache_shape(cfg, run, batch, max_seq, dtype))


# ---------------------------------------------------------------------------
# stack init
# ---------------------------------------------------------------------------


def init_stack(cfg, run, key, dtype, abstract=False):
    """Returns (params, specs) for all layers."""
    pat, n_groups, tail = grouping(cfg)
    params = {"groups": [], "tail": []}
    specs = {"groups": [], "tail": []}
    for kind in pat:
        one_abs, one_specs = layer_specs(cfg, kind, dtype)
        if abstract:
            stacked = _stack_shape(one_abs, n_groups)
        else:
            key, sub = jax.random.split(key)
            stacked = jax.vmap(layer_init_fn(cfg, run, kind, dtype))(
                jax.random.split(sub, n_groups))
        params["groups"].append(stacked)
        specs["groups"].append(_prepend_spec(one_specs, "layers"))
    for kind in tail:
        one_abs, one_specs = layer_specs(cfg, kind, dtype)
        if abstract:
            params["tail"].append(one_abs)
        else:
            key, sub = jax.random.split(key)
            params["tail"].append(layer_init_fn(cfg, run, kind, dtype)(sub))
        specs["tail"].append(one_specs)
    params["groups"] = tuple(params["groups"])
    params["tail"] = tuple(params["tail"])
    specs["groups"] = tuple(specs["groups"])
    specs["tail"] = tuple(specs["tail"])
    return params, specs


# ---------------------------------------------------------------------------
# stack apply
# ---------------------------------------------------------------------------


def _group_step(cfg, run, pat, mode):
    """One scan step: applies the whole pattern once."""

    def step(x, slot_params, slot_caches, positions, pos):
        if run.quantize_serving:
            # int8 weight-only serving: weights stream from HBM as int8 and
            # dequantize in-register, once per layer (see serve/engine.py)
            slot_params = dequantize_params(
                slot_params, jnp.dtype(run.activation_dtype))
        x = shard_activation(x, "batch", "seq", "d_model")
        new_caches = []
        aux = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(pat):
            cache_j = slot_caches[j] if slot_caches is not None else None
            x, nc, a = block_forward(slot_params[j], cfg, run, kind, x,
                                     positions, cache_j, mode, pos=pos)
            new_caches.append(nc)
            aux = aux + a
        return x, tuple(new_caches), aux

    return step


def apply_stack(stack_params, cfg, run, x, positions, mode="train",
                cache=None, pos=None):
    """Run all layers. Returns (x, new_cache_or_None, total_aux)."""
    pat, n_groups, tail = grouping(cfg)
    step = _group_step(cfg, run, pat, mode)
    with_cache = mode != "train"

    def scan_body(carry, xs):
        x = carry
        slot_params = xs[0]
        slot_caches = xs[1] if with_cache else None
        x, new_caches, aux = step(x, slot_params, slot_caches, positions, pos)
        ys = (new_caches, aux) if with_cache else aux
        return x, ys

    body = scan_body
    if run.remat and mode == "train":
        body = jax.checkpoint(scan_body)

    if n_groups > 0:
        xs = (stack_params["groups"],)
        if with_cache:
            xs = xs + (cache["groups"],)
        x, ys = lax.scan(body, x, xs)
        if with_cache:
            group_caches, auxs = ys
        else:
            group_caches, auxs = None, ys
        total_aux = jnp.sum(auxs)
    else:
        group_caches = cache["groups"] if with_cache else None
        total_aux = jnp.zeros((), jnp.float32)

    tail_caches = []
    for i, kind in enumerate(tail):
        cache_i = cache["tail"][i] if with_cache else None

        def fwd(p_, x_, cache_i_, _kind=kind):
            if run.quantize_serving:
                p_ = dequantize_params(p_, jnp.dtype(run.activation_dtype))
            return block_forward(p_, cfg, run, _kind, x_, positions,
                                 cache_i_, mode, pos=pos)

        if run.remat and mode == "train":
            fwd = jax.checkpoint(fwd)
        x, nc, a = fwd(stack_params["tail"][i], x, cache_i)
        tail_caches.append(nc)
        total_aux = total_aux + a

    new_cache = ({"groups": group_caches, "tail": tuple(tail_caches)}
                 if with_cache else None)
    return x, new_cache, total_aux
