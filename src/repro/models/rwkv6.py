"""RWKV-6 "Finch" block (arXiv:2404.05892): data-dependent decay linear
attention (time-mix) + squared-relu channel-mix, both with token shift.

Training/prefill uses a *chunked* formulation: within a chunk of length L the
pairwise decay factor exp(c_{t-1} - c_s) (s < t, c = cumulative log-decay) is
materialized directly — it is always <= 1, so the chunked path is
unconditionally stable (no exp(+c) factoring).  Chunks are carried by a
sequential scan over the per-(key,value) state S in (B, H, N, N).

The Pallas kernel (repro/kernels/rwkv6) implements the same chunked contract;
ref.py there is the naive per-token recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.modules import rms_norm
from repro.utils.tree import ParamBuilder, fan_in_init

LORA_RANK = 64


def init(pb: ParamBuilder, cfg):
    M = cfg.d_model
    N = cfg.rwkv_head_dim
    assert M % N == 0
    zeros = lambda k, s, d: jnp.zeros(s, d)
    for z in ("r", "k", "v", "w", "g"):
        pb.param(f"mix_{z}", (M,), ("d_model",), init=zeros)
    pb.param("w_bias", (M,), ("d_model",),
             init=lambda k, s, d: jnp.full(s, -1.0, d))  # exp(-exp(-1)) ~ .69 decay
    pb.param("w_lora_a", (M, LORA_RANK), ("d_model", "lora"), init=fan_in_init(M))
    pb.param("w_lora_b", (LORA_RANK, M), ("lora", "d_model"),
             init=lambda k, s, d: jnp.zeros(s, d))
    pb.param("bonus_u", (M,), ("d_model",), init=zeros)
    for z in ("r", "k", "v", "g", "o"):
        pb.param(f"w{z}", (M, M), ("d_model", "d_model_out"), init=fan_in_init(M))
    pb.param("ln_x_scale", (M,), ("d_model",), init=zeros)
    # channel mix
    cm = pb.child("cm")
    cm.param("mix_k", (M,), ("d_model",), init=zeros)
    cm.param("mix_r", (M,), ("d_model",), init=zeros)
    cm.param("wk", (M, cfg.d_ff), ("d_model", "d_ff"), init=fan_in_init(M))
    cm.param("wv", (cfg.d_ff, M), ("d_ff", "d_model"), init=fan_in_init(cfg.d_ff))
    cm.param("wr", (M, M), ("d_model", "d_model_out"), init=fan_in_init(M))


def _token_shift(x, x_prev):
    """shift(x)_t = x_{t-1}; x_prev is the last token of the previous segment
    (zeros at sequence start). x: (B, S, M); x_prev: (B, M)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix(x, sx, mu):
    return x + (sx - x) * mu.astype(x.dtype)


def _projections(p, cfg, x, x_prev):
    sx = _token_shift(x, x_prev)
    xr = _mix(x, sx, p["mix_r"])
    xk = _mix(x, sx, p["mix_k"])
    xv = _mix(x, sx, p["mix_v"])
    xw = _mix(x, sx, p["mix_w"])
    xg = _mix(x, sx, p["mix_g"])
    r = xr @ p["wr"].astype(x.dtype)
    k = xk @ p["wk"].astype(x.dtype)
    v = xv @ p["wv"].astype(x.dtype)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    lora = jnp.tanh(xw @ p["w_lora_a"].astype(x.dtype)) @ p["w_lora_b"].astype(x.dtype)
    logw = -jnp.exp(
        jnp.clip(p["w_bias"].astype(jnp.float32) + lora.astype(jnp.float32), -8.0, 4.0))
    return r, k, v, g, logw  # logw in (-inf, 0): per-token per-channel log decay


def _heads(x, N):
    B, S, M = x.shape
    return x.reshape(B, S, M // N, N).transpose(0, 2, 1, 3)  # (B,H,S,N)


def time_mix_chunked(p, cfg, x, x_prev, state, *, chunk=64,
                     bf16_streams=False):
    """x: (B,S,M); state: (B,H,N,N). Returns (y, new_x_prev, new_state)."""
    B, S, M = x.shape
    N = cfg.rwkv_head_dim
    H = M // N
    r, k, v, g, logw = _projections(p, cfg, x, x_prev)
    u = p["bonus_u"].astype(jnp.float32).reshape(H, N)

    L = min(chunk, S)
    Sp = -(-S // L) * L
    if Sp != S:
        # pad: zero k/v contributions, decay=1 (logw=0) -> state is unaffected
        pad = ((0, 0), (0, Sp - S), (0, 0))
        r, k, v = jnp.pad(r, pad), jnp.pad(k, pad), jnp.pad(v, pad)
        logw = jnp.pad(logw, pad)
    nC = Sp // L
    sdt = jnp.bfloat16 if bf16_streams else jnp.float32
    rh = _heads(r, N).reshape(B, H, nC, L, N).astype(sdt)
    kh = _heads(k, N).reshape(B, H, nC, L, N).astype(sdt)
    vh = _heads(v, N).reshape(B, H, nC, L, N).astype(sdt)
    wh = _heads(logw.astype(jnp.float32), N).reshape(B, H, nC, L, N)

    @jax.checkpoint   # recompute D/A in backward: O(L^2 N) residuals per
    @jax.named_scope("wkv_kernel_region")
    def chunk_step(S_in, inp):  # chunk would otherwise be stacked across nC
        rc, kc, vc, wc = inp                       # (B,H,L,N)
        rc, kc, vc = (t.astype(jnp.float32) for t in (rc, kc, vc))
        c = jnp.cumsum(wc, axis=2)                 # inclusive cumulative log decay
        c_prev = c - wc                            # c_{t-1} (exclusive)
        # intra-chunk: A[t,s] = sum_i r[t,i] k[s,i] exp(c_prev[t,i] - c[s,i]), s<t
        D = jnp.exp(jnp.clip(
            c_prev[:, :, :, None, :] - c[:, :, None, :, :], -60.0, 0.0))
        A = jnp.einsum("bhti,bhsi,bhtsi->bhts", rc, kc, D)
        tri = jnp.tril(jnp.ones((rc.shape[2], rc.shape[2]), jnp.float32), -1)
        A = A * tri
        diag = jnp.einsum("hi,bhti,bhti->bht", u, rc, kc)
        y = jnp.einsum("bhts,bhsn->bhtn", A, vc) + diag[..., None] * vc
        # inter-chunk: y_t += (r_t * exp(c_prev_t)) @ S_in
        q_dec = rc * jnp.exp(c_prev)
        y = y + jnp.einsum("bhti,bhin->bhtn", q_dec, S_in)
        # state update: S_out = diag(exp(c_L)) S_in + sum_s (k_s exp(c_L - c_s)) v_s^T
        c_last = c[:, :, -1:, :]
        k_dec = kc * jnp.exp(jnp.clip(c_last - c, -60.0, 0.0))
        S_out = jnp.exp(c_last.squeeze(2))[..., None] * S_in \
            + jnp.einsum("bhsi,bhsn->bhin", k_dec, vc)
        return S_out, y

    xs = (rh.transpose(2, 0, 1, 3, 4), kh.transpose(2, 0, 1, 3, 4),
          vh.transpose(2, 0, 1, 3, 4), wh.transpose(2, 0, 1, 3, 4))
    state_f, ys = lax.scan(chunk_step, state.astype(jnp.float32), xs)
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, Sp, N).transpose(0, 2, 1, 3)
    y = y.reshape(B, Sp, M)[:, :S].astype(x.dtype)

    y = rms_norm(y, p["ln_x_scale"], cfg.norm_eps) * g
    y = y @ p["wo"].astype(x.dtype)
    return y, x[:, -1, :], state_f.astype(state.dtype)


def time_mix_decode(p, cfg, x, x_prev, state):
    """Single-token recurrence. x: (B,1,M); state: (B,H,N,N) fp32."""
    B, _, M = x.shape
    N = cfg.rwkv_head_dim
    H = M // N
    r, k, v, g, logw = _projections(p, cfg, x, x_prev)
    rh = r.reshape(B, H, N).astype(jnp.float32)
    kh = k.reshape(B, H, N).astype(jnp.float32)
    vh = v.reshape(B, H, N).astype(jnp.float32)
    wh = jnp.exp(logw.reshape(B, H, N).astype(jnp.float32))
    u = p["bonus_u"].astype(jnp.float32).reshape(H, N)
    kv = kh[..., :, None] * vh[..., None, :]               # (B,H,N,N)
    y = jnp.einsum("bhi,bhin->bhn", rh, state + u[None, :, :, None] * kv)
    state = wh[..., None] * state + kv
    y = y.reshape(B, 1, M).astype(x.dtype)
    y = rms_norm(y, p["ln_x_scale"], cfg.norm_eps) * g
    return y @ p["wo"].astype(x.dtype), x[:, -1, :], state


def channel_mix(p, x, x_prev):
    sx = _token_shift(x, x_prev)
    xk = _mix(x, sx, p["mix_k"])
    xr = _mix(x, sx, p["mix_r"])
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    kv = k @ p["wv"].astype(x.dtype)
    return jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) * kv, x[:, -1, :]


# ---------------------------------------------------------------------------
# block-level API (norms included)
# ---------------------------------------------------------------------------


def init_block(pb: ParamBuilder, cfg):
    zeros = lambda k, s, d: jnp.zeros(s, d)
    pb.param("norm_tm", (cfg.d_model,), ("d_model",), init=zeros)
    pb.param("norm_cm", (cfg.d_model,), ("d_model",), init=zeros)
    init(pb, cfg)


def cache_shape(cfg, batch, dtype=jnp.bfloat16):
    M = cfg.d_model
    N = cfg.rwkv_head_dim
    H = M // N
    return {
        "state": jax.ShapeDtypeStruct((batch, H, N, N), jnp.float32),
        "tm_x_prev": jax.ShapeDtypeStruct((batch, M), dtype),
        "cm_x_prev": jax.ShapeDtypeStruct((batch, M), dtype),
    }


def cache_specs():
    return {"state": ("batch", "heads", "rwkv_n", "rwkv_n2"),
            "tm_x_prev": ("batch", "d_model"),
            "cm_x_prev": ("batch", "d_model")}


def init_cache(cfg, batch, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                                  cache_shape(cfg, batch, dtype))


def apply(p, cfg, run, x, cache=None, use_pallas=False):
    """Full-sequence forward. Returns (y, new_cache)."""
    B = x.shape[0]
    if cache is None:
        cache = jax.tree_util.tree_map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            cache_shape(cfg, B, dtype=x.dtype))
    h = rms_norm(x, p["norm_tm"], cfg.norm_eps)
    if use_pallas:
        from repro.kernels.rwkv6 import ops as rwkv_ops
        r, k, v, g, logw = _projections(p, cfg, h, cache["tm_x_prev"])
        N = cfg.rwkv_head_dim
        y, state_f = rwkv_ops.wkv6(
            _heads(r, N), _heads(k, N), _heads(v, N),
            _heads(logw.astype(jnp.float32), N),
            p["bonus_u"].astype(jnp.float32).reshape(-1, N),
            cache["state"], interpret=True)
        M = cfg.d_model
        y = y.transpose(0, 2, 1, 3).reshape(B, x.shape[1], M).astype(x.dtype)
        y = rms_norm(y, p["ln_x_scale"], cfg.norm_eps) * g
        y = y @ p["wo"].astype(x.dtype)
        tm_prev = h[:, -1, :]
    else:
        y, tm_prev, state_f = time_mix_chunked(
            p, cfg, h, cache["tm_x_prev"], cache["state"],
            chunk=run.rwkv_chunk, bf16_streams=run.rwkv_bf16_streams)
    x = x + y
    h = rms_norm(x, p["norm_cm"], cfg.norm_eps)
    y, cm_prev = channel_mix(p["cm"], h, cache["cm_x_prev"])
    x = x + y
    return x, {"state": state_f, "tm_x_prev": tm_prev, "cm_x_prev": cm_prev}


def decode(p, cfg, run, x, cache, pos=None):
    h = rms_norm(x, p["norm_tm"], cfg.norm_eps)
    y, tm_prev, state = time_mix_decode(p, cfg, h, cache["tm_x_prev"], cache["state"])
    x = x + y
    h = rms_norm(x, p["norm_cm"], cfg.norm_eps)
    y, cm_prev = channel_mix(p["cm"], h, cache["cm_x_prev"])
    x = x + y
    return x, {"state": state, "tm_x_prev": tm_prev, "cm_x_prev": cm_prev}
