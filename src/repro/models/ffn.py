"""Feed-forward blocks: SwiGLU MLP and capacity-based top-k MoE.

MoE uses the einsum-dispatch formulation (GShard/Switch style), which maps
onto the MXU and onto GSPMD sharding: tokens are grouped (``group_size`` per
group), each group builds a (T, E, C) one-hot dispatch tensor via an
intra-group position cumsum, and expert FFNs run as batched einsums over the
expert dimension.  Experts shard over the "model" axis when divisible (EP);
otherwise the per-expert hidden dim shards (TP) — see sharding/rules.py.

Shared experts (qwen2-moe) are a dense SwiGLU branch gated by a per-token
sigmoid, always active.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.activations import shard_activation
from repro.utils.tree import ParamBuilder, fan_in_init


def init_mlp(pb: ParamBuilder, d_model: int, d_ff: int, variant: str = "swiglu"):
    if variant == "swiglu":
        pb.param("w_gate", (d_model, d_ff), ("d_model", "d_ff"),
                 init=fan_in_init(d_model))
    pb.param("w_up", (d_model, d_ff), ("d_model", "d_ff"), init=fan_in_init(d_model))
    pb.param("w_down", (d_ff, d_model), ("d_ff", "d_model"), init=fan_in_init(d_ff))


def apply_mlp(p, x):
    u = jnp.einsum("...m,mf->...f", x, p["w_up"].astype(x.dtype))
    if "w_gate" in p:  # swiglu
        g = jnp.einsum("...m,mf->...f", x, p["w_gate"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:              # gelu 2-mat
        h = jax.nn.gelu(u)
    return jnp.einsum("...f,fm->...m", h, p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def init_moe(pb: ParamBuilder, cfg):
    m = cfg.moe
    M, E, F = cfg.d_model, m.n_experts, m.d_ff_expert
    pb.param("router", (M, E), ("d_model", "experts_r"), init=fan_in_init(M))
    pb.param("we_gate", (E, M, F), ("experts", "d_model", "d_ff_expert"),
             init=fan_in_init(M))
    pb.param("we_up", (E, M, F), ("experts", "d_model", "d_ff_expert"),
             init=fan_in_init(M))
    pb.param("we_down", (E, F, M), ("experts", "d_ff_expert", "d_model"),
             init=fan_in_init(F))
    if m.n_shared_experts:
        shared = pb.child("shared")
        init_mlp(shared, M, m.d_ff_shared)
        pb.param("shared_gate", (M, 1), ("d_model", "one"), init=fan_in_init(M))


def apply_moe(p, cfg, x):
    """x: (B, S, M) -> (y, aux_loss)."""
    m = cfg.moe
    B, S, M = x.shape
    E, K = m.n_experts, m.top_k
    T = min(m.group_size, B * S)
    if (B * S) % T:
        T = B * S  # small/odd shapes (smoke tests): one group
    n_groups = (B * S) // T
    xg = x.reshape(n_groups, T, M)

    logits = jnp.einsum("gtm,me->gte", xg, p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (G,T,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * mean(frac_tokens * frac_probs)
    tok_onehot = jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32)
    frac_tokens = jnp.mean(tok_onehot, axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs) * m.router_aux_weight

    C = max(1, int(T * K / E * m.capacity_factor))
    C = min(C, T)
    # position of each (token, k) within its expert queue
    kth_onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (G,T,K,E)
    flat = kth_onehot.reshape(n_groups, T * K, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(n_groups, T, K, E)
    pos = jnp.sum(pos_in_expert * kth_onehot, axis=-1)             # (G,T,K)
    keep = pos < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    pos_onehot = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=x.dtype)  # (G,T,K,C)
    dispatch = jnp.einsum("gtke,gtkc->gtec",
                          kth_onehot.astype(x.dtype) * keep[..., None].astype(x.dtype),
                          pos_onehot)                              # (G,T,E,C)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec",
                         gate_vals.astype(x.dtype),
                         kth_onehot.astype(x.dtype), pos_onehot)

    xe = jnp.einsum("gtm,gtec->gecm", xg, dispatch)
    xe = shard_activation(xe, "batch", "experts", None, None)
    g = jnp.einsum("gecm,emf->gecf", xe, p["we_gate"].astype(x.dtype))
    u = jnp.einsum("gecm,emf->gecf", xe, p["we_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("gecf,efm->gecm", h, p["we_down"].astype(x.dtype))
    y = jnp.einsum("gecm,gtec->gtm", ye, combine)

    if m.n_shared_experts:
        sg = jax.nn.sigmoid(
            jnp.einsum("gtm,mo->gto", xg, p["shared_gate"].astype(x.dtype)))
        y = y + sg * apply_mlp(p["shared"], xg)

    return y.reshape(B, S, M), aux
