"""Shared building blocks: norms, rope, blockwise (flash-style) attention in
pure JAX, chunked cross-entropy.

Everything here is shape-polymorphic pure-function code — no module classes —
so it scans, remats, vmaps and AOT-lowers cleanly on 512 placeholder devices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.activations import shard_activation

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_angles(positions, head_dim: int, theta: float):
    """positions: int array (...,) -> (cos, sin) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (S, D//2) (positions shared across batch)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    while cos.ndim < x.ndim:    # (S, half) -> (1, S, 1, half)
        cos = cos[None] if cos.ndim + 2 <= x.ndim else cos[..., None, :]
        sin = sin[None] if sin.ndim + 2 <= x.ndim else sin[..., None, :]
    c, s = cos.astype(x.dtype), sin.astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# blockwise "flash" attention, pure JAX (production fallback path; the Pallas
# kernel in repro.kernels.flash_attention implements the same contract)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _pad_to(x, size, axis):
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def blockwise_attention(q, k, v, *, q_positions, kv_positions, causal=True,
                        window=None, block_q=512, block_kv=1024, softmax_scale=None,
                        window_block_skip=False):
    """Streaming-softmax attention.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    q_positions: (Sq,) int32 absolute positions (shared across the batch);
    kv_positions: (Skv,).
    Mask: kv_pos <= q_pos (if causal) and q_pos - kv_pos < window (if window).

    ``window_block_skip``: for sliding-window attention, only materialize the
    kv band [q_pos - window, q_pos] per q block via dynamic_slice — a real
    FLOPs reduction (beyond-paper optimization; the baseline masks instead).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    nq = -(-Sq // block_q)
    q = _pad_to(q, nq * block_q, 1)
    qpos = _pad_to(q_positions, nq * block_q, 0)

    use_band = bool(window_block_skip and window is not None and Skv > block_kv
                    and Sq == Skv)
    if use_band:
        # kv band width: window rounded up to blocks + one q block of lookback
        band = min(Skv, (-(-int(window) // block_kv) + -(-block_q // block_kv)) * block_kv)
    else:
        nkv = -(-Skv // block_kv)
        k = _pad_to(k, nkv * block_kv, 1)
        v = _pad_to(v, nkv * block_kv, 1)
        kvpos = _pad_to(kv_positions, nkv * block_kv, 0)
        # padded kv positions must never win the mask
        if nkv * block_kv != Skv:
            pad_mask = jnp.arange(nkv * block_kv) >= Skv
            kvpos = jnp.where(pad_mask, jnp.iinfo(jnp.int32).max // 2, kvpos)

    q = q.reshape(B, nq, block_q, Hq, D)
    qpos = qpos.reshape(nq, block_q)

    def one_q_block(qb, qpb, qblock_idx):
        # qb: (B, block_q, Hq, D) -> grouped (B, Hkv, G, block_q, D)
        qg = qb.transpose(0, 2, 1, 3).reshape(B, Hkv, G, block_q, D)

        def kv_step(carry, inp):
            m, l, acc = carry
            kb, vb, kpb = inp                     # (B, block_kv, Hkv, D), (block_kv,)
            kg = kb.transpose(0, 2, 1, 3)         # (B, Hkv, block_kv, D)
            vg = vb.transpose(0, 2, 1, 3)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kg,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((block_q, kb.shape[1]), dtype=bool)
            if causal:
                mask &= kpb[None, :] <= qpb[:, None]
            if window is not None:
                mask &= (qpb[:, None] - kpb[None, :]) < window
            # additive bias: keeps the mask (bq, bkv)-sized and fusible; a
            # broadcasted where() would pin a giant bool residual for the VJP
            bias = jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)
            s = s + bias[None, None, None, :, :]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vg.dtype), vg,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, block_q, D), jnp.float32)

        if use_band:
            start = jnp.maximum(qblock_idx * block_q + block_q - band, 0)
            start = jnp.minimum(start, Skv - band)
            kb_band = lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vb_band = lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kp_band = lax.dynamic_slice_in_dim(kv_positions, start, band, axis=0)
            nb = band // block_kv
            ks = kb_band.reshape(B, nb, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
            vs = vb_band.reshape(B, nb, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
            ps = kp_band.reshape(nb, block_kv)
        else:
            nb = k.shape[1] // block_kv
            ks = k.reshape(B, nb, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
            vs = v.reshape(B, nb, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
            ps = kvpos.reshape(nb, block_kv)

        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (ks, vs, ps))
        l = jnp.maximum(l, 1e-30)
        out = acc / l[..., None]
        return out.reshape(B, Hq, block_q, D).transpose(0, 2, 1, 3)  # (B,bq,Hq,D)

    outs = lax.map(
        lambda i: one_q_block(q[:, i], qpos[i], i), jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * block_q, Hq, D)
    return out[:, :Sq].astype(v.dtype)


def naive_attention(q, k, v, *, q_positions, kv_positions, causal=True,
                    window=None, softmax_scale=None):
    """O(S^2)-memory reference; also the decode path (Sq tiny).
    Positions are 1-D (shared across batch)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qg = q.transpose(0, 2, 1, 3).reshape(B, Hkv, G, Sq, D)
    kg = k.transpose(0, 2, 1, 3)
    vg = v.transpose(0, 2, 1, 3)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kg,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kv_positions[None, :] <= q_positions[:, None]
    if window is not None:
        mask &= (q_positions[:, None] - kv_positions[None, :]) < window
    s = s + jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)[None, None, None, :, :]
    p = jax.nn.softmax(s, axis=-1).astype(vg.dtype)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vg)
    return o.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3).astype(v.dtype)


# ---------------------------------------------------------------------------
# chunked cross-entropy: never materializes (B, S, V) logits
# ---------------------------------------------------------------------------


def chunked_cross_entropy(x, unembed, labels, *, chunk=512, label_mask=None):
    """x: (B, S, M) final hidden; unembed: (M, V); labels: (B, S) int32.

    Returns (mean_loss_f32, total_tokens).  Scans over sequence chunks and
    recomputes logits in the backward pass (jax.checkpoint), so peak memory is
    O(B * chunk * V) instead of O(B * S * V).
    """
    B, S, M = x.shape
    chunk = min(chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        pad_valid = jnp.pad(jnp.ones((B, S), bool), ((0, 0), (0, pad)))
    else:
        pad_valid = jnp.ones((B, S), bool)
    if label_mask is not None:
        pad_valid &= jnp.pad(label_mask, ((0, 0), (0, pad))) if pad else label_mask

    xs = x.reshape(B, n, chunk, M).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    vs = pad_valid.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(carry, inp):
        xc, lc, vc = inp
        logits = jnp.einsum("bsm,mv->bsv", xc, unembed.astype(xc.dtype),
                            preferred_element_type=jnp.float32)
        logits = shard_activation(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = jnp.where(vc, lse - gold, 0.0)
        return carry + jnp.sum(nll), None

    total, _ = lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (xs, ls, vs))
    count = jnp.maximum(jnp.sum(pad_valid.astype(jnp.float32)), 1.0)
    return total / count, count
