from repro.models.model import LM
