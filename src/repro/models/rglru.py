"""RecurrentGemma / Griffin recurrent block (arXiv:2402.19427).

Block: x -> [branch_a: linear -> causal depthwise conv1d(width 4) -> RG-LRU]
            [branch_b: linear -> GeLU]
       y = out_proj(branch_a * branch_b)

RG-LRU: a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x_t))        (c = 8)
        h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(W_x x_t) * x_t)

Training/prefill evaluates the linear recurrence with
``jax.lax.associative_scan`` (log-depth, vectorized); decode is the exact
single-step update.  The Pallas kernel (repro/kernels/rglru) implements a
blocked sequential scan over chunk boundaries with in-chunk closed form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.utils.tree import ParamBuilder, fan_in_init

RG_LRU_C = 8.0


def init(pb: ParamBuilder, cfg):
    M = cfg.d_model
    D = M  # lru width = d_model
    W = cfg.rglru_conv_width
    pb.param("w_in_a", (M, D), ("d_model", "d_rnn"), init=fan_in_init(M))
    pb.param("w_in_b", (M, D), ("d_model", "d_rnn"), init=fan_in_init(M))
    pb.param("conv_w", (W, D), ("conv_w", "d_rnn"),
             init=lambda k, s, d: (jax.random.normal(k, s) * 0.1).astype(d))
    pb.param("w_gate_a", (D, D), ("d_rnn", "d_rnn_out"), init=fan_in_init(D))
    pb.param("w_gate_x", (D, D), ("d_rnn", "d_rnn_out"), init=fan_in_init(D))
    pb.param("lam", (D,), ("d_rnn",),
             init=lambda k, s, d: jnp.full(s, 1.0, d))
    pb.param("w_out", (D, M), ("d_rnn", "d_model"), init=fan_in_init(D))


def _conv1d_causal(x, w, conv_state):
    """Depthwise causal conv. x: (B,S,D); w: (W,D); conv_state: (B,W-1,D)."""
    W = w.shape[0]
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i:i + x.shape[1], :] * w[W - 1 - i][None, None, :].astype(x.dtype)
    return out, xp[:, -(W - 1):, :]


def _gates(p, xc):
    lam = jax.nn.softplus(p["lam"].astype(jnp.float32))
    r = jax.nn.sigmoid(
        jnp.einsum("...d,de->...e", xc, p["w_gate_a"]).astype(jnp.float32))
    log_a = -RG_LRU_C * lam * r                     # log a_t  (<= 0)
    i = jax.nn.sigmoid(
        jnp.einsum("...d,de->...e", xc, p["w_gate_x"]).astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12))
    b = beta * i * xc.astype(jnp.float32)
    return a, b


@jax.named_scope("rglru_kernel_region")
def rg_lru_scan(p, xc, h0):
    """xc: (B,S,D) conv output; h0: (B,D) fp32. Returns (y, h_final)."""
    a, b = _gates(p, xc)                            # (B,S,D) fp32
    # fold initial state into the first element: h_1 = a_1 h_0 + b_1
    b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, hh = lax.associative_scan(combine, (a, b), axis=1)
    return hh.astype(xc.dtype), hh[:, -1, :]


def rg_lru_step(p, xc, h):
    """xc: (B,1,D); h: (B,D) fp32."""
    a, b = _gates(p, xc)
    h_new = a[:, 0] * h + b[:, 0]
    return h_new[:, None, :].astype(xc.dtype), h_new


def cache_shape(cfg, batch, dtype=jnp.bfloat16):
    D = cfg.d_model
    W = cfg.rglru_conv_width
    return {"h": jax.ShapeDtypeStruct((batch, D), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, W - 1, D), dtype)}


def cache_specs():
    return {"h": ("batch", "d_rnn"), "conv": ("batch", "conv_w", "d_rnn")}


def init_cache(cfg, batch, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                                  cache_shape(cfg, batch, dtype))


def apply(p, cfg, run, x, cache=None, use_pallas=False):
    """x: (B,S,M) -> (y, new_cache)."""
    B = x.shape[0]
    if cache is None:
        cache = init_cache(cfg, B, dtype=x.dtype)
    xa = jnp.einsum("bsm,md->bsd", x, p["w_in_a"].astype(x.dtype))
    xb = jnp.einsum("bsm,md->bsd", x, p["w_in_b"].astype(x.dtype))
    xc, conv_state = _conv1d_causal(xa, p["conv_w"], cache["conv"])
    if use_pallas:
        from repro.kernels.rglru import ops as rglru_ops
        a, b = _gates(p, xc)
        y, h_f = rglru_ops.linear_scan(a, b, cache["h"], interpret=True)
        y = y.astype(xc.dtype)
    else:
        y, h_f = rg_lru_scan(p, xc, cache["h"])
    y = y * jax.nn.gelu(xb)
    y = jnp.einsum("bsd,dm->bsm", y, p["w_out"].astype(x.dtype))
    return y, {"h": h_f, "conv": conv_state}


def decode(p, cfg, run, x, cache, pos=None):
    xa = jnp.einsum("bsm,md->bsd", x, p["w_in_a"].astype(x.dtype))
    xb = jnp.einsum("bsm,md->bsd", x, p["w_in_b"].astype(x.dtype))
    xc, conv_state = _conv1d_causal(xa, p["conv_w"], cache["conv"])
    y, h_new = rg_lru_step(p, xc, cache["h"])
    y = y * jax.nn.gelu(xb)
    y = jnp.einsum("bsd,dm->bsm", y, p["w_out"].astype(x.dtype))
    return y, {"h": h_new, "conv": conv_state}
