"""Naive per-token WKV6 recurrence — the oracle for the chunked kernel.

S_t = diag(w_t) S_{t-1} + k_t v_t^T
y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def wkv6_ref(r, k, v, logw, u, state0):
    """r,k,v,logw: (B, H, S, N); u: (H, N); state0: (B, H, N, N) fp32.
    Returns (y (B,H,S,N) f32, state (B,H,N,N) f32)."""
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    w = jnp.exp(logw.astype(jnp.float32))
    u = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                       # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]   # (B,H,N,N)
        y = jnp.einsum("bhi,bhin->bhn", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    xs = tuple(x.transpose(2, 0, 1, 3) for x in (r, k, v, w))
    state, ys = lax.scan(step, state0.astype(jnp.float32), xs)
    return ys.transpose(1, 2, 0, 3), state
