"""Pallas TPU chunked WKV6 kernel (RWKV-6 time-mix recurrence).

TPU adaptation (DESIGN.md §6): the CUDA reference processes one token per
thread; on TPU we use the chunked matrix formulation so intra-chunk work is
three MXU matmuls, and the (N x N) per-head state is carried in VMEM scratch
across the sequential chunk grid dimension.  The pairwise decay tensor
D[t,s,i] = exp(c_{t-1,t,i} - c_{s,i}) is <= 1 by construction, so the kernel
is stable at any chunk length (no exp(+c) factoring; see models/rwkv6.py).

grid = (B * H, n_chunks)      [chunks sequential]
  r,k,v,logw blocks (1, L, N); y block (1, L, N); state scratch (N, N) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref, s_ref, *,
                n_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)               # (L, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)               # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)               # (1, N) bonus

    c = jnp.cumsum(w, axis=0)                      # (L, N) inclusive
    c_prev = c - w
    L = r.shape[0]

    # intra-chunk: A[t,s] = sum_i r[t,i] k[s,i] exp(c_prev[t,i] - c[s,i]), s<t
    D = jnp.exp(jnp.clip(c_prev[:, None, :] - c[None, :, :], -60.0, 0.0))
    A = jnp.einsum("ti,si,tsi->ts", r, k, D)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    A = jnp.where(tri, A, 0.0)
    diag = jnp.sum(u * r * k, axis=1)              # (L,)
    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + diag[:, None] * v

    # inter-chunk: y_t += (r_t * exp(c_prev_t)) @ S_in
    S_in = s_ref[...]
    q_dec = r * jnp.exp(c_prev)
    y = y + jax.lax.dot_general(q_dec, S_in, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S_out = diag(exp(c_L)) S_in + sum_s (k_s exp(c_L-c_s)) v_s^T
    c_last = c[-1:, :]
    k_dec = k * jnp.exp(jnp.clip(c_last - c, -60.0, 0.0))
    s_ref[...] = jnp.exp(c_last[0])[:, None] * S_in + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ic == n_chunks - 1)
    def _finish():
        sout_ref[0] = s_ref[...]


def wkv6_chunked(r, k, v, logw, u, state0, *, chunk=64, interpret=False):
    """r,k,v,logw: (B,H,S,N); u: (H,N); state0: (B,H,N,N) f32.
    Returns (y (B,H,S,N) f32, state_out (B,H,N,N) f32).

    NOTE: state0 must be zeros in the kernel path (the fused state-carry
    scratch starts at zero); the ops wrapper folds a nonzero state0 in.
    """
    B, H, S, N = r.shape
    L = min(chunk, S)
    nC = -(-S // L)
    Sp = nC * L

    def pad(x):
        if Sp != S:
            return jnp.pad(x, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        return x

    # layout: (B*H, nC*L, N) -> grid (B*H, nC) with the chunk dim sequential
    rf = pad(r).reshape(B * H, nC * L, N)
    kf = pad(k).reshape(B * H, nC * L, N)
    vf = pad(v).reshape(B * H, nC * L, N)
    wf = pad(logw).reshape(B * H, nC * L, N)
    uf = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, 1, N)

    kernel = functools.partial(_wkv_kernel, n_chunks=nC)
    y, state = pl.pallas_call(
        kernel,
        grid=(B * H, nC),
        in_specs=[
            pl.BlockSpec((1, L, N), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, L, N), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, L, N), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, L, N), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, 1, N), lambda bh, ic: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, N), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, N, N), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sp, N), jnp.float32),
            jax.ShapeDtypeStruct((B * H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    y = y.reshape(B, H, Sp, N)[:, :, :S]
    return y, state.reshape(B, H, N, N)
