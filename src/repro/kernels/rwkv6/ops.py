"""jit'd wrapper for the WKV6 kernel, including nonzero initial state.

The kernel carries state from zero; a nonzero ``state0`` contributes
y_t += (r_t * exp(c_{t-1})) @ state0 * prod-of-previous-chunks decay — which
is exactly (r_t * exp(C_{t-1})) @ state0 with C the *global* cumulative
decay.  We add that term (and the decayed state0 to the final state) outside
the kernel; both are O(S*N^2 / chunk-free) streaming ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv6_chunked


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, logw, u, state0, *, chunk=64, interpret=False):
    """r,k,v,logw: (B,H,S,N); u: (H,N); state0: (B,H,N,N) f32.
    Returns (y (B,H,S,N) f32, state_out (B,H,N,N) f32)."""
    y, state = wkv6_chunked(r, k, v, logw, u,
                            jnp.zeros_like(state0, dtype=jnp.float32),
                            chunk=chunk, interpret=interpret)
    # fold in nonzero initial state
    c_global = jnp.cumsum(logw.astype(jnp.float32), axis=2)
    c_prev = c_global - logw.astype(jnp.float32)
    q_dec = r.astype(jnp.float32) * jnp.exp(c_prev)
    y = y + jnp.einsum("bhsn,bhnm->bhsm", q_dec, state0.astype(jnp.float32))
    total_decay = jnp.exp(c_global[:, :, -1, :])
    state = state + total_decay[..., None] * state0.astype(jnp.float32)
    return y, state
