from repro.kernels.rwkv6.ops import wkv6
