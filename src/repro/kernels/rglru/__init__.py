from repro.kernels.rglru.ops import linear_scan
