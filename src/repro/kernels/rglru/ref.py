"""Naive sequential oracle for the blocked linear-recurrence kernel:
h_t = a_t * h_{t-1} + b_t  (per channel)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def linear_scan_ref(a, b, h0):
    """a, b: (B, S, D) f32; h0: (B, D) f32. Returns (y (B,S,D), h_final)."""
    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    xs = (a.transpose(1, 0, 2), b.transpose(1, 0, 2))
    hT, ys = lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2), hT
