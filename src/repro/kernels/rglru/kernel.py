"""Pallas TPU blocked linear-recurrence kernel (RG-LRU core).

h_t = a_t * h_{t-1} + b_t, independent per channel.  GPU implementations use
warp-parallel prefix scans; the TPU-native shape (DESIGN.md §6) is a *blocked*
scan: channels tile the lane dimension (block_d multiple of 128), a chunk of
``block_s`` timesteps is brought into VMEM, the in-chunk recurrence is
evaluated by an unrolled VPU loop over rows, and the (block_d,) carry state
lives in VMEM scratch across the sequential chunk grid dimension.

grid = (B, n_d_blocks, n_chunks)   [chunks sequential innermost]
  a, b blocks (1, block_s, block_d); y block (1, block_s, block_d);
  h scratch (1, block_d) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, b_ref, y_ref, hT_ref, h_ref, *, block_s: int,
                 n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)       # (block_s, block_d)
    b = b_ref[0].astype(jnp.float32)

    # log2(block_s)-step Blelloch-style composition within the chunk:
    # compose (a, b) pairs pairwise so the full chunk prefix is materialized
    # without a length-block_s sequential loop.
    acc_a, acc_b = a, b
    shift = 1
    while shift < block_s:
        prev_a = jnp.roll(acc_a, shift, axis=0)
        prev_b = jnp.roll(acc_b, shift, axis=0)
        row = jax.lax.broadcasted_iota(jnp.int32, acc_a.shape, 0)
        valid = row >= shift
        comp_a = jnp.where(valid, acc_a * prev_a, acc_a)
        comp_b = jnp.where(valid, acc_a * prev_b + acc_b, acc_b)
        acc_a, acc_b = comp_a, comp_b
        shift *= 2
    # now h_t (from zero state) = acc_b[t]; fold in the carried state:
    h_in = h_ref[0]
    y = acc_b + acc_a * h_in[None, :]
    y_ref[0] = y.astype(y_ref.dtype)
    h_ref[0] = y[block_s - 1]

    @pl.when(ic == n_chunks - 1)
    def _finish():
        hT_ref[0] = h_ref[0]


def linear_scan_blocked(a, b, *, block_s=128, block_d=128, interpret=False):
    """a, b: (B, S, D). Returns (y (B,S,D) f32, h_final (B,D) f32) with
    zero initial state (ops wrapper folds a nonzero h0 in)."""
    B, S, D = a.shape
    bs = min(block_s, S)
    nC = -(-S // bs)
    Sp = nC * bs
    bd = min(block_d, D)
    nD = -(-D // bd)
    Dp = nD * bd

    def pad(x, a_fill):
        if Sp != S or Dp != D:
            # pad a with 1 (identity decay) and b with 0 in padded channels /
            # steps so the carry stays exact
            x = jnp.pad(x, ((0, 0), (0, Sp - S), (0, Dp - D)),
                        constant_values=a_fill)
        return x

    ap = pad(a, 1.0)
    bp = pad(b, 0.0)

    kernel = functools.partial(_scan_kernel, block_s=bs, n_chunks=nC)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, nD, nC),
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda ib, idd, ic: (ib, ic, idd)),
            pl.BlockSpec((1, bs, bd), lambda ib, idd, ic: (ib, ic, idd)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bd), lambda ib, idd, ic: (ib, ic, idd)),
            pl.BlockSpec((1, bd), lambda ib, idd, ic: (ib, idd)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, Dp), jnp.float32),
            jax.ShapeDtypeStruct((B, Dp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return y[:, :S, :D], hT[:, :D]
