"""jit'd wrapper for the blocked RG-LRU linear scan; folds in a nonzero
initial state: h_t = (prod_{s<=t} a_s) h_0 + h_t^{(0)}."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru.kernel import linear_scan_blocked


@functools.partial(jax.jit, static_argnames=("block_s", "block_d", "interpret"))
def linear_scan(a, b, h0, *, block_s=128, block_d=128, interpret=False):
    """a, b: (B, S, D) f32; h0: (B, D) f32 -> (y (B,S,D) f32, h_final)."""
    y0, hT0 = linear_scan_blocked(a, b, block_s=block_s, block_d=block_d,
                                  interpret=interpret)
    cum_a = jnp.cumprod(a.astype(jnp.float32), axis=1)
    y = y0 + cum_a * h0.astype(jnp.float32)[:, None, :]
    hT = hT0 + cum_a[:, -1, :] * h0.astype(jnp.float32)
    return y, hT
