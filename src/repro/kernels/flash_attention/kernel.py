"""Pallas TPU flash-attention forward kernel.

TPU adaptation of the flash algorithm (DESIGN.md §6): instead of a CUDA
warp-level softmax, each grid step processes one (q-block x kv-block) tile in
VMEM, streaming kv blocks through the *innermost sequential grid dimension*
while the running (m, l, acc) state lives in VMEM scratch — the TPU-native
replacement for shared-memory accumulators.  Block shapes default to
(128, 128) so the MXU sees aligned tiles; masking is an additive bias
computed from block offsets with iota (no O(S^2) mask tensor in HBM).

grid = (batch, q_heads, n_q_blocks, n_kv_blocks)   [last dim sequential]
  q   block (1, 1, block_q, head_dim)   indexed by (b, h, iq)
  k,v block (1, 1, block_kv, head_dim)  indexed by (b, h // group, ik)  [GQA]
  out block (1, 1, block_q, head_dim)   written on the last kv step
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window, block_q: int,
               block_kv: int, n_kv: int, seq_q: int, seq_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bkv, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = ik * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = (k_pos < seq_kv) & (q_pos < seq_q)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=None, block_q=128,
                        block_kv=128, interpret=False):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D). Returns (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    block_q = max(8, min(block_q, Sq))
    block_kv = max(8, min(block_kv, Skv))
    nq = -(-Sq // block_q)
    nkv = -(-Skv // block_kv)
    scale = D ** -0.5

    def pad_seq(x, n, blk):
        pad = n * blk - x.shape[2]
        if pad:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return x

    qt = pad_seq(q.transpose(0, 2, 1, 3), nq, block_q)
    kt = pad_seq(k.transpose(0, 2, 1, 3), nkv, block_kv)
    vt = pad_seq(v.transpose(0, 2, 1, 3), nkv, block_kv)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, n_kv=nkv, seq_q=Sq, seq_kv=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # running max m
            pltpu.VMEM((block_q,), jnp.float32),        # running sum l
            pltpu.VMEM((block_q, D), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :Sq, :].transpose(0, 2, 1, 3)
