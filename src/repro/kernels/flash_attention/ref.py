"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=None):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = D ** -0.5
    qg = q.transpose(0, 2, 1, 3).reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    kg = k.transpose(0, 2, 1, 3).astype(jnp.float32)
    vg = v.transpose(0, 2, 1, 3).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kg) * scale
    qp = jnp.arange(Sq)
    kp = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vg)
    return o.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3).astype(v.dtype)
