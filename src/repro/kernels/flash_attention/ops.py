"""jit'd wrapper for the Pallas flash-attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=128,
                    block_kv=128, interpret=False):
    """Inference/forward flash attention (TPU Pallas; interpret=True on CPU).

    Training uses repro.models.flash (custom-VJP pure-JAX twin of this
    kernel); this entry point serves prefill and kernel validation.
    """
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=interpret)
