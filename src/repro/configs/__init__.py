"""Architecture config registry.

``get_config(arch_id)`` returns the exact published configuration;
``get_smoke_config(arch_id)`` returns a reduced same-family variant for CPU
smoke tests (small widths/layers/experts, tiny vocab).
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, MoEConfig, RunConfig
from repro.configs import shapes

ARCH_IDS = (
    "mixtral-8x22b",
    "qwen2-moe-a2.7b",
    "rwkv6-3b",
    "musicgen-large",
    "smollm-360m",
    "qwen3-32b",
    "granite-8b",
    "command-r-plus-104b",
    "recurrentgemma-2b",
    "chameleon-34b",
)

_MODULES = {
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "rwkv6-3b": "rwkv6_3b",
    "musicgen-large": "musicgen_large",
    "smollm-360m": "smollm_360m",
    "qwen3-32b": "qwen3_32b",
    "granite-8b": "granite_8b",
    "command-r-plus-104b": "command_r_plus_104b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "chameleon-34b": "chameleon_34b",
}


def _mod(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _mod(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _mod(arch_id).smoke()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
