"""rwkv6-3b [ssm] — Finch, data-dependent decay. [arXiv:2404.05892; hf]

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.  head size 64
(40 wkv heads).  ~3.1B parameters.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,           # d_model / rwkv_head_dim; informational for rooflines
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    rwkv_head_dim=64,
    notes="attention-free; O(1)-state decode => long_500k applicable.",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke",
        family="ssm",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        rwkv_head_dim=16,
    )
