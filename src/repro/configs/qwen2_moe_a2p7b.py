"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

24L d_model=2048 16H (GQA kv=16 = MHA) d_ff=1408 (per routed expert)
vocab=151936.  The 4 shared experts are merged into one always-on SwiGLU of
hidden 5632 (= 4 x 1408) with a per-token sigmoid gate, matching the HF
reference implementation.  ~14.3B total / ~2.7B active.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408,
                  n_shared_experts=4, d_ff_shared=5632),
    notes="full attention: long_500k skipped.",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        moe=MoEConfig(n_experts=6, top_k=3, d_ff_expert=96,
                      n_shared_experts=2, d_ff_shared=128, group_size=32),
    )
