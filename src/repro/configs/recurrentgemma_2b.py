"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 2 recurrent : 1
attention. [arXiv:2402.19427; hf]

26L d_model=2560 10H (GQA kv=1 = MQA) d_ff=7680 vocab=256000, head_dim=256,
local attention window 2048.  26 layers = 8 x (rglru, rglru, attn) + 2
trailing rglru layers (group-scanned + unrolled tail).  ~2.9B parameters.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    local_window=2048,
    tie_embeddings=True,
    block_pattern=("rglru", "rglru", "attn"),
    notes="O(1)-state + bounded-window decode => long_500k applicable; "
          "10 heads => head-TP falls back to d_ff TP on 16-way axes.",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke",
        family="hybrid",
        n_layers=5,           # 1 group (R,R,A) + tail (R,R): exercises both paths
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        local_window=16,
        tie_embeddings=True,
        block_pattern=("rglru", "rglru", "attn"),
    )
