"""chameleon-34b [vlm] — early-fusion, VQ image tokens.
[arXiv:2405.09818; unverified]

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 (unified text +
VQ-VAE image codebook), qk-norm.  The VQ image tokenizer frontend is a
STUB: ``input_specs()`` provides precomputed token ids covering interleaved
text/image streams.  ~34B parameters.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    notes="modality frontend stubbed (VQ token ids); "
          "full attention: long_500k skipped.",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        qk_norm=True,
    )
