"""smollm-360m [dense] — llama-arch small. [hf:HuggingFaceTB/SmolLM-360M; hf]

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152, tied embeddings.
~362M parameters.  15 heads do not divide the 16-way model axis: sharding
rules fall back to d_ff/vocab TP (see sharding/rules.py).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
    notes="full attention: long_500k skipped.",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m-smoke",
        family="dense",
        n_layers=3,
        d_model=60,
        n_heads=3,
        n_kv_heads=1,
        head_dim=20,
        d_ff=96,
        vocab_size=256,
        tie_embeddings=True,
    )
