"""musicgen-large [audio] — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]

48L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192 vocab=2048.  The EnCodec
modality frontend is a STUB: ``input_specs()`` provides precomputed audio
token ids (the backbone sees a plain token stream).  2-matrix GELU FFN per
the reference.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    mlp_variant="gelu",
    notes="modality frontend stubbed (EnCodec token ids); "
          "full attention: long_500k skipped.",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        mlp_variant="gelu",
    )
