"""Model / run configuration dataclasses.

One ``ModelConfig`` per assigned architecture lives in ``repro/configs/<id>.py``
with the exact published numbers; every config also provides a ``smoke()``
reduction of the same family for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0           # per-expert hidden size
    n_shared_experts: int = 0      # qwen2-moe: always-on shared expert(s)
    d_ff_shared: int = 0           # total hidden size of the merged shared expert
    capacity_factor: float = 1.25
    group_size: int = 512          # tokens per dispatch group (einsum dispatch)
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # SWA window (mixtral)
    local_window: Optional[int] = None     # local-attn window for hybrid blocks
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_bias: bool = False
    mlp_variant: str = "swiglu"    # "swiglu" (3-mat) | "gelu" (2-mat)
    moe: Optional[MoEConfig] = None
    # layer pattern for hybrids: e.g. ("rglru","rglru","attn") repeated.
    # None -> homogeneous ("attn" or "rwkv" depending on family).
    block_pattern: Optional[Sequence[str]] = None
    # rwkv6 specifics
    rwkv_head_dim: int = 64
    # rg-lru specifics
    rglru_conv_width: int = 4
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch supports O(1)-state or bounded-window decoding at
        arbitrary context length (gates long_500k applicability)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    @property
    def layer_kinds(self) -> tuple:
        if self.block_pattern is None:
            kind = "rwkv" if self.family == "ssm" else "attn"
            return tuple([kind] * self.n_layers)
        pat = list(self.block_pattern)
        out = []
        while len(out) < self.n_layers:
            out.extend(pat)
        return tuple(out[: self.n_layers])

    def param_count(self) -> int:
        """Analytic parameter count (matches models.model.init within ties)."""
        M, V, L = self.d_model, self.vocab_size, self.n_layers
        D = self.resolved_head_dim
        total = V * M                       # embed
        if not self.tie_embeddings:
            total += V * M                  # unembed
        total += M                          # final norm
        for kind in self.layer_kinds:
            if kind == "attn":
                attn = M * self.n_heads * D + 2 * M * self.n_kv_heads * D \
                    + self.n_heads * D * M
                if self.qk_norm:
                    attn += 2 * D
                total += attn + 2 * M       # norms
                total += self._ffn_params() if self.moe is None else self._moe_params()
            elif kind == "rwkv":
                total += self._rwkv_params() + 2 * M
            elif kind == "rglru":
                total += self._rglru_params() + 2 * M
                total += self._ffn_params()
            else:
                raise ValueError(kind)
        return total

    def _ffn_params(self) -> int:
        mats = 2 if self.mlp_variant == "gelu" else 3
        return mats * self.d_model * self.d_ff

    def _moe_params(self) -> int:
        m = self.moe
        M = self.d_model
        total = M * m.n_experts                      # router
        total += m.n_experts * 3 * M * m.d_ff_expert
        if m.n_shared_experts:
            total += 3 * M * m.d_ff_shared + M       # shared + gate
        return total

    def _rwkv_params(self) -> int:
        M = self.d_model
        # time-mix: r,k,v,g,w,o projections + decay lora + mix params + ln
        tm = 5 * M * M + M * M + 2 * (M * 64 + 64 * M) + 6 * M + 2 * M
        # channel-mix: k,v ffn with token shift
        cm = M * self.d_ff + self.d_ff * M + 2 * M
        return tm + cm

    def _rglru_params(self) -> int:
        M = self.d_model
        W = self.rglru_conv_width
        # recurrent block: in-proj x2, conv1d, input+rec gates, Lambda, out-proj
        return 2 * M * M + W * M + 2 * M * M + M + M * M

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_like = self.param_count() - self.n_layers * self._moe_params()
        act = self.d_model * m.n_experts \
            + m.top_k * 3 * self.d_model * m.d_ff_expert
        if m.n_shared_experts:
            act += 3 * self.d_model * m.d_ff_shared + self.d_model
        return dense_like + self.n_layers * act

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class RunConfig:
    """Execution knobs, orthogonal to architecture."""
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    use_pallas: bool = False       # CPU CI: pure-JAX path (kernels need TPU/interpret)
    remat: bool = True
    scan_layers: bool = True
    microbatches: int = 1          # gradient-accumulation steps per train step
    attn_block_q: int = 512        # blockwise-attention chunking (pure-JAX flash)
    attn_block_kv: int = 1024
    loss_chunk: int = 512          # chunked cross-entropy seq chunk
    fsdp: bool = True              # shard params/opt over "data" axis too
    zero_opt: bool = True          # shard optimizer state over "data"
    swa_block_skip: bool = True    # skip out-of-window kv blocks (beyond-paper opt)
    rwkv_chunk: int = 64           # WKV6 chunk length (kernel block size)
    rwkv_bf16_streams: bool = False  # store r/k/v chunk streams in bf16
    quantize_serving: bool = False # int8 weight-only quant for decode (beyond-paper)
    grad_compression: bool = False # int8 pod-axis gradient all-reduce
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0
