"""mixtral-8x22b [moe] — 8 experts top-2, SWA. [arXiv:2401.04088; hf]

56L d_model=6144 48H (GQA kv=8) d_ff=16384(per expert) vocab=32768.
~141B total / ~39B active parameters.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
    notes="SWA => sub-quadratic; long_500k applicable (ring KV of window size).",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        sliding_window=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, group_size=32),
    )
