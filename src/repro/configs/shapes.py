"""Assigned input shapes and (arch x shape) applicability.

LM transformer shapes are seq_len x global_batch.  ``decode_*``/``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), not
``train_step``.  ``long_500k`` requires sub-quadratic attention: it runs for
SSM / hybrid / sliding-window archs and is recorded as skipped for pure
full-attention archs (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable(cfg, shape: InputShape) -> bool:
    if shape.name == "long_500k":
        return cfg.is_subquadratic
    return True


def skip_reason(cfg, shape: InputShape) -> str | None:
    if applicable(cfg, shape):
        return None
    return (f"{cfg.name}: long_500k skipped — pure full attention "
            f"(no O(1)-state / bounded-window decode at 512k context)")


def cells(cfg):
    """All assigned (shape, applicable) pairs for an arch."""
    return [(s, applicable(cfg, s)) for s in SHAPES.values()]
