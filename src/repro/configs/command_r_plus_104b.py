"""command-r-plus-104b [dense] — GQA, no-bias.
[hf:CohereForAI/c4ai-command-r-plus; unverified]

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000, tied embeddings.
~104B parameters — the largest assigned arch; the decode_32k cell is the
serving stress test (KV cache ~1.1 TB global in bf16).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    tie_embeddings=True,
    rope_theta=75_000_000.0,
    notes="full attention: long_500k skipped.",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b-smoke",
        family="dense",
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
        tie_embeddings=True,
    )
