"""Production meshes.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.

Single pod : 16 x 16 = 256 chips, axes ("data", "model")
Multi-pod  : 2 x 16 x 16 = 512 chips, axes ("pod", "data", "model");
             "pod" is pure data parallelism across the DCN/ICI-superpod link.
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_slice_mesh(rows: int, cols: int = 16):
    """A MISO pod sub-slice (contiguous row range) as its own mesh —
    what a job scheduled on a TPUPodSpace slice actually runs under."""
    return jax.make_mesh((rows, cols), ("data", "model"), axis_types=_auto(2))


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh for CPU integration tests (needs host-device override)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=_auto(3))
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))
