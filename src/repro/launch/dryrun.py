import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: AOT-lower + compile every (arch x input-shape x mesh)
cell and extract the roofline terms (EXPERIMENTS.md §Dry-run / §Roofline).

The two lines above MUST stay first: jax locks the device count at first
init, and only the dry-run wants 512 placeholder devices (smoke tests and
benches see the real single CPU device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --multi-pod
Each cell writes artifacts/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs import shapes as shp
from repro.configs.base import RunConfig
from repro.launch.mesh import make_production_mesh
from repro.models.model import LM
from repro.roofline import costs as roofline_costs
from repro.roofline.hlo_analysis import analyze_hlo
from repro.sharding.rules import make_rules, specs_to_shardings
from repro.serve.engine import make_serve_step
from repro.train.train_step import batch_pspec, init_train_state, make_train_step
from repro.utils.tree import tree_count

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")

# TPU v5e constants (system prompt):
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
HBM_GB = 16.0
LINK_BW = 50e9               # bytes/s / link (ICI)


def default_run(cfg, shape, overrides=None) -> RunConfig:
    kw = dict(
        param_dtype="bfloat16", activation_dtype="bfloat16",
        remat=True, scan_layers=True,
        microbatches=4 if shape.kind == "train" else 1,
        attn_block_q=512, attn_block_kv=1024, loss_chunk=512,
        fsdp=True, zero_opt=True,
    )
    if overrides:
        kw.update(overrides)
    return RunConfig(**kw)


def input_specs(arch: str, shape_name: str, run=None):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:  {tokens, labels}: (global_batch, seq) int32
    prefill:{tokens}: (global_batch, seq) int32
    decode: {tokens}: (global_batch, 1) int32 + KV/state cache of seq_len + pos
    Modality-frontend archs (musicgen/chameleon) take precomputed token ids —
    the frontend is a stub per the assignment.
    """
    cfg = configs.get_config(arch)
    shape = shp.SHAPES[shape_name]
    run = run or default_run(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind == "train":
        return {"tokens": tok, "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if shape.kind == "prefill":
        return {"tokens": tok}
    cache = LM.cache_shape(cfg, run, B, S, jnp.bfloat16)
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "cache": cache,
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# collective-bytes extraction from the partitioned HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum of operand bytes per collective opcode, from the per-device
    partitioned HLO (so totals are bytes *per chip*)."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"=\s+(\S+)\s+(\S+)\(", line)
        if not m:
            continue
        opcode = m.group(2).split(".")[0]
        if opcode.endswith("-start"):
            opcode = opcode[:-6]
        if opcode not in _COLLECTIVES:
            continue
        # operand types are inside the parens
        paren = line[line.index("(") + 1:]
        ops = _shape_bytes(paren)
        if ops == 0:  # fall back to result type (left of '=')
            ops = _shape_bytes(line[:line.index("=")])
        out[opcode] += ops
        count[opcode] += 1
    return out, count


# ---------------------------------------------------------------------------
# per-cell dry run
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             overrides=None, save: bool = True, mesh=None, tag: str = "",
             mesh_shape=None):
    """``mesh_shape``: optional (data, model) or (pod, data, model) override
    for §Perf hillclimbing (e.g. right-sizing small archs)."""
    cfg = configs.get_config(arch)
    shape = shp.SHAPES[shape_name]
    if not shp.applicable(cfg, shape):
        rec = {"arch": arch, "shape": shape_name, "skipped": True,
               "reason": shp.skip_reason(cfg, shape)}
        if save:
            _save(rec, arch, shape_name, multi_pod, tag)
        return rec

    run = default_run(cfg, shape, overrides)
    if mesh is None and mesh_shape is not None:
        axes = ("pod", "data", "model")[-len(mesh_shape):]
        mesh = jax.make_mesh(tuple(mesh_shape), axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(mesh_shape))
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rules = make_rules(mesh, fsdp=run.fsdp)
    t0 = time.time()

    params, opt, pspecs, ospecs = init_train_state(cfg, run, abstract=True)
    param_sh = specs_to_shardings(params, pspecs, mesh, rules)
    n_params = tree_count(params)
    B, S = shape.global_batch, shape.seq_len

    def sh(axes, dims):   # divisibility-aware NamedSharding
        return NamedSharding(mesh, rules.pspec(axes, dims))

    with mesh:
        if shape.kind == "train":
            tok_sh = sh(("batch", "seq"), (B, S))
            opt_sh = {"m": param_sh, "v": param_sh,
                      "step": NamedSharding(mesh, P())}
            step = make_train_step(cfg, run)
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, tok_sh, tok_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1))
            ins = input_specs(arch, shape_name, run)
            lowered = jitted.lower(params, opt, ins["tokens"], ins["labels"])
        elif shape.kind == "prefill":
            tok_sh = sh(("batch", "seq"), (B, S))
            logit_sh = sh(("batch", "seq", "vocab"), (B, 1, cfg.vocab_size))
            cache_sh = specs_to_shardings(
                LM.cache_shape(cfg, run, B, S), LM.cache_specs(cfg, run),
                mesh, rules)

            def prefill_step(params, tokens):
                return LM.prefill(params, cfg, run, tokens, max_seq=S)

            jitted = jax.jit(prefill_step, in_shardings=(param_sh, tok_sh),
                             out_shardings=(logit_sh, cache_sh))
            ins = input_specs(arch, shape_name, run)
            lowered = jitted.lower(params, ins["tokens"])
        else:  # decode
            ins = input_specs(arch, shape_name, run)
            tok_sh = sh(("batch", "seq"), (B, 1))
            logit_sh = sh(("batch", "seq", "vocab"), (B, 1, cfg.vocab_size))
            cache_sh = specs_to_shardings(ins["cache"], LM.cache_specs(cfg, run),
                                          mesh, rules)
            serve_params = params
            serve_param_sh = param_sh
            if run.quantize_serving:
                from repro.utils.quant import abstract_quantize
                serve_params, qspecs = abstract_quantize(params, pspecs)
                serve_param_sh = specs_to_shardings(serve_params, qspecs,
                                                    mesh, rules)
            step = make_serve_step(cfg, run)
            jitted = jax.jit(
                step,
                in_shardings=(serve_param_sh, tok_sh, cache_sh,
                              NamedSharding(mesh, P())),
                out_shardings=(logit_sh, cache_sh),
                donate_argnums=(2,))
            lowered = jitted.lower(serve_params, ins["tokens"], ins["cache"],
                                   ins["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    # loop-aware HLO walk (backend cost_analysis counts while bodies once)
    walk = analyze_hlo(hlo)
    coll = walk["collectives"]
    coll_count = walk["collective_counts"]
    flops_dev = float(walk["flops"])
    bytes_dev = float(walk["hbm_bytes"])
    bytes_kern_dev = float(walk["hbm_bytes_kernelized"])
    coll_dev = float(walk["collective_bytes"])
    if run.quantize_serving and shape.kind == "decode":
        # the lazy-dequant HLO reads int8 then re-reads the bf16 dequant as
        # the dot operand; a fused int8 kernel reads 1 byte/param instead of
        # 2 — subtract the difference (documented modeling adjustment)
        adj = float(n_params)  # 1 byte per (active) parameter per step
        bytes_dev = max(bytes_dev - adj / chips, 0.0)
        bytes_kern_dev = max(bytes_kern_dev - adj / chips, 0.0)

    # roofline terms (seconds); cost_analysis is per-device post-SPMD
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_mem_kern = bytes_kern_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]

    n_active = cfg.active_param_count()
    model_fl = roofline_costs.model_flops(cfg, shape.seq_len,
                                          shape.global_batch, shape.kind,
                                          n_params=n_active)
    flops_global = flops_dev * chips

    rec = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "x".join(str(d) for d in mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names), "chips": chips,
        "tag": tag or ("multipod" if multi_pod else "pod"),
        "overrides": overrides or {},
        "n_params": int(n_params), "n_active_params": int(n_active),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "backend_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "collectives": coll, "collective_counts": coll_count,
        "roofline": {
            "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
            "dominant": dominant,
            "bound_s": max(t_comp, t_mem, t_coll),
        },
        "roofline_kernelized": {
            "compute_s": t_comp, "memory_s": t_mem_kern,
            "collective_s": t_coll,
            "dominant": max(("compute", t_comp), ("memory", t_mem_kern),
                            ("collective", t_coll), key=lambda kv: kv[1])[0],
            "bound_s": max(t_comp, t_mem_kern, t_coll),
        },
        "model_flops": model_fl,
        "model_flops_ratio": model_fl / max(flops_global, 1.0),
        "memory_analysis": _mem_summary(mem),
        "skipped": False,
    }
    if save:
        _save(rec, arch, shape_name, multi_pod, tag)
    return rec


def _mem_summary(mem):
    if mem is None:
        return None
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    if "argument_size_in_bytes" in out and "temp_size_in_bytes" in out:
        live = out["argument_size_in_bytes"] + out["temp_size_in_bytes"] \
            - out.get("alias_size_in_bytes", 0) + out.get("output_size_in_bytes", 0)
        resident = out["argument_size_in_bytes"] \
            + out.get("output_size_in_bytes", 0) \
            - out.get("alias_size_in_bytes", 0)
        out["approx_live_bytes_per_device"] = live
        out["resident_bytes_per_device"] = resident
        # CPU-backend temps include f32-upcast copies TPU would not have;
        # the residency check is the hard floor, `live` the upper bound
        out["fits_v5e_16gb"] = bool(live <= HBM_GB * 1e9)
        out["resident_fits_v5e_16gb"] = bool(resident <= HBM_GB * 1e9)
    return out


def _save(rec, arch, shape_name, multi_pod, tag=""):
    os.makedirs(ARTIFACTS, exist_ok=True)
    mesh_tag = tag or ("multipod" if multi_pod else "pod")
    path = os.path.join(ARTIFACTS, f"{arch}__{shape_name}__{mesh_tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="RunConfig overrides key=value (e.g. fsdp=False)")
    ap.add_argument("--mesh-shape", default=None,
                    help="e.g. 64x4 (data x model) or 2x16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh_shape.split("x")) \
        if args.mesh_shape else None

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        overrides[k] = {"True": True, "False": False}.get(v) \
            if v in ("True", "False") else (int(v) if v.isdigit() else v)

    archs = configs.ARCH_IDS if args.arch == "all" else [args.arch]
    names = list(shp.SHAPES) if args.shape == "all" else [args.shape]
    for arch in archs:
        for shape_name in names:
            t0 = time.time()
            try:
                rec = run_cell(arch, shape_name, multi_pod=args.multi_pod,
                               overrides=overrides or None,
                               mesh_shape=mesh_shape, tag=args.tag)
                if rec.get("skipped"):
                    print(f"[dryrun] {arch} x {shape_name}: SKIP "
                          f"({rec['reason']})")
                else:
                    r = rec["roofline"]
                    rk = rec["roofline_kernelized"]
                    print(f"[dryrun] {arch} x {shape_name} "
                          f"[{rec['mesh']}]: compile={rec['compile_s']:.0f}s "
                          f"comp={r['compute_s']*1e3:.1f}ms "
                          f"mem={r['memory_s']*1e3:.1f}ms "
                          f"(kern={rk['memory_s']*1e3:.1f}ms) "
                          f"coll={r['collective_s']*1e3:.1f}ms "
                          f"dom={r['dominant']} "
                          f"useful={rec['model_flops_ratio']:.2f}")
            except Exception as e:
                print(f"[dryrun] {arch} x {shape_name}: FAIL {e}")
                traceback.print_exc()


if __name__ == "__main__":
    main()
