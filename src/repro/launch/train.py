"""End-to-end training driver.

CPU-runnable on smoke/preset configs; the same step lowers on the production
meshes (launch/dryrun.py proves it).  Features: deterministic resumable data,
periodic checkpointing with atomic writes, resume-from-latest, graceful
SIGTERM checkpoint (fault tolerance), throughput logging.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticLMData
from repro.models import LM
from repro.roofline.costs import model_flops
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.optim import adamw_init
from repro.train.train_step import make_train_step


def preset_config(name: str):
    """Training presets: 'smoke' per-arch reductions, or ~sized LMs."""
    if name == "100m":
        return configs.get_config("smollm-360m").replace(
            name="lm-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768)
    if name == "20m":
        return configs.get_config("smollm-360m").replace(
            name="lm-20m", n_layers=8, d_model=384, n_heads=6, n_kv_heads=2,
            head_dim=64, d_ff=1024, vocab_size=8192)
    raise KeyError(name)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    help="arch id (see repro.configs.ARCH_IDS)")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for this arch")
    ap.add_argument("--preset", default=None, choices=[None, "20m", "100m"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.preset:
        cfg = preset_config(args.preset)
    elif args.smoke:
        cfg = configs.get_smoke_config(args.arch)
    else:
        cfg = configs.get_config(args.arch)
    run = RunConfig(param_dtype="float32", activation_dtype="float32",
                    learning_rate=args.lr, microbatches=args.microbatches,
                    attn_block_q=64, attn_block_kv=64,
                    loss_chunk=min(256, args.seq))

    data = SyntheticLMData(cfg.vocab_size, args.seq, args.batch,
                           seed=args.seed)
    step_fn = jax.jit(make_train_step(cfg, run))

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, start = restore_checkpoint(args.ckpt_dir)
        params, opt = state["params"], state["opt"]
        print(f"[train] resumed from step {start}")
    else:
        params, _ = LM.init(cfg, run, jax.random.PRNGKey(args.seed))
        opt = adamw_init(params)

    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"batch={args.batch} seq={args.seq}")

    stop = {"now": False}

    def _sigterm(signum, frame):
        stop["now"] = True
    try:
        signal.signal(signal.SIGTERM, _sigterm)
    except ValueError:
        pass   # non-main thread (tests)

    tokens_per_step = args.batch * args.seq
    flops_per_step = model_flops(cfg, args.seq, args.batch, "train",
                                 n_params=n_params)
    t_start = time.time()
    losses = []
    for s in range(start, args.steps):
        toks, labs = data.batch_at(s)
        t0 = time.time()
        params, opt, metrics = step_fn(params, opt, jnp.asarray(toks),
                                       jnp.asarray(labs))
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0
        if s % args.log_every == 0 or s == args.steps - 1:
            print(f"[train] step {s:5d} loss={loss:.4f} "
                  f"tok/s={tokens_per_step/dt:,.0f} "
                  f"gflop/s={flops_per_step/dt/1e9:.1f}")
        if args.ckpt_dir and ((s + 1) % args.ckpt_every == 0 or stop["now"]
                              or s == args.steps - 1):
            save_checkpoint(args.ckpt_dir, s + 1,
                            {"params": params, "opt": opt})
        if stop["now"]:
            print("[train] SIGTERM: checkpointed and exiting")
            return 0
    print(f"[train] done in {time.time()-t_start:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
