"""Parallel policy x placer x objective x scenario x seed sweep engine.

Fans a grid of cluster simulations across worker *processes* (each cell is
an independent event-driven run, so the sweep is embarrassingly parallel)
and emits one schema-stable JSON report consumed by ``benchmarks/`` for
trajectory tracking (``BENCH_*.json``).

  PYTHONPATH=src python -m repro.launch.sweep \\
      --policies miso,srpt --scenarios bursty,diurnal,heavy_tail --seeds 3
  PYTHONPATH=src python -m repro.launch.sweep --scenarios smoke --seeds 2
  PYTHONPATH=src python -m repro.launch.sweep --scenarios hetero_smoke \\
      --placers least-loaded,hetero-speed --seeds 2
  PYTHONPATH=src python -m repro.launch.sweep --scenarios hetero_smoke \\
      --policies miso --objectives throughput,energy,edp --seeds 2
  PYTHONPATH=src python -m repro.launch.sweep --fleet a100:8 --serial

Scenarios come from :mod:`repro.core.scenarios` (each carries a default
heterogeneous fleet spec, placer, objective and optional SimConfig
overrides; override with ``--fleet`` / ``--placers`` / ``--objectives``);
policies are any registered scheduling policy, placers any registered
placement layer (:mod:`repro.core.sim.placement`) and objectives any
registered Algorithm-1 goal (:mod:`repro.core.sim.objectives`).  The JSON
schema is versioned: bump ``SCHEMA_VERSION`` on any breaking change to the
result shape (v2 added the placer axis; v3 added the objective axis and the
energy columns; v4 adds the robustness columns — ``goodput`` /
``gross_stp`` / ``work_lost_s`` / blast, recovery and quarantine counters
in every result, ``goodput_mean`` / ``work_lost_s_mean`` in the summary —
plus a top-level ``errors`` list of cells that crashed or timed out).

Two execution engines share the cell-build path (``--engine``):

* ``pool`` (default) — one process per cell on the persistent warm pool
  below;
* ``batched`` — cells sharing a resolved fleet spec coalesce into one
  in-process lockstep replica batch (``core/sim/batch.py``): estimator
  forwards and Algorithm-1 solves fuse across cells, metrics stay
  bit-identical per cell, and ``config.batched_cells`` records how many
  cells actually ran batched.  Profiled sweeps and groups that fail to
  build or run fall back to the pool path per cell.

Warm-pool execution (the driver loop that makes cheap rollouts cheap):

* The worker pool is a **process-lifetime singleton**, not a per-sweep
  throwaway: the first parallel :func:`run_sweep` spawns it (spawn
  context — forking a jax-initialized parent deadlocks in XLA's inherited
  thread-pool locks) and every later sweep in the same driver process
  reuses the already-warm workers, so the spawn + import + jit-warm cost
  (~seconds per worker) is paid once per process instead of once per
  sweep.  ``shutdown_pool()`` tears it down explicitly; an ``atexit`` hook
  does so at interpreter exit, and a worker crash (``BrokenProcessPool``)
  rebuilds the pool once and retries the batch.
* Job traces are served from a **content-addressed scenario/trace cache**:
  in-process memo keyed (scenario, effective seed, trace length) — seeds
  collapse for ``seed_sensitive=False`` replay scenarios — plus an
  optional on-disk pickle tier (``--trace-cache DIR``, atomic writes keyed
  by the sha256 of the cell key) shared across driver processes.  The
  engine deep-copies its job list (`simulate()` contract), so cached
  pristine traces are reused bit-identically; repeated cells across
  sweeps, ``--resume`` re-runs and warm-pool rollout loops all skip job
  generation.  ``--profile`` attaches per-cell ``gen_s`` / ``setup_s`` /
  ``overhead_s`` buckets so the saving is measurable, not asserted.

Hardening (chaos sweeps run long and can die mid-grid): every cell runs
under a per-cell wall-clock budget (``--cell-timeout``, SIGALRM) with
bounded retry (``--retries``); a cell that still fails is recorded in
``report["errors"]`` instead of sinking the whole sweep, and ``--resume
partial.json`` skips cells already present in an earlier report of the
same schema version (error cells are always re-run).  POSIX reserves
signal delivery for the main thread: when the runner is embedded off the
main thread (test harnesses, GUI drivers) or the platform has no SIGALRM
(Windows), the timeout degrades to a documented no-op — the cell runs
unbounded — instead of dying on ``signal.signal``'s ValueError.
"""
from __future__ import annotations

import argparse
import atexit
import hashlib
import json
import os
import pickle
import signal
import sys
import threading
import time
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Dict, List, Optional, Sequence, Tuple

SCHEMA_VERSION = 4

# grids whose total simulated jobs fall under this run in-process: worker
# startup (fork + pool plumbing, ~hundreds of ms) dwarfs such cells
_AUTO_SERIAL_JOBS = 64

#: bump when trace generation changes in a way that invalidates cached
#: pickles (new Job fields, different attribute streams); part of every
#: cache key, so stale on-disk entries simply stop being addressed
TRACE_CACHE_VERSION = 1

# in-process trace memo: key -> pristine job list (never simulated on
# directly — the engine deep-copies; see _get_jobs)
_TRACE_CACHE: Dict[tuple, list] = {}
_TRACE_CACHE_MAX = 32                 # traces can be 100K jobs; FIFO-bound
_FLEET_CACHE: Dict[str, list] = {}    # fleet spec string -> GPUSpec list

_WARMED = False


def _warm_runtime() -> None:
    """Pay one-time lazy costs before simulating: numpy's random-module
    machinery (~40 ms on first Generator construction) and — when per-kind
    predictor artifacts exist, i.e. sweeps will run U-Net estimators — the
    shared jitted U-Net apply for the standard shapes.  Runs in the parent
    for serial sweeps and as the pool initializer in every worker; the
    persistent pool means each worker pays it exactly once per driver
    process, not once per sweep."""
    global _WARMED
    if _WARMED:
        return
    _WARMED = True
    import glob
    import os

    import numpy as np
    np.random.default_rng(0)
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "artifacts")
    if glob.glob(os.path.join(art_dir, "predictor*.npz")):
        from repro.core.predictor.unet import warm_jit_cache
        warm_jit_cache()


# ------------------------------------------------------------ warm pool

_POOL: Optional[ProcessPoolExecutor] = None
_POOL_WORKERS = 0


def _get_pool(workers: Optional[int]) -> ProcessPoolExecutor:
    """The process-lifetime worker pool.  ``workers=None`` reuses whatever
    pool exists (or sizes a new one to the CPU count); an explicit size
    that differs from the live pool recycles it."""
    global _POOL, _POOL_WORKERS
    want = workers or _POOL_WORKERS or (os.cpu_count() or 1)
    if _POOL is not None and want != _POOL_WORKERS:
        shutdown_pool()
    if _POOL is None:
        import multiprocessing
        # spawn, not fork: workers run jitted U-Net inference (per-kind
        # predictor artifacts), and forking a jax-initialized parent
        # deadlocks in XLA's inherited thread-pool locks
        _POOL = ProcessPoolExecutor(
            max_workers=want,
            mp_context=multiprocessing.get_context("spawn"),
            initializer=_warm_runtime)
        _POOL_WORKERS = want
    return _POOL


def shutdown_pool() -> None:
    """Tear down the persistent worker pool (no-op when none is live).
    Registered at exit; call explicitly to reclaim the workers early."""
    global _POOL, _POOL_WORKERS
    if _POOL is not None:
        _POOL.shutdown(wait=True)
        _POOL = None
        _POOL_WORKERS = 0


atexit.register(shutdown_pool)


# ---------------------------------------------------------- trace cache

def _trace_key(task: Dict, sc) -> tuple:
    """Content address of a cell's job trace.  Replay scenarios
    (``seed_sensitive=False``) generate the identical workload for every
    seed, so their seeds collapse to one entry."""
    return (TRACE_CACHE_VERSION, task["scenario"],
            task["seed"] if sc.seed_sensitive else 0,
            task.get("n_jobs") or sc.n_jobs)


def _get_jobs(task: Dict, sc) -> Tuple[list, float, str]:
    """The cell's pristine job list, its load cost in seconds, and where
    it came from (``"memo"`` / ``"disk"`` / ``"fresh"``).  Callers must
    not mutate the returned list or its jobs — every simulation runs on a
    deep copy (the ``simulate()`` contract), which is what makes sharing
    one trace across cells bit-identical to regenerating it."""
    t0 = time.perf_counter()
    key = _trace_key(task, sc)
    jobs = _TRACE_CACHE.get(key)
    if jobs is not None:
        return jobs, time.perf_counter() - t0, "memo"
    src = "fresh"
    path = None
    cache_dir = task.get("trace_cache")
    if cache_dir:
        os.makedirs(cache_dir, exist_ok=True)
        h = hashlib.sha256(repr(key).encode()).hexdigest()[:24]
        path = os.path.join(cache_dir, f"trace_{h}.pkl")
        if os.path.exists(path):
            try:
                with open(path, "rb") as f:
                    jobs = pickle.load(f)
                src = "disk"
            except Exception:
                jobs = None          # corrupt/partial entry: regenerate
    if jobs is None:
        jobs = sc.make_jobs(task["seed"], task.get("n_jobs"))
        if path is not None:
            # atomic publish: concurrent workers race benignly (same key
            # -> same bytes), readers never see a torn file
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "wb") as f:
                pickle.dump(jobs, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, path)
    while len(_TRACE_CACHE) >= _TRACE_CACHE_MAX:
        _TRACE_CACHE.pop(next(iter(_TRACE_CACHE)))
    _TRACE_CACHE[key] = jobs
    return jobs, time.perf_counter() - t0, src


def _get_fleet(spec: str) -> list:
    fleet = _FLEET_CACHE.get(spec)
    if fleet is None:
        from repro.core.fleet import parse_fleet
        fleet = _FLEET_CACHE[spec] = parse_fleet(spec)
    return fleet


def _build_cell(task: Dict, profile: bool = False):
    """Resolve one cell's (scenario, fleet, config) and construct its
    ready-to-run ``ClusterSim`` on a deep copy of the (possibly cached)
    pristine trace.  Shared by the per-process scalar path and the
    in-process batched engine, so a cell is built identically either way.
    Returns ``(sim, meta)`` where ``meta`` carries everything
    :func:`_cell_result` needs to describe the cell."""
    import copy

    from repro.core.scenarios import get_scenario
    from repro.core.simulator import ClusterSim, SimConfig

    sc = get_scenario(task["scenario"])
    jobs, gen_s, trace_src = _get_jobs(task, sc)
    fleet = _get_fleet(task.get("fleet") or sc.fleet)
    placer = task.get("placer") or sc.placer
    objective = task.get("objective") or sc.objective
    cfg_kwargs = dict(sc.sim_kwargs)     # scenario-bundled SimConfig knobs
    if task.get("mtbf") is not None:     # explicit --mtbf wins, 0 included
        cfg_kwargs["gpu_mtbf_s"] = task["mtbf"]
    cfg = SimConfig(n_gpus=len(fleet), policy=task["policy"],
                    placer=placer, objective=objective, seed=task["seed"],
                    profile=profile, **cfg_kwargs)
    t_set0 = time.perf_counter()
    sim = ClusterSim(copy.deepcopy(list(jobs)), cfg, fleet=fleet)
    setup_s = time.perf_counter() - t_set0
    meta = {"task": task, "placer": placer, "objective": objective,
            "fleet": fleet, "n_jobs": len(jobs), "gen_s": gen_s,
            "setup_s": setup_s, "trace_src": trace_src}
    return sim, meta


def _cell_result(meta: Dict, m, wall_s: float) -> Dict:
    """The schema-stable result record for one finished cell."""
    from repro.core.fleet import describe_fleet

    task = meta["task"]
    return {
        "policy": task["policy"],
        "placer": meta["placer"],
        "objective": meta["objective"],
        "scenario": task["scenario"],
        "seed": task["seed"],
        "fleet": describe_fleet(meta["fleet"]),
        "n_jobs": meta["n_jobs"],
        "n_completed": len(m.jcts),
        "metrics": {
            "avg_jct_s": m.avg_jct,
            "p50_jct_s": m.p50_jct,
            "p90_jct_s": m.p90_jct,
            "makespan_s": m.makespan,
            "stp": m.stp,
            "energy_j": m.energy_j,
            "avg_power_w": m.avg_power_w,
            "energy_per_job_j": m.energy_per_job_j,
            "jct_per_joule": m.jct_per_joule,
            "breakdown_s": dict(m.breakdown),
            # v4 robustness columns (all zero when no fault model ran)
            "goodput": m.goodput,
            "gross_stp": m.gross_stp,
            "work_lost_s": m.work_lost_s,
            "n_fault_events": m.n_fault_events,
            "blast_jobs": m.blast_jobs,
            "blast_radius_max": m.blast_radius_max,
            "mean_recover_s": m.mean_recover_s,
            "quarantine_occupancy": m.quarantine_occupancy,
            "n_quarantines": m.n_quarantines,
            "n_migrations": m.n_migrations,
        },
        "wall_s": wall_s,
    }


def run_task(task: Dict) -> Dict:
    """One sweep cell: simulate (policy, placer, objective, scenario, seed)
    on a fleet.

    Module-level and dict-in/dict-out so it pickles cleanly into worker
    processes.
    """
    t0 = time.time()
    profile = bool(task.get("profile"))
    sim, meta = _build_cell(task, profile)
    t_run0 = time.perf_counter()
    m = sim.run()
    run_s = time.perf_counter() - t_run0
    out = _cell_result(meta, m, time.time() - t0)
    if profile:
        p = sim.prof
        out["profile"] = {
            "placement_s": p["placement_s"],
            "alg1_s": p["alg1_s"],
            "estimator_s": p["estimator_s"],
            # everything else the run loop did: heap churn, accounting,
            # phase bookkeeping
            "event_loop_s": max(0.0, p["total_s"] - p["placement_s"]
                                - p["alg1_s"] - p["estimator_s"]),
            "total_s": p["total_s"],
            "events": int(p["events"]),
            # per-cell overhead buckets (everything that is not the
            # simulation itself); trace_src says whether job generation
            # was skipped by the content-addressed cache
            "gen_s": meta["gen_s"],
            "setup_s": meta["setup_s"],
            "trace_src": meta["trace_src"],
            "overhead_s": max(0.0, out["wall_s"] - run_s),
        }
    return out


def _run_batched(tasks: List[Dict]) -> Tuple[List[Dict], List[Dict]]:
    """Run sweep cells through the in-process replica-batched engine.

    Cells coalesce by resolved fleet spec: one spec string means one fleet
    shape *and* (via the fleet cache) shared ``GPUSpec`` objects, so every
    replica in a group fuses its estimator forwards and Algorithm-1 solves
    with the others (``core/sim/batch.py``).  Each group runs as one
    lockstep ``BatchSim``; per-replica metrics are bit-identical to the
    scalar engine, and ``wall_s`` is the group's wall-clock amortized over
    its members (lockstep execution has no per-cell attribution).

    Returns ``(results, fallback_tasks)``: a group whose build or run
    raises falls back wholesale to the warm-pool path (which retries,
    times out and error-records per cell), as do any cells this function
    never attempts.  Per-cell SIGALRM budgets cannot interrupt a lockstep
    round, so ``cell_timeout`` is enforced only on fallback cells.
    """
    from repro.core.scenarios import get_scenario
    from repro.core.sim.batch import BatchSim

    _warm_runtime()
    groups: Dict[str, List[Dict]] = {}
    for task in tasks:
        sc = get_scenario(task["scenario"])
        groups.setdefault(task.get("fleet") or sc.fleet, []).append(task)
    results: List[Dict] = []
    fallback: List[Dict] = []
    for members in groups.values():
        t0 = time.time()
        try:
            built = [_build_cell(t) for t in members]
            ms = BatchSim([sim for sim, _ in built]).run()
        except Exception:
            # anything from a bad scenario to a diverging replica: the
            # scalar pool path owns per-cell isolation and error records
            fallback.extend(members)
            continue
        wall = (time.time() - t0) / len(members)
        results.extend(_cell_result(meta, m, wall)
                       for (_, meta), m in zip(built, ms))
    return results, fallback


class CellTimeout(Exception):
    """A sweep cell exceeded its per-cell wall-clock budget."""


def _on_alarm(signum, frame):
    raise CellTimeout("cell exceeded its wall-clock budget")


def run_task_safe(task: Dict) -> Dict:
    """Crash-isolated :func:`run_task`: per-cell wall-clock budget
    (``task["cell_timeout"]`` seconds, SIGALRM) and bounded retry
    (``task["retries"]`` attempts).  The alarm is armed only when the
    platform has SIGALRM *and* we are on the main thread — CPython rejects
    ``signal.signal`` anywhere else — so off-main-thread or Windows runs
    degrade to a documented no-op (the cell runs unbounded) instead of
    crashing the grid.  A cell that exhausts its attempts returns an
    *error record* (same identity keys, an ``"error"`` string, no
    ``"metrics"``) instead of raising, so one diverging simulation cannot
    sink an hours-long grid."""
    timeout = task.get("cell_timeout")
    attempts = max(1, int(task.get("retries") or 1))
    use_alarm = (bool(timeout) and hasattr(signal, "SIGALRM")
                 and threading.current_thread() is threading.main_thread())
    err: Optional[BaseException] = None
    for _ in range(attempts):
        try:
            if use_alarm:
                old = signal.signal(signal.SIGALRM, _on_alarm)
                signal.setitimer(signal.ITIMER_REAL, float(timeout))
            try:
                return run_task(task)
            finally:
                if use_alarm:
                    signal.setitimer(signal.ITIMER_REAL, 0.0)
                    signal.signal(signal.SIGALRM, old)
        except Exception as e:
            err = e                      # recorded below, never swallowed
    from repro.core.scenarios import get_scenario
    sc = get_scenario(task["scenario"])
    return {
        "policy": task["policy"],
        "placer": task.get("placer") or sc.placer,
        "objective": task.get("objective") or sc.objective,
        "scenario": task["scenario"],
        "seed": task["seed"],
        "error": f"{type(err).__name__}: {err}",
        "attempts": attempts,
    }


def _task_key(task: Dict) -> Tuple[str, str, str, str, int]:
    """The identity of a cell inside a report, with the scenario's default
    placer / objective resolved exactly as :func:`run_task` resolves it."""
    from repro.core.scenarios import get_scenario
    sc = get_scenario(task["scenario"])
    return (task["scenario"], task["policy"],
            task.get("placer") or sc.placer,
            task.get("objective") or sc.objective, task["seed"])


def _load_resume_cells(path: str) -> Dict[Tuple, Dict]:
    """Successful cells of a partial report, keyed by cell identity.
    Cells recorded in ``report["errors"]`` — and any defensive error
    record that leaked into ``results`` — are *not* loaded, so a resumed
    sweep always re-runs them; a report from a different schema version
    resumes nothing — its metric columns would not line up with the cells
    this sweep produces."""
    with open(path) as f:
        rep = json.load(f)
    if rep.get("kind") != "miso-sweep":
        raise ValueError(f"{path} is not a miso-sweep report")
    if rep.get("schema_version") != SCHEMA_VERSION:
        return {}
    return {(r["scenario"], r["policy"], r["placer"], r["objective"],
             r["seed"]): r for r in rep.get("results", [])
            if "error" not in r and "metrics" in r}


def run_sweep(policies: Sequence[str], scenarios: Sequence[str],
              seeds: Sequence[int], placers: Optional[Sequence[str]] = None,
              objectives: Optional[Sequence[str]] = None,
              fleet: Optional[str] = None,
              n_jobs: Optional[int] = None, mtbf: Optional[float] = None,
              workers: Optional[int] = None, serial: bool = False,
              profile: bool = False, retries: int = 1,
              cell_timeout: Optional[float] = None,
              resume: Optional[str] = None,
              trace_cache: Optional[str] = None,
              engine: str = "pool") -> Dict:
    """Run the full grid and return the JSON-ready report dict.

    ``placers=None`` / ``objectives=None`` run each scenario's own default;
    an explicit list crosses it with every (policy, scenario, seed) cell.
    ``profile=True`` attaches per-component wall-clock (placement /
    Algorithm-1 / estimator / event loop) plus per-cell overhead buckets
    (generation / setup / total non-simulation time) to every result.
    ``retries`` / ``cell_timeout`` bound each cell (exhausted cells land in
    ``report["errors"]``); ``resume`` is the path of a partial report whose
    successful same-schema cells are carried over instead of re-run (its
    error cells are re-run).  ``trace_cache`` names a directory for the
    on-disk tier of the content-addressed trace cache (None = in-process
    memo only).  Parallel grids run on the persistent warm pool — see the
    module docstring.

    ``engine="batched"`` routes cells through the in-process
    replica-batched engine first: cells sharing a resolved fleet spec run
    in lockstep with fused estimator / Algorithm-1 services and
    bit-identical per-cell metrics (coalesce and fallback rules:
    :func:`_run_batched`).  Profiled sweeps keep the pool path — the
    per-component clocks are not accumulated through the collect
    pipeline — and any cell the batched engine could not run falls back
    to the pool/serial path below."""
    tasks = [{"policy": p, "placer": pl, "objective": ob, "scenario": sc,
              "seed": s, "fleet": fleet, "n_jobs": n_jobs, "mtbf": mtbf,
              "profile": profile, "retries": retries,
              "cell_timeout": cell_timeout, "trace_cache": trace_cache}
             for sc in scenarios for p in policies
             for pl in (placers or [None])
             for ob in (objectives or [None]) for s in seeds]
    resumed: List[Dict] = []
    if resume is not None:
        done = _load_resume_cells(resume)
        if done:
            fresh = []
            for t in tasks:
                prev = done.get(_task_key(t))
                if prev is not None:
                    resumed.append(prev)
                else:
                    fresh.append(t)
            tasks = fresh
    t0 = time.time()
    batched_results: List[Dict] = []
    if engine == "batched" and tasks and not profile:
        batched_results, tasks = _run_batched(tasks)
    if workers is None and not serial:
        # tiny grids (e.g. the CI smoke sweep) finish faster in-process than
        # a pool takes to start; an explicit --workers always gets the pool
        from repro.core.scenarios import get_scenario
        total_jobs = sum(t["n_jobs"] or get_scenario(t["scenario"]).n_jobs
                         for t in tasks)
        serial = total_jobs <= _AUTO_SERIAL_JOBS
    if not tasks:          # fully resumed or fully batched: nothing pooled
        results = []
        workers_used = 1
    elif serial or len(tasks) == 1:
        _warm_runtime()
        results = [run_task_safe(t) for t in tasks]
        workers_used = 1
    else:
        pool = _get_pool(workers)
        workers_used = _POOL_WORKERS
        try:
            results = list(pool.map(run_task_safe, tasks))
        except BrokenProcessPool:
            # a worker died hard (OOM, segfault in native code): rebuild
            # the warm pool once and retry the whole batch — cells are
            # idempotent, so a clean second pass is safe
            shutdown_pool()
            pool = _get_pool(workers)
            workers_used = _POOL_WORKERS
            results = list(pool.map(run_task_safe, tasks))
    errors = [r for r in results if "error" in r]
    results = [r for r in results if "error" not in r] + batched_results \
        + resumed
    sort_key = lambda r: (r["scenario"], r["policy"], r["placer"],
                          r["objective"], r["seed"])
    results.sort(key=sort_key)
    errors.sort(key=sort_key)

    # summary: scenario -> policy -> placer -> objective -> seed-mean
    # aggregates (the leaf levels are what let diff_sweeps compare placement
    # layers and optimization objectives)
    cells: Dict[tuple, List[Dict]] = {}
    for r in results:
        cells.setdefault((r["scenario"], r["policy"], r["placer"],
                          r["objective"]), []).append(r)
    summary: Dict[str, Dict] = {}
    for (sc, p, pl, ob), cell in cells.items():
        mean = lambda key: (sum(r["metrics"][key] for r in cell)
                            / len(cell))
        summary.setdefault(sc, {}).setdefault(p, {}).setdefault(pl, {})[ob] = {
            "avg_jct_s_mean": mean("avg_jct_s"),
            "p90_jct_s_mean": mean("p90_jct_s"),
            "stp_mean": mean("stp"),
            "makespan_s_mean": mean("makespan_s"),
            "energy_j_mean": mean("energy_j"),
            "energy_per_job_j_mean": mean("energy_per_job_j"),
            "goodput_mean": mean("goodput"),
            "work_lost_s_mean": mean("work_lost_s"),
        }

    report = {
        "schema_version": SCHEMA_VERSION,
        "kind": "miso-sweep",
        "config": {
            "policies": list(policies),
            "placers": list(placers) if placers else None,
            "objectives": list(objectives) if objectives else None,
            "scenarios": list(scenarios),
            "seeds": list(seeds),
            "fleet": fleet,          # null = each scenario's default fleet
            "n_jobs": n_jobs,        # null = each scenario's default length
            "mtbf_s": mtbf,
            "workers": workers_used,
            "serial": bool(serial or len(tasks) <= 1),
            "retries": retries,
            "cell_timeout_s": cell_timeout,
            "resumed_cells": len(resumed),
            "trace_cache": trace_cache,
            "engine": engine,
            # cells the batched engine actually ran (0 under --profile or
            # when every group fell back to the pool path)
            "batched_cells": len(batched_results),
        },
        "wall_s_total": time.time() - t0,
        "results": results,
        "errors": errors,
        "summary": summary,
    }
    if profile:
        # stamp which determinism contract produced these numbers: the
        # misolint rule-set hash ties a benchmark JSON to the exact lint
        # rules the tree was clean under (see README "Static analysis")
        try:
            from misolint import ruleset_hash
            report["lint_version"] = ruleset_hash()
        except ImportError:     # lint tooling not on sys.path: stamp absent
            report["lint_version"] = None
    return report


def _print_summary(report: Dict) -> None:
    print(f"[sweep] {len(report['results'])} runs on "
          f"{report['config']['workers']} worker(s) in "
          f"{report['wall_s_total']:.1f}s")
    if report["config"]["resumed_cells"]:
        print(f"[sweep] resumed {report['config']['resumed_cells']} "
              f"cell(s) from a partial report")
    for e in report.get("errors", ()):
        print(f"[sweep] ERROR {e['scenario']}/{e['policy']}/{e['placer']}/"
              f"{e['objective']} seed={e['seed']}: {e['error']} "
              f"({e['attempts']} attempt(s))")
    w = max((len(s) for s in report["summary"]), default=8)
    for sc, by_policy in report["summary"].items():
        for p, by_placer in by_policy.items():
            for pl, by_obj in by_placer.items():
                for ob, agg in by_obj.items():
                    print(f"  {sc:<{w}}  {p:<10} {pl:<15} {ob:<11}"
                          f" avg_jct {agg['avg_jct_s_mean']:>9,.0f}s"
                          f"  p90 {agg['p90_jct_s_mean']:>9,.0f}s"
                          f"  stp {agg['stp_mean']:.3f}"
                          f"  energy {agg['energy_j_mean'] / 1e6:>7.2f}MJ")
    profiled = [r for r in report["results"] if r.get("profile")]
    if profiled:
        tot = {k: sum(r["profile"][k] for r in profiled)
               for k in ("placement_s", "alg1_s", "estimator_s",
                         "event_loop_s", "total_s")}
        n_ev = sum(r["profile"]["events"] for r in profiled)
        print(f"[sweep] profile: total {tot['total_s']:.2f}s over "
              f"{n_ev:,} events — placement {tot['placement_s']:.2f}s, "
              f"Algorithm-1 {tot['alg1_s']:.2f}s, estimator "
              f"{tot['estimator_s']:.2f}s, event loop "
              f"{tot['event_loop_s']:.2f}s")
        ov = [r["profile"] for r in profiled
              if "overhead_s" in r["profile"]]
        if ov:
            n = len(ov)
            mean_ms = lambda k: sum(o[k] for o in ov) / n * 1e3
            hits = sum(1 for o in ov if o.get("trace_src") != "fresh")
            print(f"[sweep] per-cell overhead: mean "
                  f"{mean_ms('overhead_s'):.1f} ms "
                  f"(gen {mean_ms('gen_s'):.1f} ms, "
                  f"setup {mean_ms('setup_s'):.1f} ms; "
                  f"trace cache {hits}/{n} hits)")
        # per-cell wall-clock spread: mean alone hides a grid whose tail
        # cell dominates the sweep; name the slowest cell so it can be
        # bounded (--cell-timeout) or investigated directly
        walls = sorted(r["wall_s"] for r in profiled)
        pct = lambda q: walls[min(len(walls) - 1,
                                  int(round(q * (len(walls) - 1))))]
        slow = max(profiled, key=lambda r: r["wall_s"])
        print(f"[sweep] per-cell wall: p50 {pct(0.50):.2f}s "
              f"p95 {pct(0.95):.2f}s; slowest {slow['scenario']}/"
              f"{slow['policy']}/{slow['placer']}/{slow['objective']} "
              f"seed={slow['seed']} at {slow['wall_s']:.2f}s")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="parallel policy x placer x objective x scenario x seed "
                    "simulation sweep")
    ap.add_argument("--policies", default="miso,srpt",
                    help="comma-separated policy names")
    ap.add_argument("--placers", default=None,
                    help="comma-separated placer names to cross with every "
                         "cell (see repro.core.sim.placement; default: each "
                         "scenario's own placer)")
    ap.add_argument("--objectives", default=None,
                    help="comma-separated objective names to cross with "
                         "every cell (see repro.core.sim.objectives; "
                         "default: each scenario's own objective)")
    ap.add_argument("--scenarios", default="bursty,diurnal,heavy_tail",
                    help="comma-separated scenario names "
                         "(see repro.core.scenarios)")
    ap.add_argument("--seeds", type=int, default=3,
                    help="number of seeds (0..N-1) per cell")
    ap.add_argument("--fleet", default=None,
                    help="fleet spec like a100:4+h100:4 "
                         "(default: each scenario's own fleet)")
    ap.add_argument("--jobs", type=int, default=None,
                    help="override each scenario's trace length")
    ap.add_argument("--mtbf", type=float, default=None,
                    help="accelerator MTBF seconds (fault injection); "
                         "overrides any scenario-bundled value, 0 disables "
                         "faults even for fault scenarios (default: each "
                         "scenario's own setting)")
    ap.add_argument("--workers", type=int, default=None,
                    help="worker processes (default: reuse the live warm "
                         "pool, else one per CPU)")
    ap.add_argument("--serial", action="store_true",
                    help="run in-process, no worker pool")
    ap.add_argument("--profile", action="store_true",
                    help="attach per-component wall-clock (placement, "
                         "Algorithm-1, estimator, event loop) and per-cell "
                         "overhead buckets (gen/setup/total) to every "
                         "result and print the totals")
    ap.add_argument("--retries", type=int, default=1,
                    help="attempts per cell before recording it as an "
                         "error cell (default 1: no retry)")
    ap.add_argument("--cell-timeout", type=float, default=None,
                    help="per-cell wall-clock budget in seconds (SIGALRM; "
                         "a timed-out attempt counts against --retries; "
                         "no-op off the main thread or without SIGALRM)")
    ap.add_argument("--resume", default=None,
                    help="partial report JSON whose successful same-schema "
                         "cells are carried over instead of re-run "
                         "(error cells are retried)")
    ap.add_argument("--trace-cache", default=None,
                    help="directory for the on-disk tier of the "
                         "content-addressed trace cache (default: "
                         "in-process memo only)")
    ap.add_argument("--engine", choices=("pool", "batched"),
                    default="pool",
                    help="cell execution engine: 'pool' runs one process "
                         "per cell on the warm worker pool; 'batched' "
                         "coalesces cells that share a fleet spec into "
                         "one in-process lockstep replica batch with "
                         "fused estimator/Algorithm-1 services "
                         "(bit-identical metrics; profiled sweeps and "
                         "failed groups fall back to the pool)")
    ap.add_argument("--out", default="BENCH_sweep.json",
                    help="JSON report path")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    from repro.core.scenarios import available_scenarios, get_scenario
    from repro.core.sim.objectives import get_objective
    from repro.core.sim.placement import get_placer
    from repro.core.sim.policies import available_policies, get_policy

    policies = [p.strip() for p in args.policies.split(",") if p.strip()]
    scenarios = [s.strip() for s in args.scenarios.split(",") if s.strip()]
    placers = ([p.strip() for p in args.placers.split(",") if p.strip()]
               if args.placers else None)
    objectives = ([o.strip() for o in args.objectives.split(",") if o.strip()]
                  if args.objectives else None)
    for p in policies:
        get_policy(p)                    # fail fast with the full list
    for s in scenarios:
        get_scenario(s)
    for pl in placers or ():
        get_placer(pl)
    for ob in objectives or ():
        get_objective(ob)

    report = run_sweep(policies, scenarios, seeds=list(range(args.seeds)),
                       placers=placers, objectives=objectives,
                       fleet=args.fleet, n_jobs=args.jobs,
                       mtbf=args.mtbf, workers=args.workers,
                       serial=args.serial, profile=args.profile,
                       retries=args.retries, cell_timeout=args.cell_timeout,
                       resume=args.resume, trace_cache=args.trace_cache,
                       engine=args.engine)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=False)
        f.write("\n")
    _print_summary(report)
    print(f"[sweep] report -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
