"""MISO cluster controller driver: the paper's Fig 6 pipeline end-to-end.

Central controller + per-accelerator server API over a job trace:
FCFS queue -> least-loaded placement -> MPS profiling (interference-prone
co-run) -> U-Net MPS->MIG translation -> Algorithm 1 -> dynamic partitions.
The execution backend is the event simulator (no A100s/TPUs in this
container, DESIGN.md §2); with ``--space tpu`` the accelerators are v5e pods
partitioned into contiguous sub-mesh slices and each slice maps onto a
``launch.mesh.make_slice_mesh`` JAX mesh (printed per scheduling decision
with ``--show-meshes``).

``--policy`` accepts any registered scheduling policy
(``repro/core/sim/policies/``):

* ``nopart``    — exclusive whole-GPU execution (paper baseline)
* ``optsta``    — best static MIG partition, never reconfigured
* ``mpsonly``   — MPS co-location at a fixed level, no partitioning
* ``miso``      — the paper's policy: MPS probe -> predict -> repartition
* ``oracle``    — perfect knowledge, zero overhead (upper bound)
* ``miso-frag`` — MISO preferring partitions that keep large contiguous
                  slices free (fragmentation-aware)
* ``srpt``      — MISO with a preemptive shortest-remaining-work queue

``--placer`` accepts any registered placement layer
(``repro/core/sim/placement.py``): ``least-loaded`` (paper default),
``hetero-speed`` (long jobs to fast GPUs on mixed fleets), ``frag-aware``
(keep large contiguous slices free), ``best-fit-slice`` (tightest feasible
partition wins).

``--objective`` accepts any registered Algorithm-1 goal
(``repro/core/sim/objectives.py``): ``throughput`` (the paper's Eq. 2–4,
bit-identical default), ``energy`` (min joules per unit work subject to a
QoS floor), ``edp`` (energy-delay product).  Every run reports the
fleet-integrated energy alongside JCT/STP.

  PYTHONPATH=src python -m repro.launch.cluster --policy miso --jobs 60
  PYTHONPATH=src python -m repro.launch.cluster --policy srpt --lam 20
  PYTHONPATH=src python -m repro.launch.cluster --space tpu --show-meshes
  PYTHONPATH=src python -m repro.launch.cluster --fleet a100:4+h100:4
  PYTHONPATH=src python -m repro.launch.cluster --fleet a100:4+h100:4 \\
      --placer hetero-speed

``--fleet`` runs a heterogeneous cluster (per-GPU slice menus / perf models,
see ``repro.core.fleet``); scenario x policy grids over fleets are driven in
parallel by ``python -m repro.launch.sweep``.
"""
from __future__ import annotations

import argparse
import os
import sys

if "--show-meshes" in sys.argv:
    # slice meshes need placeholder devices; must be set before first jax init
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=256").strip()

from repro.core.estimators import NoisyEstimator, OracleEstimator, UNetEstimator
from repro.core.partitions import a100_mig_space, tpu_pod_space
from repro.core.perfmodel import A100, TPU_V5E_POD, PerfModel
from repro.core.simulator import (SimConfig, available_objectives,
                                  available_placers, available_policies,
                                  simulate)
from repro.core.traces import generate_trace

def _a100_artifact():
    """The committed a100 predictor artifact (per-kind name, with the
    legacy un-suffixed predictor.npz accepted), or None."""
    from repro.core.fleet import default_artifact_path
    return default_artifact_path("a100")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--space", choices=["a100", "tpu"], default="a100")
    ap.add_argument("--fleet", default=None,
                    help="heterogeneous fleet spec, e.g. a100:4+h100:4 "
                         "(overrides --space/--accelerators/--estimator)")
    ap.add_argument("--policy", default="miso", choices=available_policies())
    ap.add_argument("--placer", default="least-loaded",
                    choices=available_placers(),
                    help="placement layer: which feasible GPU a queued job "
                         "lands on (least-loaded = paper default)")
    ap.add_argument("--objective", default="throughput",
                    choices=available_objectives(),
                    help="Algorithm-1 goal: what the partition search "
                         "optimizes (throughput = paper default; energy/edp "
                         "trade JCT for joules)")
    ap.add_argument("--estimator", default="auto",
                    choices=["auto", "unet", "oracle", "noisy"])
    ap.add_argument("--sigma", type=float, default=0.05)
    ap.add_argument("--accelerators", type=int, default=8)
    ap.add_argument("--jobs", type=int, default=100)
    ap.add_argument("--lam", type=float, default=60.0,
                    help="mean inter-arrival time in seconds (1/rate, "
                         "not the Poisson rate itself)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mtbf", type=float, default=0.0,
                    help="accelerator MTBF seconds (fault injection)")
    from repro.core.simulator import available_fault_injectors
    ap.add_argument("--faults", default=None,
                    help="comma-separated fault injectors to enable with "
                         "demo chaos knobs (available: "
                         f"{', '.join(available_fault_injectors())}); "
                         "repeated faults quarantine the GPU and migrate "
                         "its residents off")
    ap.add_argument("--show-meshes", action="store_true")
    return ap


# demo knobs applied per enabled injector by --faults (the flaky_fleet
# scenario's settings); sweeps wanting full control use scenario sim_kwargs
_FAULT_DEMO_KNOBS = {
    "mps_blast": {"mps_crash_mtbf_s": 1500.0},
    "flaky_reconfig": {"reconfig_fail_p": 0.15, "reconfig_retry_s": 15.0,
                       "reconfig_max_retries": 2},
    "straggler": {"straggler_mtbf_s": 700.0, "straggler_factor": 0.25,
                  "straggler_recover_s": 100000.0},
    "estimator_garbage": {"estimator_fault_p": 0.2},
}


def _fault_kwargs(spec: str | None) -> dict:
    """SimConfig overrides for a ``--faults`` spec (empty dict when off)."""
    if not spec:
        return {}
    from repro.core.simulator import get_fault_injector
    names = tuple(s.strip() for s in spec.split(",") if s.strip())
    for n in names:
        get_fault_injector(n)            # fail fast with the full list
    kw: dict = {"faults": names, "ckpt_interval_s": 240.0,
                "quarantine_faults": 2, "quarantine_window_s": 3600.0,
                "quarantine_repair_s": 480.0}
    for n in names:
        kw.update(_FAULT_DEMO_KNOBS.get(n, {}))
    return kw


def _print_robustness(metrics) -> None:
    print(f"  goodput   : {metrics.goodput:.3f} committed work-seconds/s/"
          f"accelerator (gross {metrics.gross_stp:.3f}, "
          f"{metrics.work_lost_s:,.0f} work-s destroyed)")
    print(f"  faults    : {metrics.n_fault_events} events | "
          f"{metrics.blast_jobs} blast kills (max radius "
          f"{metrics.blast_radius_max}) | {metrics.n_quarantines} "
          f"quarantines | {metrics.n_migrations} migrations | "
          f"quarantine occupancy {metrics.quarantine_occupancy:.1%}")


def main(argv=None):
    args = build_parser().parse_args(argv)

    if args.fleet:
        from repro.core.fleet import describe_fleet, parse_fleet
        fleet = parse_fleet(args.fleet)
        jobs = generate_trace(args.jobs, lam_s=args.lam, seed=args.seed)
        cfg = SimConfig(n_gpus=len(fleet), policy=args.policy,
                        placer=args.placer, objective=args.objective,
                        gpu_mtbf_s=args.mtbf, seed=args.seed,
                        **_fault_kwargs(args.faults))
        metrics = simulate(jobs, cfg, fleet=fleet)
        b = metrics.breakdown
        by_kind = {s.kind: type(s.estimator).__name__ for s in fleet}
        ests = ", ".join(f"{k}={v}" for k, v in by_kind.items())
        print(f"[cluster] {args.policy} (placer {args.placer}, objective "
              f"{args.objective}) on fleet {describe_fleet(fleet)}: "
              f"{len(metrics.jcts)} jobs (per-kind estimators: {ests})")
        print(f"  avg JCT   : {metrics.avg_jct:,.0f} s "
              f"(p50 {metrics.p50_jct:,.0f}, p90 {metrics.p90_jct:,.0f})")
        print(f"  makespan  : {metrics.makespan:,.0f} s")
        print(f"  STP       : {metrics.stp:.3f} work-seconds/s/accelerator")
        print(f"  energy    : {metrics.energy_j / 3.6e6:,.2f} kWh "
              f"({metrics.avg_power_w:,.0f} W cluster avg, "
              f"{metrics.energy_per_job_j / 3.6e6:,.3f} kWh/job)")
        print(f"  breakdown : queue {b['queue']:,.0f}s | mps {b['mps']:,.0f}s"
              f" | ckpt {b['ckpt']:,.0f}s | run {b['run']:,.0f}s")
        if args.faults:
            _print_robustness(metrics)
        return 0

    if args.space == "tpu":
        space, hw = tpu_pod_space(), TPU_V5E_POD
    else:
        space, hw = a100_mig_space(), A100
    pm = PerfModel(space, hw)

    artifact = _a100_artifact() if args.space == "a100" else None
    if args.estimator == "oracle" or args.policy == "oracle":
        est = OracleEstimator(pm)
    elif args.estimator == "noisy":
        est = NoisyEstimator(pm, sigma=args.sigma, seed=args.seed)
    elif args.estimator == "unet" or (args.estimator == "auto"
                                      and artifact is not None):
        if args.space != "a100":
            raise SystemExit(
                "[cluster] --estimator unet: no U-Net predictor exists for "
                f"the {args.space} space (its slice menu does not match the "
                "net's 7g/4g/3g output rows); use --estimator oracle")
        if artifact is None:
            raise SystemExit(
                "[cluster] --estimator unet: no trained a100 artifact found; "
                "train one with  PYTHONPATH=src python -m "
                "repro.core.predictor.train --kinds a100")
        est = UNetEstimator.from_artifact(pm, artifact)
        print("[cluster] estimator: trained U-Net + linreg heads")
    else:
        est = OracleEstimator(pm)
        print("[cluster] estimator: oracle (no artifact / tpu space)")

    jobs = generate_trace(args.jobs, lam_s=args.lam, seed=args.seed)
    cfg = SimConfig(n_gpus=args.accelerators, policy=args.policy,
                    placer=args.placer, objective=args.objective,
                    gpu_mtbf_s=args.mtbf, seed=args.seed,
                    **_fault_kwargs(args.faults))
    metrics = simulate(jobs, cfg, space, pm, est)

    if args.show_meshes and args.space == "tpu":
        from repro.launch.mesh import make_slice_mesh
        print("[cluster] slice -> JAX mesh mapping:")
        for size in sorted(space.slices):
            st = space.slices[size]
            if st.mesh_shape:
                mesh = make_slice_mesh(*st.mesh_shape)
                print(f"  {st.name}: mesh {st.mesh_shape} axes "
                      f"{mesh.axis_names} = {mesh.devices.size} devices")

    b = metrics.breakdown
    print(f"[cluster] {args.policy} on {args.accelerators} x {args.space}: "
          f"{len(metrics.jcts)} jobs")
    print(f"  avg JCT   : {metrics.avg_jct:,.0f} s (p50 {metrics.p50_jct:,.0f},"
          f" p90 {metrics.p90_jct:,.0f})")
    print(f"  makespan  : {metrics.makespan:,.0f} s")
    print(f"  STP       : {metrics.stp:.3f} work-seconds/s/accelerator")
    print(f"  energy    : {metrics.energy_j / 3.6e6:,.2f} kWh "
          f"({metrics.avg_power_w:,.0f} W cluster avg)")
    print(f"  breakdown : queue {b['queue']:,.0f}s | mps {b['mps']:,.0f}s | "
          f"ckpt {b['ckpt']:,.0f}s | run {b['run']:,.0f}s")
    if args.faults:
        _print_robustness(metrics)
    return 0


if __name__ == "__main__":
    sys.exit(main())
