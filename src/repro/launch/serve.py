"""Batched serving driver (CPU-runnable on smoke configs).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 24 [--quantize]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import RunConfig
from repro.models import LM
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quantize", action="store_true",
                    help="int8 weight-only quantization")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke_config(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    run = RunConfig(param_dtype="float32", activation_dtype="float32",
                    attn_block_q=64, attn_block_kv=64,
                    quantize_serving=args.quantize)
    params, _ = LM.init(cfg, run, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, run, params,
                         max_seq=args.prompt_len + args.new_tokens + 8)

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)
    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=args.new_tokens,
                          temperature=args.temperature,
                          key=jax.random.PRNGKey(args.seed))
    dt = time.time() - t0
    total_new = args.batch * args.new_tokens
    print(f"[serve] {cfg.name} quantize={args.quantize}: generated "
          f"{total_new} tokens in {dt:.2f}s ({total_new/dt:.1f} tok/s)")
    for i in range(min(2, args.batch)):
        print(f"  seq{i}: {list(map(int, out[i, -args.new_tokens:]))[:12]}...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
