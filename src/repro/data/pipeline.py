"""Deterministic synthetic LM data pipeline.

Step-addressable: batch(step) is a pure function of (seed, step, shard), so
* resume-after-failure replays the exact stream (no data loss/duplication);
* data-parallel shards draw disjoint substreams (multi-host ready);
* tests can assert bit-exact batches across restarts and re-meshes.

The token stream is structured (Zipf unigrams + a Markov chain + EOS-split
documents) rather than uniform noise so that small-model training in the
examples actually shows a falling loss.
"""
from __future__ import annotations

import numpy as np


class SyntheticLMData:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, n_shards: int = 1, shard: int = 0,
                 order: int = 1):
        assert global_batch % n_shards == 0
        self.vocab = int(vocab_size)
        self.seq = int(seq_len)
        self.batch = global_batch // n_shards
        self.seed = seed
        self.shard = shard
        self.n_shards = n_shards
        # fixed Markov transition table derived from the seed
        rng = np.random.default_rng(seed)
        self._hot = rng.integers(0, self.vocab,
                                 size=(min(self.vocab, 4096), 4))
        self._zipf_a = 1.3

    def _zipf(self, rng, n):
        z = rng.zipf(self._zipf_a, size=n).astype(np.int64)
        return (z - 1) % self.vocab

    def batch_at(self, step: int):
        """Returns (tokens, labels) uint32 arrays (batch, seq)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.shard, step]))
        B, S = self.batch, self.seq
        toks = self._zipf(rng, B * (S + 1)).reshape(B, S + 1)
        # inject Markov continuity: with p=.5, next token = f(prev)
        follow = rng.random((B, S)) < 0.5
        mapped = self._hot[toks[:, :-1] % len(self._hot),
                           toks[:, :-1] % 4]
        toks[:, 1:] = np.where(follow, mapped % self.vocab, toks[:, 1:])
        # documents: EOS (=0) every ~Geometric(1/128) tokens
        eos = rng.random((B, S + 1)) < (1.0 / 128)
        toks = np.where(eos, 0, toks)
        return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
