"""Serving: prefill + decode steps and a batched engine.

``make_serve_step`` builds the one-token decode step that decode_32k /
long_500k lower on the production mesh: inputs are (params, tokens (B,1),
cache, pos).  ``ServeEngine`` drives real batched generation on small models
(examples + tests): prefill the prompt batch, then greedy/temperature decode
with the same step, optionally with int8 weight-only quantization
(beyond-paper serving optimization; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.model import LM
from repro.utils.quant import (abstract_quantize, dequantize_params,
                               is_quantized_leaf, maybe_dequant,
                               quantize_params)


def make_serve_step(cfg, run):
    def serve_step(params, tokens, cache, pos):
        logits, cache = LM.decode_step(params, cfg, run, tokens, cache, pos)
        return logits, cache
    return serve_step


def make_prefill_step(cfg, run, max_seq: int):
    def prefill_step(params, tokens):
        return LM.prefill(params, cfg, run, tokens, max_seq)
    return prefill_step


class ServeEngine:
    """Batched generation for small models (CPU-runnable examples/tests)."""

    def __init__(self, cfg, run, params, max_seq: int = 512):
        self.cfg, self.run = cfg, run
        self.max_seq = max_seq
        if run.quantize_serving:
            # keep the int8 tree: the model dequantizes lazily per layer
            params = quantize_params(params)
        self.params = params
        self._prefill = jax.jit(make_prefill_step(cfg, run, max_seq))
        self._step = jax.jit(make_serve_step(cfg, run))

    def generate(self, prompts: jnp.ndarray, max_new_tokens: int = 32,
                 temperature: float = 0.0, key=None):
        """prompts: (B, S0) int32. Returns (B, S0 + max_new_tokens)."""
        B, S0 = prompts.shape
        logits, cache = self._prefill(self.params, prompts)
        out = [prompts]
        tok = self._sample(logits[:, -1], temperature, key, 0)
        for i in range(max_new_tokens):
            out.append(tok)
            if i == max_new_tokens - 1:
                break
            logits, cache = self._step(self.params, tok, cache,
                                       jnp.int32(S0 + i))
            tok = self._sample(logits[:, -1], temperature, key, i + 1)
        return jnp.concatenate(out, axis=1)

    @staticmethod
    def _sample(logits, temperature, key, i):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(
            k, logits / temperature, axis=-1)[:, None].astype(jnp.int32)
