"""Analytic per-step cost model shared by (a) the MISO performance model
(ground-truth job speeds for the cluster simulator + predictor training) and
(b) the §Roofline MODEL_FLOPS reference term.

All counts are *algorithmic* (useful work): MODEL_FLOPS = 6·N·D for training
(2·N·D for prefill) plus the attention quadratic term; the HLO terms from
``compiled.cost_analysis()`` are compared against these to expose
remat/dispatch waste.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CostReport:
    flops: float           # algorithmic FLOPs per step
    hbm_bytes: float       # estimated HBM traffic per step
    mem_bytes: float       # resident footprint (params + opt/kv + activations)
    param_bytes: float
    tokens: int

    @property
    def intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)


def _attn_flops(cfg, seq: int, batch: int, kind: str) -> float:
    """Quadratic (or windowed / recurrent) sequence-mixing FLOPs."""
    D = cfg.resolved_head_dim
    total = 0.0
    for k in cfg.layer_kinds:
        if k == "attn":
            from repro.models.transformer import kind_window
            w = kind_window(cfg, k)
            if kind == "decode":
                kv = min(seq, w) if w else seq
                total += 4 * batch * cfg.n_heads * kv * D
            else:
                eff = min(seq, w) if w else seq
                # causal: each query sees ~eff/2 keys on average (full) or ~w
                avg_kv = (eff / 2) if w is None else min(w, seq / 2)
                f = 4 * batch * cfg.n_heads * seq * avg_kv * D
                total += f * (3 if kind == "train" else 1)
        elif k == "rwkv":
            H = cfg.d_model // cfg.rwkv_head_dim
            N = cfg.rwkv_head_dim
            steps = 1 if kind == "decode" else seq
            f = 4 * batch * H * steps * N * N
            total += f * (3 if kind == "train" else 1)
        elif k == "rglru":
            steps = 1 if kind == "decode" else seq
            f = 8 * batch * steps * cfg.d_model
            total += f * (3 if kind == "train" else 1)
    return total


def model_flops(cfg, seq: int, batch: int, kind: str, n_params: int | None = None) -> float:
    """6·N_active·D (train) / 2·N_active·D (prefill/decode) + seq-mixing term."""
    n = n_params if n_params is not None else cfg.active_param_count()
    if kind == "train":
        tokens = seq * batch
        return 6.0 * n * tokens + _attn_flops(cfg, seq, batch, kind)
    if kind == "prefill":
        tokens = seq * batch
        return 2.0 * n * tokens + _attn_flops(cfg, seq, batch, kind)
    if kind == "decode":
        return 2.0 * n * batch + _attn_flops(cfg, seq, batch, kind)
    raise ValueError(kind)


def kv_cache_bytes(cfg, seq: int, batch: int, dtype_bytes: int = 2) -> float:
    total = 0.0
    for k in cfg.layer_kinds:
        if k == "attn":
            from repro.models.transformer import kind_window
            w = kind_window(cfg, k)
            s = min(seq, w) if w else seq
            total += 2 * batch * s * cfg.n_kv_heads * cfg.resolved_head_dim * dtype_bytes
        elif k == "rwkv":
            H = cfg.d_model // cfg.rwkv_head_dim
            total += batch * H * cfg.rwkv_head_dim ** 2 * 4  # fp32 state
            total += 2 * batch * cfg.d_model * dtype_bytes
        elif k == "rglru":
            total += batch * cfg.d_model * 4
            total += batch * (cfg.rglru_conv_width - 1) * cfg.d_model * dtype_bytes
    return total


def step_costs(cfg, seq: int, batch: int, kind: str, *, dtype_bytes: int = 2,
               opt_bytes_per_param: int = 8, remat: bool = True,
               n_params: int | None = None,
               n_active: int | None = None) -> CostReport:
    n = n_params if n_params is not None else cfg.param_count()
    na = n_active if n_active is not None else cfg.active_param_count()
    flops = model_flops(cfg, seq, batch, kind, n_params=na)
    tokens = seq * batch if kind != "decode" else batch
    pbytes = n * dtype_bytes

    act_unit = tokens * cfg.d_model * dtype_bytes
    if kind == "train":
        # weights fwd+bwd (+grad +opt traffic) + boundary activations per layer
        hbm = 4.0 * pbytes + 1.5 * opt_bytes_per_param * n \
            + cfg.n_layers * act_unit * (4.0 if remat else 8.0)
        mem = pbytes + opt_bytes_per_param * n \
            + cfg.n_layers * act_unit * (1.0 if remat else 6.0)
    elif kind == "prefill":
        hbm = pbytes + cfg.n_layers * act_unit * 3.0
        mem = pbytes + kv_cache_bytes(cfg, seq, batch, dtype_bytes) \
            + 4 * act_unit
    else:  # decode: weight-read bound
        kv = kv_cache_bytes(cfg, seq, batch, dtype_bytes)
        # active weights are read once per token step; kv cache read once
        hbm = na * dtype_bytes + kv + cfg.n_layers * act_unit * 3.0
        mem = pbytes + kv + 4 * act_unit
    return CostReport(flops=float(flops), hbm_bytes=float(hbm),
                      mem_bytes=float(mem), param_bytes=float(pbytes),
                      tokens=int(tokens))
