from repro.roofline.costs import step_costs, CostReport, model_flops
