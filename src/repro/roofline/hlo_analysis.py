"""Roofline-term extraction from partitioned, optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop body ONCE
(verified on this backend: a 10-iteration scan of a matmul reports 1 matmul
of FLOPs), which would understate every scanned-layer model by ~n_layers.
This walker parses the HLO module into computations, multiplies while bodies
by their ``known_trip_count`` backend config, and accumulates three terms:

* flops            — dot/convolution FLOPs (2*M*N*K from operand shapes)
* hbm_bytes        — post-fusion memory traffic: for every top-level
                     instruction, operand bytes + result bytes.  Fusion nodes
                     count only their inputs/outputs — exactly the HBM-traffic
                     semantics we want; fused elementwise ops are free.
* hbm_bytes_kernelized — the same walk with instructions inside
                     ``*_kernel_region`` named scopes (the regions the Pallas
                     kernels implement: flash attention, WKV6, RG-LRU) kept
                     VMEM-resident: non-dot ops contribute zero traffic and
                     dots contribute operand streams only.  This models the
                     §Perf "kernelize" iteration without needing Mosaic on CPU.
* collective_bytes — per collective opcode, ring-model traffic per device:
                     all-gather       (g-1)/g * result
                     reduce-scatter   (g-1)/g * operand(=result*g)
                     all-reduce       2*(g-1)/g * result
                     all-to-all       (g-1)/g * result
                     collective-permute   result
                     with g = replica-group size parsed from the op.

All numbers are per device: the partitioned module is a single device's
program.  Multiply by the mesh size for global counts.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast")

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"(\w[\w\d]*)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*\S.*{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    rest: str          # everything after the opening paren
    line: str


@dataclass
class _Totals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    hbm_bytes_kern: float = 0.0      # with *_kernel_region scopes in VMEM
    transcendentals: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)
    collective_count: Dict[str, int] = field(default_factory=dict)

    def add(self, other: "_Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.hbm_bytes_kern += other.hbm_bytes_kern * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v * mult
        for k, v in other.collective_count.items():
            self.collective_count[k] = self.collective_count.get(k, 0) + int(v * mult)


class HloAnalysis:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[_Instr]] = {}
        self._parse(hlo_text)
        self._memo: Dict[str, _Totals] = {}
        self.entry = self._find_entry(hlo_text)

    # ------------------------------------------------------------- parsing

    def _parse(self, text: str):
        """Computation headers start at column 0 (``%name (...)-> T {`` or
        ``ENTRY %name ...``) and may wrap over several lines; instructions are
        indented.  Bodies close with a column-0 '}'."""
        current = None
        in_header = False
        pending_name = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if in_header:
                if line.endswith("{"):
                    current = pending_name
                    self.computations[current] = []
                    in_header = False
                continue
            if line[0] in "%E" and (line.startswith("%")
                                    or line.startswith("ENTRY")):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
                if m:
                    pending_name = m.group(1)
                    if line.endswith("{"):
                        current = pending_name
                        self.computations[current] = []
                    else:
                        in_header = True
                continue
            if line.startswith("}"):
                current = None
                continue
            if current is None:
                continue
            # the HLO printer inserts /*index=N*/ comments inside long tuple
            # types; they contain '=' and would break the instruction regex
            clean = re.sub(r"/\*.*?\*/", "", line)
            mi = _INSTR_RE.match(clean)
            if mi:
                name, type_str, opcode, rest = mi.groups()
                self.computations[current].append(
                    _Instr(name, type_str, opcode, rest, clean))

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
        if m:
            return m.group(1)
        return next(iter(self.computations))

    # ------------------------------------------------------------ analysis

    def totals(self) -> _Totals:
        return self._comp_totals(self.entry)

    def _comp_totals(self, comp: str) -> _Totals:
        if comp in self._memo:
            return self._memo[comp]
        out = _Totals()
        symbols = {i.name: i.type_str for i in self.computations.get(comp, [])}
        for ins in self.computations.get(comp, []):
            op = ins.opcode
            if op == "while":
                trip = 1
                m = _TRIP_RE.search(ins.line)
                if m:
                    trip = int(m.group(1))
                body = self._attr(ins.line, "body")
                cond = self._attr(ins.line, "condition")
                if body:
                    out.add(self._comp_totals(body), trip)
                if cond:
                    out.add(self._comp_totals(cond), trip)
                continue
            if op in ("fusion", "call", "custom-call", "reduce", "map", "sort",
                      "scatter", "reduce-window", "select-and-scatter",
                      "conditional", "async-start"):
                for callee in self._callees(ins.line):
                    out.add(self._comp_totals(callee), 1.0)
            if op == "dot":
                out.flops += self._dot_flops(ins, symbols)
            elif op == "convolution":
                out.flops += self._conv_flops(ins, symbols)
            t = self._traffic(ins, symbols)
            out.hbm_bytes += t
            if "_kernel_region" in ins.line:
                # kernelized: elementwise/softmax state lives in VMEM; dots
                # stream their operands from HBM (upper bound: includes the
                # VMEM-resident probability operand)
                if op == "dot":
                    out.hbm_bytes_kern += sum(
                        _type_bytes(symbols[n]) for n in self._operands(ins)
                        if n in symbols)
            else:
                out.hbm_bytes_kern += t
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                b = self._collective_bytes(ins)
                out.collectives[base] = out.collectives.get(base, 0.0) + b
                out.collective_count[base] = out.collective_count.get(base, 0) + 1
        self._memo[comp] = out
        return out

    # -------------------------------------------------------- per-op costs

    @staticmethod
    def _attr(line: str, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w.\-]+)", line)
        return m.group(1) if m else None

    @staticmethod
    def _callees(line: str) -> List[str]:
        out = []
        m = re.search(r"calls=%?([\w.\-]+)", line)
        if m:
            out.append(m.group(1))
        m = re.search(r"to_apply=%?([\w.\-]+)", line)
        if m:
            out.append(m.group(1))
        return out

    def _operands(self, ins: _Instr) -> List[str]:
        # operand names up to the closing paren of the call
        depth = 1
        buf = ""
        for ch in ins.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf += ch
        return re.findall(r"%([\w.\-]+)", buf)

    def _dot_flops(self, ins: _Instr, symbols) -> float:
        res = _shape_dims(ins.type_str)
        if res is None:
            return 0.0
        _, rdims = res
        n_out = 1
        for d in rdims:
            n_out *= d
        ops = self._operands(ins)
        k = 1
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
        if ops and m and ops[0] in symbols:
            lhs = _shape_dims(symbols[ops[0]])
            if lhs:
                for idx in (int(x) for x in m.group(1).split(",") if x):
                    if idx < len(lhs[1]):
                        k *= lhs[1][idx]
        return 2.0 * n_out * k

    def _conv_flops(self, ins: _Instr, symbols) -> float:
        res = _shape_dims(ins.type_str)
        if res is None:
            return 0.0
        _, rdims = res
        n_out = 1
        for d in rdims:
            n_out *= d
        ops = self._operands(ins)
        if len(ops) >= 2 and ops[1] in symbols:
            ker = _shape_dims(symbols[ops[1]])
            if ker:
                k = 1
                for d in ker[1][:-1]:   # all but output-feature dim
                    k *= d
                return 2.0 * n_out * k
        return 2.0 * n_out

    _SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                     "bitcast", "bitcast-convert", "reshape", "after-all",
                     "partition-id", "replica-id", "iota", "while",
                     "conditional", "call"}

    # bare element-wise ops fuse into neighbours on the TPU target; counting
    # them (CPU XLA leaves many unfused) would overstate HBM traffic ~10x
    _ELEMENTWISE = {
        "add", "subtract", "multiply", "divide", "maximum", "minimum",
        "exponential", "exponential-minus-one", "log", "log-plus-one",
        "tanh", "logistic", "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign",
        "floor", "ceil", "round-nearest-afz", "round-nearest-even", "power",
        "compare", "select", "and", "or", "not", "xor", "clamp", "convert",
        "is-finite", "real", "imag", "atan2", "remainder", "shift-left",
        "shift-right-logical", "shift-right-arithmetic", "popcnt", "clz",
        "sine", "cosine", "tan", "erf", "expm1", "log1p", "broadcast", "map",
    }

    def _traffic(self, ins: _Instr, symbols) -> float:
        op = ins.opcode
        base = op[:-6] if op.endswith("-start") else op
        if base in self._SKIP_TRAFFIC or base in _COLLECTIVES or \
                base in self._ELEMENTWISE or \
                op.endswith("-done") or op.endswith("-update-done"):
            return 0.0
        if base == "fusion" and ins.name.startswith("convert"):
            # pure dtype-conversion fusions are CPU-lowering artifacts: the
            # TPU backend computes bf16/int8 natively or fuses the convert
            # into the consumer; the payload is counted at the consumer
            return 0.0
        out_b = _type_bytes(ins.type_str)
        op_bytes = [(_type_bytes(symbols[n]))
                    for n in self._operands(ins) if n in symbols]
        in_b = float(sum(op_bytes))
        if base == "dynamic-update-slice" or (
                base == "fusion" and "dynamic-update-slice" in ins.name):
            # in-place slice update: read+write the update region only.  Any
            # buffer-sized operands (the aliased target plus CPU-inserted
            # dtype-converted copies of it) do not stream through HBM on TPU
            big = max(op_bytes) if op_bytes else out_b
            small = sum(b for b in op_bytes if b < 0.5 * big)
            return float(2.0 * small)
        if base == "dynamic-slice" or (
                base == "fusion" and ins.name.startswith(
                    ("dynamic-slice", "bitcast_dynamic-slice"))):
            big = max(op_bytes) if op_bytes else 0.0
            return float(2.0 * out_b + (in_b - big))
        if op_bytes:
            # generic sliced-read: a fusion whose single dominant operand is
            # >> its output (and >> its other operands) reads that operand
            # sparsely (scan slicing a stacked buffer); on TPU only the
            # consumed window streams from HBM
            big = max(op_bytes)
            rest = in_b - big
            if big > 4.0 * max(out_b, rest, 1.0):
                return float(2.0 * out_b + rest)
        return float(out_b + in_b)

    def _collective_bytes(self, ins: _Instr) -> float:
        res_b = _type_bytes(ins.type_str)
        g = 2
        m = _GROUPS_IOTA_RE.search(ins.line)
        if m:
            g = int(m.group(2))
        else:
            m = _GROUPS_LIST_RE.search(ins.line)
            if m:
                g = max(2, len([x for x in m.group(1).split(",") if x.strip()]))
        base = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
        ring = (g - 1) / g
        if base == "all-gather":
            return res_b * ring
        if base == "all-reduce":
            return 2.0 * res_b * ring
        if base == "reduce-scatter":
            return res_b * (g - 1)
        if base == "all-to-all":
            return res_b * ring
        return float(res_b)   # collective-permute / broadcast


def analyze_hlo(hlo_text: str) -> dict:
    t = HloAnalysis(hlo_text).totals()
    return {
        "flops": t.flops,
        "hbm_bytes": t.hbm_bytes,
        "hbm_bytes_kernelized": t.hbm_bytes_kern,
        "collective_bytes": sum(t.collectives.values()),
        "collectives": dict(t.collectives),
        "collective_counts": dict(t.collective_count),
    }
