# MISO core: partition spaces, performance model, MPS->MIG predictor,
# partition optimizer, cluster scheduler and event simulator.
from repro.core.partitions import a100_mig_space, tpu_pod_space, PartitionSpace
from repro.core.jobs import Job, JobProfile, WORKLOADS, job_profile
from repro.core.perfmodel import PerfModel, A100, TPU_V5E_POD
