"""Slice-speed estimators: how a scheduling policy learns f_i(x).

* OracleEstimator  — ground-truth speeds from the performance model (the
  paper's Oracle; also used *after* partitioning for actual execution speed).
* NoisyEstimator   — ground truth + multiplicative Gaussian error (paper
  Fig 18 sensitivity).
* UNetEstimator    — the full MISO path: the job mix's measured MPS matrix ->
  U-Net -> (7g,4g,3g), then the linear-regression heads -> (2g,1g), then the
  memory monitor zeroes OOM slices (paper §4.1 + §4.3).

Batched contract
----------------
``estimate_batch(requests)`` takes a list of ``(profs, mps_matrix, qos)``
tuples — one per co-location group / profiling window — and returns one
``estimate``-shaped result per request, in order.  Semantics:

* results are identical to calling ``estimate`` once per request in the
  same order (estimators that consume RNG draw it in request order);
* ``mps_matrix`` may be None per request; estimators that need one measure
  it themselves (as ``estimate`` does);
* the U-Net estimator stacks every request's matrix into a single
  ``(B, levels, jobs)`` jitted forward (padded to a power-of-two batch
  bucket) instead of B separate ``(1, levels, jobs)`` dispatches — the
  engine's same-tick window coalescing is the main caller.  A batched
  forward is numerically equal to per-request forwards up to XLA batch
  reassociation (float32 last-ulp); single-request batches go through the
  exact same compiled shape as ``estimate`` and are bit-identical to it.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.jobs import DUMMY_PROFILE, JobProfile
from repro.core.partitions import PartitionSpace
from repro.core.perfmodel import PerfModel
from repro.core.predictor import linreg as linreg_mod
from repro.core.predictor import unet as unet_mod
from repro.core.predictor.dataset import LIN_SLICES, OUT_SLICES

#: one estimate_batch request: (profiles, optional MPS matrix, optional QoS)
EstimateRequest = Tuple[Sequence[JobProfile], Optional[np.ndarray],
                        Optional[Sequence[int]]]


def _apply_mem_constraints(space: PartitionSpace, prof: JobProfile,
                           speeds: Dict[int, float],
                           qos_min_slice: int = 0) -> Dict[int, float]:
    out = {}
    for size, v in speeds.items():
        st = space.slices[size]
        if prof.mem_gb > st.memory_gb or size < qos_min_slice:
            out[size] = 0.0
        else:
            out[size] = max(0.0, min(1.0, v))
    return out


class OracleEstimator:
    needs_mps = False

    def __init__(self, pm: PerfModel):
        self.pm = pm
        # per-(profile, qos) estimate memo: the oracle's slice-speed map is
        # a pure function of the (immutable) profile and the QoS floor, and
        # the oracle policy re-runs it on every repartition.  The profile is
        # pinned in the value so the id key cannot be recycled.  The result
        # dicts are shared — every consumer treats estimates as read-only
        # (the estimator-fault injector builds fresh dicts).
        self._est_cache: Dict[Tuple[int, int], Tuple[JobProfile,
                                                     Dict[int, float]]] = {}

    def _estimate_one(self, p: JobProfile, q: int) -> Dict[int, float]:
        key = (id(p), q)
        hit = self._est_cache.get(key)
        if hit is not None and hit[0] is p:
            return hit[1]
        est = _apply_mem_constraints(self.pm.space, p,
                                     self.pm.speed_vector(p), q)
        if len(self._est_cache) >= 65536:
            self._est_cache.pop(next(iter(self._est_cache)))
        self._est_cache[key] = (p, est)
        return est

    def estimate(self, profs: Sequence[JobProfile], mps_matrix=None,
                 qos=None) -> List[Dict[int, float]]:
        qos = qos or [0] * len(profs)
        return [self._estimate_one(p, q) for p, q in zip(profs, qos)]

    def estimate_batch(self, requests: Sequence[EstimateRequest]
                       ) -> List[List[Dict[int, float]]]:
        """Default batched path: per-request ``estimate`` in request order
        (exact for any estimator whose estimate is per-request; overridden
        where a fused pass exists)."""
        return [self.estimate(profs, mat, qos)
                for profs, mat, qos in requests]


class NoisyEstimator(OracleEstimator):
    """Ground truth with relative error ~ N(0, sigma) (paper Fig 18).

    The inherited ``estimate_batch`` loops requests in order, so the noise
    stream is consumed exactly as back-to-back ``estimate`` calls would.
    """
    needs_mps = False

    def __init__(self, pm: PerfModel, sigma: float, seed: int = 0):
        super().__init__(pm)
        self.sigma = sigma
        self.rng = np.random.default_rng(seed)

    def estimate(self, profs, mps_matrix=None, qos=None):
        qos = qos or [0] * len(profs)
        out = []
        for p, q in zip(profs, qos):
            sv = {s: v * float(1.0 + self.rng.normal(0.0, self.sigma))
                  for s, v in self.pm.speed_vector(p).items()}
            sv[self.pm.space.full_size] = 1.0   # normalization anchor
            out.append(_apply_mem_constraints(self.pm.space, p, sv, q))
        return out


class UNetEstimator:
    """MPS-profile -> U-Net -> linreg heads -> memory-constrained speeds."""
    needs_mps = True

    def __init__(self, pm: PerfModel, params, heads, jobs: int = 7,
                 seed: int = 0):
        self.pm = pm
        self.net = unet_mod.UNet(params, jobs=jobs)
        self.heads = heads
        self.jobs = jobs
        # fallback noise stream: advances across calls so every profiling
        # window draws fresh measurement noise (callers normally thread the
        # simulator's RNG through instead)
        self._rng = np.random.default_rng(seed)

    @classmethod
    def from_artifact(cls, pm: PerfModel, path: str, jobs: int = 7):
        from repro.core.predictor.train import load_artifact
        params, heads, _ = load_artifact(path)
        return cls(pm, params, heads, jobs=jobs)

    def measure_mps(self, profs: Sequence[JobProfile],
                    noise_sigma: float = 0.0,
                    rng: Optional[np.random.Generator] = None) -> np.ndarray:
        """The profiling measurement itself (what the 30s MPS phase yields).

        ``noise_sigma`` models measurement noise from a finite profiling
        window: speeds are averaged over ~10s per level, so shorter windows
        give noisier estimates (paper Fig 14 sensitivity: sigma ~ 1/sqrt(T)).
        Pass the simulator's ``rng`` so successive windows draw independent
        noise; without one, an instance-local stream is used (it advances
        across calls — noise is never identical between windows).
        """
        if len(profs) > self.jobs:
            raise ValueError(
                f"cannot profile {len(profs)} co-located jobs: this predictor "
                f"was trained on matrices of at most {self.jobs} columns")
        padded = list(profs) + [DUMMY_PROFILE] * (self.jobs - len(profs))
        m = np.asarray(self.pm.mps_matrix(padded), dtype=np.float32)
        if noise_sigma > 0:
            if rng is None:
                rng = self._rng
            m = m * (1.0 + rng.normal(0.0, noise_sigma, size=m.shape)
                     ).astype(np.float32)
            m = np.maximum(m, 1e-6)
        return m / np.maximum(m.max(axis=0, keepdims=True), 1e-9)

    def estimate(self, profs, mps_matrix: Optional[np.ndarray] = None,
                 qos=None) -> List[Dict[int, float]]:
        if mps_matrix is None:
            mps_matrix = self.measure_mps(profs)
        pred = np.asarray(self.net(mps_matrix))            # (3, J)
        return self._postprocess(profs, pred, qos)

    def estimate_batch(self, requests: Sequence[EstimateRequest]
                       ) -> List[List[Dict[int, float]]]:
        """Fused path: all B requests' matrices go through one stacked
        ``(B, levels, jobs)`` jitted forward (see module docstring for the
        numerical contract); measurement (and thus any RNG use) happens in
        request order before the forward."""
        if not requests:
            return []
        mats = [np.asarray(mat if mat is not None else self.measure_mps(profs),
                           dtype=np.float32)
                for profs, mat, _ in requests]
        preds = np.asarray(self.net(np.stack(mats)))       # (B, 3, J)
        return [self._postprocess(profs, pred, qos)
                for (profs, _, qos), pred in zip(requests, preds)]

    def _postprocess(self, profs, pred: np.ndarray,
                     qos=None) -> List[Dict[int, float]]:
        """(3, J) U-Net output -> per-job speed dicts: linreg heads for the
        small slices, full-slice anchor, then the memory/QoS monitor."""
        qos = qos or [0] * len(profs)
        lin = linreg_mod.apply_linreg(self.heads, pred.T)  # (J, 2)
        out = []
        for j, (p, q) in enumerate(zip(profs, qos)):
            sv = {s: float(pred[r, j]) for r, s in enumerate(OUT_SLICES)}
            sv[self.pm.space.full_size] = 1.0
            for r, s in enumerate(LIN_SLICES):
                sv[s] = float(lin[j, r])
            out.append(_apply_mem_constraints(self.pm.space, p, sv, q))
        return out
