"""Event-driven cluster simulator for MISO and the competing policies
(paper §5-6): NoPart / OptSta / MPS-only / MISO / Oracle.

Time model
----------
Each GPU is a small state machine over phases:

  IDLE -> (jobs placed) -> CKPT (checkpoint + GPU reset dead time)
       -> MPS_PROF (jobs progress at interference-prone MPS speeds; the
          measurement happens here)                                [MISO only]
       -> CKPT (reconfigure to the optimizer's MIG partition)
       -> MIG_RUN (jobs progress at interference-free slice speeds)

Oracle skips CKPT/MPS phases entirely (paper: "does not suffer from profiling
overhead or prediction inaccuracies"); OptSta/NoPart/MPS-only never profile.
MISO pays every overhead (conservative reporting, §5 "Competing Techniques").

Job accounting (Fig 12): every second of a job's life lands in exactly one of
{queue, ckpt, mps, run}.

Fault tolerance: optional Poisson GPU failures re-queue affected jobs with
progress rolled back to the last periodic checkpoint; the failed GPU is out
for ``repair_s``.  MISO's normal arrival path handles re-admission — job-level
fault tolerance is the scheduler itself.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.estimators import OracleEstimator
from repro.core.jobs import Job
from repro.core.metrics import TraceMetrics, compute_metrics
from repro.core.optimizer import optimize_partition
from repro.core.partitions import PartitionSpace
from repro.core.perfmodel import MPS_LEVELS, PerfModel

IDLE, CKPT, MPS_PROF, MIG_RUN = "idle", "ckpt", "mps", "mig"


@dataclass
class SimConfig:
    n_gpus: int = 8
    policy: str = "miso"             # nopart | optsta | mpsonly | miso | oracle
    static_partition: Tuple[int, ...] = (4, 2, 1)   # optsta only
    mps_level_time_s: float = 10.0   # per MPS level (paper: 10s x 3 levels)
    mig_reconfig_s: float = 4.0      # GPU reset (paper §3)
    ckpt_base_s: float = 2.0
    ckpt_bw_gbps: float = 4.0        # job state of mem_gb -> save+restore time
    overhead_scale: float = 1.0      # Fig 17 sensitivity knob
    mps_only_level: float = 0.33
    mps_only_max_jobs: int = 3
    max_sim_s: float = 10_000_000.0
    # fault injection
    gpu_mtbf_s: float = 0.0          # 0 = no failures
    repair_s: float = 600.0
    ckpt_interval_s: float = 600.0   # periodic checkpoint for fault rollback
    seed: int = 0


@dataclass
class _RJob:
    job: Job
    slice_size: Optional[int] = None
    speed: float = 0.0               # work-seconds per second, right now


class _GPU:
    def __init__(self, gid: int, sim: "ClusterSim"):
        self.gid = gid
        self.sim = sim
        self.phase = IDLE
        self.phase_end = 0.0
        self.jobs: Dict[int, _RJob] = {}
        self.partition: Tuple[int, ...] = ()
        self.estimates: Dict[int, Dict[int, float]] = {}
        self.last_update = 0.0
        self.stamp = 0               # event invalidation
        self.needs_profile = False
        self.down_until = 0.0

    # ------------------------------------------------------------ progress

    def advance(self, t: float):
        dt = t - self.last_update
        if dt <= 0:
            self.last_update = t
            return
        for rj in self.jobs.values():
            if self.phase == MIG_RUN:
                rj.job.remaining -= rj.speed * dt
                rj.job.t_run += dt
            elif self.phase == MPS_PROF:
                rj.job.remaining -= rj.speed * dt
                rj.job.t_mps += dt
            elif self.phase == CKPT:
                rj.job.t_ckpt += dt
            else:
                rj.job.t_queue += dt
        self.last_update = t

    def refresh_speeds(self):
        sim = self.sim
        profs = [rj.job.profile_at(1.0 - rj.job.remaining / rj.job.work)
                 for rj in self.jobs.values()]
        rjs = list(self.jobs.values())
        if self.phase == MIG_RUN:
            for rj in rjs:
                prof = rj.job.profile_at(1.0 - rj.job.remaining / rj.job.work)
                rj.speed = (sim.pm.slice_speed(prof, rj.slice_size)
                            if rj.slice_size else 0.0)
        elif self.phase == MPS_PROF:
            if rjs:
                if sim.cfg.policy == "mpsonly":
                    speeds = sim.pm.mps_speeds(profs, sim.cfg.mps_only_level)
                else:
                    # profiling sweeps 3 levels back-to-back; use the mean
                    mats = [sim.pm.mps_speeds(profs, lv) for lv in MPS_LEVELS]
                    speeds = np.mean(np.asarray(mats), axis=0)
                for rj, s in zip(rjs, speeds):
                    rj.speed = float(s)
        else:
            for rj in rjs:
                rj.speed = 0.0

    def next_completion(self) -> Optional[Tuple[float, int]]:
        best = None
        for jid, rj in self.jobs.items():
            if rj.speed > 1e-12 and self.phase in (MIG_RUN, MPS_PROF):
                tf = self.last_update + max(rj.job.remaining, 0.0) / rj.speed
                if best is None or tf < best[0]:
                    best = (tf, jid)
        return best

    # --------------------------------------------------------- transitions

    def ckpt_duration(self) -> float:
        if not self.jobs:
            return self.sim.cfg.mig_reconfig_s * self.sim.cfg.overhead_scale
        per_job = max(
            self.sim.cfg.ckpt_base_s + rj.job.profile.mem_gb / self.sim.cfg.ckpt_bw_gbps
            for rj in self.jobs.values())
        return (self.sim.cfg.mig_reconfig_s + per_job) * self.sim.cfg.overhead_scale


class ClusterSim:
    def __init__(self, jobs: Sequence[Job], cfg: SimConfig,
                 space: PartitionSpace, pm: PerfModel, estimator=None):
        self.cfg = cfg
        self.space = space
        self.pm = pm
        self.estimator = estimator or OracleEstimator(pm)
        self.jobs = {j.jid: j for j in jobs}
        self.queue: List[int] = []
        self.gpus = [_GPU(i, self) for i in range(cfg.n_gpus)]
        self.events: List[tuple] = []
        self.t = 0.0
        self.rng = np.random.default_rng(cfg.seed)
        self.profile_cache: Dict[str, Dict[int, float]] = {}  # multi-instance
        self.completed: List[int] = []
        self._counter = itertools.count()

        for j in jobs:
            self._push(j.arrival, "arrival", j.jid)
        if cfg.gpu_mtbf_s > 0:
            for g in self.gpus:
                self._push(float(self.rng.exponential(cfg.gpu_mtbf_s)),
                           "failure", g.gid)

    # ---------------------------------------------------------- event glue

    def _push(self, t, kind, payload, stamp=0):
        heapq.heappush(self.events, (t, next(self._counter), kind, payload, stamp))

    def _schedule_gpu_events(self, g: _GPU):
        g.stamp += 1
        if g.phase in (CKPT, MPS_PROF):
            self._push(g.phase_end, "gpu_timer", g.gid, g.stamp)
        nc = g.next_completion()
        if nc:
            self._push(nc[0], "completion", (g.gid, nc[1]), g.stamp)

    # ---------------------------------------------------------- run loop

    def run(self) -> TraceMetrics:
        n_target = len(self.jobs)
        while self.events and len(self.completed) < n_target:
            t, _, kind, payload, stamp = heapq.heappop(self.events)
            if t > self.cfg.max_sim_s:
                break
            self.t = t
            if kind == "arrival":
                self._on_arrival(self.jobs[payload])
            elif kind == "gpu_timer":
                g = self.gpus[payload]
                if stamp != g.stamp or t < g.phase_end - 1e-9:
                    continue
                self._on_phase_end(g)
            elif kind == "completion":
                gid, jid = payload
                g = self.gpus[gid]
                if stamp != g.stamp:
                    continue
                g.advance(t)
                rj = g.jobs.get(jid)
                if rj is None or rj.job.remaining > 1e-6:
                    self._schedule_gpu_events(g)
                    continue
                self._on_completion(g, rj.job)
            elif kind == "failure":
                self._on_failure(self.gpus[payload])
            elif kind == "repair":
                self._admit()
        return compute_metrics([self.jobs[i] for i in self.completed],
                               self.cfg.n_gpus)

    # ---------------------------------------------------------- policies

    def _on_arrival(self, job: Job):
        # multi-instance clones are expanded by traces.expand_multi_instance;
        # clones share an mi_group so the MPS profile is measured only once.
        job.queue_since = self.t
        self.queue.append(job.jid)
        self._admit()

    def _admit(self):
        """FCFS: try to place queue-head jobs."""
        progressed = True
        while progressed and self.queue:
            progressed = False
            jid = self.queue[0]
            job = self.jobs[jid]
            g = self._pick_gpu(job)
            if g is None:
                return
            self.queue.pop(0)
            self._place(g, job)
            progressed = True

    def _pick_gpu(self, job: Job) -> Optional[_GPU]:
        pol = self.cfg.policy
        cands = []
        for g in self.gpus:
            if self.t < g.down_until:
                continue
            m = len(g.jobs)
            if pol == "nopart":
                if m == 0:
                    cands.append((0, g.gid, g))
            elif pol == "optsta":
                free = self._optsta_free_slices(g)
                fits = [s for s in free
                        if self.space.slice_mem_gb(s) >= max(job.profile.mem_gb,
                                                             job.min_mem_gb)
                        and s >= job.qos_min_slice]
                if fits:
                    cands.append((m, g.gid, g))
            elif pol == "mpsonly":
                if m < self.cfg.mps_only_max_jobs and self._mem_ok(g, job):
                    cands.append((m, g.gid, g))
            else:  # miso / oracle
                if m < self.space.max_jobs and self._mem_ok(g, job) \
                        and self._spare_slice_ok(g, job):
                    cands.append((m, g.gid, g))
        if not cands:
            return None
        cands.sort()
        return cands[0][2]

    def _mem_ok(self, g: _GPU, job: Job) -> bool:
        total = sum(rj.job.profile.mem_gb for rj in g.jobs.values())
        return total + job.profile.mem_gb <= self.pm.hw.mem_gb

    def _spare_slice_ok(self, g: _GPU, job: Job) -> bool:
        """'Maximum spare slice' check (paper §4.3): after adding the job,
        some valid partition must give every job a memory-feasible slice."""
        mems = [max(rj.job.profile.mem_gb, rj.job.min_mem_gb)
                for rj in g.jobs.values()]
        qoss = [rj.job.qos_min_slice for rj in g.jobs.values()]
        mems.append(max(job.profile.mem_gb, job.min_mem_gb))
        qoss.append(job.qos_min_slice)
        m = len(mems)
        order = sorted(range(m), key=lambda i: -mems[i])
        for part in self.space.partitions_of_len(m):
            sizes = sorted(part, reverse=True)
            ok = all(
                self.space.slice_mem_gb(sizes[r]) >= mems[i]
                and sizes[r] >= qoss[i]
                for r, i in enumerate(order))
            if ok:
                return True
        return False

    # ------------------------------------------------------- place / phases

    def _place(self, g: _GPU, job: Job):
        g.advance(self.t)
        if job.start_time is None:
            job.start_time = self.t
        job.t_queue += max(0.0, self.t - job.queue_since)
        g.jobs[job.jid] = _RJob(job)
        pol = self.cfg.policy
        if pol == "nopart":
            g.phase = MIG_RUN
            g.partition = (self.space.full_size,)
            g.jobs[job.jid].slice_size = self.space.full_size
        elif pol == "optsta":
            self._optsta_assign(g)
            g.phase = MIG_RUN
        elif pol == "mpsonly":
            g.phase = MPS_PROF          # progresses at MPS speeds forever
            g.phase_end = float("inf")
        elif pol == "oracle":
            self._repartition(g, profile=False)
        else:  # miso
            cached = (self.profile_cache.get(job.mi_group)
                      if job.mi_group is not None else None)
            if cached is not None:
                # multi-instance clone: skip MPS, straight to optimizer
                # (paper §4.3: spawned instances are not re-profiled)
                g.estimates[job.jid] = cached
                self._repartition(g, profile=False, overhead=True)
            else:
                self._begin_profiling(g)
        self._finalize(g)

    def _begin_profiling(self, g: _GPU):
        g.advance(self.t)
        dead = g.ckpt_duration() if any(
            rj.slice_size for rj in g.jobs.values()) else 0.0
        g.phase = CKPT
        g.phase_end = self.t + dead
        g.needs_profile = True
        for rj in g.jobs.values():
            rj.slice_size = None
        if dead == 0.0:
            self._on_phase_end(g, schedule=False)

    def _on_phase_end(self, g: _GPU, schedule=True):
        g.advance(self.t)
        if g.phase == CKPT and g.needs_profile:
            g.phase = MPS_PROF
            g.phase_end = self.t + 3 * self.cfg.mps_level_time_s \
                * self.cfg.overhead_scale
            g.needs_profile = False
        elif g.phase == MPS_PROF and self.cfg.policy == "miso":
            self._measure_and_partition(g)
        elif g.phase == CKPT:
            g.phase = MIG_RUN if g.jobs else IDLE
        self._finalize(g)
        if not schedule:
            return

    def _measure_and_partition(self, g: _GPU):
        profs = [rj.job.profile_at(1.0 - rj.job.remaining / rj.job.work)
                 for rj in g.jobs.values()]
        jids = list(g.jobs)
        qos = [self.jobs[j].qos_min_slice for j in jids]
        mps_mat = None
        if getattr(self.estimator, "needs_mps", False):
            mps_mat = self.estimator.measure_mps(profs)
        ests = self.estimator.estimate(profs, mps_mat, qos=qos)
        for jid, est in zip(jids, ests):
            g.estimates[jid] = est
            grp = self.jobs[jid].mi_group
            if grp is not None:
                self.profile_cache[grp] = est
        self._repartition(g, profile=False, overhead=True)

    def _repartition(self, g: _GPU, profile: bool, overhead: bool = False):
        """Run Algorithm 1 with current estimates; apply the partition."""
        jids = list(g.jobs)
        if not jids:
            g.phase = IDLE
            g.partition = ()
            return
        if self.cfg.policy == "oracle":
            speeds = self.estimator.estimate(
                [self.jobs[j].profile_at(1.0 - self.jobs[j].remaining /
                                         self.jobs[j].work) for j in jids],
                qos=[self.jobs[j].qos_min_slice for j in jids])
        else:
            speeds = [g.estimates.get(j, {self.space.full_size: 1.0})
                      for j in jids]
        choice = optimize_partition(self.space, speeds, require_feasible=True) \
            or optimize_partition(self.space, speeds)
        old = tuple(rj.slice_size for rj in g.jobs.values())
        for jid, size in zip(jids, choice.partition):
            g.jobs[jid].slice_size = size
        g.partition = tuple(sorted(choice.partition, reverse=True))
        if overhead and old != tuple(choice.partition):
            g.phase = CKPT
            g.phase_end = self.t + g.ckpt_duration()
            g.needs_profile = False
        else:
            g.phase = MIG_RUN

    # ---------------------------------------------------------- optsta

    def _optsta_free_slices(self, g: _GPU) -> List[int]:
        used = [rj.slice_size for rj in g.jobs.values() if rj.slice_size]
        free = list(self.cfg.static_partition)
        for s in used:
            if s in free:
                free.remove(s)
        return free

    def _optsta_assign(self, g: _GPU):
        """(Re)assign this GPU's jobs to its fixed slices, best-first
        (paper: OptSta migrates jobs to larger slices on availability)."""
        jids = list(g.jobs)
        speeds = []
        for j in jids:
            job = self.jobs[j]
            prof = job.profile_at(1.0 - job.remaining / job.work)
            sv = self.pm.speed_vector(prof)
            speeds.append({s: (sv.get(s, 0.0)
                               if self.space.slice_mem_gb(s) >= prof.mem_gb
                               and s >= job.qos_min_slice else 0.0)
                           for s in self.cfg.static_partition})
        # best assignment of m jobs to the fixed multiset's best m slices
        from repro.core.optimizer import _assign_dp
        part = tuple(sorted(self.cfg.static_partition, reverse=True))
        best_obj, best_perm = -1.0, None
        for sub in set(itertools.combinations(part, len(jids))):
            obj, perm = _assign_dp(sub, speeds)
            if obj > best_obj:
                best_obj, best_perm = obj, perm
        for jid, size in zip(jids, best_perm):
            g.jobs[jid].slice_size = size

    # ---------------------------------------------------------- completion

    def _on_completion(self, g: _GPU, job: Job):
        job.finish_time = self.t
        job.remaining = 0.0
        del g.jobs[job.jid]
        g.estimates.pop(job.jid, None)
        self.completed.append(job.jid)
        pol = self.cfg.policy
        if pol == "nopart":
            g.phase = IDLE
            g.partition = ()
        elif pol == "optsta":
            self._optsta_assign(g)
            g.phase = MIG_RUN if g.jobs else IDLE
        elif pol == "mpsonly":
            if not g.jobs:
                g.phase = IDLE
        elif pol == "oracle":
            self._repartition(g, profile=False)
        else:  # miso: re-optimize with known profiles (no new MPS needed)
            if g.jobs and g.phase == MIG_RUN:
                self._repartition(g, profile=False, overhead=True)
            elif not g.jobs:
                g.phase = IDLE
                g.partition = ()
        self._finalize(g)
        self._admit()

    # ---------------------------------------------------------- failures

    def _on_failure(self, g: _GPU):
        g.advance(self.t)
        if g.jobs:
            rollback = self.cfg.ckpt_interval_s
            for rj in list(g.jobs.values()):
                job = rj.job
                job.remaining = min(job.work,
                                    job.remaining + min(rollback, job.t_run))
                job.queue_since = self.t
                self.queue.insert(0, job.jid)
            g.jobs.clear()
            g.estimates.clear()
        g.phase = IDLE
        g.partition = ()
        g.down_until = self.t + self.cfg.repair_s
        g.stamp += 1
        self._push(g.down_until, "repair", g.gid, g.stamp)
        if self.cfg.gpu_mtbf_s > 0:
            self._push(self.t + float(self.rng.exponential(self.cfg.gpu_mtbf_s)),
                       "failure", g.gid)

    # ---------------------------------------------------------- common

    def _finalize(self, g: _GPU):
        g.refresh_speeds()
        self._schedule_gpu_events(g)


def simulate(jobs, cfg: SimConfig, space: PartitionSpace, pm: PerfModel,
             estimator=None) -> TraceMetrics:
    import copy
    jobs = copy.deepcopy(list(jobs))
    return ClusterSim(jobs, cfg, space, pm, estimator).run()
