"""Compatibility shim — the simulator now lives in :mod:`repro.core.sim`.

The event loop is in ``repro/core/sim/engine.py``, the per-GPU state machine
in ``repro/core/sim/gpu.py`` and the scheduling policies (NoPart / OptSta /
MPS-only / MISO / Oracle / MISO-frag / SRPT) under
``repro/core/sim/policies/``.  Existing callers keep working::

    from repro.core.simulator import SimConfig, ClusterSim, simulate
"""
from repro.core.sim import (CKPT, DEGRADED, HEALTHY, IDLE, MIG_RUN, MPS_PROF,
                            QUARANTINED, ClusterSim, FaultInjector, GPU,
                            Objective, Placer, Policy, RJob, SimConfig,
                            available_fault_injectors, available_objectives,
                            available_placers, available_policies,
                            get_fault_injector, get_objective, get_placer,
                            get_policy, register_fault_injector,
                            register_objective, register_placer,
                            register_policy, simulate)

__all__ = [
    "ClusterSim", "SimConfig", "simulate",
    "GPU", "RJob", "IDLE", "CKPT", "MPS_PROF", "MIG_RUN",
    "HEALTHY", "DEGRADED", "QUARANTINED",
    "Policy", "register_policy", "get_policy", "available_policies",
    "Placer", "register_placer", "get_placer", "available_placers",
    "Objective", "register_objective", "get_objective",
    "available_objectives",
    "FaultInjector", "register_fault_injector", "get_fault_injector",
    "available_fault_injectors",
]
