"""Scenario layer: named (arrival process x workload mix x fleet) bundles.

The paper evaluates on Poisson arrivals over one homogeneous cluster; real
multi-tenant fleets (Flex-MIG; online fragmentation-aware MIG scheduling)
see bursty, diurnal and heavy-tailed demand over mixed hardware.  A
:class:`Scenario` packages one such setting so every policy PR is evaluated
on the same grid: it names an arrival process, job-mix knobs (QoS /
multi-instance / memory-constraint fractions, duration tail) and a default
fleet spec string (see :mod:`repro.core.fleet`).

Arrival processes (all seeded, all returning sorted times):

* ``poisson``      — the paper's baseline (exponential inter-arrivals)
* ``bursty``       — ON/OFF bursts: batches of tightly-spaced arrivals
* ``diurnal``      — sinusoidal-rate nonhomogeneous Poisson (thinning)
* ``heavy_tail``   — Pareto inter-arrivals + heavier lognormal work tail
* ``flash_crowd``  — Poisson background + a near-instant mid-trace spike
* ``mixed_qos``    — Poisson with QoS / multi-instance / mem-constrained mix
* ``smoke``        — tiny fast trace for CI
* ``hetero_smoke`` — small heavy-tailed trace on a mixed a100+h100 fleet;
  the CI cell that exercises fleet-aware placement (see
  :mod:`repro.core.sim.placement`)
* ``rack_outage``  — correlated rack-level failures: whole racks of GPUs go
  down in one event (``SimConfig.rack_size`` / ``rack_mtbf_s``), the
  failure-domain realism per-GPU Poisson faults cannot express
* ``mps_blast``    — chaos: crash shocks whose blast radius depends on the
  victim GPU's phase (MPS window kills every co-resident, MIG one slice) —
  the paper §2 containment asymmetry, via the ``mps_blast`` fault injector
* ``flaky_fleet``  — chaos: blasts + flaky MIG reconfigs + persistent
  stragglers with the health/quarantine machinery ON (its ablation twin
  ``flaky_fleet_noq`` turns quarantine+migration OFF; the pair is the CI
  gate showing graceful degradation buys goodput)

Usage::

    sc = get_scenario("bursty")
    jobs = sc.make_jobs(seed=0)
    fleet = parse_fleet(sc.fleet)
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional

import numpy as np

from repro.core.jobs import Job
from repro.core.traces import generate_trace

DEFAULT_FLEET = "a100:2+h100:2"


# --------------------------------------------------------------- arrivals

def poisson_arrivals(rng: np.random.Generator, n: int,
                     mean_iat: float) -> np.ndarray:
    return np.cumsum(rng.exponential(mean_iat, size=n))


def bursty_arrivals(rng: np.random.Generator, n: int, mean_iat: float,
                    burst_factor: float = 10.0, p_burst: float = 0.3,
                    burst_len: tuple = (4, 12)) -> np.ndarray:
    """ON/OFF process: with probability ``p_burst`` a batch of ``burst_len``
    jobs arrives ``burst_factor``x faster than the background rate."""
    out: List[float] = []
    t = 0.0
    while len(out) < n:
        if rng.random() < p_burst:
            k = int(rng.integers(burst_len[0], burst_len[1] + 1))
            for _ in range(min(k, n - len(out))):
                t += float(rng.exponential(mean_iat / burst_factor))
                out.append(t)
        else:
            t += float(rng.exponential(mean_iat))
            out.append(t)
    return np.asarray(out)


def diurnal_arrivals(rng: np.random.Generator, n: int, mean_iat: float,
                     period_s: float = 4 * 3600.0,
                     amplitude: float = 0.8) -> np.ndarray:
    """Nonhomogeneous Poisson with rate (1 + A sin(2πt/T)) / mean_iat, drawn
    by Lewis-Shedler thinning."""
    lam_max = (1.0 + amplitude) / mean_iat
    out: List[float] = []
    t = 0.0
    while len(out) < n:
        t += float(rng.exponential(1.0 / lam_max))
        lam_t = (1.0 + amplitude * math.sin(2 * math.pi * t / period_s)) / mean_iat
        if rng.random() < lam_t / lam_max:
            out.append(t)
    return np.asarray(out)


def heavy_tail_arrivals(rng: np.random.Generator, n: int, mean_iat: float,
                        alpha: float = 1.5) -> np.ndarray:
    """Pareto(α) inter-arrivals scaled to mean ``mean_iat`` (α<=2 gives the
    infinite-variance burst-and-lull pattern of production traces)."""
    iats = mean_iat * (alpha - 1.0) * rng.pareto(alpha, size=n)
    return np.cumsum(iats)


def flash_crowd_arrivals(rng: np.random.Generator, n: int, mean_iat: float,
                         crowd_frac: float = 0.35,
                         crowd_speedup: float = 50.0) -> np.ndarray:
    """Poisson background with ``crowd_frac`` of all jobs slamming in near
    the middle of the trace inside a window ``crowd_speedup``x denser."""
    n_crowd = max(1, int(n * crowd_frac))
    n_base = n - n_crowd
    base = np.cumsum(rng.exponential(mean_iat, size=max(n_base, 1)))
    t_spike = float(base[len(base) // 2])
    crowd = t_spike + np.cumsum(
        rng.exponential(mean_iat / crowd_speedup, size=n_crowd))
    out = np.sort(np.concatenate([base[:n_base], crowd]))
    return out


# --------------------------------------------------------------- scenarios

@dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    make: Callable[..., List[Job]]       # (seed, n_jobs) -> jobs
    fleet: str = DEFAULT_FLEET           # default fleet spec string
    n_jobs: int = 60                     # default trace length
    placer: str = "least-loaded"         # default placement layer for sweeps
    objective: str = "throughput"        # default Algorithm-1 objective
    # False for fixed-trace replays: make_jobs ignores the seed (every seed
    # in a sweep grid replays the identical workload; seeds still vary
    # fault injection inside the simulator)
    seed_sensitive: bool = True
    # extra SimConfig overrides bundled with the scenario (e.g. rack-fault
    # or chaos-injector knobs); the sweep's explicit flags still win over
    # these
    sim_kwargs: Mapping[str, object] = field(default_factory=dict)

    def make_jobs(self, seed: int, n_jobs: Optional[int] = None) -> List[Job]:
        return self.make(seed, n_jobs or self.n_jobs)


_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(sc: Scenario) -> Scenario:
    if sc.name in _REGISTRY:
        raise ValueError(f"duplicate scenario name {sc.name!r}")
    _REGISTRY[sc.name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; "
            f"available: {', '.join(available_scenarios())}") from None


def available_scenarios() -> List[str]:
    return sorted(_REGISTRY)


def _with_arrivals(arrival_fn, mean_iat: float, seed_salt: int, **trace_kw):
    """Build a make() that draws arrivals from ``arrival_fn`` and composes
    jobs via generate_trace.  Separate RNG streams for arrivals vs. job
    attributes, so the same seed yields the same workload mix across
    scenarios (only the timing differs)."""
    def make(seed: int, n_jobs: int) -> List[Job]:
        rng = np.random.default_rng((seed_salt, seed))
        arrivals = arrival_fn(rng, n_jobs, mean_iat)
        return generate_trace(n_jobs, seed=seed, arrival_times=arrivals,
                              **trace_kw)
    return make


register_scenario(Scenario(
    "smoke", "tiny Poisson trace for CI smoke runs",
    lambda seed, n: generate_trace(n, lam_s=20.0, seed=seed,
                                   max_duration_s=600.0),
    fleet="a100:2", n_jobs=10))

register_scenario(Scenario(
    "hetero_smoke", "small heavy-tailed trace on a mixed a100+h100 fleet "
                    "(the CI cell for fleet-aware placement)",
    _with_arrivals(heavy_tail_arrivals, 30.0, seed_salt=505,
                   max_duration_s=2400.0, duration_sigma=1.6),
    fleet="a100:2+h100:2", n_jobs=16, placer="hetero-speed"))

register_scenario(Scenario(
    "poisson", "the paper's baseline arrival process",
    lambda seed, n: generate_trace(n, lam_s=45.0, seed=seed,
                                   max_duration_s=2400.0)))

register_scenario(Scenario(
    "bursty", "ON/OFF bursts of tightly-spaced arrivals",
    _with_arrivals(bursty_arrivals, 60.0, seed_salt=101,
                   max_duration_s=2400.0)))

register_scenario(Scenario(
    "diurnal", "sinusoidal-rate day/night demand cycle",
    _with_arrivals(diurnal_arrivals, 45.0, seed_salt=202,
                   max_duration_s=2400.0)))

register_scenario(Scenario(
    "heavy_tail", "Pareto arrivals + heavy-tailed job durations",
    _with_arrivals(heavy_tail_arrivals, 60.0, seed_salt=303,
                   max_duration_s=4800.0, duration_sigma=1.6)))

register_scenario(Scenario(
    "flash_crowd", "steady background plus a mid-trace arrival spike",
    _with_arrivals(flash_crowd_arrivals, 45.0, seed_salt=404,
                   max_duration_s=2400.0)))

register_scenario(Scenario(
    "mixed_qos", "Poisson with QoS floors, multi-instance and declared-"
                 "memory jobs in the mix",
    lambda seed, n: generate_trace(n, lam_s=45.0, seed=seed,
                                   max_duration_s=2400.0, qos_frac=0.3,
                                   multi_instance_frac=0.15,
                                   mem_constraint_frac=0.3)))

register_scenario(Scenario(
    "rack_outage", "correlated rack-level failures: racks of 2 GPUs fail "
                   "together (power/network domain), on top of the mixed "
                   "a100+h100 fleet",
    _with_arrivals(poisson_arrivals, 40.0, seed_salt=606,
                   max_duration_s=1800.0),
    fleet="a100:2+h100:2", n_jobs=14,
    sim_kwargs={"rack_size": 2, "rack_mtbf_s": 2400.0, "repair_s": 240.0,
                "ckpt_interval_s": 300.0}))


# ------------------------------------------------------- chaos scenarios
# Fault-injection settings (see repro.core.sim.faults): seeds vary the
# chaos schedule via the dedicated (seed, 0xFA17) fault stream even where
# the workload itself is fixed.

register_scenario(Scenario(
    "mps_blast", "chaos: phase-dependent crash shocks — a fault during an "
                 "MPS exploration window kills every co-resident, under "
                 "MIG exactly one slice (paper §2 containment asymmetry)",
    _with_arrivals(poisson_arrivals, 35.0, seed_salt=707,
                   max_duration_s=1800.0),
    fleet="a100:3+h100:1", n_jobs=16,
    sim_kwargs={"faults": ("mps_blast",), "mps_crash_mtbf_s": 900.0,
                "ckpt_interval_s": 240.0, "quarantine_faults": 2,
                "quarantine_window_s": 1800.0,
                "quarantine_repair_s": 600.0}))

# shared chaos knobs for the flaky-fleet ablation pair: blasts + flaky MIG
# reconfigs + persistent stragglers (recover_s far beyond the trace, so
# only a quarantine's hardware swap clears a straggler)
_FLAKY_FAULTS = {
    "faults": ("mps_blast", "flaky_reconfig", "straggler"),
    "mps_crash_mtbf_s": 1500.0,
    "reconfig_fail_p": 0.15, "reconfig_retry_s": 15.0,
    "reconfig_max_retries": 2,
    "straggler_mtbf_s": 700.0, "straggler_factor": 0.25,
    "straggler_recover_s": 100000.0,
    "ckpt_interval_s": 240.0, "repair_s": 480.0,
}

register_scenario(Scenario(
    "flaky_fleet", "chaos: blasts + flaky reconfigs + persistent "
                   "stragglers, health/quarantine machinery ON (repeated "
                   "faults evacuate via the migration primitive)",
    _with_arrivals(poisson_arrivals, 40.0, seed_salt=808,
                   max_duration_s=1800.0),
    fleet="a100:3+h100:1", n_jobs=16,
    sim_kwargs={**_FLAKY_FAULTS, "quarantine_faults": 2,
                "quarantine_window_s": 3600.0,
                "quarantine_repair_s": 480.0}))

register_scenario(Scenario(
    "flaky_fleet_noq", "ablation twin of flaky_fleet with quarantine + "
                       "migration OFF: degraded GPUs stay in service "
                       "(stragglers never clear, blast repeat-offenders "
                       "keep hosting jobs)",
    _with_arrivals(poisson_arrivals, 40.0, seed_salt=808,
                   max_duration_s=1800.0),
    fleet="a100:3+h100:1", n_jobs=16,
    sim_kwargs={**_FLAKY_FAULTS, "quarantine_faults": 0}))


# ------------------------------------------------------------ trace replay

def _replay_jobs(seed: int, n_jobs: int):
    """The committed Alibaba v2020 sample, sliced to the first ``n_jobs``
    expanded jobs.  Deterministic: the CSV fixes arrivals, sizes and QoS —
    ``seed`` is ignored by design, so every seed in a sweep grid replays
    the identical workload (seed still varies fault injection)."""
    from repro.core.traces_alibaba import load_alibaba_trace
    return load_alibaba_trace(limit_jobs=n_jobs)


def _synth_jobs(seed: int, n_jobs: int):
    """Synthetic jobs bootstrapped from the sample's empirical joint
    (size, duration, task) distribution and inter-arrival gaps."""
    from repro.core.traces_alibaba import synthesize_alibaba_trace
    return synthesize_alibaba_trace(n_jobs, seed=seed)


register_scenario(Scenario(
    "trace_replay", "replay of the committed Alibaba cluster-trace-gpu-"
                    "v2020 sample CSV (production arrival bursts, task-"
                    "class QoS tiers, multi-instance groups)",
    _replay_jobs, fleet="a100:12+h100:4", n_jobs=200, seed_sensitive=False))

register_scenario(Scenario(
    "trace_synth", "synthetic workload drawn from the Alibaba sample's "
                   "empirical size/duration/arrival distributions "
                   "(scales to arbitrary job counts)",
    _synth_jobs, fleet="a100:12+h100:4", n_jobs=200,
    placer="hetero-speed"))
