"""Event-driven cluster simulation engine.

The engine owns time: the event heap, the simulation clock, GPU failure /
repair injection, job accounting and metric collection.  Every *scheduling*
decision — queue discipline, placement, phase transitions after a timer,
reaction to completions — is delegated to the :class:`~repro.core.sim
.policies.Policy` named by ``SimConfig.policy`` (see
``repro/core/sim/policies/`` for the built-ins and how to add one).  The
*placement* choice within a policy's feasible GPUs is a further pluggable
layer: the :class:`~repro.core.sim.placement.Placer` named by
``SimConfig.placer`` (default ``least-loaded``, the paper's rule).

Fleets may be heterogeneous: pass ``fleet=`` (a list of
:class:`~repro.core.fleet.GPUSpec`, e.g. from ``fleet.parse_fleet
("a100:4+h100:4")``) and every GPU carries its own partition space,
performance model and estimator.  The legacy ``(space, pm, estimator)``
arguments build a homogeneous fleet and stay bit-identical to the
pre-fleet simulator; ``sim.space`` / ``sim.pm`` / ``sim.estimator`` remain
as the first spec's objects for homogeneous callers.

The *goal* of every partition decision is the third pluggable layer: the
:class:`~repro.core.sim.objectives.Objective` named by
``SimConfig.objective`` (``throughput`` — the paper's, bit-identical
default — / ``energy`` / ``edp``).  Each GPU integrates its wall power
(per-kind :class:`~repro.core.fleet.PowerModel`) into ``GPU.energy_j``;
the run's total lands in ``TraceMetrics.energy_j``.

Fault tolerance: optional Poisson GPU failures re-queue affected jobs with
progress rolled back to the last checkpoint *of the current placement*
(periodic ones every ``ckpt_interval_s`` of progressing time, plus any CKPT
phase the GPU actually executed); the destroyed work is speed-weighted, not
wall-clock.  The failed GPU is out for ``repair_s``.  The policy's normal
arrival path handles re-admission — job-level fault tolerance is the
scheduler itself.  ``rack_size`` / ``rack_mtbf_s`` add *correlated*
failures on top: whole racks of consecutive GPU ids go down in one event
(the power/network failure domain per-GPU Poisson faults cannot express).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.estimators import OracleEstimator
from repro.core.fleet import GPUSpec, homogeneous_fleet
from repro.core.jobs import Job
from repro.core.metrics import TraceMetrics, compute_metrics
from repro.core.partitions import PartitionSpace
from repro.core.perfmodel import PerfModel
from repro.core.sim.faults import FaultInjector, get_fault_injector
from repro.core.sim.gpu import (CKPT, DEGRADED, GPU, HEALTHY, IDLE, MIG_RUN,
                                MPS_PROF, QUARANTINED)
from repro.core.sim.index import FleetIndex, WorkAggregate
from repro.core.sim.soa import FleetState
from repro.core.sim.policies import get_policy


@dataclass
class SimConfig:
    n_gpus: int = 8
    policy: str = "miso"             # any name in policies.available_policies()
    placer: str = "least-loaded"     # any name in placement.available_placers()
    objective: str = "throughput"    # any name in objectives.available_objectives()
    static_partition: Tuple[int, ...] = (4, 2, 1)   # optsta only
    mps_level_time_s: float = 10.0   # per MPS level (paper: 10s x 3 levels)
    mig_reconfig_s: float = 4.0      # GPU reset (paper §3)
    ckpt_base_s: float = 2.0
    ckpt_bw_gbps: float = 4.0        # job state of mem_gb -> save+restore time
    overhead_scale: float = 1.0      # Fig 17 sensitivity knob
    mps_only_level: float = 0.33
    mps_only_max_jobs: int = 3
    max_sim_s: float = 10_000_000.0
    # fault injection
    gpu_mtbf_s: float = 0.0          # 0 = no failures
    repair_s: float = 600.0
    ckpt_interval_s: float = 600.0   # periodic checkpoint for fault rollback
    # correlated (rack-level) failures: racks of `rack_size` consecutive
    # GPU ids fail together at Poisson rate 1/rack_mtbf_s (both must be > 0)
    rack_size: int = 0
    rack_mtbf_s: float = 0.0
    # pluggable fault injectors (core/sim/faults.py) by registry name; the
    # default () enables nothing — no fault events exist, no fault RNG is
    # drawn, golden traces stay bit-identical (zero-overhead guarantee)
    faults: Tuple[str, ...] = ()
    mps_crash_mtbf_s: float = 0.0    # mps_blast: mean s between crash shocks
    reconfig_fail_p: float = 0.0     # flaky_reconfig: P(repartition op fails)
    reconfig_retry_s: float = 20.0   # base retry backoff, doubled per attempt
    reconfig_max_retries: int = 3    # exhausted retries = hard GPU fault
    straggler_mtbf_s: float = 0.0    # straggler: mean s between onsets
    straggler_factor: float = 0.5    # degraded speed multiplier while struck
    straggler_recover_s: float = 1800.0  # degradation clears after this
    estimator_fault_p: float = 0.0   # estimator_garbage: P(garbage window)
    # GPU health state machine (healthy -> degraded -> quarantined ->
    # repaired): `quarantine_faults` soft faults within `quarantine_window_s`
    # quarantine the GPU for `quarantine_repair_s`, migrating its residents
    # off via the checkpoint/rollback primitive.  0 = never quarantine.
    quarantine_faults: int = 0
    quarantine_window_s: float = 3600.0
    quarantine_repair_s: float = 1800.0
    seed: int = 0
    # profiling measurement noise (paper Fig 14): sigma of the relative error
    # on each MPS-matrix entry; drawn from the simulator RNG per window
    mps_noise_sigma: float = 0.0
    # collect per-component wall-clock (placement / Algorithm-1 / estimator /
    # event loop) into ClusterSim.prof; surfaced by `launch/sweep --profile`
    profile: bool = False


class ClusterSim:
    def __init__(self, jobs: Sequence[Job], cfg: SimConfig,
                 space: Optional[PartitionSpace] = None,
                 pm: Optional[PerfModel] = None, estimator=None,
                 fleet: Optional[Sequence[GPUSpec]] = None):
        if fleet is None:
            if space is None or pm is None:
                raise TypeError("ClusterSim needs either (space, pm) or fleet=")
            fleet = homogeneous_fleet(space, pm,
                                      estimator or OracleEstimator(pm),
                                      cfg.n_gpus)
        else:
            fleet = list(fleet)
            if cfg.n_gpus != len(fleet):
                # the fleet defines the cluster size; keep the caller's
                # config object untouched
                cfg = dataclasses.replace(cfg, n_gpus=len(fleet))
        self.cfg = cfg
        self.fleet: List[GPUSpec] = list(fleet)
        # homogeneous-compat defaults (first spec); per-GPU code must use
        # g.space / g.pm / g.estimator
        self.space = self.fleet[0].space
        self.pm = self.fleet[0].pm
        self.estimator = self.fleet[0].estimator
        self.jobs = {j.jid: j for j in jobs}
        self.queue: List[int] = []
        self.gpus = [GPU(i, self, spec) for i, spec in enumerate(self.fleet)]
        self.events: List[tuple] = []
        self.t = 0.0
        self.rng = np.random.default_rng(cfg.seed)
        # separate stream for profiling measurement noise: common random
        # numbers across sensitivity arms — varying mps_noise_sigma must not
        # perturb the failure-injection schedule drawn from self.rng
        self.noise_rng = np.random.default_rng((cfg.seed, 0xA100))
        # third dedicated stream, for the pluggable fault injectors
        # (core/sim/faults.py): enabling or tuning chaos must not perturb
        # the Poisson failure schedule in self.rng or the measurement noise
        # in self.noise_rng — and vice versa (CONTRIBUTING, determinism
        # contract)
        self.fault_rng = np.random.default_rng((cfg.seed, 0xFA17))
        self.profile_cache: Dict[tuple, Dict[int, float]] = {}  # (mi_group, space)
        self.completed: List[int] = []
        self._counter = itertools.count()
        # per-component wall-clock buckets (None = profiling off, the hot
        # paths check `prof is not None` and pay nothing)
        self.prof: Optional[Dict[str, float]] = (
            {"placement_s": 0.0, "alg1_s": 0.0, "estimator_s": 0.0,
             "total_s": 0.0, "events": 0.0} if cfg.profile else None)
        # -- placement hot-path structures (see repro.core.sim.index):
        # in-system remaining-work aggregate (hetero-speed split point) ...
        self.work_agg = WorkAggregate()
        self._resident_count = 0
        # ... cached up-set, invalidated on failure / repair promotion; the
        # down-heap drives promotions lazily as the clock passes down_until
        self._up_cache: Optional[List[GPU]] = None
        self._down_heap: List[Tuple[float, int]] = []
        # ... and the per-kind (count, max-addable-slice) fleet index; built
        # before the policy so its placer can bind to it
        self.index = FleetIndex(self)
        for g in self.gpus:
            self._refresh_feas(g)
            self.index.add(g)
        # fleet-wide SoA staging buffers for vectorized batch settles
        # (end-of-run, rollout snapshots); per-event paths never touch it
        self.fleet_state = FleetState(self.gpus)
        self.policy = get_policy(cfg.policy)(self)
        # -- robustness accounting (all zero when nothing ever faults):
        # destroyed work and recovery waits are Kahan-summed like the
        # in-system work aggregate; counters are plain ints
        self.fstats: Dict[str, float] = {
            "n_faults": 0, "n_blasts": 0, "blast_jobs": 0,
            "blast_radius_max": 0, "n_quarantines": 0, "n_migrations": 0,
            "n_reconfig_retries": 0, "n_estimator_faults": 0,
            "quarantine_gpu_s": 0.0,
        }
        self.lost_agg = WorkAggregate()    # work-seconds destroyed by faults
        self.recover_agg = WorkAggregate()  # fault-eviction -> re-place waits
        self._evict_t: Dict[int, float] = {}  # jid -> last fault-evict time
        # -- fault injectors: engine-side hooks are collected once so runs
        # without them pay a single empty-list check per hook point
        self.fault_injectors: Dict[str, FaultInjector] = {}
        self._reconfig_hooks: List[FaultInjector] = []
        self._est_hooks: List[FaultInjector] = []
        for name in cfg.faults:
            inj = get_fault_injector(name)(self)
            self.fault_injectors[name] = inj
            if type(inj).on_reconfig_end is not FaultInjector.on_reconfig_end:
                self._reconfig_hooks.append(inj)
            if type(inj).filter_estimates is not FaultInjector.filter_estimates:
                self._est_hooks.append(inj)

        for j in jobs:
            self._push(j.arrival, "arrival", j.jid)
        if cfg.gpu_mtbf_s > 0:
            for g in self.gpus:
                self._push(float(self.rng.exponential(cfg.gpu_mtbf_s)),
                           "failure", g.gid)
        if cfg.rack_mtbf_s > 0 and cfg.rack_size > 0:
            n_racks = (len(self.gpus) + cfg.rack_size - 1) // cfg.rack_size
            for r in range(n_racks):
                self._push(float(self.rng.exponential(cfg.rack_mtbf_s)),
                           "rack_failure", r)
        for inj in self.fault_injectors.values():
            inj.schedule_initial()

    # ---------------------------------------------------------- event glue

    def _push(self, t, kind, payload, stamp=0):
        heapq.heappush(self.events, (t, next(self._counter), kind, payload, stamp))

    def _schedule_gpu_events(self, g: GPU):
        g.stamp += 1
        phase = g.phase
        if phase == CKPT or phase == MPS_PROF:
            heapq.heappush(self.events, (g.phase_end, next(self._counter),
                                         "gpu_timer", g.gid, g.stamp))
        nc = g.next_completion()
        if nc:
            heapq.heappush(self.events, (nc[0], next(self._counter),
                                         "completion", (g.gid, nc[1]),
                                         g.stamp))

    # ---------------------------------------------------------- run loop

    def run(self) -> TraceMetrics:
        n_target = len(self.jobs)
        prof = self.prof
        t_run0 = time.perf_counter() if prof is not None else 0.0
        # hot-loop locals: the heap, the completion list and the clock cap
        # are bound once (none is ever rebound after __init__)
        events = self.events
        completed = self.completed
        gpus = self.gpus
        heappop = heapq.heappop
        max_sim_s = self.cfg.max_sim_s
        while events and len(completed) < n_target:
            t, _, kind, payload, stamp = heappop(events)
            if t > max_sim_s:
                break
            self.t = t
            if prof is not None:
                prof["events"] += 1.0
            # dispatch ordered by event frequency: stale-stamped timer /
            # completion entries dominate the heap traffic at scale
            if kind == "gpu_timer":
                g = gpus[payload]
                if stamp != g.stamp or t < g.phase_end - 1e-9:
                    continue
                self._dispatch_timer(t, g)
            elif kind == "completion":
                gid, jid = payload
                g = gpus[gid]
                if stamp != g.stamp:
                    continue
                g.advance(t)
                rj = g.jobs.get(jid)
                if rj is None or rj.job.remaining > 1e-6:
                    self._schedule_gpu_events(g)
                    continue
                self._dispatch_completion(t, g, rj.job)
            elif kind == "arrival":
                self._dispatch_arrival(t, payload)
            elif kind == "failure":
                self._on_failure(self.gpus[payload])
            elif kind == "rack_failure":
                self._on_rack_failure(payload)
            elif kind == "fault":
                # pluggable chaos (core/sim/faults.py): payload routes to
                # the owning injector, which handles and usually re-arms it
                name, data = payload
                self.fault_injectors[name].on_event(data)
            elif kind == "repair":
                self.policy.admit()
        # settle every GPU's accounting (and energy integral) to the final
        # clock; completed-job metrics are already fixed, so this only
        # extends idle/energy windows.  One masked vector update covers the
        # eligible rows (bit-identical to the scalar advance — see
        # core/sim/soa.py); the rest keep scalar operation order.
        self.fleet_state.settle_all(self.t)
        if prof is not None:
            prof["total_s"] += time.perf_counter() - t_run0
        return self.finish(settle=False)

    # ------------------------------------------------- stepping / batching
    # The same event bodies run() inlines, exposed one tick at a time so
    # BatchSim (core/sim/batch.py) can advance many replicas in lockstep.
    # run() stays the hot scalar path: the dispatchers below are only
    # called on *valid* timer/completion/arrival events, whose policy work
    # dwarfs one extra method call; stale-stamp traffic never leaves the
    # inline loop.

    def _dispatch_timer(self, t: float, g: GPU, collect: bool = False):
        """Process a valid gpu_timer event (plus its same-tick batch).

        ``collect=True`` (BatchSim) returns a :class:`PendingPhaseEnd`
        holding the policy's estimator work instead of finishing the tick,
        or True when the policy has no batchable work (processed inline)."""
        batch = self._drain_same_tick_timers(t, g)
        if collect:
            gs = [g] if batch is None else batch
            pend = self._collect_phase_end(gs)
            return True if pend is None else pend
        if batch is None:
            self.end_phase(g)
        else:
            self.end_phase_batch(batch)
        return True

    def _dispatch_completion(self, t: float, g: GPU, job: Job,
                             collect: bool = False):
        """Process a valid completion event (plus its same-tick batch);
        ``collect=True`` may return a :class:`PendingCompletion`."""
        batch = self._drain_same_tick_completions(t, g, job)
        if collect:
            items = [(g, job)] if batch is None else batch
            pend = self._collect_completions(items)
            return True if pend is None else pend
        if batch is None:
            self._on_completion(g, job)
        else:
            self._on_completion_batch(batch)
        return True

    def _dispatch_arrival(self, t: float, jid: int) -> None:
        # drain every further arrival stamped exactly t so the FCFS
        # admit runs once over the whole burst (trace replays carry
        # integer timestamps with heavy same-second bursts); for
        # FCFS this is literally the same placement sequence, and
        # queue-scanning disciplines (SRPT) see the full burst at
        # once — their intended semantics
        events = self.events
        prof = self.prof
        self._enqueue(self.jobs[jid])
        while events and events[0][0] == t and events[0][2] == "arrival":
            _, _, _, jid2, _ = heapq.heappop(events)
            if prof is not None:
                prof["events"] += 1.0
            self._enqueue(self.jobs[jid2])
        self.policy.admit()

    def step_event(self, collect: bool = False):
        """Advance the simulation by one *processed* event tick.

        Pops events exactly as :meth:`run` does (stale-stamped entries are
        skipped without returning) and processes the first valid one.
        Returns:

        * ``True`` — a tick was fully processed, more work may remain;
        * ``False`` — terminal: heap empty, all jobs completed, or the
          clock cap was passed (matching run()'s loop conditions);
        * a pending object (``collect=True`` only) — the tick's policy
          decisions were *collected* but not applied: the caller owns the
          estimate -> partition -> apply pipeline (see
          :class:`PendingPhaseEnd` / :class:`PendingCompletion`), which
          lets BatchSim fuse this work across replicas.
        """
        events = self.events
        gpus = self.gpus
        prof = self.prof
        n_target = len(self.jobs)
        max_sim_s = self.cfg.max_sim_s
        while events and len(self.completed) < n_target:
            t, _, kind, payload, stamp = heapq.heappop(events)
            if t > max_sim_s:
                return False
            self.t = t
            if prof is not None:
                prof["events"] += 1.0
            if kind == "gpu_timer":
                g = gpus[payload]
                if stamp != g.stamp or t < g.phase_end - 1e-9:
                    continue
                return self._dispatch_timer(t, g, collect)
            elif kind == "completion":
                gid, jid = payload
                g = gpus[gid]
                if stamp != g.stamp:
                    continue
                g.advance(t)
                rj = g.jobs.get(jid)
                if rj is None or rj.job.remaining > 1e-6:
                    self._schedule_gpu_events(g)
                    continue
                return self._dispatch_completion(t, g, rj.job, collect)
            elif kind == "arrival":
                self._dispatch_arrival(t, payload)
                return True
            elif kind == "failure":
                self._on_failure(gpus[payload])
                return True
            elif kind == "rack_failure":
                self._on_rack_failure(payload)
                return True
            elif kind == "fault":
                name, data = payload
                self.fault_injectors[name].on_event(data)
                return True
            elif kind == "repair":
                self.policy.admit()
                return True
        return False

    def run_until_collect(self):
        """Drain events inline — :meth:`run`'s hoisted hot loop — until a
        tick yields a pending collect batch, and return it.  Ticks whose
        policy has no batchable work are processed inline exactly as
        ``step_event(collect=True)`` would; returns None when the replica
        is terminal (heap empty, all jobs completed, or clock cap passed).

        This is BatchSim's per-round frontier: every live replica
        surrenders exactly one pending per round, so the cross-replica
        fusion batch is as wide as the batch itself while the per-event
        overhead stays at run()-loop level (no per-event method call).
        A replica whose policy never collects (no fusable hooks) runs to
        completion in one call — bit-identical to its scalar run."""
        events = self.events
        completed = self.completed
        gpus = self.gpus
        heappop = heapq.heappop
        prof = self.prof
        n_target = len(self.jobs)
        max_sim_s = self.cfg.max_sim_s
        while events and len(completed) < n_target:
            t, _, kind, payload, stamp = heappop(events)
            if t > max_sim_s:
                return None
            self.t = t
            if prof is not None:
                prof["events"] += 1.0
            if kind == "gpu_timer":
                g = gpus[payload]
                if stamp != g.stamp or t < g.phase_end - 1e-9:
                    continue
                r = self._dispatch_timer(t, g, collect=True)
                if r is not True:
                    return r
            elif kind == "completion":
                gid, jid = payload
                g = gpus[gid]
                if stamp != g.stamp:
                    continue
                g.advance(t)
                rj = g.jobs.get(jid)
                if rj is None or rj.job.remaining > 1e-6:
                    self._schedule_gpu_events(g)
                    continue
                r = self._dispatch_completion(t, g, rj.job, collect=True)
                if r is not True:
                    return r
            elif kind == "arrival":
                self._dispatch_arrival(t, payload)
            elif kind == "failure":
                self._on_failure(gpus[payload])
            elif kind == "rack_failure":
                self._on_rack_failure(payload)
            elif kind == "fault":
                name, data = payload
                self.fault_injectors[name].on_event(data)
            elif kind == "repair":
                self.policy.admit()
        return None

    def _collect_phase_end(self, gs: List[GPU]):
        """Collect-mode twin of :meth:`end_phase_batch`: same reconfig
        filter and pre-phase accounting, but the policy's estimator windows
        are *collected* for cross-replica fusion instead of being estimated
        here.  Returns None when the policy has nothing to fuse (the whole
        tick was processed inline, exactly as end_phase_batch would)."""
        if self._reconfig_hooks:
            gs = [g for g in gs
                  if not (g.phase == CKPT and self._reconfig_failed(g))]
            if not gs:
                return None
        for g in gs:
            self._pre_phase_end(g)
        work = self.policy.collect_phase_end(gs)
        if work is None:
            # policy has no batchable estimator work this tick (or does not
            # support collection): fall through to the scalar batch path
            self.policy.on_phase_end_batch(gs)
            for g in gs:
                self.finalize(g)
            return None
        return PendingPhaseEnd(self, gs, work)

    def _collect_completions(self, items: List[Tuple[GPU, Job]]):
        """Collect-mode twin of :meth:`_on_completion_batch`: completion
        accounting runs now; the policy's repartition decisions are
        collected for cross-replica fusion.  Returns None when the policy
        does not support collection (tick processed inline) or had no
        decisions to make (finalize/admit run inline)."""
        for g, job in items:
            self._finish(g, job)
        decisions = self.policy.collect_completion(items)
        if decisions is None:
            self.policy.on_completion_batch(items)
            for g, _ in items:
                self.finalize(g)
            self.policy.admit()
            return None
        if not decisions:
            for g, _ in items:
                self.finalize(g)
            self.policy.admit()
            return None
        return PendingCompletion(self, items, decisions)

    def finish(self, settle: bool = True) -> TraceMetrics:
        """Final accounting + metric collection (end of run()/BatchSim).
        ``settle=False`` is for callers that already settled the fleet to
        ``self.t`` (run()'s tail, BatchSim's batched settle)."""
        if settle:
            self.fleet_state.settle_all(self.t)
        fs = self.fstats
        if fs["n_quarantines"]:
            # a quarantine still open at the final clock only occupied the
            # fleet up to that clock, not its whole repair window
            fs["quarantine_gpu_s"] -= sum(
                g.down_until - self.t for g in self.gpus
                if g.health == QUARANTINED and g.down_until > self.t)
        return compute_metrics([self.jobs[i] for i in self.completed],
                               self.cfg.n_gpus,
                               energy_j=float(sum(g.energy_j
                                                  for g in self.gpus)),
                               energy_span_s=self.t,
                               fault_stats={
                                   **fs,
                                   "work_lost_s": self.lost_agg.total,
                                   "recover_s_total": self.recover_agg.total,
                                   "n_recovered": self.recover_agg.count})

    # ----------------------------------------------- placement constraints
    # Shared feasibility checks usable by any policy's pick_gpu; all are
    # evaluated against the candidate GPU's own space / perf model.

    def _sync_up(self):
        """Promote repaired GPUs back into the in-service structures once
        the clock passes their ``down_until``.  Entries whose GPU failed
        again while down (``down_until`` extended, a fresh entry pushed) or
        was already promoted are stale and skipped."""
        heap = self._down_heap
        t = self.t
        while heap and heap[0][0] <= t:
            _, gid = heapq.heappop(heap)
            g = self.gpus[gid]
            if g._in_index or t < g.down_until:
                continue
            if g.health != HEALTHY:
                # repairs are full repairs: a quarantined (or degraded-then-
                # failed) GPU comes back healthy
                g.health = HEALTHY
            self._refresh_feas(g)
            self.index.add(g)
            self._up_cache = None

    def up_gpus(self):
        """GPUs currently in service (not failed / under repair).  Cached:
        the up-set only changes at failure events and ``down_until``
        boundaries, both of which invalidate it — not on every call."""
        self._sync_up()
        if self._up_cache is None:
            self._up_cache = [g for g in self.gpus if self.t >= g.down_until]
        return self._up_cache

    def _refresh_feas(self, g: GPU):
        """Recompute ``g._max_add``: the largest menu slice a new job could
        still require with ``g``'s residents feasibly re-partitioned around
        it (0 = nothing fits).  ``PartitionSpace.placeable`` is monotone in
        the added requirement, so for memory-monotone menus
        ``spare_slice_ok(g, job) == (min_required_slice(job) <= _max_add)``
        — which is what lets the fleet index prune whole buckets instead of
        running ``feasible_exact`` per GPU.  Non-monotone menus (no shipped
        space) get ``None``: never pruned, always exact-checked."""
        space = g.space
        if not space._mem_monotone:
            g._max_add = None
            return
        if len(g.jobs) >= space.max_jobs:
            g._max_add = 0
            return
        reqs = []
        for rj in g.jobs.values():
            r = space.job_required_slice(rj.job)
            if r is None:                # unplaceable resident (forced state):
                g._max_add = 0           # nothing more fits for sure
                return
            reqs.append(r)
        key = tuple(sorted(reqs))
        cached = space._max_add_cache.get(key)
        if cached is not None:
            g._max_add = cached
            return
        best = 0
        for s in space.sizes:            # sizes are stored descending
            if space.placeable(reqs + [s]):
                best = s
                break
        if len(space._max_add_cache) >= 65536:
            space._max_add_cache.pop(next(iter(space._max_add_cache)))
        space._max_add_cache[key] = best
        g._max_add = best

    def _resident_changed(self, g: GPU):
        """Re-bucket ``g`` after its resident set changed (in-service GPUs
        only; failed ones re-enter via the repair promotion)."""
        if g._in_index:
            self._refresh_feas(g)
            self.index.update(g)

    def remove_resident(self, g: GPU, jid: int):
        """Remove one resident from ``g`` keeping the placement index and
        resident accounting consistent.  Policies must route evictions
        through this instead of ``del g.jobs[jid]``."""
        rj = g._pop_resident(jid)
        if rj.job.phases:
            g._n_phased -= 1
        g._spd_dirty = True
        self._resident_count -= 1
        self._resident_changed(g)

    def mem_ok(self, g: GPU, job: Job, exclude: Optional[int] = None) -> bool:
        if exclude is None:
            # resident memory sum cached on the speed-key identity chain: a
            # changed resident set always re-keys refresh_speeds before the
            # next placement scan, and the recompute below runs in dict
            # order — bit-identical to summing fresh on every call
            if g._mem_key is g._spd_key:
                total = g._mem_total
            else:
                total = sum(rj.job.profile.mem_gb
                            for rj in g.jobs.values())
                g._mem_total = total
                g._mem_key = g._spd_key
        else:
            total = sum(rj.job.profile.mem_gb for jid, rj in g.jobs.items()
                        if jid != exclude)
        return total + job.profile.mem_gb <= g.pm.hw.mem_gb

    def spare_slice_ok(self, g: GPU, job: Job,
                       exclude: Optional[int] = None) -> bool:
        """'Maximum spare slice' check (paper §4.3): after adding the job,
        some valid partition must give every job a memory- AND QoS-feasible
        slice.  ``exclude`` ignores one resident jid (what-if for
        preemption).

        The check is *exact* and vectorized: each job's (memory, QoS) pair
        collapses to one scalar slice requirement
        (:meth:`PartitionSpace.min_required_slice`), compared in one pass
        against the space's precomputed per-length sorted-size matrix.  The
        historical biggest-memory-first greedy missed feasible placements
        when a small-memory job carried a large QoS floor (e.g. mem=1 GB
        qos_min_slice=4 next to mem=10 GB qos=0 on partition (4, 2))."""
        space = g.space
        mems = [max(job.profile.mem_gb, job.min_mem_gb)]
        qoss = [job.qos_min_slice]
        for jid, rj in g.jobs.items():
            if jid != exclude:
                mems.append(max(rj.job.profile.mem_gb, rj.job.min_mem_gb))
                qoss.append(rj.job.qos_min_slice)
        return space.feasible_exact(mems, qoss)

    # ------------------------------------------------------ job lifecycle

    def _enqueue(self, job: Job):
        job.queue_since = self.t
        self.queue.append(job.jid)
        self.work_agg.add(job.remaining)

    def _on_arrival(self, job: Job):
        # multi-instance clones are expanded by traces.expand_multi_instance;
        # clones share an mi_group so the MPS profile is measured only once.
        self._enqueue(job)
        self.policy.admit()

    def place(self, g: GPU, job: Job):
        """Land ``job`` on ``g`` (accounting + policy phase setup)."""
        g.advance(self.t)
        if job.start_time is None:
            job.start_time = self.t
        job.t_queue += max(0.0, self.t - job.queue_since)
        if self._evict_t:
            # time-to-recover: the wait between a fault eviction (failure /
            # blast / migration) and this re-placement
            t0 = self._evict_t.pop(job.jid, None)
            if t0 is not None:
                self.recover_agg.add(self.t - t0)
        g._add_resident(job)
        if job.phases:
            g._n_phased += 1
        g._spd_dirty = True
        self._resident_count += 1
        self._resident_changed(g)
        self.policy.on_place(g, job)
        self.finalize(g)

    def _drain_same_tick_timers(self, t: float, first: GPU):
        """Pop every further *valid* gpu_timer event stamped exactly ``t``
        off the heap so their phase ends process as one batch (the fused
        estimator service feeds all same-tick MPS windows through a single
        predictor forward).  Safe because a GPU's phase end never touches
        another GPU's state: validity checked at drain time equals validity
        checked after processing the earlier timers, and at most one timer
        per GPU can carry its current stamp.  Returns None when ``first`` is
        alone at this tick."""
        batch = None
        events = self.events
        while events and events[0][0] == t and events[0][2] == "gpu_timer":
            _, _, _, payload, stamp = heapq.heappop(events)
            g2 = self.gpus[payload]
            if stamp != g2.stamp or t < g2.phase_end - 1e-9:
                continue
            if batch is None:
                batch = [first]
            batch.append(g2)
        return batch

    def end_phase(self, g: GPU, schedule: bool = True):
        """A phase window on ``g`` expired; let the policy transition the
        state machine.  ``schedule=False`` suppresses event scheduling for
        callers that finalize the GPU themselves right after (e.g. the
        zero-dead-time checkpoint in MISO's ``begin_profiling`` — such
        instant transitions are not reconfigure ops and skip the fault
        hook)."""
        if schedule and self._reconfig_hooks and g.phase == CKPT \
                and self._reconfig_failed(g):
            return
        self._pre_phase_end(g)
        self.policy.on_phase_end(g)
        self.finalize(g, schedule=schedule)

    def end_phase_batch(self, gs: Sequence[GPU]):
        """Process several same-tick phase ends as one policy batch.  The
        accounting before and the finalize after bracket the policy hook per
        GPU exactly as back-to-back :meth:`end_phase` calls would (phase
        ends are cross-GPU independent; event counters are consumed only by
        the finalize loop, in the same order)."""
        if self._reconfig_hooks:
            gs = [g for g in gs
                  if not (g.phase == CKPT and self._reconfig_failed(g))]
            if not gs:
                return
        for g in gs:
            self._pre_phase_end(g)
        self.policy.on_phase_end_batch(gs)
        for g in gs:
            self.finalize(g)

    def _reconfig_failed(self, g: GPU) -> bool:
        """Give enabled injectors a shot at failing the reconfigure op that
        ends a CKPT window (transient MIG-reconfiguration faults).  True
        means the op failed: the injector already rescheduled the retry (or
        escalated to a hard fault) and the phase end must not proceed — in
        particular the in-flight checkpoint is NOT durable (no since_ckpt
        reset), matching the mid-save failure semantics in ``GPU.advance``."""
        for inj in self._reconfig_hooks:
            if inj.on_reconfig_end(g):
                return True
        return False

    def _pre_phase_end(self, g: GPU):
        g.advance(self.t)
        if g.phase == CKPT:
            # the checkpoint window ran to completion: the save is durable,
            # so resident jobs have nothing left at risk
            g.reset_ckpt_marks()

    def _drain_same_tick_completions(self, t: float, first: GPU,
                                     first_job: Job):
        """Pop every further *valid* completion event stamped exactly ``t``
        so the policies' completion reactions batch (MISO re-optimizes every
        affected GPU through one Algorithm-1 pass) and the queue is admitted
        once for the whole tick.  Only contiguous completion events are
        taken — interleaved other-kind events keep their heap order — and at
        most one completion per GPU can be valid (``next_completion``
        schedules only the earliest; a same-tick follow-up is rescheduled by
        the finalize and drains on the next loop iteration).  Returns None
        when ``first`` is alone at this tick."""
        batch = None
        events = self.events
        prof = self.prof
        while events and events[0][0] == t and events[0][2] == "completion":
            _, _, _, (gid, jid), stamp = heapq.heappop(events)
            if prof is not None:
                prof["events"] += 1.0
            g2 = self.gpus[gid]
            if stamp != g2.stamp:
                continue
            g2.advance(t)
            rj = g2.jobs.get(jid)
            if rj is None or rj.job.remaining > 1e-6:
                self._schedule_gpu_events(g2)
                continue
            if batch is None:
                batch = [(first, first_job)]
            batch.append((g2, rj.job))
        return batch

    def _finish(self, g: GPU, job: Job):
        """Shared completion accounting (single and batched paths)."""
        job.finish_time = self.t
        self.work_agg.discard(job.remaining)
        job.remaining = 0.0
        self.remove_resident(g, job.jid)
        g.estimates.pop(job.jid, None)
        self.completed.append(job.jid)

    def _on_completion(self, g: GPU, job: Job):
        self._finish(g, job)
        self.policy.on_completion(g, job)
        self.finalize(g)
        self.policy.admit()

    def _on_completion_batch(self, items: Sequence[Tuple[GPU, Job]]):
        """Several same-tick completions on distinct GPUs: account them all,
        let the policy react once (batched Algorithm-1 across the affected
        GPUs), then finalize each GPU and admit the queue once."""
        for g, job in items:
            self._finish(g, job)
        self.policy.on_completion_batch(items)
        for g, _ in items:
            self.finalize(g)
        self.policy.admit()

    # ------------------------------------------- failures, faults & health

    def _on_failure(self, g: GPU):
        # an independent failure landing on a GPU already down (rack outage
        # or an earlier fault) is absorbed: it must not restart the repair
        # clock, re-evacuate an empty GPU or push a second live heap entry —
        # the same guard the rack path applies.  The next-failure draw still
        # happens, so the Poisson schedule is unchanged either way.
        if self.t >= g.down_until:
            self.record_fault(g, hard=True)
            self._fail_gpu(g)
        if self.cfg.gpu_mtbf_s > 0:
            self._push(self.t + float(self.rng.exponential(self.cfg.gpu_mtbf_s)),
                       "failure", g.gid)

    def _on_rack_failure(self, rack: int):
        """Correlated failure: every in-service GPU of ``rack`` (a block of
        ``cfg.rack_size`` consecutive ids) goes down at once — the
        rack-level power/network event per-GPU Poisson faults cannot model.
        Already-down members stay on their existing repair clock."""
        lo = rack * self.cfg.rack_size
        for g in self.gpus[lo:lo + self.cfg.rack_size]:
            if self.t >= g.down_until:
                self.record_fault(g, hard=True)
                self._fail_gpu(g)
        self._push(self.t + float(self.rng.exponential(self.cfg.rack_mtbf_s)),
                   "rack_failure", rack)

    def record_fault(self, g: GPU, hard: bool = False) -> bool:
        """Account one fault on ``g`` and drive the health state machine
        (healthy -> degraded -> quarantined).  ``hard`` faults — outright
        GPU/rack failures, which already pay a full repair window — are
        counted but do not feed the quarantine tracker.  Returns True when
        this fault tipped ``g`` into quarantine (its residents are already
        migrated off and the GPU is down)."""
        self.fstats["n_faults"] += 1
        if hard:
            return False
        cfg = self.cfg
        g.fault_times.append(self.t)
        lo = self.t - cfg.quarantine_window_s
        while g.fault_times and g.fault_times[0] < lo:
            g.fault_times.pop(0)
        if (cfg.quarantine_faults > 0
                and len(g.fault_times) >= cfg.quarantine_faults
                and self.t >= g.down_until):
            self._quarantine(g)
            return True
        if g.health == HEALTHY:
            g.health = DEGRADED
        return False

    def _quarantine(self, g: GPU):
        """Too many faults inside the window: migrate every resident off
        ``g`` (checkpoint/rollback primitive) and take it out of service
        for ``cfg.quarantine_repair_s`` through the same down machinery
        plain failures use; the repair promotion restores it to healthy."""
        fs = self.fstats
        fs["n_quarantines"] += 1
        fs["quarantine_gpu_s"] += self.cfg.quarantine_repair_s
        self.migrate_residents(g)
        g.fault_times = []
        self._take_down(g, self.cfg.quarantine_repair_s)
        g.health = QUARANTINED
        # unlike plain failures (whose victims wait for the next admit),
        # evacuation is a deliberate scheduling action: re-place now
        self.policy.admit()

    def migrate_residents(self, g: GPU) -> int:
        """Migration primitive (quarantine evacuation today; live migration
        / defragmentation tomorrow): checkpoint-roll every resident of
        ``g`` back and requeue it at the head in placement order.  A
        migrating job pays exactly its since-last-checkpoint work — the
        same price a failure charges — and its re-placement wait lands in
        the time-to-recover metric.  Returns the number migrated; ``g``
        stays in service (callers that also fail the GPU take it down
        themselves)."""
        n = len(g.jobs)
        if n:
            self.fstats["n_migrations"] += n
            self._evacuate_residents(g)
            g.phase = IDLE
            g.partition = ()
            self._resident_changed(g)
            self.finalize(g)
        return n

    def crash_jobs(self, g: GPU, jids: Sequence[int]):
        """Fault-kill specific residents of ``g`` (MPS blast radius / MIG
        slice containment): each victim rolls back to its last placement
        checkpoint and requeues at the head in placement order; ``g`` stays
        in service and the policy reshapes the survivors
        (``Policy.on_fault_evict``)."""
        g.advance(self.t)
        victims = set(jids)
        requeued = []
        for jid in list(g.jobs):
            if jid not in victims:
                continue
            rj = g.jobs[jid]
            job = rj.job
            rolled = min(job.work, job.remaining + rj.since_ckpt_work)
            self.work_agg.shift(rolled - job.remaining)
            self.lost_agg.shift(rolled - job.remaining)
            job.remaining = rolled
            job.queue_since = self.t
            self._evict_t[jid] = self.t
            requeued.append(jid)
            if job.phases:
                g._n_phased -= 1
            g._pop_resident(jid)
            g.estimates.pop(jid, None)
        self.queue[:0] = requeued
        self._resident_count -= len(requeued)
        g._spd_dirty = True
        self._resident_changed(g)
        self.policy.on_fault_evict(g)
        self.finalize(g)
        self.policy.admit()

    def _evacuate_residents(self, g: GPU):
        """Checkpoint-rollback eviction shared by failures, quarantine and
        migration: every resident loses its since-last-checkpoint work
        (speed-weighted, never wall-clock seconds and never cumulative
        t_run across earlier placements), is requeued at the head without
        reversing placement order, and starts a time-to-recover clock."""
        g.advance(self.t)
        if not g.jobs:
            return
        requeued = []
        for rj in g.jobs.values():
            job = rj.job
            rolled = min(job.work, job.remaining + rj.since_ckpt_work)
            self.work_agg.shift(rolled - job.remaining)
            self.lost_agg.shift(rolled - job.remaining)
            job.remaining = rolled
            job.queue_since = self.t
            self._evict_t[job.jid] = self.t
            requeued.append(job.jid)
        self.queue[:0] = requeued
        self._resident_count -= len(g.jobs)
        g._clear_residents()
        g._n_phased = 0
        g._spd_dirty = True
        g.estimates.clear()

    def _take_down(self, g: GPU, repair_s: float):
        """Out of service for ``repair_s``, shared by failures and
        quarantine.  Repairs are full repairs: straggler degradation and
        any in-flight reconfig retry clear with the hardware swap."""
        g.phase = IDLE
        g.partition = ()
        g.speed_fault = 1.0
        g.sched_ok = True
        g.reconfig_tries = 0
        # mutations here bypass refresh_speeds: break the speed/watts/memory
        # validity chains so the next refresh and advance recompute
        g._spd_dirty = True
        g._spd_key = object()
        g.down_until = self.t + repair_s
        g.stamp += 1
        # out of service: drop from the fleet index and the up-set cache;
        # _sync_up promotes it back once the clock passes down_until (a
        # re-failure while down just leaves a stale, skipped heap entry)
        self.index.remove(g)
        self._up_cache = None
        heapq.heappush(self._down_heap, (g.down_until, g.gid))
        self._push(g.down_until, "repair", g.gid, g.stamp)

    def _fail_gpu(self, g: GPU):
        """Take ``g`` down now: roll resident jobs back to their last
        placement checkpoint, requeue them at the head, schedule the
        repair.  Shared by independent failures, rack outages and
        exhausted reconfig retries."""
        self._evacuate_residents(g)
        self._take_down(g, self.cfg.repair_s)

    def filter_estimates(self, g: GPU, jids: Sequence[int], ests):
        """Give enabled estimator-fault injectors a chance to corrupt the
        freshly-produced slice-speed estimates (no-op list when no injector
        hooks the point — the zero-overhead path)."""
        for inj in self._est_hooks:
            ests = inj.filter_estimates(g, jids, ests)
        return ests

    # ---------------------------------------------------------- common

    def finalize(self, g: GPU, schedule: bool = True):
        g.refresh_speeds()
        if schedule:
            self._schedule_gpu_events(g)


class PendingPhaseEnd:
    """A collected same-tick phase-end batch awaiting its estimator pass.

    Produced by ``step_event(collect=True)`` when the tick's policy has MPS
    windows to estimate.  The owner (BatchSim) runs the pipeline in stages
    so the expensive middle fuses across replicas:

    1. ``work`` — :class:`~repro.core.sim.policies.base.EstimateWork` items
       whose ``ests`` the owner fills via one fused ``estimate_batch`` per
       estimator object (stage A);
    2. :meth:`apply` — store the estimates / run the non-MPS transitions in
       scalar order and collect
       :class:`~repro.core.sim.policies.base.RepartDecision` items, whose
       ``choice`` the owner fills via fused ``optimize_partition_batch``
       calls (stages B/C);
    3. :meth:`finish` — apply the solved choices and finalize the GPUs,
       completing the tick exactly as ``end_phase_batch`` would (stage D).
    """

    __slots__ = ("sim", "gs", "work", "decisions")
    kind = "phase_end"

    def __init__(self, sim: "ClusterSim", gs: List[GPU], work: list):
        self.sim = sim
        self.gs = gs
        self.work = work
        self.decisions: list = []

    def apply(self) -> list:
        self.decisions = self.sim.policy.apply_phase_end(self.gs, self.work)
        return self.decisions

    def finish(self) -> None:
        sim = self.sim
        pol = sim.policy
        for d in self.decisions:
            pol.apply_decision(d)
        for g in self.gs:
            sim.finalize(g)


class PendingCompletion:
    """A collected same-tick completion batch awaiting its partition pass.

    Completion accounting and the policy's non-repartition side effects
    already ran; ``decisions`` (non-empty) awaits fused Algorithm-1 solves.
    :meth:`finish` applies the solved choices, finalizes the affected GPUs
    and admits the queue once — the tail of ``_on_completion_batch``."""

    __slots__ = ("sim", "items", "decisions")
    kind = "completion"

    def __init__(self, sim: "ClusterSim", items: List[Tuple[GPU, Job]],
                 decisions: list):
        self.sim = sim
        self.items = items
        self.decisions = decisions

    def apply(self) -> list:
        return self.decisions

    def finish(self) -> None:
        sim = self.sim
        pol = sim.policy
        for d in self.decisions:
            pol.apply_decision(d)
        for g, _ in self.items:
            sim.finalize(g)
        pol.admit()


def simulate(jobs, cfg: SimConfig, space: Optional[PartitionSpace] = None,
             pm: Optional[PerfModel] = None, estimator=None,
             fleet: Optional[Sequence[GPUSpec]] = None) -> TraceMetrics:
    import copy
    jobs = copy.deepcopy(list(jobs))
    return ClusterSim(jobs, cfg, space, pm, estimator, fleet=fleet).run()
