"""Replica-batched engine: lockstep execution of many sim replicas.

A parameter sweep, a seed ensemble or an RL rollout wants B *independent*
simulations — same fleet shape, different seeds / policies / knobs.  Run
serially, each replica pays the per-event Python overhead alone and every
estimator forward / Algorithm-1 solve ships one request.  :class:`BatchSim`
advances all B replicas in lockstep rounds instead:

* each replica's event heap is drained through
  ``ClusterSim.run_until_collect()`` — the scalar engine's own hoisted
  hot loop, run to the replica's next *collectible* tick, so each round's
  frontier is one pending decision batch per live replica (finished
  replicas are masked out) and non-decision events cost exactly what the
  scalar engine pays for them;
* ticks whose policy work is fusable come back as pending objects
  (:class:`~repro.core.sim.engine.PendingPhaseEnd` /
  :class:`~repro.core.sim.engine.PendingCompletion`) instead of being
  processed inline, and the round funnels the work of ALL replicas through
  the fused services:

  - **stage A** — every collected MPS window, grouped by estimator object,
    goes through one ``estimate_batch`` call: a single stacked
    ``(sum B_i, levels, jobs)`` predictor forward for the whole round;
  - **stage B** — each pending resumes its tick (store estimates, run
    non-profiling transitions) and surrenders its repartition decisions;
  - **stage C** — decisions grouped by (partition space, power model,
    objective) solve through one stacked-DP ``optimize_partition_batch``
    per group, with the scalar ``optimize_partition`` fallback per
    infeasible element and the policy's own ``choose_partition`` for
    policies that override it;
  - **stage D** — each pending applies its solved choices and finalizes,
    completing the tick exactly as the scalar engine would.

Bit-identity: replicas share nothing mutable but deterministic pure caches
(optimizer memo, space feasibility caches) whose values are
order-independent, every noise draw happens at collect time inside its own
replica in event order, and the fused services are element-exact twins of
their scalar counterparts — so each replica's metrics are bit-identical to
running it alone through ``ClusterSim.run()``.  ``tests/test_batch.py``
holds that property over the golden traces.

Cross-replica fusion needs shared spec objects: build replicas against the
same ``GPUSpec`` list (the sweep runner's fleet cache already does this)
or grouping keys degenerate to one group per replica — still correct,
just unfused.

The :meth:`BatchSim.step` / :meth:`BatchSim.observe` pair is the
vectorized-environment surface for learned scheduling (a future GPUJobEnv):
step advances every live replica to its next decision point (the natural
environment granularity — between decisions there is nothing to act on),
observe exports replica-major ``(B, G)`` fleet scalars and ``(B, G, S)``
resident columns.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Optional, Sequence

import numpy as np

from repro.core.metrics import TraceMetrics
from repro.core.optimizer import optimize_partition, optimize_partition_batch
from repro.core.sim.policies.base import EstimateWork, Policy, RepartDecision
from repro.core.sim.soa import settle_rows

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.core.sim.engine import ClusterSim
    from repro.core.sim.gpu import GPU


class BatchFleetState:
    """Replica-major view over B fleets: rows are ``(B, G)`` flattened.

    Per-GPU state stays canonical on the :class:`GPU` objects (exactly the
    single-replica SoA contract in ``core/sim/soa.py``); this class owns
    the cross-replica batch barriers: the per-replica-clock settle and the
    ``(B, G)`` / ``(B, G, S)`` array exports for vectorized consumers.
    """

    __slots__ = ("sims", "gpus", "b", "g", "idle_w")

    def __init__(self, sims: Sequence["ClusterSim"]):
        if not sims:
            raise ValueError("BatchFleetState needs at least one replica")
        self.sims = list(sims)
        g0 = len(self.sims[0].gpus)
        for s in self.sims[1:]:
            if len(s.gpus) != g0:
                raise ValueError(
                    f"replica fleet shapes differ: {len(s.gpus)} vs {g0} "
                    f"GPUs (BatchSim requires one shape across the batch)")
        self.b = len(self.sims)
        self.g = g0
        # replica-major flatten: row b*G + g is replica b's GPU g
        self.gpus: List["GPU"] = [g for s in self.sims for g in s.gpus]
        self.idle_w = np.array([g._idle_w for g in self.gpus])

    def settle_all(self,
                   free_min: Optional[int] = None,
                   occ_min: Optional[int] = None) -> None:
        """Settle every replica's fleet to that replica's clock in one
        ``settle_rows`` pass over all ``B*G`` rows (per-row target times).
        Work-aggregate shifts land on each row's own replica, in gid order
        within it — bit-identical to per-replica ``settle_all`` calls."""
        ts: List[float] = []
        for s in self.sims:
            ts.extend([s.t] * len(s.gpus))
        settle_rows(self.gpus, ts, idle_w=self.idle_w,
                    free_min=free_min, occ_min=occ_min)

    def scalars(self) -> Dict[str, np.ndarray]:
        """Snapshot the per-GPU fleet scalars as ``(B, G)`` arrays."""
        n = len(self.gpus)
        shape = (self.b, self.g)
        out = {}
        for name in ("last_update", "down_until", "energy_j"):
            out[name] = np.fromiter(
                (getattr(g, name) for g in self.gpus),
                dtype=np.float64, count=n).reshape(shape)
        return out

    def resident_matrix(self) -> Dict[str, np.ndarray]:
        """Export the per-resident SoA columns as replica-major
        ``(B, G, S)`` arrays (``S`` = widest resident count anywhere in the
        batch; ``mask`` marks occupied slots).  Read-only bridge for
        vectorized consumers — never feeds back into simulation state."""
        gpus = self.gpus
        s = max((len(g._rjobs) for g in gpus), default=0)
        shape = (self.b, self.g, max(s, 1))
        speed = np.zeros(shape)
        ck_t = np.zeros(shape)
        ck_w = np.zeros(shape)
        remaining = np.zeros(shape)
        mask = np.zeros(shape, dtype=bool)
        for i, g in enumerate(gpus):
            k = len(g._rjobs)
            if not k:
                continue
            b, gg = divmod(i, self.g)
            speed[b, gg, :k] = g._spd
            ck_t[b, gg, :k] = g._ckt
            ck_w[b, gg, :k] = g._ckw
            # replica-major gather — MS110 recognizes this subscript-store
            # pattern in batch.py; <=7 slots per row (the soa.py bound)
            remaining[b, gg, :k] = [rj.job.remaining for rj in g._rjobs]
            mask[b, gg, :k] = True
        return {"speed": speed, "since_ckpt_t": ck_t,
                "since_ckpt_work": ck_w, "remaining": remaining,
                "mask": mask}


class BatchSim:
    """Advance B independent :class:`ClusterSim` replicas in lockstep.

    Replicas must share one fleet shape (GPU count); seeds, policies,
    placers, objectives and workloads may differ per replica.  Callers own
    job-list isolation (each replica needs its own ``Job`` objects, as
    ``simulate`` guarantees via deepcopy).
    """

    def __init__(self, sims: Sequence["ClusterSim"]):
        self.sims: List["ClusterSim"] = list(sims)
        self.fleet_state = BatchFleetState(self.sims)
        self.done: List[bool] = [False] * len(self.sims)
        self.rounds = 0

    @property
    def b(self) -> int:
        return len(self.sims)

    # ------------------------------------------------------------ stepping

    def step(self) -> bool:
        """One lockstep round: every live replica drains its event heap to
        its next collectible tick (``ClusterSim.run_until_collect`` — the
        scalar hot loop, so non-decision events cost exactly what they cost
        the scalar engine) and surrenders one pending batch; the fusable
        work of all of them then runs through the staged services.  A
        replica with no pending left is done.  Returns True while any
        replica remains live."""
        pendings = []
        for i, sim in enumerate(self.sims):
            if self.done[i]:
                continue
            r = sim.run_until_collect()
            if r is None:
                self.done[i] = True
            else:
                pendings.append(r)
        if pendings:
            # stage A: one stacked predictor forward per estimator object
            self._fuse_estimates(
                [w for p in pendings if p.kind == "phase_end"
                 for w in p.work])
            # stage B: resume each tick, collect its pending decisions
            decisions: List[RepartDecision] = []
            for p in pendings:
                decisions.extend(p.apply())
            # stage C: fused Algorithm-1 solves across replicas
            self._solve_decisions(decisions)
            # stage D: apply + finalize, completing each replica's tick
            for p in pendings:
                p.finish()
        self.rounds += 1
        return not all(self.done)

    def run(self) -> List[TraceMetrics]:
        """Drive every replica to completion; per-replica metrics in input
        order, each bit-identical to ``ClusterSim.run()`` on that replica
        alone."""
        while self.step():
            pass
        self.settle()
        return [sim.finish(settle=False) for sim in self.sims]

    def settle(self) -> None:
        """Settle every replica's fleet accounting to its current clock
        (cheap, idempotent at a fixed clock; call before reading
        :meth:`observe` progress or computing metrics).  Note extra
        mid-flight settles split energy-integration intervals and so can
        move ``energy_j`` by float rounding relative to an unobserved run;
        :meth:`run` settles only once, at the end, like the scalar engine."""
        self.fleet_state.settle_all()

    # ----------------------------------------------------- fused services

    @staticmethod
    def _fuse_estimates(works: List[EstimateWork]) -> None:
        """Stage A: fill ``w.ests`` for every collected MPS window via one
        ``estimate_batch`` call per estimator object.  Measurements (and
        their noise draws) already happened at collect time inside each
        replica; the forward is pure, so cross-replica fusion is exact."""
        if not works:
            return
        by_est: Dict[int, List[EstimateWork]] = {}
        for w in works:
            by_est.setdefault(id(w.g.estimator), []).append(w)
        for group in by_est.values():
            requests = [(w.profs, w.mat, w.qos) for w in group]
            ests = group[0].g.estimator.estimate_batch(requests)
            for w, est in zip(group, ests):
                w.ests = est

    @staticmethod
    def _solve_decisions(decisions: List[RepartDecision]) -> None:
        """Stage C: fill ``d.choice`` for every pending repartition.

        Decisions are grouped by (partition space, power model, objective
        identity) — the complete input signature of the stacked DP — so one
        ``optimize_partition_batch`` serves each group across replicas,
        with the scalar ``optimize_partition`` fallback for elements whose
        feasible-first pass returns None: exactly
        ``Policy.choose_partition_batch``, element for element.  A policy
        class that overrides ``choose_partition`` keeps its own per-decision
        logic (same guard the scalar batch path applies)."""
        if not decisions:
            return
        groups: Dict[tuple, List[RepartDecision]] = {}
        for d in decisions:
            pol = d.policy
            if type(pol).choose_partition is not Policy.choose_partition:
                d.choice = pol.choose_partition(d.speeds, space=d.g.space,
                                                power=d.g.power)
                continue
            key = (id(d.g.space), id(d.g.power), pol.objective.memo_key())
            groups.setdefault(key, []).append(d)
        for group in groups.values():
            d0 = group[0]
            space, power = d0.g.space, d0.g.power
            objective = d0.policy.objective
            first = optimize_partition_batch(
                space, [d.speeds for d in group], require_feasible=True,
                objective=objective, power=power)
            for d, c in zip(group, first):
                d.choice = c if c is not None else optimize_partition(
                    space, d.speeds, objective=objective, power=power)

    # --------------------------------------------------------- observation

    def observe(self) -> Dict[str, np.ndarray]:
        """Replica-major snapshot for vectorized consumers (the GPUJobEnv
        surface): per-replica scalars (clock, queue depth, completions,
        done mask), ``(B, G)`` fleet scalars and ``(B, G, S)`` resident
        columns.  Pure read — call :meth:`settle` first when progress must
        be current to each replica's clock."""
        out: Dict[str, np.ndarray] = {
            "t": np.array([s.t for s in self.sims]),
            "queue_len": np.array([len(s.queue) for s in self.sims]),
            "completed": np.array([len(s.completed) for s in self.sims]),
            "done": np.array(self.done, dtype=bool),
        }
        out.update(self.fleet_state.scalars())
        out.update(self.fleet_state.resident_matrix())
        return out
