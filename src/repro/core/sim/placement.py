"""Pluggable placement layer: which GPU gets the next job.

The paper fixes placement to least-loaded (§4) and spends all its machinery
on the per-GPU partition decision; fragmentation-aware MIG schedulers
(PAPERS.md: Ting et al.; Zambianco et al.) show the *placement* decision
dominates JCT on shared MIG clusters, and PR 2's heterogeneous fleets add a
per-GPU ``speed_scale`` that least-loaded is blind to.  This module makes
placement a first-class, swappable layer mirroring the policy registry:

* a :class:`Placer` ranks the GPUs a policy deems feasible and picks one
  (or ``None`` to leave the job queued).  Feasibility itself stays with the
  policy (``Policy.placement_candidates``) — NoPart wants an empty GPU,
  MPS-only caps co-location by job count, the MIG policies use the engine's
  shared ``mem_ok`` / ``spare_slice_ok`` checks — so a placer composes with
  every policy, current and future;
* :func:`register_placer` / :func:`get_placer` mirror the policy registry;
  any name here is reachable from ``SimConfig.placer``, ``repro.launch
  .cluster --placer`` and the sweep grid (``repro.launch.sweep --placers``).

Built-ins:

* ``least-loaded``   — fewest resident jobs, GPU id tie-break.  The paper's
  rule and the default: bit-identical to the pre-placer simulator.
* ``hetero-speed``   — weighs ``GPUSpec.speed_scale`` against remaining
  work: jobs with more remaining work than the in-system mean go to the
  fastest GPUs (their wall-time win scales with length), short jobs pack on
  the slow ones so the fast capacity stays available.  Degenerates to
  least-loaded on homogeneous fleets.
* ``frag-aware``     — scores the *post-placement* partition space: among
  feasible covering partitions, how large a contiguous slice stays free
  (``PartitionSpace.part_spare``).  Prefers the GPU that keeps the most
  contiguous room for future arrivals.
* ``best-fit-slice`` — classic best-fit over the precomputed feasibility
  rows: picks the GPU whose tightest feasible partition wastes the fewest
  compute slots, packing jobs densely so whole GPUs stay empty.

All four only ever *rank* the candidate list — they never return a GPU the
policy did not offer, so every feasibility guarantee of the policy layer is
preserved by construction.
"""
from __future__ import annotations

from abc import ABC, abstractmethod
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Type

import numpy as np

from repro.core.jobs import Job
from repro.core.sim.gpu import GPU

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.core.sim.engine import ClusterSim

_REGISTRY: Dict[str, Type["Placer"]] = {}

DEFAULT_PLACER = "least-loaded"


def register_placer(cls: Type["Placer"]) -> Type["Placer"]:
    """Class decorator: make ``cls`` reachable as ``SimConfig.placer=name``."""
    if not getattr(cls, "name", None):
        raise ValueError(f"{cls.__name__} must define a non-empty `name`")
    if cls.name in _REGISTRY:
        raise ValueError(f"duplicate placer name {cls.name!r} "
                         f"({_REGISTRY[cls.name].__name__} vs {cls.__name__})")
    _REGISTRY[cls.name] = cls
    return cls


def get_placer(name: str) -> Type["Placer"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown placer {name!r}; "
            f"available: {', '.join(available_placers())}") from None


def available_placers() -> List[str]:
    return sorted(_REGISTRY)


class Placer(ABC):
    """Ranks a policy's feasible GPUs for one queued job (one instance per
    simulation, created by the policy as its ``placer`` collaborator)."""

    name: str = ""

    def __init__(self, sim: "ClusterSim"):
        self.sim = sim

    @abstractmethod
    def pick(self, job: Job, candidates: Sequence[GPU]) -> Optional[GPU]:
        """Choose one of ``candidates`` for ``job`` (None leaves it queued).
        ``candidates`` is the policy's feasible set; implementations must
        only ever return a member of it."""

    def pick_for(self, job: Job, policy) -> Optional[GPU]:
        """Entry point from ``Policy.pick_gpu``.  Default: enumerate the
        policy's feasible GPUs through the fleet index (count-capped,
        feasibility-level-pruned buckets instead of an O(fleet) scan) and
        rank them with :meth:`pick` — whose ``min`` over a total key is
        enumeration-order independent, so this is exactly
        ``pick(job, policy.placement_candidates(job))``.  Placers that can
        rank straight off the index order override this further; policies
        whose candidate rule is not index-expressible fall back to the
        materialized list."""
        if not policy.indexable:
            return self.pick(job, policy.placement_candidates(job))
        return self.pick(job, self._index_candidates(job, policy))

    def _index_candidates(self, job: Job, policy) -> List[GPU]:
        max_count, prune = policy.admit_caps(job)
        return self.sim.index.candidates(
            lambda g: policy.admit_ok(g, job), job,
            max_count=max_count, prune=prune)

    # ------------------------------------------------------ shared helpers

    @staticmethod
    def least_loaded(gpus: Sequence[GPU]) -> Optional[GPU]:
        """Fewest resident jobs, GPU id as tie-break (paper §4)."""
        if not gpus:
            return None
        return min(gpus, key=lambda g: (len(g.jobs), g.gid))

    def required_sizes(self, g: GPU, job: Job) -> Optional[Sequence[int]]:
        """Post-placement scalar slice requirements on ``g`` — ``job`` plus
        every resident — via ``PartitionSpace.required_sizes``; None when
        some job has no feasible slice on ``g``'s menu (or the menu's memory
        is not monotone in slice size, where the scalar collapse is inexact
        — no shipped menu, but scoring placers must not silently mis-rank)."""
        jobs = [job] + [rj.job for rj in g.jobs.values()]
        return g.space.required_sizes(
            [max(j.profile.mem_gb, j.min_mem_gb) for j in jobs],
            [j.qos_min_slice for j in jobs])

    def _covering_mask(self, g: GPU, job: Job) -> Optional[np.ndarray]:
        """(P,) bool mask over ``g.space.part_sizes(m)`` rows that give every
        post-placement job a big-enough slice; None when nothing covers."""
        reqs = self.required_sizes(g, job)
        if reqs is None:
            return None
        m = len(reqs)
        sizes = g.space.part_sizes(m)
        if sizes.shape[0] == 0:
            return None
        req = np.sort(np.asarray(reqs, dtype=np.int64))[::-1]
        mask = (sizes >= req).all(axis=1)
        return mask if mask.any() else None


@register_placer
class LeastLoadedPlacer(Placer):
    """The paper's placement rule; the default (bit-identical to the
    pre-placer simulator for every policy)."""

    name = "least-loaded"

    def pick(self, job: Job, candidates: Sequence[GPU]) -> Optional[GPU]:
        return self.least_loaded(candidates)

    def pick_for(self, job: Job, policy) -> Optional[GPU]:
        # the index streams GPUs in exactly this placer's preference order —
        # (resident count, gid) — so the first feasible one IS the pick; a
        # saturated fleet costs a bucket scan, not an O(fleet) rebuild
        if not policy.indexable:
            return self.pick(job, policy.placement_candidates(job))
        max_count, prune = policy.admit_caps(job)
        return self.sim.index.first(lambda g: policy.admit_ok(g, job), job,
                                    max_count=max_count, prune=prune)


@register_placer
class HeteroSpeedPlacer(Placer):
    """Speed-aware placement for heterogeneous fleets.

    A job's wall-time win from a fast GPU is proportional to its remaining
    work, so long jobs should claim the h100s while short jobs pack on the
    a100s and leave the fast capacity free.  "Long" is judged against the
    mean remaining work over everything currently in the system (queue +
    residents) — an adaptive split point with no tuning knob.  Within the
    preferred speed class, least-loaded; on homogeneous fleets (one speed
    class) this is exactly least-loaded.
    """

    name = "hetero-speed"

    def pick(self, job: Job, candidates: Sequence[GPU]) -> Optional[GPU]:
        gpus = list(candidates)
        if not gpus:
            return None
        if len({g.speed_scale for g in gpus}) == 1:
            return self.least_loaded(gpus)
        prefer_fast = job.remaining >= self._split_point()
        sign = -1.0 if prefer_fast else 1.0
        return min(gpus, key=lambda g: (sign * g.speed_scale,
                                        len(g.jobs), g.gid))

    def pick_for(self, job: Job, policy) -> Optional[GPU]:
        # walk the fleet's speed classes in this job's preference order and
        # take the (count, gid)-first feasible GPU of the first class that
        # has one — the same GPU ``pick`` finds by ranking the materialized
        # list, without building it (a class whose candidates are empty
        # costs one pruned bucket scan)
        if not policy.indexable:
            return self.pick(job, policy.placement_candidates(job))
        sim = self.sim
        max_count, prune = policy.admit_caps(job)
        pred = lambda g: policy.admit_ok(g, job)   # noqa: E731
        groups = sim.index.speed_groups()
        if len(groups) > 1 and job.remaining >= self._split_point():
            groups = groups[::-1]
        for _, kinds in groups:
            g = sim.index.first(pred, job, max_count=max_count,
                                prune=prune, kinds=kinds)
            if g is not None:
                return g
        return None

    def _split_point(self) -> float:
        """Mean remaining work over everything in the system, O(1) from the
        engine's incremental aggregate.  Hand-built sims that bypass the
        arrival path (tests assigning ``sim.queue`` directly) show up as a
        population mismatch and fall back to the exact recompute."""
        sim = self.sim
        agg = sim.work_agg
        n = len(sim.queue) + sim._resident_count
        if agg.count != n:
            return self._split_point_exact()
        return agg.total / n if n else 0.0

    def _split_point_exact(self) -> float:
        sim = self.sim
        rem = [sim.jobs[j].remaining for j in sim.queue]
        for g in sim.gpus:
            rem.extend(rj.job.remaining for rj in g.jobs.values())
        return sum(rem) / len(rem) if rem else 0.0


@register_placer
class FragAwarePlacer(Placer):
    """Keep the largest contiguous slice free after placement.

    For each candidate, score the best ``largest_free_slice`` over every
    partition that covers the post-placement job set (precomputed per space:
    ``part_spare``), normalized by the full-slice size so mixed menus
    compare.  Bigger spare = less fragmentation = more room for the next
    arrival's worst-case slice demand.  Ties fall back to least-loaded."""

    name = "frag-aware"

    def pick(self, job: Job, candidates: Sequence[GPU]) -> Optional[GPU]:
        gpus = list(candidates)
        if not gpus:
            return None
        return min(gpus, key=lambda g: (-self._spare_frac(g, job),
                                        len(g.jobs), g.gid))

    def _spare_frac(self, g: GPU, job: Job) -> float:
        mask = self._covering_mask(g, job)
        if mask is None:
            # unscoreable (policy admitted via its own rules, e.g. MPS-only
            # without partitions): rank below every scored GPU
            return -1.0
        m = len(g.jobs) + 1
        return float(g.space.part_spare(m)[mask].max()) / g.space.full_size


@register_placer
class BestFitSlicePlacer(Placer):
    """Tightest feasible Pareto row wins (classic best-fit bin packing).

    For each candidate, find the covering partition using the fewest compute
    slots; the GPU where that tightest fit is *largest* relative to its
    capacity is the most packed one — placing there keeps other GPUs empty
    for jobs that need big contiguous slices.  Ties fall back to
    least-loaded."""

    name = "best-fit-slice"

    def pick(self, job: Job, candidates: Sequence[GPU]) -> Optional[GPU]:
        gpus = list(candidates)
        if not gpus:
            return None
        return min(gpus, key=lambda g: (-self._used_frac(g, job),
                                        len(g.jobs), g.gid))

    def _used_frac(self, g: GPU, job: Job) -> float:
        mask = self._covering_mask(g, job)
        if mask is None:
            return -1.0
        m = len(g.jobs) + 1
        return float(g.space.part_compute(m)[mask].min()) / g.space.total_compute
