"""Per-accelerator phase state machine and job accounting.

Each GPU is a small state machine over phases:

  IDLE -> (jobs placed) -> CKPT (checkpoint + GPU reset dead time)
       -> MPS_PROF (jobs progress at interference-prone MPS speeds; the
          measurement happens here)                                [MISO only]
       -> CKPT (reconfigure to the optimizer's MIG partition)
       -> MIG_RUN (jobs progress at interference-free slice speeds)

Job accounting (paper Fig 12): every second of a job's life lands in exactly
one of {queue, ckpt, mps, run} — ``advance`` charges elapsed time to the
bucket matching the current phase.  Phase ends are cross-GPU independent,
which is what lets the engine coalesce same-tick windows into one batched
policy call (``Policy.on_phase_end_batch``) and the MISO policies fuse the
per-GPU estimator forwards.

Heterogeneous fleets: every GPU carries its own :class:`~repro.core.fleet
.GPUSpec` — partition space, performance model, estimator and speed scale —
so a mixed a100/h100/tpu cluster needs no global ``sim.space``/``sim.pm``.

Energy accounting: ``advance`` integrates each GPU's wall power over the
same windows it charges time to (``GPU.energy_j``, joules): the per-kind
:class:`~repro.core.fleet.PowerModel`'s idle floor always draws (except
while the GPU is down for repair — powered off), active MIG slices add
their sublinear per-slice watts, and an MPS window powers the whole chip.
The engine sums the per-GPU integrals into ``TraceMetrics.energy_j``.

Fault-rollback bookkeeping: periodic checkpoints (every
``cfg.ckpt_interval_s`` of *progressing* wall time, taken asynchronously at
zero cost) bound how much work a GPU failure destroys.  ``advance`` tracks
each resident job's un-checkpointed work (``RJob.since_ckpt_work``,
speed-weighted, reset whenever the GPU actually sits in a CKPT phase or a
periodic boundary passes), which is exactly what the engine re-adds to
``job.remaining`` on failure.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Optional, Tuple

from repro.core.jobs import Job

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.core.fleet import GPUSpec
    from repro.core.sim.engine import ClusterSim

IDLE, CKPT, MPS_PROF, MIG_RUN = "idle", "ckpt", "mps", "mig"

# GPU health states (healthy -> degraded -> quarantined -> repaired back to
# healthy); driven by engine.record_fault / the repair promotion.  Orthogonal
# to the phase machine: a degraded GPU still schedules, a quarantined one is
# down (its residents were migrated off via the checkpoint/rollback
# primitive) until the quarantine repair promotes it.
HEALTHY, DEGRADED, QUARANTINED = "healthy", "degraded", "quarantined"


class RJob:
    """A job resident on a GPU — a *view* over one slot of the GPU's
    struct-of-arrays resident columns (see :mod:`repro.core.sim.soa`).

    The hot per-resident scalars — instantaneous speed, progressing seconds
    since the last checkpoint, and the un-checkpointed (at-risk) work —
    live in the slot-aligned column lists ``GPU._spd`` / ``_ckt`` / ``_ckw``
    so the engine's inner loops walk contiguous columns instead of chasing
    one object per resident; the properties below keep every policy-side
    reader (``rj.speed``, fault rollback's ``rj.since_ckpt_work``, tests)
    source-compatible.  ``job`` and ``slice_size`` stay plain attributes:
    they are identity/assignment state, not per-event integrands."""

    __slots__ = ("g", "slot", "job", "slice_size")

    def __init__(self, g: "GPU", slot: int, job: Job,
                 slice_size: Optional[int] = None):
        self.g = g
        self.slot = slot
        self.job = job
        self.slice_size = slice_size

    @property
    def speed(self) -> float:        # work-seconds per second, right now
        return self.g._spd[self.slot]

    @speed.setter
    def speed(self, v: float):
        self.g._spd[self.slot] = v

    @property
    def since_ckpt_t(self) -> float:  # progressing seconds since last ckpt
        return self.g._ckt[self.slot]

    @since_ckpt_t.setter
    def since_ckpt_t(self, v: float):
        self.g._ckt[self.slot] = v

    @property
    def since_ckpt_work(self) -> float:  # un-checkpointed work (at risk)
        return self.g._ckw[self.slot]

    @since_ckpt_work.setter
    def since_ckpt_work(self, v: float):
        self.g._ckw[self.slot] = v


class GPU:
    def __init__(self, gid: int, sim: "ClusterSim", spec: "GPUSpec"):
        self.gid = gid
        self.sim = sim
        self.spec = spec
        self.space = spec.space
        self.pm = spec.pm
        self.estimator = spec.estimator
        self.speed_scale = spec.speed_scale
        self.power = spec.power
        # per-slice active watts, precomputed off the hot path
        self._slice_w = {s: spec.power.active_w(spec.space.compute_frac(s))
                         for s in spec.space.sizes}
        self._idle_w = spec.power.idle_w
        self._mps_w = spec.power.idle_w + (spec.power.max_active_w
                                           * spec.power.mps_active_frac)
        self.energy_j = 0.0
        self.phase = IDLE
        self.phase_end = 0.0
        # resident store, struct-of-arrays: ``jobs`` (jid -> slot view, in
        # placement order) is the lookup/iteration surface policies use;
        # the parallel column lists below are the hot data, slot-aligned
        # with ``_rjobs`` (list position == slot == dict order).  All four
        # mutate ONLY through _add_resident/_pop_resident/_clear_residents.
        self.jobs: Dict[int, RJob] = {}
        self._rjobs: list = []           # slot -> RJob view
        self._spd: list = []             # slot -> speed (w-s per second)
        self._ckt: list = []             # slot -> progressing s since ckpt
        self._ckw: list = []             # slot -> at-risk work-seconds
        self.partition: Tuple[int, ...] = ()
        self.estimates: Dict[int, Dict[int, float]] = {}
        self.last_update = 0.0
        self.stamp = 0               # event invalidation
        self.needs_profile = False
        self.down_until = 0.0
        # ---- health state machine (engine.record_fault / faults.py):
        # recent fault times inside the quarantine window, the straggler
        # speed multiplier (1.0 = healthy, folded into refresh_speeds only
        # when != 1.0 so the golden path's float ops are untouched), and
        # the schedulability gate flaky reconfig retries clear while the
        # GPU is stuck re-running a failed repartition op
        self.health = HEALTHY
        self.fault_times: list = []
        self.speed_fault = 1.0
        self.sched_ok = True
        self.reconfig_tries = 0
        # ---- speed-validity cache.  Per-resident speeds are pure functions
        # of (phase, speed_fault, resident (jid, slice) mix) for progress-
        # independent profiles, so ``refresh_speeds`` skips the recompute
        # unless (a) a mutation site flagged ``_spd_dirty`` (resident set or
        # slice assignment changed — engine place/remove/evict paths and
        # every policy path that writes ``rj.slice_size``; see the
        # determinism contract in CONTRIBUTING), (b) the phase object
        # changed (``is`` on the module constants — a false negative only
        # recomputes), or (c) the straggler multiplier moved.  ``_n_phased``
        # counts residents with progress-dependent profiles (``job.phases``),
        # which disable the skip entirely.  ``_spd_key`` is a fresh object
        # per recompute: the wall-watts and resident-memory-sum caches hang
        # off its *identity*, so an unchanged key proves their inputs are
        # unchanged and the cached values are bit-identical to a fresh
        # dict-order recompute.
        self._spd_dirty = True
        self._spd_phase: object = None
        self._spd_fault = 1.0
        self._spd_key: object = None
        self._w_key: object = object()
        self._w_val = 0.0
        self._mem_key: object = object()
        self._mem_total = 0.0
        self._n_phased = 0
        # fleet-index bookkeeping (owned by engine + sim.index): current
        # bucket, membership flag, and the largest menu slice a new job
        # could still require here (None = non-monotone menu, never pruned)
        self._idx_pos: Optional[Tuple[int, int]] = None
        self._in_index = False
        self._max_add: Optional[int] = None

    # ---------------------------------------------------- resident columns

    def _add_resident(self, job: Job) -> RJob:
        """Append ``job`` as the newest resident (slot = placement order)."""
        rj = RJob(self, len(self._rjobs), job)
        self.jobs[job.jid] = rj
        self._rjobs.append(rj)
        self._spd.append(0.0)
        self._ckt.append(0.0)
        self._ckw.append(0.0)
        return rj

    def _pop_resident(self, jid: int) -> RJob:
        """Remove one resident, left-compacting the columns so slot order
        keeps matching dict (placement) order."""
        rj = self.jobs.pop(jid)
        i = rj.slot
        del self._rjobs[i]
        del self._spd[i]
        del self._ckt[i]
        del self._ckw[i]
        # misolint: disable=MS110 -- slot re-indexing IS the column
        # maintenance; <=7 slots, scalar wins per the measure_settle.py
        # numbers recorded in soa.py
        for r in self._rjobs[i:]:
            r.slot -= 1
        return rj

    def _clear_residents(self):
        self.jobs.clear()
        self._rjobs.clear()
        self._spd.clear()
        self._ckt.clear()
        self._ckw.clear()

    def reset_ckpt_marks(self):
        """A checkpoint just committed: nothing is at risk any more."""
        k = len(self._ckt)
        self._ckt[:] = [0.0] * k
        self._ckw[:] = [0.0] * k

    # ------------------------------------------------------------ progress

    def advance(self, t: float):
        dt = t - self.last_update
        if dt <= 0:
            self.last_update = t
            return
        # ---- energy: integrate wall power over [last_update, t].  A GPU
        # under repair is powered off; the live part of the window starts
        # at down_until (down_until only ever moves forward, so an interval
        # straddles at most one repair boundary).
        live = dt if self.last_update >= self.down_until \
            else max(0.0, t - self.down_until)
        if live > 0.0:
            if self._w_key is self._spd_key:
                w = self._w_val
            else:
                if self.phase == MIG_RUN:
                    w = self._idle_w
                    slice_w = self._slice_w
                    # misolint: disable=MS110 -- sanctioned scalar walk
                    # (<=7 residents, memoized on the speed-cache key;
                    # measure_settle.py numbers recorded in soa.py)
                    for rj in self._rjobs:
                        if rj.slice_size:
                            # misolint: disable=MS107 -- bounded watts sum over
                            # <=7 resident slices per window; fsum would shift
                            # the golden energy integrals' bits
                            w += slice_w[rj.slice_size]
                elif self.phase == MPS_PROF and self._rjobs:
                    w = self._mps_w
                else:
                    w = self._idle_w
                self._w_val = w
                self._w_key = self._spd_key
            self.energy_j += w * live
        phase = self.phase
        rjobs = self._rjobs
        if rjobs:
            # scalar column walk: slot order == placement (dict) order, so
            # the progress/aggregate float-op sequence is the historical
            # one.  Measured: at <=7 residents a numpy row round-trip costs
            # more than this whole loop (benchmarks/measure_settle.py; the
            # numbers are recorded next to _FREE_VEC_MIN/_OCC_VEC_MIN in
            # soa.py); the vectorized path lives in soa.FleetState for
            # fleet-scope batches only.
            if phase == MIG_RUN or phase == MPS_PROF:
                interval = self.sim.cfg.ckpt_interval_s
                run = phase == MIG_RUN
                spd = self._spd
                dec = 0.0            # progress drained from the in-system
                if interval > 0:     # remaining-work aggregate below
                    ckt = self._ckt
                    ckw = self._ckw
                    # misolint: disable=MS110 -- sanctioned scalar walk, see
                    # the rationale comment above this block
                    for i, rj in enumerate(rjobs):
                        s = spd[i]
                        done = s * dt
                        job = rj.job
                        job.remaining -= done
                        # misolint: disable=MS107 -- one GPU's same-window
                        # progress (<=7 residents); the fleet-wide total is
                        # maintained by the Kahan WorkAggregate this sum is
                        # shifted into below
                        dec += done
                        if run:
                            job.t_run += dt
                        else:
                            job.t_mps += dt
                        ct = ckt[i] + dt
                        ckw[i] += done
                        while ct >= interval:
                            # a periodic checkpoint boundary fell inside this
                            # window; the boundary lies within the current dt
                            # (the pre-add remainder was < interval), so the
                            # still-at-risk tail ran at the current speed
                            ct -= interval
                            ckw[i] = s * ct
                        ckt[i] = ct
                else:
                    # misolint: disable=MS110 -- sanctioned scalar walk, see
                    # the rationale comment above this block
                    for i, rj in enumerate(rjobs):
                        done = spd[i] * dt
                        job = rj.job
                        job.remaining -= done
                        dec += done  # misolint: disable=MS107 -- as above
                        if run:
                            job.t_run += dt
                        else:
                            job.t_mps += dt
                if dec:
                    self.sim.work_agg.shift(-dec)
            elif phase == CKPT:
                # the save is in flight, not durable: only a CKPT window that
                # runs to completion commits (engine.end_phase resets the
                # since_ckpt counters); a failure mid-save loses everything
                # back to the last *completed* checkpoint
                # misolint: disable=MS110 -- sanctioned scalar walk (<=7
                # slots; measure_settle.py numbers recorded in soa.py)
                for rj in rjobs:
                    rj.job.t_ckpt += dt
            else:
                # misolint: disable=MS110 -- sanctioned scalar walk (<=7
                # slots; measure_settle.py numbers recorded in soa.py)
                for rj in rjobs:
                    rj.job.t_queue += dt
        self.last_update = t

    def refresh_speeds(self):
        if (not self._spd_dirty and self._n_phased == 0
                and self._spd_phase is self.phase
                and self._spd_fault == self.speed_fault):
            return
        self._spd_dirty = False
        self._spd_phase = self.phase
        self._spd_fault = self.speed_fault
        self._spd_key = object()     # break the watts/memory identity chains
        rjs = self._rjobs
        spd = self._spd
        # straggler degradation folds into the scale only when present:
        # the healthy path multiplies by speed_scale alone, bit-identical
        # to the pre-fault-model simulator
        scale = self.speed_scale if self.speed_fault == 1.0 \
            else self.speed_scale * self.speed_fault
        if self.phase == MIG_RUN:
            slice_speed = self.pm.slice_speed
            # misolint: disable=MS110 -- scalar column walk (<=7 slots;
            # layout rationale and measure_settle.py numbers in soa.py)
            for i, rj in enumerate(rjs):
                job = rj.job
                prof = job.profile if not job.phases else \
                    job.profile_at(1.0 - job.remaining / job.work)
                spd[i] = (scale * slice_speed(prof, rj.slice_size)
                          if rj.slice_size else 0.0)
        elif self.phase == MPS_PROF:
            if rjs:
                # misolint: disable=MS110 -- scalar column walk (<=7 slots;
                # measure_settle.py numbers recorded in soa.py)
                profs = [rj.job.profile if not rj.job.phases else
                         rj.job.profile_at(1.0 - rj.job.remaining / rj.job.work)
                         for rj in rjs]
                speeds = self.sim.policy.mps_phase_speeds(profs, g=self)
                for i, s in enumerate(speeds):
                    spd[i] = scale * float(s)
        else:
            spd[:] = [0.0] * len(spd)

    def next_completion(self) -> Optional[Tuple[float, int]]:
        # called after every event on this GPU: hoist the phase check out of
        # the per-job loop (jobs only progress in MIG_RUN / MPS_PROF)
        if self.phase != MIG_RUN and self.phase != MPS_PROF:
            return None
        best = None
        lu = self.last_update
        spd = self._spd
        # misolint: disable=MS110 -- scalar column walk (<=7 slots;
        # measure_settle.py numbers recorded in soa.py)
        for i, rj in enumerate(self._rjobs):
            s = spd[i]
            if s > 1e-12:
                r = rj.job.remaining
                tf = lu + (r if r > 0.0 else 0.0) / s
                if best is None or tf < best[0]:
                    best = (tf, rj.job.jid)
        return best

    # --------------------------------------------------------- transitions

    def ckpt_duration(self) -> float:
        if not self._rjobs:
            return self.sim.cfg.mig_reconfig_s * self.sim.cfg.overhead_scale
        # misolint: disable=MS110 -- scalar column walk (<=7 slots;
        # measure_settle.py numbers recorded in soa.py)
        per_job = max(
            self.sim.cfg.ckpt_base_s + rj.job.profile.mem_gb / self.sim.cfg.ckpt_bw_gbps
            for rj in self._rjobs)
        return (self.sim.cfg.mig_reconfig_s + per_job) * self.sim.cfg.overhead_scale
