"""Per-accelerator phase state machine and job accounting.

Each GPU is a small state machine over phases:

  IDLE -> (jobs placed) -> CKPT (checkpoint + GPU reset dead time)
       -> MPS_PROF (jobs progress at interference-prone MPS speeds; the
          measurement happens here)                                [MISO only]
       -> CKPT (reconfigure to the optimizer's MIG partition)
       -> MIG_RUN (jobs progress at interference-free slice speeds)

Job accounting (paper Fig 12): every second of a job's life lands in exactly
one of {queue, ckpt, mps, run} — ``advance`` charges elapsed time to the
bucket matching the current phase.  Phase ends are cross-GPU independent,
which is what lets the engine coalesce same-tick windows into one batched
policy call (``Policy.on_phase_end_batch``) and the MISO policies fuse the
per-GPU estimator forwards.

Heterogeneous fleets: every GPU carries its own :class:`~repro.core.fleet
.GPUSpec` — partition space, performance model, estimator and speed scale —
so a mixed a100/h100/tpu cluster needs no global ``sim.space``/``sim.pm``.

Energy accounting: ``advance`` integrates each GPU's wall power over the
same windows it charges time to (``GPU.energy_j``, joules): the per-kind
:class:`~repro.core.fleet.PowerModel`'s idle floor always draws (except
while the GPU is down for repair — powered off), active MIG slices add
their sublinear per-slice watts, and an MPS window powers the whole chip.
The engine sums the per-GPU integrals into ``TraceMetrics.energy_j``.

Fault-rollback bookkeeping: periodic checkpoints (every
``cfg.ckpt_interval_s`` of *progressing* wall time, taken asynchronously at
zero cost) bound how much work a GPU failure destroys.  ``advance`` tracks
each resident job's un-checkpointed work (``RJob.since_ckpt_work``,
speed-weighted, reset whenever the GPU actually sits in a CKPT phase or a
periodic boundary passes), which is exactly what the engine re-adds to
``job.remaining`` on failure.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, Optional, Tuple

from repro.core.jobs import Job

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.core.fleet import GPUSpec
    from repro.core.sim.engine import ClusterSim

IDLE, CKPT, MPS_PROF, MIG_RUN = "idle", "ckpt", "mps", "mig"

# GPU health states (healthy -> degraded -> quarantined -> repaired back to
# healthy); driven by engine.record_fault / the repair promotion.  Orthogonal
# to the phase machine: a degraded GPU still schedules, a quarantined one is
# down (its residents were migrated off via the checkpoint/rollback
# primitive) until the quarantine repair promotes it.
HEALTHY, DEGRADED, QUARANTINED = "healthy", "degraded", "quarantined"


@dataclass
class RJob:
    """A job resident on a GPU: its current slice and instantaneous speed."""
    job: Job
    slice_size: Optional[int] = None
    speed: float = 0.0               # work-seconds per second, right now
    since_ckpt_t: float = 0.0        # progressing seconds since last ckpt
    since_ckpt_work: float = 0.0     # un-checkpointed work-seconds (at risk)


class GPU:
    def __init__(self, gid: int, sim: "ClusterSim", spec: "GPUSpec"):
        self.gid = gid
        self.sim = sim
        self.spec = spec
        self.space = spec.space
        self.pm = spec.pm
        self.estimator = spec.estimator
        self.speed_scale = spec.speed_scale
        self.power = spec.power
        # per-slice active watts, precomputed off the hot path
        self._slice_w = {s: spec.power.active_w(spec.space.compute_frac(s))
                         for s in spec.space.sizes}
        self._idle_w = spec.power.idle_w
        self._mps_w = spec.power.idle_w + (spec.power.max_active_w
                                           * spec.power.mps_active_frac)
        self.energy_j = 0.0
        self.phase = IDLE
        self.phase_end = 0.0
        self.jobs: Dict[int, RJob] = {}
        self.partition: Tuple[int, ...] = ()
        self.estimates: Dict[int, Dict[int, float]] = {}
        self.last_update = 0.0
        self.stamp = 0               # event invalidation
        self.needs_profile = False
        self.down_until = 0.0
        # ---- health state machine (engine.record_fault / faults.py):
        # recent fault times inside the quarantine window, the straggler
        # speed multiplier (1.0 = healthy, folded into refresh_speeds only
        # when != 1.0 so the golden path's float ops are untouched), and
        # the schedulability gate flaky reconfig retries clear while the
        # GPU is stuck re-running a failed repartition op
        self.health = HEALTHY
        self.fault_times: list = []
        self.speed_fault = 1.0
        self.sched_ok = True
        self.reconfig_tries = 0
        # fleet-index bookkeeping (owned by engine + sim.index): current
        # bucket, membership flag, and the largest menu slice a new job
        # could still require here (None = non-monotone menu, never pruned)
        self._idx_pos: Optional[Tuple[int, int]] = None
        self._in_index = False
        self._max_add: Optional[int] = None

    # ------------------------------------------------------------ progress

    def advance(self, t: float):
        dt = t - self.last_update
        if dt <= 0:
            self.last_update = t
            return
        # ---- energy: integrate wall power over [last_update, t].  A GPU
        # under repair is powered off; the live part of the window starts
        # at down_until (down_until only ever moves forward, so an interval
        # straddles at most one repair boundary).
        live = dt if self.last_update >= self.down_until \
            else max(0.0, t - self.down_until)
        if live > 0.0:
            if self.phase == MIG_RUN:
                w = self._idle_w
                slice_w = self._slice_w
                for rj in self.jobs.values():
                    if rj.slice_size:
                        # misolint: disable=MS107 -- bounded watts sum over
                        # <=7 resident slices per window; fsum would shift
                        # the golden energy integrals' bits
                        w += slice_w[rj.slice_size]
            elif self.phase == MPS_PROF and self.jobs:
                w = self._mps_w
            else:
                w = self._idle_w
            self.energy_j += w * live
        interval = self.sim.cfg.ckpt_interval_s
        dec = 0.0                    # progress drained from the in-system
        for rj in self.jobs.values():  # remaining-work aggregate below
            if self.phase in (MIG_RUN, MPS_PROF):
                done = rj.speed * dt
                rj.job.remaining -= done
                # misolint: disable=MS107 -- one GPU's same-window progress
                # (<=7 residents); the fleet-wide total is maintained by the
                # Kahan WorkAggregate this sum is shifted into below
                dec += done
                if self.phase == MIG_RUN:
                    rj.job.t_run += dt
                else:
                    rj.job.t_mps += dt
                if interval > 0:
                    rj.since_ckpt_t += dt
                    rj.since_ckpt_work += done
                    while rj.since_ckpt_t >= interval:
                        # a periodic checkpoint boundary fell inside this
                        # window; the boundary lies within the current dt
                        # (the pre-add remainder was < interval), so the
                        # still-at-risk tail ran at the current speed
                        rj.since_ckpt_t -= interval
                        rj.since_ckpt_work = rj.speed * rj.since_ckpt_t
            elif self.phase == CKPT:
                # the save is in flight, not durable: only a CKPT window that
                # runs to completion commits (engine.end_phase resets the
                # since_ckpt counters); a failure mid-save loses everything
                # back to the last *completed* checkpoint
                rj.job.t_ckpt += dt
            else:
                rj.job.t_queue += dt
        if dec:
            self.sim.work_agg.shift(-dec)
        self.last_update = t

    def refresh_speeds(self):
        sim = self.sim
        rjs = list(self.jobs.values())
        # straggler degradation folds into the scale only when present:
        # the healthy path multiplies by speed_scale alone, bit-identical
        # to the pre-fault-model simulator
        scale = self.speed_scale if self.speed_fault == 1.0 \
            else self.speed_scale * self.speed_fault
        if self.phase == MIG_RUN:
            for rj in rjs:
                prof = rj.job.profile_at(1.0 - rj.job.remaining / rj.job.work)
                rj.speed = (scale * self.pm.slice_speed(prof, rj.slice_size)
                            if rj.slice_size else 0.0)
        elif self.phase == MPS_PROF:
            if rjs:
                profs = [rj.job.profile_at(1.0 - rj.job.remaining / rj.job.work)
                         for rj in rjs]
                speeds = sim.policy.mps_phase_speeds(profs, g=self)
                for rj, s in zip(rjs, speeds):
                    rj.speed = scale * float(s)
        else:
            for rj in rjs:
                rj.speed = 0.0

    def next_completion(self) -> Optional[Tuple[float, int]]:
        # called after every event on this GPU: hoist the phase check out of
        # the per-job loop (jobs only progress in MIG_RUN / MPS_PROF)
        if self.phase != MIG_RUN and self.phase != MPS_PROF:
            return None
        best = None
        for jid, rj in self.jobs.items():
            if rj.speed > 1e-12:
                tf = self.last_update + max(rj.job.remaining, 0.0) / rj.speed
                if best is None or tf < best[0]:
                    best = (tf, jid)
        return best

    # --------------------------------------------------------- transitions

    def ckpt_duration(self) -> float:
        if not self.jobs:
            return self.sim.cfg.mig_reconfig_s * self.sim.cfg.overhead_scale
        per_job = max(
            self.sim.cfg.ckpt_base_s + rj.job.profile.mem_gb / self.sim.cfg.ckpt_bw_gbps
            for rj in self.jobs.values())
        return (self.sim.cfg.mig_reconfig_s + per_job) * self.sim.cfg.overhead_scale
