"""Per-accelerator phase state machine and job accounting.

Each GPU is a small state machine over phases:

  IDLE -> (jobs placed) -> CKPT (checkpoint + GPU reset dead time)
       -> MPS_PROF (jobs progress at interference-prone MPS speeds; the
          measurement happens here)                                [MISO only]
       -> CKPT (reconfigure to the optimizer's MIG partition)
       -> MIG_RUN (jobs progress at interference-free slice speeds)

Job accounting (paper Fig 12): every second of a job's life lands in exactly
one of {queue, ckpt, mps, run} — ``advance`` charges elapsed time to the
bucket matching the current phase.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, Optional, Tuple

from repro.core.jobs import Job

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.core.sim.engine import ClusterSim

IDLE, CKPT, MPS_PROF, MIG_RUN = "idle", "ckpt", "mps", "mig"


@dataclass
class RJob:
    """A job resident on a GPU: its current slice and instantaneous speed."""
    job: Job
    slice_size: Optional[int] = None
    speed: float = 0.0               # work-seconds per second, right now


class GPU:
    def __init__(self, gid: int, sim: "ClusterSim"):
        self.gid = gid
        self.sim = sim
        self.phase = IDLE
        self.phase_end = 0.0
        self.jobs: Dict[int, RJob] = {}
        self.partition: Tuple[int, ...] = ()
        self.estimates: Dict[int, Dict[int, float]] = {}
        self.last_update = 0.0
        self.stamp = 0               # event invalidation
        self.needs_profile = False
        self.down_until = 0.0

    # ------------------------------------------------------------ progress

    def advance(self, t: float):
        dt = t - self.last_update
        if dt <= 0:
            self.last_update = t
            return
        for rj in self.jobs.values():
            if self.phase == MIG_RUN:
                rj.job.remaining -= rj.speed * dt
                rj.job.t_run += dt
            elif self.phase == MPS_PROF:
                rj.job.remaining -= rj.speed * dt
                rj.job.t_mps += dt
            elif self.phase == CKPT:
                rj.job.t_ckpt += dt
            else:
                rj.job.t_queue += dt
        self.last_update = t

    def refresh_speeds(self):
        sim = self.sim
        rjs = list(self.jobs.values())
        if self.phase == MIG_RUN:
            for rj in rjs:
                prof = rj.job.profile_at(1.0 - rj.job.remaining / rj.job.work)
                rj.speed = (sim.pm.slice_speed(prof, rj.slice_size)
                            if rj.slice_size else 0.0)
        elif self.phase == MPS_PROF:
            if rjs:
                profs = [rj.job.profile_at(1.0 - rj.job.remaining / rj.job.work)
                         for rj in rjs]
                speeds = sim.policy.mps_phase_speeds(profs)
                for rj, s in zip(rjs, speeds):
                    rj.speed = float(s)
        else:
            for rj in rjs:
                rj.speed = 0.0

    def next_completion(self) -> Optional[Tuple[float, int]]:
        best = None
        for jid, rj in self.jobs.items():
            if rj.speed > 1e-12 and self.phase in (MIG_RUN, MPS_PROF):
                tf = self.last_update + max(rj.job.remaining, 0.0) / rj.speed
                if best is None or tf < best[0]:
                    best = (tf, jid)
        return best

    # --------------------------------------------------------- transitions

    def ckpt_duration(self) -> float:
        if not self.jobs:
            return self.sim.cfg.mig_reconfig_s * self.sim.cfg.overhead_scale
        per_job = max(
            self.sim.cfg.ckpt_base_s + rj.job.profile.mem_gb / self.sim.cfg.ckpt_bw_gbps
            for rj in self.jobs.values())
        return (self.sim.cfg.mig_reconfig_s + per_job) * self.sim.cfg.overhead_scale
