"""MISO: MPS-probe -> predict -> MIG-repartition (the paper's contribution).

Every placement triggers the full pipeline with all its overheads
(conservative reporting, paper §5 "Competing Techniques"):

  checkpoint -> MPS profiling sweep (3 levels) -> estimator -> Algorithm 1
  -> checkpoint + reconfigure -> MIG run

Multi-instance clones reuse their group's cached MPS profile and skip the
sweep (paper §4.3: spawned instances are not re-profiled).
"""
from __future__ import annotations

import time

from repro.core.jobs import Job
from repro.core.sim.gpu import CKPT, GPU, IDLE, MIG_RUN, MPS_PROF
from repro.core.sim.policies.base import (EstimateWork, Policy,
                                          register_policy)


@register_policy
class MisoPolicy(Policy):
    name = "miso"

    # placement: the inherited candidates (shared-MIG admission) ranked by
    # the configured placer — least-loaded by default (paper §4)

    def on_place(self, g: GPU, job: Job):
        # profiles are space-specific: a clone landing on a different
        # accelerator kind must not reuse another kind's slice estimates
        cached = (self.sim.profile_cache.get((job.mi_group, g.space.name))
                  if job.mi_group is not None else None)
        if cached is not None:
            # multi-instance clone: skip MPS, straight to optimizer
            g.estimates[job.jid] = cached
            self.repartition(g, overhead=True)
        else:
            self.begin_profiling(g)

    def on_phase_end(self, g: GPU):
        cfg = self.sim.cfg
        if g.phase == CKPT and g.needs_profile:
            g.phase = MPS_PROF
            g.phase_end = self.sim.t + 3 * cfg.mps_level_time_s \
                * cfg.overhead_scale
            g.needs_profile = False
        elif g.phase == MPS_PROF:
            self.measure_and_partition(g)
        elif g.phase == CKPT:
            g.phase = MIG_RUN if g.jobs else IDLE

    def on_phase_end_batch(self, gs):
        """Fused estimator service: every MPS window ending at this tick is
        measured in event order (one noise draw each, same stream as
        sequential processing), estimated through a single batched predictor
        forward per estimator, and repartitioned through one batched
        Algorithm-1 pass per space.  Non-profiling phase ends in the batch
        keep their sequential semantics."""
        prof_gs = [g for g in gs if g.phase == MPS_PROF]
        if len(prof_gs) < 2:
            for g in gs:
                self.on_phase_end(g)
            return
        mixes = {g.gid: self._mix(g) for g in prof_gs}
        prof = self.sim.prof
        t0 = time.perf_counter() if prof is not None else 0.0
        mats = {g.gid: self._measure(g, mixes[g.gid][1]) for g in prof_gs}
        by_est = {}
        for g in prof_gs:
            by_est.setdefault(id(g.estimator), []).append(g)
        ests = {}
        for group in by_est.values():
            requests = [(mixes[g.gid][1], mats[g.gid], mixes[g.gid][2])
                        for g in group]
            for g, est in zip(group,
                              group[0].estimator.estimate_batch(requests)):
                ests[g.gid] = est
        if prof is not None:
            prof["estimator_s"] += time.perf_counter() - t0
        for g in gs:
            if g.phase == MPS_PROF:
                self._store_estimates(g, mixes[g.gid][0], ests[g.gid])
            else:
                self.on_phase_end(g)
        self.repartition_many(prof_gs, overhead=True)

    # ------------------------------------------- collect/apply (BatchSim)

    def collect_phase_end(self, gs):
        """Collect every MPS window ending at this tick: the mix and its
        (noise-drawing) measurement happen NOW, in event order — exactly
        where :meth:`on_phase_end_batch` draws them — so the dedicated
        noise stream sees the same sequence; only the estimator forward is
        deferred for cross-replica fusion."""
        prof_gs = [g for g in gs if g.phase == MPS_PROF]
        if not prof_gs:
            return None
        work = []
        for g in prof_gs:
            jids, profs, qos = self._mix(g)
            work.append(EstimateWork(g, jids, profs, qos,
                                     self._measure(g, profs)))
        return work

    def apply_phase_end(self, gs, work):
        """Stage B: estimates are in — store them / run the non-profiling
        transitions in scalar hook order, and hand back the repartition
        decisions for the fused Algorithm-1 pass."""
        by_gid = {w.g.gid: w for w in work}
        prof_gs = []
        for g in gs:
            if g.phase == MPS_PROF:
                w = by_gid[g.gid]
                self._store_estimates(g, w.jids, w.ests)
                prof_gs.append(g)
            else:
                self.on_phase_end(g)
        return self.collect_repartitions(prof_gs, overhead=True)

    def collect_completion(self, items):
        """Collect-mode twin of :meth:`on_completion_batch`: emptied GPUs
        go IDLE now; re-optimizations of GPUs that keep running jobs are
        returned as pending decisions for the fused solve."""
        repart = [g for g, _ in items if g.jobs and g.phase == MIG_RUN]
        for g, _ in items:
            if not g.jobs:
                g.phase = IDLE
                g.partition = ()
        return self.collect_repartitions(repart, overhead=True)

    def on_completion(self, g: GPU, job: Job):
        # re-optimize with known profiles (no new MPS sweep needed)
        if g.jobs and g.phase == MIG_RUN:
            self.repartition(g, overhead=True)
        elif not g.jobs:
            g.phase = IDLE
            g.partition = ()

    def on_completion_batch(self, items):
        """Same-tick completions: one batched Algorithm-1 pass re-optimizes
        every affected GPU that keeps running jobs (equivalent to the
        per-GPU :meth:`on_completion` reactions — completions in a batch
        land on distinct GPUs, so the reactions are independent)."""
        repart = [g for g, _ in items if g.jobs and g.phase == MIG_RUN]
        for g, _ in items:
            if not g.jobs:
                g.phase = IDLE
                g.partition = ()
        if repart:
            self.repartition_many(repart, overhead=True)

    def on_fault_evict(self, g: GPU):
        """A fault killed some residents mid-flight: re-optimize the
        surviving slice layout exactly like a completion does (survivors
        already have profiles; no new MPS sweep).  A GPU caught outside its
        MIG run (checkpointing / profiling) keeps its in-flight phase — the
        pipeline re-converges on its own."""
        if g.jobs and g.phase == MIG_RUN:
            self.repartition(g, overhead=True)
        elif not g.jobs:
            g.phase = IDLE
            g.partition = ()

    # ------------------------------------------------------------ profiling

    def begin_profiling(self, g: GPU):
        """Checkpoint whatever is running, then open the MPS window.  A
        freshly-started GPU (no job had a slice yet) has zero dead time and
        transitions straight to MPS_PROF."""
        sim = self.sim
        g.advance(sim.t)
        dead = g.ckpt_duration() if any(
            rj.slice_size for rj in g.jobs.values()) else 0.0
        g.phase = CKPT
        g.phase_end = sim.t + dead
        g.needs_profile = True
        for rj in g.jobs.values():
            rj.slice_size = None
        g._spd_dirty = True
        if dead == 0.0:
            # the caller finalizes the GPU once afterwards; suppress the
            # redundant event scheduling here
            sim.end_phase(g, schedule=False)

    def measure_and_partition(self, g: GPU):
        jids, profs, qos = self._mix(g)
        prof = self.sim.prof
        t0 = time.perf_counter() if prof is not None else 0.0
        mps_mat = self._measure(g, profs)
        ests = g.estimator.estimate(profs, mps_mat, qos=qos)
        if prof is not None:
            prof["estimator_s"] += time.perf_counter() - t0
        self._store_estimates(g, jids, ests)
        self.repartition(g, overhead=True)

    def _mix(self, g: GPU):
        """The co-location group on ``g``: (jids, progress profiles, QoS)."""
        sim = self.sim
        jids = list(g.jobs)
        profs = [rj.job.profile_at(1.0 - rj.job.remaining / rj.job.work)
                 for rj in g.jobs.values()]
        qos = [sim.jobs[j].qos_min_slice for j in jids]
        return jids, profs, qos

    def _measure(self, g: GPU, profs):
        """The MPS measurement for ``g``'s window (None for estimators that
        do not consume one).  Draws measurement noise from the simulator's
        dedicated stream — call in event order."""
        sim = self.sim
        if not getattr(g.estimator, "needs_mps", False):
            return None
        # thread the simulator's noise stream so every profiling window
        # draws fresh measurement noise (Fig 14 sensitivity) without
        # disturbing the main RNG's failure-injection schedule
        return g.estimator.measure_mps(
            profs, noise_sigma=sim.cfg.mps_noise_sigma, rng=sim.noise_rng)

    def _store_estimates(self, g: GPU, jids, ests):
        """Record the estimator's output on the GPU (and the shared
        multi-instance profile cache).  Subclasses hook here to keep their
        own profile bookkeeping, so the fused batch path sees it too."""
        sim = self.sim
        if sim._est_hooks:
            # estimator-fault corruption point + graceful degradation: the
            # sanitizer runs before anything is cached, so last-known-good
            # lookups see the previous window's estimates
            ests = [self.sanitize_estimate(g, jid, est)
                    for jid, est in zip(jids,
                                        sim.filter_estimates(g, jids, ests))]
        for jid, est in zip(jids, ests):
            g.estimates[jid] = est
            grp = sim.jobs[jid].mi_group
            if grp is not None:
                sim.profile_cache[(grp, g.space.name)] = est
