"""MISO: MPS-probe -> predict -> MIG-repartition (the paper's contribution).

Every placement triggers the full pipeline with all its overheads
(conservative reporting, paper §5 "Competing Techniques"):

  checkpoint -> MPS profiling sweep (3 levels) -> estimator -> Algorithm 1
  -> checkpoint + reconfigure -> MIG run

Multi-instance clones reuse their group's cached MPS profile and skip the
sweep (paper §4.3: spawned instances are not re-profiled).
"""
from __future__ import annotations

from typing import Optional

from repro.core.jobs import Job
from repro.core.sim.gpu import CKPT, GPU, IDLE, MIG_RUN, MPS_PROF
from repro.core.sim.policies.base import Policy, register_policy


@register_policy
class MisoPolicy(Policy):
    name = "miso"

    def pick_gpu(self, job: Job) -> Optional[GPU]:
        sim = self.sim
        return self.least_loaded(
            [g for g in sim.up_gpus()
             if len(g.jobs) < g.space.max_jobs and sim.mem_ok(g, job)
             and sim.spare_slice_ok(g, job)])

    def on_place(self, g: GPU, job: Job):
        # profiles are space-specific: a clone landing on a different
        # accelerator kind must not reuse another kind's slice estimates
        cached = (self.sim.profile_cache.get((job.mi_group, g.space.name))
                  if job.mi_group is not None else None)
        if cached is not None:
            # multi-instance clone: skip MPS, straight to optimizer
            g.estimates[job.jid] = cached
            self.repartition(g, overhead=True)
        else:
            self.begin_profiling(g)

    def on_phase_end(self, g: GPU):
        cfg = self.sim.cfg
        if g.phase == CKPT and g.needs_profile:
            g.phase = MPS_PROF
            g.phase_end = self.sim.t + 3 * cfg.mps_level_time_s \
                * cfg.overhead_scale
            g.needs_profile = False
        elif g.phase == MPS_PROF:
            self.measure_and_partition(g)
        elif g.phase == CKPT:
            g.phase = MIG_RUN if g.jobs else IDLE

    def on_completion(self, g: GPU, job: Job):
        # re-optimize with known profiles (no new MPS sweep needed)
        if g.jobs and g.phase == MIG_RUN:
            self.repartition(g, overhead=True)
        elif not g.jobs:
            g.phase = IDLE
            g.partition = ()

    # ------------------------------------------------------------ profiling

    def begin_profiling(self, g: GPU):
        """Checkpoint whatever is running, then open the MPS window.  A
        freshly-started GPU (no job had a slice yet) has zero dead time and
        transitions straight to MPS_PROF."""
        sim = self.sim
        g.advance(sim.t)
        dead = g.ckpt_duration() if any(
            rj.slice_size for rj in g.jobs.values()) else 0.0
        g.phase = CKPT
        g.phase_end = sim.t + dead
        g.needs_profile = True
        for rj in g.jobs.values():
            rj.slice_size = None
        if dead == 0.0:
            # the caller finalizes the GPU once afterwards; suppress the
            # redundant event scheduling here
            sim.end_phase(g, schedule=False)

    def measure_and_partition(self, g: GPU):
        sim = self.sim
        profs = [rj.job.profile_at(1.0 - rj.job.remaining / rj.job.work)
                 for rj in g.jobs.values()]
        jids = list(g.jobs)
        qos = [sim.jobs[j].qos_min_slice for j in jids]
        mps_mat = None
        if getattr(g.estimator, "needs_mps", False):
            # thread the simulator's noise stream so every profiling window
            # draws fresh measurement noise (Fig 14 sensitivity) without
            # disturbing the main RNG's failure-injection schedule
            mps_mat = g.estimator.measure_mps(
                profs, noise_sigma=sim.cfg.mps_noise_sigma, rng=sim.noise_rng)
        ests = g.estimator.estimate(profs, mps_mat, qos=qos)
        for jid, est in zip(jids, ests):
            g.estimates[jid] = est
            grp = sim.jobs[jid].mi_group
            if grp is not None:
                sim.profile_cache[(grp, g.space.name)] = est
        self.repartition(g, overhead=True)
