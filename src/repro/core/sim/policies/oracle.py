"""Oracle: perfect slice-speed knowledge, zero profiling/reconfigure cost
(paper §5: "does not suffer from profiling overhead or prediction
inaccuracies").  Upper bound for MISO.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from repro.core.jobs import Job
from repro.core.sim.gpu import GPU
from repro.core.sim.policies.base import Policy, register_policy


@register_policy
class OraclePolicy(Policy):
    name = "oracle"

    # placement: inherited candidates + configured placer

    def on_place(self, g: GPU, job: Job):
        self.repartition(g)              # no overhead: instant, free

    def on_completion(self, g: GPU, job: Job):
        self.repartition(g)

    def collect_completion(self, items):
        """Replica-batched engine: every affected GPU re-optimizes (emptied
        ones go IDLE inside the collect), exactly the per-GPU
        :meth:`on_completion` reactions — zero-overhead, so ``overhead``
        stays False."""
        return self.collect_repartitions([g for g, _ in items],
                                         overhead=False)

    def partition_speeds(self, g: GPU, jids: Sequence[int]) -> List[Dict[int, float]]:
        """Ground truth straight from the GPU's estimator, fresh every time."""
        sim = self.sim
        return g.estimator.estimate(
            [sim.jobs[j].profile_at(1.0 - sim.jobs[j].remaining /
                                    sim.jobs[j].work) for j in jids],
            qos=[sim.jobs[j].qos_min_slice for j in jids])
