"""SRPT: preemptive shortest-remaining-work queue discipline on top of the
MISO pipeline.

The FCFS ``admit`` suffers head-of-line blocking: a queued giant that fits
nowhere stalls every small job behind it.  This policy (a) scans the whole
queue shortest-remaining-first, and (b) when nothing fits, preempts the
running job with the most remaining work — provided it has more than
``preempt_factor`` times the candidate's remaining work, so long jobs cannot
be starved by a stream of short ones.  Preempted jobs keep their progress
(they are checkpointed on eviction) and their measured MPS profile, so
re-admission skips the profiling sweep.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.jobs import Job
from repro.core.sim.gpu import GPU, IDLE, MIG_RUN
from repro.core.sim.policies.base import register_policy
from repro.core.sim.policies.miso import MisoPolicy


@register_policy
class SrptPolicy(MisoPolicy):
    name = "srpt"

    preempt_factor = 2.0       # victim must have > factor x candidate's work
    max_preemptions = 3        # per victim job, to bound churn

    def __init__(self, sim):
        super().__init__(sim)
        self._evicted: Dict[int, int] = {}       # jid -> times preempted
        # keyed (jid, space name): estimates only transfer within a kind
        self._known_profiles: Dict[tuple, Dict[int, float]] = {}
        # blocked-queue cache, same idea as the FCFS blocked-head cache but
        # over the whole scan: with an unchanged (index version, queue
        # length) nothing that could unblock any queued job has happened —
        # queue edits other than arrivals all bump the version, arrivals
        # change the length, and the preemption condition only *degrades*
        # as victims progress (remaining work shrinks monotonically)
        self._stalled = None

    # ------------------------------------------------------ queue discipline

    def admit(self):
        sim = self.sim
        sim._sync_up()
        if self._stalled is not None and \
                self._stalled == (sim.index.version, len(sim.queue)):
            return
        while sim.queue:
            order = sorted(sim.queue,
                           key=lambda j: (sim.jobs[j].remaining, j))
            for jid in order:
                g = self.pick_gpu(sim.jobs[jid])
                if g is not None:
                    sim.queue.remove(jid)
                    sim.place(g, sim.jobs[jid])
                    break
            else:
                if not self._try_preempt(sim.jobs[order[0]]):
                    self._stalled = (sim.index.version, len(sim.queue))
                    return
        self._stalled = None

    def _try_preempt(self, job: Job) -> bool:
        """Evict the largest-remaining running job whose departure actually
        makes room for ``job``; returns True if an eviction was made
        (admit() then retries)."""
        sim = self.sim
        victim, vg = None, None
        for g in sim.up_gpus():
            if g.phase != MIG_RUN:
                continue
            g.advance(sim.t)             # remaining-work must not be stale
            for rj in g.jobs.values():
                if ((victim is None or rj.job.remaining > victim.remaining)
                        and self._fits_after_evict(g, rj.job, job)):
                    victim, vg = rj.job, g
        if (victim is None
                or victim.remaining <= self.preempt_factor * job.remaining
                or self._evicted.get(victim.jid, 0) >= self.max_preemptions):
            return False
        self._evicted[victim.jid] = self._evicted.get(victim.jid, 0) + 1
        self._evict(vg, victim)
        return True

    def _fits_after_evict(self, g: GPU, victim: Job, job: Job) -> bool:
        """Would ``job`` be placeable on ``g`` once ``victim`` leaves?
        Evicting a job that does not unblock the candidate only charges
        checkpoint windows to bystanders."""
        sim = self.sim
        return (len(g.jobs) - 1 < g.space.max_jobs
                and sim.mem_ok(g, job, exclude=victim.jid)
                and sim.spare_slice_ok(g, job, exclude=victim.jid))

    def _evict(self, g: GPU, victim: Job):
        sim = self.sim
        g.advance(sim.t)
        sim.remove_resident(g, victim.jid)   # keeps the fleet index in sync
        est = g.estimates.pop(victim.jid, None)
        if est is not None:
            self._known_profiles[(victim.jid, g.space.name)] = est
        victim.queue_since = sim.t
        sim.queue.append(victim.jid)
        if g.jobs:
            self.repartition(g, overhead=True)   # ckpt covers the eviction
        else:
            g.phase = IDLE
            g.partition = ()
        sim.finalize(g)

    # ------------------------------------------------------------ placement

    def on_place(self, g: GPU, job: Job):
        known = self._known_profiles.get((job.jid, g.space.name))
        if known is not None:
            # re-admission after preemption on the same accelerator kind:
            # profile already measured
            g.estimates[job.jid] = known
            self.repartition(g, overhead=True)
        else:
            super().on_place(g, job)

    def _store_estimates(self, g: GPU, jids, ests):
        # hook below measure_and_partition so the fused same-tick batch path
        # records known profiles exactly like the sequential one
        super()._store_estimates(g, jids, ests)
        for jid, est in g.estimates.items():
            self._known_profiles[(jid, g.space.name)] = est

    def on_completion(self, g: GPU, job: Job):
        self._forget(job)
        super().on_completion(g, job)

    def on_completion_batch(self, items):
        for _, job in items:
            self._forget(job)
        super().on_completion_batch(items)

    def collect_completion(self, items):
        # mirror on_completion_batch for the replica-batched engine: the
        # profile bookkeeping runs before the inherited decision collection
        for _, job in items:
            self._forget(job)
        return super().collect_completion(items)

    def _forget(self, job: Job):
        for key in [k for k in self._known_profiles if k[0] == job.jid]:
            del self._known_profiles[key]
        self._evicted.pop(job.jid, None)
