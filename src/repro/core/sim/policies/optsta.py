"""OptSta: the best *static* MIG partition, fixed for the whole trace
(paper §5).  Jobs are matched to the fixed slice multiset best-first and
migrate to larger slices as they free up; the partition itself never changes,
so there is no reconfigure overhead — and no adaptation either.

The job->slice matching solves one batched assignment over every distinct
size-subset of the fixed multiset (the same vectorized bitmask-DP kernel
Algorithm 1 uses), replacing the historical per-subset dict DP.
"""
from __future__ import annotations

import itertools
import time
from typing import List

import numpy as np

from repro.core.jobs import Job
from repro.core.optimizer import assign_multisets
from repro.core.sim.gpu import GPU, IDLE, MIG_RUN
from repro.core.sim.policies.base import Policy, register_policy


@register_policy
class OptStaPolicy(Policy):
    name = "optsta"

    def placement_candidates(self, job: Job) -> List[GPU]:
        return [g for g in self.sim.up_gpus()
                if g.sched_ok and self.admit_ok(g, job)]

    # index contract: feasibility is "some free fixed slice fits", checked
    # per GPU; the static partition is not the spare-slice model, so the
    # slice-requirement bucket pruning stays off
    def admit_ok(self, g: GPU, job: Job) -> bool:
        need = max(job.profile.mem_gb, job.min_mem_gb)
        return any(g.space.slice_mem_gb(s) >= need
                   and s >= job.qos_min_slice
                   for s in self._free_slices(g))

    def admit_caps(self, job: Job):
        return None, False

    def on_place(self, g: GPU, job: Job):
        self._assign(g)
        g.phase = MIG_RUN

    def on_completion(self, g: GPU, job: Job):
        self._assign(g)
        g.phase = MIG_RUN if g.jobs else IDLE

    def on_fault_evict(self, g: GPU):
        # survivors migrate best-first onto the freed fixed slices, the
        # same reshuffle a completion triggers (no reconfigure: static)
        if g.jobs:
            self._assign(g)
            g.phase = MIG_RUN
        else:
            g.phase = IDLE
            g.partition = ()

    # ------------------------------------------------------------ internals

    def _menu_sizes(self, g: GPU) -> List[int]:
        """The static partition restricted to sizes this GPU's slice menu
        actually offers."""
        return [s for s in self.sim.cfg.static_partition if s in g.space.slices]

    def _free_slices(self, g: GPU) -> List[int]:
        used = [rj.slice_size for rj in g.jobs.values() if rj.slice_size]
        free = self._menu_sizes(g)
        for s in used:
            if s in free:
                free.remove(s)
        return free

    def _assign(self, g: GPU):
        """(Re)assign this GPU's jobs to its fixed slices, best-first
        (paper: OptSta migrates jobs to larger slices on availability).
        All distinct size-subsets are solved in one batched DP; the winner
        is the first strict maximum in subset-enumeration order, exactly as
        the historical per-subset scan chose it."""
        sim = self.sim
        jids = list(g.jobs)
        if not jids:
            return
        sizes = self._menu_sizes(g)
        speeds = []
        for j in jids:
            job = sim.jobs[j]
            prof = job.profile_at(1.0 - job.remaining / job.work)
            sv = g.pm.speed_vector(prof)
            speeds.append({s: (sv.get(s, 0.0)
                               if g.space.slice_mem_gb(s) >= prof.mem_gb
                               and s >= job.qos_min_slice else 0.0)
                           for s in sizes})
        # best assignment of m jobs to the fixed multiset's best m slices;
        # the configured objective ranks the size-subsets (throughput's
        # first-strict-max over subset order is the historical np.argmax),
        # with each subset's watts from the GPU's own power model
        prof = sim.prof
        t0 = time.perf_counter() if prof is not None else 0.0
        part = tuple(sorted(sizes, reverse=True))
        # descending-lex dedup: for a non-increasing `part`, combinations()
        # already yields subsets in this order, so sorting pins the historical
        # subset-enumeration tie-break without trusting set hash order
        subs = sorted(set(itertools.combinations(part, len(jids))),
                      reverse=True)
        objs, perms, _ = assign_multisets(g.space, subs, speeds)
        objs = np.asarray(objs)
        if self.objective.needs_power:
            watts = np.asarray([g.power.partition_w(g.space, sub)
                                for sub in subs])
        else:
            watts = None
        idx = self.objective.select(objs, watts,
                                    np.ones(len(subs), dtype=bool))
        best_perm = perms[idx]
        if prof is not None:
            prof["alg1_s"] += time.perf_counter() - t0
        for jid, size in zip(jids, best_perm):
            g.jobs[jid].slice_size = int(size)
        g._spd_dirty = True
