"""NoPart: exclusive whole-GPU execution, no partitioning (paper §5 baseline).

One job per GPU on the full slice; everything else waits in the FCFS queue.
"""
from __future__ import annotations

from typing import List

from repro.core.jobs import Job
from repro.core.sim.gpu import GPU, IDLE, MIG_RUN
from repro.core.sim.policies.base import Policy, register_policy


@register_policy
class NoPartPolicy(Policy):
    name = "nopart"

    def placement_candidates(self, job: Job) -> List[GPU]:
        return [g for g in self.sim.up_gpus() if g.sched_ok and not g.jobs]

    # index contract: empty GPUs are exactly the count-0 buckets
    def admit_ok(self, g: GPU, job: Job) -> bool:
        return not g.jobs

    def admit_caps(self, job: Job):
        return 0, False

    def on_place(self, g: GPU, job: Job):
        g.phase = MIG_RUN
        g.partition = (g.space.full_size,)
        g.jobs[job.jid].slice_size = g.space.full_size
        g._spd_dirty = True

    def on_completion(self, g: GPU, job: Job):
        g.phase = IDLE
        g.partition = ()
