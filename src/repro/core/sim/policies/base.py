"""Scheduling-policy plugin layer.

A :class:`Policy` owns every scheduling decision the cluster makes; the
engine (``repro.core.sim.engine``) owns time, events and accounting.  The
hooks mirror the lifecycle of a job:

* ``admit``          — queue discipline (default FCFS; override for e.g. SRPT)
* ``placement_candidates`` — feasibility: the GPUs a queued job *may* land on
  under this policy's co-location rules (default: the engine's shared
  job-count / memory / spare-slice checks)
* ``pick_gpu``       — placement: delegates the choice among those
  candidates to the pluggable :class:`~repro.core.sim.placement.Placer`
  named by ``SimConfig.placer`` (``least-loaded`` by default)
* ``on_place``       — set the GPU's phase/partition after a job lands
* ``on_phase_end``   — a CKPT/MPS_PROF timer expired; advance the state machine
* ``on_phase_end_batch`` — several timers expired at one tick (the engine
  coalesces them); default replays ``on_phase_end`` sequentially
* ``on_completion``  — a job finished; reshape what is left on the GPU
* ``mps_phase_speeds`` — how co-located jobs progress during an MPS phase

New policies subclass :class:`Policy`, set ``name``, and decorate with
:func:`register_policy`; they are then reachable from ``SimConfig.policy``,
``repro.launch.cluster --policy`` and the benchmark harness with no engine
changes.  See ``miso_frag.py`` / ``srpt.py`` for ~30-line examples.

The *goal* of the partition search is a third pluggable layer: the
:class:`~repro.core.sim.objectives.Objective` named by
``SimConfig.objective`` (default ``throughput``, the paper's Eq. 2–4 and
bit-identical to the historical optimizer; ``energy`` / ``edp`` trade
throughput for watts).  ``choose_partition`` threads it — plus the target
GPU's per-kind power model — into every Algorithm-1 call.
"""
from __future__ import annotations

import time
from abc import ABC, abstractmethod
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple, Type

from repro.core.estimators import OracleEstimator
from repro.core.jobs import Job, JobProfile
from repro.core.optimizer import optimize_partition, optimize_partition_batch
from repro.core.perfmodel import MPS_LEVELS
from repro.core.sim.gpu import CKPT, GPU, IDLE, MIG_RUN
from repro.core.sim.objectives import get_objective
from repro.core.sim.placement import get_placer

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.core.sim.engine import ClusterSim

_REGISTRY: Dict[str, Type["Policy"]] = {}


def register_policy(cls: Type["Policy"]) -> Type["Policy"]:
    """Class decorator: make ``cls`` reachable as ``SimConfig.policy=name``."""
    if not getattr(cls, "name", None):
        raise ValueError(f"{cls.__name__} must define a non-empty `name`")
    if cls.name in _REGISTRY:
        raise ValueError(f"duplicate policy name {cls.name!r} "
                         f"({_REGISTRY[cls.name].__name__} vs {cls.__name__})")
    _REGISTRY[cls.name] = cls
    return cls


def get_policy(name: str) -> Type["Policy"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduling policy {name!r}; "
            f"available: {', '.join(available_policies())}") from None


def available_policies() -> List[str]:
    return sorted(_REGISTRY)


class EstimateWork:
    """One MPS profiling window collected for a fused estimator pass.

    Produced by :meth:`Policy.collect_phase_end`; the owner (BatchSim)
    groups items by estimator object and fills ``ests`` with one
    ``estimate_batch`` call per group — one stacked predictor forward for
    every same-tick window across every replica."""

    __slots__ = ("g", "jids", "profs", "qos", "mat", "ests")

    def __init__(self, g: GPU, jids, profs, qos, mat):
        self.g = g
        self.jids = jids
        self.profs = profs
        self.qos = qos
        self.mat = mat          # measured MPS matrix (None: estimator-only)
        self.ests: Optional[list] = None   # filled by the owner (stage A)


class RepartDecision:
    """One pending Algorithm-1 decision collected for a fused solve.

    Produced by :meth:`Policy.collect_repartitions`; the owner groups
    decisions by (space, power, objective) and fills ``choice`` through
    ``optimize_partition_batch`` —  exactly the stacked DP
    ``choose_partition_batch`` runs, so the solved choice is bit-identical
    to the scalar ``repartition`` path.  ``Policy.apply_decision`` then
    applies it."""

    __slots__ = ("policy", "g", "jids", "speeds", "overhead", "choice")

    def __init__(self, policy: "Policy", g: GPU, jids, speeds,
                 overhead: bool):
        self.policy = policy
        self.g = g
        self.jids = jids
        self.speeds = speeds
        self.overhead = overhead
        self.choice = None      # filled by the owner (stage C)


class Policy(ABC):
    """Base class for scheduling policies (one instance per simulation)."""

    name: str = ""

    def __init__(self, sim: "ClusterSim"):
        self.sim = sim
        self.placer = get_placer(sim.cfg.placer)(sim)
        self.objective = get_objective(sim.cfg.objective)()
        self.indexable = self._index_exact()
        # blocked-head cache: (head jid, index version) when the last admit
        # stalled — feasibility depends only on resident sets and the
        # up-set, both versioned by the fleet index, so an unchanged pair
        # means the head still fits nowhere and the queue scan is skipped
        self._blocked: Optional[Tuple[int, int]] = None
        # 3-level MPS mean memo: profiles are immutable and drawn from a
        # bounded pool, so the mean speed list for a (perf model, profile
        # mix) pair never changes; the profile tuple is pinned in the value
        # so the id key cannot be recycled.  Callers never mutate the list.
        self._mps_mean_cache: Dict[tuple, tuple] = {}

    def _index_exact(self) -> bool:
        """Whether ``placement_candidates`` is faithfully described by the
        (``admit_ok``, ``admit_caps``) fleet-index contract: the class
        providing ``placement_candidates`` must itself provide ``admit_ok``
        (declaring the pair in sync).  A subclass that overrides the
        candidate rule alone falls back to the materialized scan instead of
        silently getting the base contract's candidates."""
        for klass in type(self).__mro__:
            if "placement_candidates" in vars(klass):
                return klass is Policy or "admit_ok" in vars(klass)
        return True

    # ------------------------------------------------------ queue discipline

    def admit(self):
        """FCFS: place queue-head jobs until the head does not fit.  A head
        recorded as blocked stays blocked until the fleet index version
        moves (placement, completion, eviction, failure, repair) — FCFS
        never looks past it, so the whole call short-circuits."""
        sim = self.sim
        sim._sync_up()                   # repair promotions bump the version
        if self._blocked is not None and sim.queue \
                and self._blocked == (sim.queue[0], sim.index.version):
            return
        while sim.queue:
            job = sim.jobs[sim.queue[0]]
            g = self.pick_gpu(job)
            if g is None:
                self._blocked = (job.jid, sim.index.version)
                return
            sim.queue.pop(0)
            sim.place(g, job)
        self._blocked = None

    # ---------------------------------------------------------- placement

    def placement_candidates(self, job: Job) -> List[GPU]:
        """GPUs ``job`` may land on under this policy's co-location rules.
        Default: the shared-MIG admission every partitioning policy uses —
        in-service, under the space's job cap, memory-feasible and passing
        the exact spare-slice check.  Policies with different co-location
        semantics (NoPart, MPS-only, OptSta) override *this* — together
        with the (``admit_ok``, ``admit_caps``) index contract below — so
        every placer composes with them."""
        sim = self.sim
        return [g for g in sim.up_gpus()
                if g.sched_ok and len(g.jobs) < g.space.max_jobs
                and sim.mem_ok(g, job) and sim.spare_slice_ok(g, job)]

    # The same admission as a fleet-index query, so placers can enumerate
    # feasible GPUs from the index instead of scanning the fleet: the index
    # applies ``admit_caps`` (resident-count cap; prune=True additionally
    # skips buckets whose max addable slice cannot cover the job — exactly
    # the spare-slice check for memory-monotone menus) and ``admit_ok``
    # settles whatever the buckets cannot.

    def admit_ok(self, g: GPU, job: Job) -> bool:
        """Per-GPU residue of ``placement_candidates`` once the index has
        applied this policy's caps.  Default: the memory check, plus the
        exact spare-slice check only where bucket pruning could not prove
        it (non-monotone menus, ``g._max_add is None``)."""
        sim = self.sim
        return sim.mem_ok(g, job) and (g._max_add is not None
                                       or sim.spare_slice_ok(g, job))

    def admit_caps(self, job: Job) -> Tuple[Optional[int], bool]:
        """(max resident count, prune by slice-requirement level) for the
        index query.  None = each kind's ``space.max_jobs - 1``."""
        return None, True

    def pick_gpu(self, job: Job) -> Optional[GPU]:
        """Choose a GPU for ``job`` (None leaves it queued): the pluggable
        placer ranks this policy's feasible candidates (straight off the
        fleet index wherever the policy's rule is index-expressible)."""
        prof = self.sim.prof
        if prof is None:
            return self.placer.pick_for(job, self)
        t0 = time.perf_counter()
        g = self.placer.pick_for(job, self)
        prof["placement_s"] += time.perf_counter() - t0
        return g

    # ------------------------------------------------------------ lifecycle

    @abstractmethod
    def on_place(self, g: GPU, job: Job):
        """``job`` was just added to ``g.jobs``; set phase / slices."""

    def on_phase_end(self, g: GPU):
        """A CKPT or MPS_PROF window on ``g`` ended (no-op by default —
        only profiling policies drive multi-step phase chains)."""

    def on_phase_end_batch(self, gs: Sequence[GPU]):
        """Several GPUs' windows ended at the same simulation tick (the
        engine drains the heap for same-tick timers).  Default: process
        sequentially in event order — results are identical because phase
        ends are cross-GPU independent.  Profiling policies override this to
        fuse the per-GPU estimator forwards into one batched inference."""
        for g in gs:
            self.on_phase_end(g)

    @abstractmethod
    def on_completion(self, g: GPU, job: Job):
        """``job`` finished and was removed from ``g.jobs``."""

    def on_completion_batch(self, items: Sequence[tuple]):
        """Several jobs finished at the same simulation tick, on distinct
        GPUs (``items`` is (gpu, job) pairs in event order; the engine
        drains the heap for same-tick completions).  Default: sequential —
        correct for policies whose completion reaction is local to the
        affected GPU.  MISO-family policies override this to fuse the
        re-optimizations into one batched Algorithm-1 pass."""
        for g, job in items:
            self.on_completion(g, job)

    # ------------------------------------------- collect/apply (BatchSim)
    # The staged twin of the batch hooks above, used by the replica-batched
    # engine (core/sim/batch.py): instead of estimating and solving inside
    # the hook, a policy *collects* its estimator windows and Algorithm-1
    # decisions so the owner can fuse them across replicas.  Contract: a
    # ``collect_*`` hook either returns None having touched NOTHING (the
    # engine falls back to the scalar batch hook), or performs all of its
    # non-fusable side effects and returns the collected work — never both.
    # The default implementations return None: a policy that doesn't opt in
    # simply runs its scalar hooks inside the batched engine, which keeps
    # the bit-identity contract trivially.

    def collect_phase_end(self, gs: Sequence[GPU]
                          ) -> Optional[List[EstimateWork]]:
        """Collect this tick's estimator windows instead of running them.
        None (default) = no fusable work: the engine processes the tick via
        ``on_phase_end_batch``.  A non-None return must be non-empty; the
        engine will call :meth:`apply_phase_end` with the estimated work."""
        return None

    def apply_phase_end(self, gs: Sequence[GPU],
                        work: Sequence[EstimateWork]
                        ) -> List[RepartDecision]:
        """Resume the phase-end tick once ``work[i].ests`` are filled:
        store estimates / run non-profiling transitions in scalar hook
        order, and return the repartition decisions still to be solved."""
        raise NotImplementedError(
            f"{type(self).__name__}.collect_phase_end returned work but "
            f"apply_phase_end is not implemented")

    def collect_completion(self, items: Sequence[tuple]
                           ) -> Optional[List[RepartDecision]]:
        """Collect this tick's completion-triggered repartitions.  None
        (default) = not supported: the engine falls back to
        ``on_completion_batch``.  A supporting policy performs its
        non-repartition side effects and returns the (possibly empty)
        decision list."""
        return None

    def collect_repartitions(self, gs: Sequence[GPU], overhead: bool = False
                             ) -> List[RepartDecision]:
        """Collect-mode twin of :meth:`repartition_many`: emptied GPUs go
        IDLE immediately (no optimizer run, exactly as the scalar path);
        the rest become pending decisions carrying their slice-speed
        estimates.  The solved choices are applied by
        :meth:`apply_decision` in collection order — cross-GPU independent,
        so any order is bit-identical to the scalar loop."""
        out: List[RepartDecision] = []
        for g in gs:
            jids = list(g.jobs)
            if not jids:
                g.phase = IDLE
                g.partition = ()
                continue
            out.append(RepartDecision(self, g, jids,
                                      self.partition_speeds(g, jids),
                                      overhead))
        return out

    def apply_decision(self, d: RepartDecision) -> None:
        """Apply one solved repartition decision (stage D)."""
        self._apply_choice(d.g, d.jids, d.choice, d.overhead)

    def on_fault_evict(self, g: GPU):
        """Fault injection just killed *some* residents of ``g``
        (``ClusterSim.crash_jobs``); the GPU itself stays in service.
        Reshape what is left.  Default: only reset an emptied GPU —
        partitioning policies override to re-optimize the survivors."""
        if not g.jobs:
            g.phase = IDLE
            g.partition = ()

    # ------------------------------------------------------------ MPS model

    def mps_phase_speeds(self, profs: Sequence[JobProfile],
                         g: Optional[GPU] = None):
        """Per-job progress rates while ``g`` is in an MPS phase.  The
        profiling sweep runs 3 levels back-to-back, so use the mean
        (accumulated in level order, matching np.mean's axis-0 reduction
        bit-for-bit).  ``g=None`` falls back to the homogeneous default
        perf model."""
        pm = g.pm if g is not None else self.sim.pm
        key = (id(pm),) + tuple(id(p) for p in profs)
        hit = self._mps_mean_cache.get(key)
        if hit is not None and all(a is b for a, b in zip(hit[0], profs)):
            return hit[1]
        m0, m1, m2 = (pm.mps_speeds(profs, lv) for lv in MPS_LEVELS)
        out = [((a + b) + c) / 3.0 for a, b, c in zip(m0, m1, m2)]
        if len(self._mps_mean_cache) >= 65536:
            self._mps_mean_cache.pop(next(iter(self._mps_mean_cache)))
        self._mps_mean_cache[key] = (tuple(profs), out)
        return out

    # -------------------------------------------------- partition machinery
    # Shared by every MIG-partitioning policy (miso / oracle / variants).

    def partition_speeds(self, g: GPU, jids: Sequence[int]) -> List[Dict[int, float]]:
        """Per-job slice-speed estimates used by the optimizer; the default
        reads the estimates cached on the GPU at profiling time."""
        return [g.estimates.get(j, {g.space.full_size: 1.0})
                for j in jids]

    def sanitize_estimate(self, g: GPU, jid: int,
                          est: Dict[int, float]) -> Dict[int, float]:
        """Graceful degradation for estimator faults: a slice-speed map is
        valid iff every value is a finite fraction in [0, 1.5] (slight
        super-linearity tolerated) and at least one slice shows progress.
        Garbage degrades to the job's last-known-good estimate when one is
        cached, else to the oracle's ground-truth slice speeds — Algorithm 1
        never sees NaNs, negatives or an all-zero map."""
        ok = True
        mx = 0.0
        for v in est.values():
            if not (0.0 <= v <= 1.5):        # NaN fails this comparison too
                ok = False
                break
            if v > mx:
                mx = v
        if ok and mx > 0.0:
            return est
        prev = g.estimates.get(jid)
        if prev is not None:
            return prev
        job = self.sim.jobs[jid]
        prof = job.profile_at(1.0 - job.remaining / job.work)
        return OracleEstimator(g.pm).estimate(
            [prof], qos=[job.qos_min_slice])[0]

    def choose_partition(self, speeds: Sequence[Dict[int, float]],
                         space=None, power=None):
        """Algorithm 1 under the configured objective: feasible-first, fall
        back to best-effort.  ``space`` is the target GPU's partition space
        (defaults to the homogeneous one); ``power`` its per-kind
        :class:`~repro.core.fleet.PowerModel`, consumed by energy-aware
        objectives (``None`` = reference a100)."""
        space = space if space is not None else self.sim.space
        return optimize_partition(space, speeds, require_feasible=True,
                                  objective=self.objective, power=power) \
            or optimize_partition(space, speeds,
                                  objective=self.objective, power=power)

    def choose_partition_batch(self, speeds_list, space=None, power=None):
        """Algorithm 1 for several decisions against one space at once,
        via the stacked DP (``optimize_partition_batch``) — element i equals
        ``choose_partition(speeds_list[i], space)`` exactly.  Policies that
        override ``choose_partition`` fall back to their per-decision logic
        automatically."""
        space = space if space is not None else self.sim.space
        if type(self).choose_partition is not Policy.choose_partition:
            return [self.choose_partition(sp, space=space, power=power)
                    for sp in speeds_list]
        first = optimize_partition_batch(space, speeds_list,
                                         require_feasible=True,
                                         objective=self.objective, power=power)
        return [c if c is not None else
                optimize_partition(space, sp,
                                   objective=self.objective, power=power)
                for c, sp in zip(first, speeds_list)]

    def repartition(self, g: GPU, overhead: bool = False):
        """Run the optimizer with current estimates and apply the partition;
        ``overhead=True`` charges a checkpoint+reconfigure window when the
        partition actually changes."""
        jids = list(g.jobs)
        if not jids:
            g.phase = IDLE
            g.partition = ()
            return
        prof = self.sim.prof
        if prof is None:
            choice = self.choose_partition(self.partition_speeds(g, jids),
                                           space=g.space, power=g.power)
        else:
            t0 = time.perf_counter()
            speeds = self.partition_speeds(g, jids)
            t1 = time.perf_counter()
            choice = self.choose_partition(speeds, space=g.space,
                                           power=g.power)
            t2 = time.perf_counter()
            prof["estimator_s"] += t1 - t0   # oracle runs its estimator here
            prof["alg1_s"] += t2 - t1
        self._apply_choice(g, jids, choice, overhead)

    def repartition_many(self, gs: Sequence[GPU], overhead: bool = False):
        """Repartition several GPUs in one batched Algorithm-1 pass (grouped
        by partition space + power model — one shared spec per kind, so the
        group key is stable).  Equivalent to calling :meth:`repartition` per
        GPU in order — used by the same-tick phase-end batch."""
        per_space: Dict[tuple, List] = {}
        for g in gs:
            jids = list(g.jobs)
            if not jids:
                g.phase = IDLE
                g.partition = ()
                continue
            per_space.setdefault((id(g.space), id(g.power)),
                                 []).append((g, jids))
        prof = self.sim.prof
        for items in per_space.values():
            g0 = items[0][0]
            if prof is None:
                choices = self.choose_partition_batch(
                    [self.partition_speeds(g, jids) for g, jids in items],
                    space=g0.space, power=g0.power)
            else:
                t0 = time.perf_counter()
                speeds = [self.partition_speeds(g, jids)
                          for g, jids in items]
                t1 = time.perf_counter()
                choices = self.choose_partition_batch(
                    speeds, space=g0.space, power=g0.power)
                t2 = time.perf_counter()
                # the prof clocks are metrics-only (sweep --profile) and
                # never feed simulation state, hence the MS107 suppression
                prof["estimator_s"] += t1 - t0  # misolint: disable=MS107 -- prof clock bucket, metrics-only
                prof["alg1_s"] += t2 - t1
            for (g, jids), choice in zip(items, choices):
                self._apply_choice(g, jids, choice, overhead)

    def _apply_choice(self, g: GPU, jids, choice, overhead: bool):
        old = tuple(rj.slice_size for rj in g.jobs.values())
        for jid, size in zip(jids, choice.partition):
            g.jobs[jid].slice_size = size
        g._spd_dirty = True
        g.partition = tuple(sorted(choice.partition, reverse=True))
        if overhead and old != tuple(choice.partition):
            g.phase = CKPT
            g.phase_end = self.sim.t + g.ckpt_duration()
            g.needs_profile = False
        else:
            g.phase = MIG_RUN
