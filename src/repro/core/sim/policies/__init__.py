"""Pluggable scheduling policies for the cluster simulator.

Importing this package registers every built-in policy; external code can
add more with::

    from repro.core.sim.policies import Policy, register_policy

    @register_policy
    class MyPolicy(Policy):
        name = "mine"
        ...
"""
from repro.core.sim.policies.base import (Policy, available_policies,
                                          get_policy, register_policy)

# importing the modules registers the built-ins
from repro.core.sim.policies import (miso, miso_frag, mpsonly, nopart,  # noqa: F401
                                     optsta, oracle, srpt)
from repro.core.sim.policies.miso import MisoPolicy
from repro.core.sim.policies.miso_frag import MisoFragPolicy
from repro.core.sim.policies.mpsonly import MpsOnlyPolicy
from repro.core.sim.policies.nopart import NoPartPolicy
from repro.core.sim.policies.optsta import OptStaPolicy
from repro.core.sim.policies.oracle import OraclePolicy
from repro.core.sim.policies.srpt import SrptPolicy

__all__ = [
    "Policy", "register_policy", "get_policy", "available_policies",
    "MisoPolicy", "MisoFragPolicy", "MpsOnlyPolicy", "NoPartPolicy",
    "OptStaPolicy", "OraclePolicy", "SrptPolicy",
]
