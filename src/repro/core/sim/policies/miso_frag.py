"""Fragmentation-aware MISO (after the fragmentation-aware MIG scheduling
line of work, e.g. Ting et al., arXiv 2512.16099).

Plain MISO maximizes instantaneous throughput (Algorithm 1) and is blind to
what the chosen partition does to *future* placements: (4g, 2g) and (3g, 3g)
can score within a hair of each other, yet only one of them leaves room to
grow a contiguous slice for the next arrival.  This variant keeps the MISO
pipeline intact and only changes the partition choice: among partitions whose
predicted throughput is within ``frag_tolerance`` of the optimum, prefer the
one that keeps the largest contiguous slice free (then higher throughput,
then fewer compute slots used).

This is exactly the kind of drop-in the policy layer exists for: ~30 lines,
zero engine changes.
"""
from __future__ import annotations

from typing import Dict, Sequence

from repro.core.optimizer import _assign_dp
from repro.core.optimizer import PartitionChoice
from repro.core.sim.policies.base import register_policy
from repro.core.sim.policies.miso import MisoPolicy


@register_policy
class MisoFragPolicy(MisoPolicy):
    name = "miso-frag"

    frag_tolerance = 0.05      # accept up to 5% predicted-STP loss for space

    def choose_partition(self, speeds: Sequence[Dict[int, float]],
                         space=None):
        space = space if space is not None else self.sim.space
        m = len(speeds)
        cands = []                       # (obj, feasible, spare, perm, part)
        for part in space.partitions_of_len(m):
            obj, perm = _assign_dp(part, speeds)
            feasible = all(speeds[j].get(perm[j], 0.0) > 0.0 for j in range(m))
            cands.append((obj, feasible, space.largest_free_slice(part),
                          perm, part))
        pool = [c for c in cands if c[1]] or cands
        best_obj = max(c[0] for c in pool)
        near = [c for c in pool if c[0] >= (1.0 - self.frag_tolerance) * best_obj]
        used = lambda part: sum(space.slices[s].compute_slots for s in part)
        obj, feasible, _, perm, part = max(
            near, key=lambda c: (c[2], c[0], -used(c[4])))
        return PartitionChoice(perm, obj, feasible)
