"""Fragmentation-aware MISO (after the fragmentation-aware MIG scheduling
line of work, e.g. Ting et al., arXiv 2512.16099).

Plain MISO maximizes instantaneous throughput (Algorithm 1) and is blind to
what the chosen partition does to *future* placements: (4g, 2g) and (3g, 3g)
can score within a hair of each other, yet only one of them leaves room to
grow a contiguous slice for the next arrival.  This variant keeps the MISO
pipeline intact and only changes the partition choice: among partitions whose
predicted throughput is within ``frag_tolerance`` of the optimum, prefer the
one that keeps the largest contiguous slice free (then higher throughput,
then fewer compute slots used).

The per-partition objectives come from the same batched Algorithm-1 kernel
the base policy uses (one numpy pass over every multiset); the fragmentation
scores and compute-slot counts are precomputed per length at
:class:`~repro.core.partitions.PartitionSpace` construction, so this policy
adds no per-decision Python loops beyond the final (tiny) tolerance scan.
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from repro.core.optimizer import PartitionChoice, solve_all_partitions
from repro.core.sim.objectives import partition_watts, resolve_power
from repro.core.sim.policies.base import register_policy
from repro.core.sim.policies.miso import MisoPolicy


@register_policy
class MisoFragPolicy(MisoPolicy):
    name = "miso-frag"

    frag_tolerance = 0.05      # accept up to 5% predicted-score loss for space

    def choose_partition(self, speeds: Sequence[Dict[int, float]],
                         space=None, power=None):
        space = space if space is not None else self.sim.space
        m = len(speeds)
        objs, perms, feas = solve_all_partitions(space, speeds)
        spare = space.part_spare(m)
        used = space.part_compute(m)
        # the tolerance band is judged on the configured objective's row
        # scores (throughput -> the raw objs array, so the default is
        # bit-identical to the historical scan), restricted to the
        # objective's eligible rows so its guarantees (e.g. the energy
        # QoS floor) survive the fragmentation scan
        if self.objective.needs_power:
            watts = partition_watts(space, resolve_power(power), m)
        else:
            watts = None
        scores = self.objective.score_rows(objs, watts)
        mask = feas if feas.any() else np.ones(objs.shape[0], dtype=bool)
        pool = np.nonzero(self.objective.eligible(objs, watts, mask))[0]
        best = float(scores[pool].max())
        near = pool[scores[pool] >= (1.0 - self.frag_tolerance) * best]
        # first strict max of (spare, score, -compute slots used) — the
        # same tie-breaking as a Python max() over rows in partition order
        win = near[0]
        for i in near[1:]:
            if (spare[i], scores[i], -used[i]) > (spare[win], scores[win],
                                                  -used[win]):
                win = i
        return PartitionChoice(tuple(int(s) for s in perms[win]),
                               float(objs[win]), bool(feas[win]))
