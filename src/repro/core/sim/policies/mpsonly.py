"""MPS-only: co-locate jobs under CUDA MPS at a fixed active-thread level,
never partition (paper §5 / Fig 15 baseline).  Jobs progress at
interference-prone MPS speeds for their whole life.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.core.jobs import Job, JobProfile
from repro.core.sim.gpu import GPU, IDLE, MPS_PROF
from repro.core.sim.policies.base import Policy, register_policy


@register_policy
class MpsOnlyPolicy(Policy):
    name = "mpsonly"

    def placement_candidates(self, job: Job) -> List[GPU]:
        sim = self.sim
        return [g for g in sim.up_gpus()
                if g.sched_ok and len(g.jobs) < sim.cfg.mps_only_max_jobs
                and sim.mem_ok(g, job)]

    # index contract: the job-count cap lives in the buckets; no partitions
    # are ever built, so slice-requirement pruning must stay off
    def admit_ok(self, g: GPU, job: Job) -> bool:
        return self.sim.mem_ok(g, job)

    def admit_caps(self, job: Job):
        return self.sim.cfg.mps_only_max_jobs - 1, False

    def on_place(self, g: GPU, job: Job):
        g.phase = MPS_PROF               # progresses at MPS speeds forever
        g.phase_end = float("inf")

    def on_completion(self, g: GPU, job: Job):
        if not g.jobs:
            g.phase = IDLE

    def mps_phase_speeds(self, profs: Sequence[JobProfile], g=None):
        pm = g.pm if g is not None else self.sim.pm
        return pm.mps_speeds(profs, self.sim.cfg.mps_only_level)
