"""Pluggable optimization objectives for Algorithm 1.

MISO's Algorithm 1 historically maximized one thing: predicted aggregate
throughput ``sum_i f_i(x_i)``.  This module makes the *goal* of the
partition search a swappable layer mirroring the policy and placer
registries: an :class:`Objective` scores candidate partitions from the
batched DP's per-row throughput plus the per-row electrical power
(:class:`~repro.core.fleet.PowerModel`), and :mod:`repro.core.optimizer`
selects the winning row with it.

The decomposition that keeps the vectorized bitmask-DP intact: per-slice
active power depends only on the slice *kind*, never on which job runs in
it, so a partition row's wall watts are constant across job→slice
assignments.  The DP therefore still solves the assignment by maximizing
additive speeds (the best-throughput assignment is also the best
energy/EDP assignment within a row), and the objective only re-ranks the
*rows* — ``select`` picks the first strict maximum of ``score_rows`` over
the candidate pool, the same tie-break rule as the historical scan.

Built-ins:

* ``throughput`` — the paper's objective and the default.  The optimizer
  recognizes it and takes the historical code path unchanged, so it is
  bit-identical to the pre-objective DP (proven by the golden traces).
* ``energy``     — minimize joules per unit of work (maximize work per
  joule, ``T / P``) subject to a QoS floor: only rows achieving at least
  ``qos_floor`` of the best attainable throughput are considered, so the
  scheduler never starves jobs to shave watts — and never stretches the
  makespan into idle-floor losses that dwarf the per-slice savings.
* ``edp``        — energy-delay product (maximize ``T^2 / P``) within a
  slightly looser floor: the classic balanced efficiency metric.

Feasibility (memory + per-job QoS slice floors, encoded as zero speeds by
the estimators) is orthogonal: the optimizer restricts the pool to rows
whose winning assignment gives every job a non-zero speed exactly as the
throughput path does, so no objective can pick a QoS-violating partition
when a feasible one exists.

Registering a new goal is ~10 lines::

    @register_objective
    class CarbonObjective(Objective):
        name = "carbon"

        def score_rows(self, objs, watts):
            return objs / (watts * CARBON_INTENSITY)
"""
from __future__ import annotations

from abc import ABC, abstractmethod
from typing import Dict, List, Optional, Type, Union

import numpy as np

DEFAULT_OBJECTIVE = "throughput"

_REGISTRY: Dict[str, Type["Objective"]] = {}


def register_objective(cls: Type["Objective"]) -> Type["Objective"]:
    """Class decorator: make ``cls`` reachable as ``SimConfig.objective``."""
    if not getattr(cls, "name", None):
        raise ValueError(f"{cls.__name__} must define a non-empty `name`")
    if cls.name in _REGISTRY:
        raise ValueError(f"duplicate objective name {cls.name!r} "
                         f"({_REGISTRY[cls.name].__name__} vs {cls.__name__})")
    _REGISTRY[cls.name] = cls
    return cls


def get_objective(name: str) -> Type["Objective"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown objective {name!r}; "
            f"available: {', '.join(available_objectives())}") from None


def available_objectives() -> List[str]:
    return sorted(_REGISTRY)


def resolve_objective(objective: Union[str, "Objective", None]
                      ) -> Optional["Objective"]:
    """Normalize an objective argument to an instance — or ``None`` for the
    default throughput goal, which callers treat as "take the historical
    bit-identical path"."""
    if objective is None:
        return None
    if isinstance(objective, str):
        objective = get_objective(objective)()
    if objective.name == DEFAULT_OBJECTIVE:
        return None
    return objective


def resolve_power(power):
    """The :class:`~repro.core.fleet.PowerModel` to score with; ``None``
    falls back to the reference a100 model (import deferred — the fleet
    module pulls in the estimator/predictor stack)."""
    if power is not None:
        return power
    from repro.core.fleet import DEFAULT_POWER
    return DEFAULT_POWER


# per-(space, power, length) row-watts vectors; spaces and power models are
# tiny interned value objects, so this stays small and lives process-wide
_WATTS_CACHE: Dict[tuple, np.ndarray] = {}
_WATTS_MAX = 4096


def partition_watts(space, power, m: int) -> np.ndarray:
    """(P,) wall watts of every valid length-``m`` partition of ``space``
    under ``power`` (idle floor + per-slice active draw), rows in
    ``space.partitions_of_len(m)`` order — the dense companion of
    ``space.part_sizes(m)`` for objective scoring."""
    key = (space.uid, power, m)
    watts = _WATTS_CACHE.get(key)
    if watts is None:
        cols = space.part_cols(m)
        per_col = np.asarray([power.active_w(space.compute_frac(s))
                              for s in space.sizes], dtype=np.float64)
        if cols.shape[0] == 0:
            watts = np.empty((0,), dtype=np.float64)
        else:
            watts = power.idle_w + per_col[cols].sum(axis=1)
        if len(_WATTS_CACHE) >= _WATTS_MAX:
            _WATTS_CACHE.pop(next(iter(_WATTS_CACHE)))
        _WATTS_CACHE[key] = watts
    return watts


def first_strict_max(scores: np.ndarray, pool: np.ndarray) -> int:
    """Index of the first maximal score within ``pool`` — ``np.argmax``'s
    first-occurrence rule, which replicates the historical strictly-greater
    replacement scan over rows in partition order."""
    return int(np.argmax(np.where(pool, scores, -np.inf)))


class Objective(ABC):
    """Scores candidate partition rows (one instance per simulation).

    ``objs`` is the (P,) best-assignment predicted throughput per row from
    the batched DP; ``watts`` the (P,) row wall power (``None`` when
    ``needs_power`` is False); ``pool`` a (P,) bool mask of admissible rows
    (feasibility under ``require_feasible``).  ``select`` must return an
    index into the pool; the default takes the first strict maximum of
    ``score_rows``, matching the historical tie-break.
    """

    name: str = ""
    needs_power: bool = True

    @abstractmethod
    def score_rows(self, objs: np.ndarray,
                   watts: Optional[np.ndarray]) -> np.ndarray:
        """Per-row goodness (higher is better)."""

    def eligible(self, objs: np.ndarray, watts: Optional[np.ndarray],
                 pool: np.ndarray) -> np.ndarray:
        """Restrict ``pool`` to rows this objective may pick at all (e.g.
        a QoS floor).  Must never return an empty mask for a non-empty
        pool.  Consumers that rank rows themselves (miso-frag's tolerance
        scan) must restrict to this mask, or they silently void the
        objective's guarantees."""
        return pool

    def select(self, objs: np.ndarray, watts: Optional[np.ndarray],
               pool: np.ndarray) -> int:
        return first_strict_max(self.score_rows(objs, watts),
                                self.eligible(objs, watts, pool))

    def memo_key(self) -> tuple:
        """Hashable identity for the optimizer's memo (instances are
        parameterless; subclasses with knobs must extend this)."""
        return (self.name,)


@register_objective
class ThroughputObjective(Objective):
    """The paper's Eq. 2–4 goal: maximize predicted aggregate throughput.
    The optimizer special-cases this name onto the historical code path, so
    it never actually scores through here during simulation — the methods
    exist for generic consumers (miso-frag's tolerance scan, tests)."""

    name = "throughput"
    needs_power = False

    def score_rows(self, objs, watts):
        return objs


@register_objective
class EnergyObjective(Objective):
    """Minimize joules per unit of work, subject to a QoS floor.

    A row's energy per work-second is ``watts / throughput``; maximizing
    ``throughput / watts`` minimizes it.  Only rows achieving at least
    ``qos_floor`` of the pool's best throughput are eligible; the row
    attaining the best throughput is always eligible, so the floor can
    never empty the pool.

    The floor is deliberately tight (0.95): a per-GPU decision only sees
    its own instantaneous watts, but a throughput sacrifice is paid
    *cluster-wide* — the queue drains slower, the makespan stretches, and
    every GPU's idle floor (plus MISO's full-power profiling windows)
    burns for the extra time.  Empirically on the heterogeneous sweep
    cell, floors of 0.75–0.9 *increase* total joules through exactly that
    idle-stretching; 0.95 harvests only the near-free watt savings (a
    small job running at ~full speed on a cheap slice) and reduces total
    joules at ~unchanged JCT.
    """

    name = "energy"
    qos_floor = 0.95          # min fraction of attainable throughput

    def score_rows(self, objs, watts):
        return objs / np.maximum(watts, 1e-9)

    def eligible(self, objs, watts, pool):
        best_t = objs[pool].max()
        return pool & (objs >= self.qos_floor * best_t - 1e-12)

    def memo_key(self):
        return (self.name, self.qos_floor)


@register_objective
class EdpObjective(EnergyObjective):
    """Energy-delay product: maximize ``throughput^2 / watts`` (equivalently
    minimize ``watts / T^2 = (energy per work) x (delay per work)``) within
    the same QoS floor as ``energy``.  The quadratic throughput term
    self-limits *within* the eligible pool, but a per-decision T^2 still
    underweights the cluster-wide queueing externality of slowing down
    (see :class:`EnergyObjective` — a looser 0.9 floor measurably
    *increased* both JCT and joules on the heterogeneous sweep cell), so
    the tight floor stays; within it, edp leans toward faster rows than
    energy does."""

    name = "edp"

    def score_rows(self, objs, watts):
        return objs * objs / np.maximum(watts, 1e-9)
