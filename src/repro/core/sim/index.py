"""Incremental indexed structures for the engine's placement hot path.

Pre-refactor, every placement decision rescanned the whole fleet: the
candidate filter walked ``up_gpus()`` (itself rebuilt per call) and ran the
memory + spare-slice checks on each GPU, and the hetero-speed placer summed
every queued and resident job's remaining work for its split point — all
O(fleet) or O(jobs) per decision, which is what kept production-trace scale
(5,000 GPUs / 100K jobs) out of reach.  This module holds the replacement
structures; the engine owns their maintenance at its (few) mutation points:

* :class:`FleetIndex` — per-kind buckets of in-service GPUs keyed
  ``(resident count, max addable slice)``, each bucket a sorted gid list.
  ``first()`` streams GPUs in exactly the least-loaded order — count
  ascending, gid ascending within a count, merged across kinds — returning
  the first one that passes the policy's admission predicate, so the
  paper's ``min(candidates, key=(len(jobs), gid))`` rule is reproduced
  bit-for-bit without materializing the candidate list.  The *max addable
  slice* dimension (``GPU._max_add``, maintained by the engine from the
  exact spare-slice feasibility) prunes whole buckets: a saturated fleet is
  skipped in O(buckets), not O(GPUs).
* :class:`WorkAggregate` — Kahan-compensated running sum of in-system
  remaining work, updated as jobs arrive / progress / complete / roll back,
  turning the hetero-speed placer's split point into O(1).

The index only ever *accelerates* enumeration — feasibility itself stays
with ``Policy.admit_ok`` / the engine's exact checks, so a policy the index
cannot see (one that overrides ``placement_candidates`` wholesale) simply
falls back to the materialized path.
"""
from __future__ import annotations

import heapq
from bisect import bisect_left, insort
from typing import TYPE_CHECKING, Callable, Dict, List, Optional

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.core.jobs import Job
    from repro.core.sim.engine import ClusterSim
    from repro.core.sim.gpu import GPU


class WorkAggregate:
    """Kahan-compensated sum of remaining work over in-system jobs.

    ``count`` tracks how many jobs the total covers; consumers compare it
    against the engine's queue + resident population and fall back to an
    exact recompute on mismatch (hand-built test sims assign ``sim.queue``
    directly and never see the arrival hook)."""

    __slots__ = ("total", "count", "_c")

    def __init__(self):
        self.total = 0.0
        self.count = 0
        self._c = 0.0

    def add(self, x: float):
        """A job entered the system (arrival)."""
        self.count += 1
        self._acc(x)

    def discard(self, x: float):
        """A job left the system (completion) holding ``x`` remaining."""
        self.count -= 1
        self._acc(-x)

    def shift(self, dx: float):
        """An in-system job's remaining work changed by ``dx`` in place
        (progress integration, failure rollback)."""
        self._acc(dx)

    def _acc(self, x: float):
        y = x - self._c
        t = self.total + y
        self._c = (t - self.total) - y
        self.total = t


class _Kind:
    """Buckets for one GPU kind (one shared :class:`GPUSpec`)."""

    __slots__ = ("space", "speed", "levels", "n_levels", "counts")

    def __init__(self, space, speed: float):
        self.space = space
        self.speed = speed
        # level 0 = nothing addable; level k = k-th smallest menu size is
        # the largest still-addable slice.  Feasibility is monotone in the
        # requirement, so "admits a job needing r" == "level >= level(r)".
        self.levels: Dict[int, int] = {0: 0}
        for k, s in enumerate(sorted(space.sizes)):
            self.levels[s] = k + 1
        self.n_levels = len(space.sizes) + 1
        self.counts: List[List[List[int]]] = []      # [count][level] -> gids

    def bucket(self, count: int, level: int) -> List[int]:
        while count >= len(self.counts):
            self.counts.append([[] for _ in range(self.n_levels)])
        return self.counts[count][level]


class FleetIndex:
    """Per-kind (count, max-addable-slice) buckets over in-service GPUs."""

    def __init__(self, sim: "ClusterSim"):
        self.sim = sim
        self._kinds: Dict[int, _Kind] = {}           # id(spec) -> _Kind
        #: bumps on every membership / up-set change; the FCFS admit's
        #: blocked-head cache keys on it (placement feasibility depends
        #: only on resident sets and the up-set, never on elapsed time)
        self.version = 0

    # ------------------------------------------------------- maintenance

    def _kind_of(self, g: "GPU") -> _Kind:
        kd = self._kinds.get(id(g.spec))
        if kd is None:
            kd = self._kinds[id(g.spec)] = _Kind(g.space, g.speed_scale)
        return kd

    def _level(self, kd: _Kind, g: "GPU") -> int:
        if g._max_add is None:                       # non-monotone menu:
            return kd.n_levels - 1                   # never prune it away
        return kd.levels[g._max_add]

    def add(self, g: "GPU"):
        """Insert an in-service GPU at its current (count, max_add)."""
        kd = self._kind_of(g)
        pos = (len(g.jobs), self._level(kd, g))
        insort(kd.bucket(*pos), g.gid)
        g._idx_pos = pos
        g._in_index = True
        self.version += 1

    def remove(self, g: "GPU"):
        """Drop a GPU (failure takes it out of service)."""
        if not g._in_index:
            return
        kd = self._kind_of(g)
        lst = kd.bucket(*g._idx_pos)
        del lst[bisect_left(lst, g.gid)]     # sorted: binary-search removal
        g._idx_pos = None
        g._in_index = False
        self.version += 1

    def update(self, g: "GPU"):
        """Re-bucket after a resident-set change on an in-service GPU."""
        kd = self._kind_of(g)
        pos = (len(g.jobs), self._level(kd, g))
        if pos != g._idx_pos:
            lst = kd.bucket(*g._idx_pos)
            del lst[bisect_left(lst, g.gid)]
            insort(kd.bucket(*pos), g.gid)
            g._idx_pos = pos
        self.version += 1

    # ------------------------------------------------------------ queries

    def first(self, pred: Callable[["GPU"], bool], job: "Job",
              max_count: Optional[int] = None, prune: bool = True,
              kinds: Optional[List[_Kind]] = None) -> Optional["GPU"]:
        """First GPU in least-loaded order — (resident count, gid), merged
        across kinds — passing ``pred``; None when nothing does.

        ``max_count`` caps the resident count (None = each kind's
        ``space.max_jobs - 1``, the default admission's cap; policies like
        MPS-only pass their own).  ``prune=True`` skips buckets whose max
        addable slice cannot cover ``job``'s exact slice requirement — only
        valid when ``pred`` implies the engine's spare-slice check, i.e.
        for the default shared-MIG admission."""
        return self._scan(pred, job, max_count, prune, kinds, None)

    def candidates(self, pred: Callable[["GPU"], bool], job: "Job",
                   max_count: Optional[int] = None, prune: bool = True,
                   kinds: Optional[List[_Kind]] = None) -> List["GPU"]:
        """Every GPU :meth:`first` would consider that passes ``pred`` —
        the policy's full candidate set, for placers that score rather than
        take the least-loaded order (frag-aware, best-fit-slice).  Count-
        major order, NOT the gid order ``Policy.placement_candidates``
        returns: callers must rank with an order-independent total key."""
        out: List["GPU"] = []
        self._scan(pred, job, max_count, prune, kinds, out)
        return out

    def _scan(self, pred, job, max_count, prune, kinds, collect):
        self.sim._sync_up()
        gpus = self.sim.gpus
        plans = []
        cmax = -1
        for kd in (kinds if kinds is not None else self._kinds.values()):
            cap = kd.space.max_jobs - 1 if max_count is None else max_count
            lvl0 = 0
            if prune:
                sp = kd.space
                if sp._mem_monotone:
                    r = sp.job_required_slice(job)
                    if r is None:
                        continue                 # no slice of this kind fits
                    lvl0 = kd.levels[r]
            cap = min(cap, len(kd.counts) - 1)
            if cap < 0:
                continue
            plans.append((kd, cap, lvl0))
            if cap > cmax:
                cmax = cap
        for c in range(cmax + 1):
            lists = []
            for kd, cap, lvl0 in plans:
                if c > cap:
                    continue
                rows = kd.counts[c]
                for i in range(lvl0, len(rows)):
                    lst = rows[i]
                    if lst:
                        lists.append(lst)
            if not lists:
                continue
            gids = lists[0] if len(lists) == 1 else heapq.merge(*lists)
            for gid in gids:
                g = gpus[gid]
                if pred(g):
                    if collect is None:
                        return g
                    collect.append(g)
        return None

    def speed_groups(self) -> List[tuple]:
        """Distinct speed scales ascending, each with its kinds — the
        hetero-speed placer walks them in preference order."""
        by_speed: Dict[float, List[_Kind]] = {}
        for kd in self._kinds.values():
            by_speed.setdefault(kd.speed, []).append(kd)
        return sorted(by_speed.items())
