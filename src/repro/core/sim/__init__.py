"""Event-driven cluster simulator, split into engine / GPU / policy layers.

* ``engine``   — event loop, clock, failures, accounting (:class:`ClusterSim`)
* ``gpu``      — per-GPU phase state machine ``IDLE→CKPT→MPS_PROF→MIG_RUN``
* ``policies`` — pluggable scheduling policies (``Policy`` ABC + registry)
* ``placement`` — pluggable placement layer (``Placer`` ABC + registry)
* ``objectives`` — pluggable Algorithm-1 goals (``Objective`` ABC + registry:
  ``throughput`` / ``energy`` / ``edp``)

``from repro.core.simulator import ...`` remains a supported alias.
"""
from repro.core.sim.engine import ClusterSim, SimConfig, simulate
from repro.core.sim.gpu import CKPT, GPU, IDLE, MIG_RUN, MPS_PROF, RJob
from repro.core.sim.objectives import (Objective, available_objectives,
                                       get_objective, register_objective)
from repro.core.sim.placement import (Placer, available_placers, get_placer,
                                      register_placer)
from repro.core.sim.policies import (Policy, available_policies, get_policy,
                                     register_policy)

__all__ = [
    "ClusterSim", "SimConfig", "simulate",
    "GPU", "RJob", "IDLE", "CKPT", "MPS_PROF", "MIG_RUN",
    "Policy", "register_policy", "get_policy", "available_policies",
    "Placer", "register_placer", "get_placer", "available_placers",
    "Objective", "register_objective", "get_objective",
    "available_objectives",
]
