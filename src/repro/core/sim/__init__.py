"""Event-driven cluster simulator, split into engine / GPU / policy layers.

* ``engine``   — event loop, clock, failures, accounting (:class:`ClusterSim`)
* ``gpu``      — per-GPU phase state machine ``IDLE→CKPT→MPS_PROF→MIG_RUN``
  plus the orthogonal health machine ``healthy→degraded→quarantined``
* ``policies`` — pluggable scheduling policies (``Policy`` ABC + registry)
* ``placement`` — pluggable placement layer (``Placer`` ABC + registry)
* ``objectives`` — pluggable Algorithm-1 goals (``Objective`` ABC + registry:
  ``throughput`` / ``energy`` / ``edp``)
* ``faults``   — pluggable fault injectors (``FaultInjector`` ABC + registry:
  ``mps_blast`` / ``flaky_reconfig`` / ``straggler`` / ``estimator_garbage``)

``from repro.core.simulator import ...`` remains a supported alias.
"""
from repro.core.sim.engine import ClusterSim, SimConfig, simulate
from repro.core.sim.faults import (FaultInjector, available_fault_injectors,
                                   get_fault_injector,
                                   register_fault_injector)
from repro.core.sim.gpu import (CKPT, DEGRADED, GPU, HEALTHY, IDLE, MIG_RUN,
                                MPS_PROF, QUARANTINED, RJob)
from repro.core.sim.objectives import (Objective, available_objectives,
                                       get_objective, register_objective)
from repro.core.sim.placement import (Placer, available_placers, get_placer,
                                      register_placer)
from repro.core.sim.policies import (Policy, available_policies, get_policy,
                                     register_policy)

__all__ = [
    "ClusterSim", "SimConfig", "simulate",
    "GPU", "RJob", "IDLE", "CKPT", "MPS_PROF", "MIG_RUN",
    "HEALTHY", "DEGRADED", "QUARANTINED",
    "Policy", "register_policy", "get_policy", "available_policies",
    "Placer", "register_placer", "get_placer", "available_placers",
    "Objective", "register_objective", "get_objective",
    "available_objectives",
    "FaultInjector", "register_fault_injector", "get_fault_injector",
    "available_fault_injectors",
]
