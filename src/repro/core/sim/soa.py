"""Struct-of-arrays fleet state: vectorized batch updates over per-GPU rows.

The simulator's hot state is stored in two layouts, each where it is
measurably fastest on the event loop's access patterns:

* **Per-resident columns** (``GPU._spd`` / ``_ckt`` / ``_ckw`` — speed,
  progressing-seconds-since-checkpoint and at-risk work per resident slot)
  live as slot-aligned *Python* lists on each GPU.  A GPU hosts at most
  ``space.max_jobs`` (7 on an a100) residents, and at that row length
  CPython list indexing beats numpy fancy/scalar indexing by 3-10x (a
  ``row[:k].tolist()`` round-trip alone costs more than the whole scalar
  update).  ``RJob`` is a *view* over one slot — policies keep reading
  ``rj.speed`` etc.; the engine's hot loops walk the columns directly.
* **Per-GPU rows** (energy integral, accounting clock, repair deadline)
  stay as plain attributes for the single-GPU per-event path, and this
  module gathers them into fleet-wide numpy arrays at *batch barriers* —
  points where one masked vector update replaces O(fleet) Python-loop
  iterations (the end-of-run settle, rack-scale evacuations, rollout
  sweeps).  All vector arithmetic is elementwise (sub/mul/maximum/where),
  which IEEE-754 guarantees bit-identical to the scalar expressions in
  ``GPU.advance`` — the repo's golden traces are the proof obligation, and
  :func:`settle_scalar` stays behind as the property-test oracle.

Masked-update contract
----------------------
``settle_all`` partitions the fleet by ``bool(g.jobs)``: resident-free GPUs
(idle floors, possibly under repair) take the vectorized path; GPUs with
residents route through ``GPU.advance`` so per-job progress, checkpoint
marks and the Kahan work-aggregate shifts keep their exact scalar operation
order.  The vector path reproduces ``advance``'s energy integral for the
resident-free case:

    dt   = t - last_update
    live = dt                      if last_update >= down_until
           max(0.0, t-down_until)  otherwise
    energy += idle_w * live        when dt > 0 and live > 0

(a resident-free GPU's wall power is exactly its idle floor in every
phase — see the watts derivation in ``GPU.advance``).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Sequence

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.core.sim.gpu import GPU


def settle_scalar(gpus: Sequence["GPU"], t: float) -> None:
    """Scalar reference settle: per-GPU ``advance`` in gid order.  This is
    the oracle the vectorized path is property-tested against — do not
    'optimize' it."""
    for g in gpus:
        g.advance(t)


class FleetState:
    """Fleet-wide SoA staging buffers + the vectorized batch operations.

    The object attributes on :class:`GPU` stay canonical; ``gather()``
    snapshots them into numpy arrays, the vector ops compute on the arrays,
    and ``scatter()`` writes results back.  Gather/scatter cost O(fleet)
    attribute traffic once per *batch*, not per event — the win is every
    Python-level ``advance`` call the mask elides.
    """

    __slots__ = ("gpus", "n", "idle_w", "last_update", "down_until",
                 "energy_j")

    def __init__(self, gpus: Sequence["GPU"]):
        self.gpus = list(gpus)
        self.n = len(self.gpus)
        # idle floors are fixed per GPU kind: gather once
        self.idle_w = np.array([g._idle_w for g in self.gpus])
        self.last_update = np.zeros(self.n)
        self.down_until = np.zeros(self.n)
        self.energy_j = np.zeros(self.n)

    # -------------------------------------------------------- staging I/O

    def gather(self) -> None:
        """Snapshot the per-GPU scalar attributes into the arrays."""
        gpus = self.gpus
        n = self.n
        self.last_update = np.fromiter(
            (g.last_update for g in gpus), dtype=np.float64, count=n)
        self.down_until = np.fromiter(
            (g.down_until for g in gpus), dtype=np.float64, count=n)
        self.energy_j = np.fromiter(
            (g.energy_j for g in gpus), dtype=np.float64, count=n)

    def scatter(self, idx: Sequence[int]) -> None:
        """Write the arrays back to the GPU attributes for rows ``idx``."""
        gpus = self.gpus
        lu = self.last_update.tolist()
        ej = self.energy_j.tolist()
        for i in idx:
            g = gpus[i]
            g.last_update = lu[i]
            g.energy_j = ej[i]

    # -------------------------------------------------- batch operations

    def settle_all(self, t: float) -> None:
        """Advance every GPU's accounting clock and energy integral to
        ``t`` — one masked vector update for the resident-free rows, the
        scalar ``advance`` for rows with residents (whose per-job progress
        and Kahan shifts must keep scalar operation order).  State-for-state
        bit-identical to :func:`settle_scalar`."""
        gpus = self.gpus
        free = [i for i, g in enumerate(gpus) if not g.jobs]
        if len(free) < 8:
            # under the numpy break-even row count: scalar is faster AND
            # trivially identical
            settle_scalar(gpus, t)
            return
        self.gather()
        idx = np.asarray(free, dtype=np.intp)
        lu = self.last_update[idx]
        du = self.down_until[idx]
        dt = t - lu
        # live window: repairs power the GPU off until down_until;
        # down_until only moves forward, so a window straddles at most one
        # repair boundary (same derivation as GPU.advance)
        live = np.where(lu >= du, dt, np.maximum(0.0, t - du))
        pos = (dt > 0.0) & (live > 0.0)
        add = self.idle_w[idx] * live
        self.energy_j[idx] = np.where(pos, self.energy_j[idx] + add,
                                      self.energy_j[idx])
        self.last_update[idx] = t
        self.scatter(free)
        for i, g in enumerate(gpus):
            if g.jobs:
                g.advance(t)

    # ------------------------------------------------- resident snapshot

    def resident_matrix(self) -> Dict[str, np.ndarray]:
        """Export the per-resident SoA columns as fleet-wide ``(G, S)``
        arrays (``S`` = the largest resident count in the fleet; shorter
        rows zero-padded, with ``mask`` marking occupied slots).  This is
        the read-only bridge for vectorized consumers — rollout scoring,
        property tests, offline analysis — and never feeds back into
        simulation state."""
        gpus = self.gpus
        s = max((len(g._rjobs) for g in gpus), default=0)
        shape = (self.n, max(s, 1))
        speed = np.zeros(shape)
        ck_t = np.zeros(shape)
        ck_w = np.zeros(shape)
        remaining = np.zeros(shape)
        mask = np.zeros(shape, dtype=bool)
        for i, g in enumerate(gpus):
            k = len(g._rjobs)
            if not k:
                continue
            speed[i, :k] = g._spd
            ck_t[i, :k] = g._ckt
            ck_w[i, :k] = g._ckw
            # misolint: disable=MS110 -- gather into the (G, S) export is
            # itself the vectorization boundary; <=7 slots per row
            remaining[i, :k] = [rj.job.remaining for rj in g._rjobs]
            mask[i, :k] = True
        return {"speed": speed, "since_ckpt_t": ck_t, "since_ckpt_work": ck_w,
                "remaining": remaining, "mask": mask}
