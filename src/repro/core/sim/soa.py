"""Struct-of-arrays fleet state: vectorized batch updates over per-GPU rows.

The simulator's hot state is stored in two layouts, each where it is
measurably fastest on the event loop's access patterns:

* **Per-resident columns** (``GPU._spd`` / ``_ckt`` / ``_ckw`` — speed,
  progressing-seconds-since-checkpoint and at-risk work per resident slot)
  live as slot-aligned *Python* lists on each GPU.  A GPU hosts at most
  ``space.max_jobs`` (7 on an a100) residents, and at that row length
  CPython list indexing beats numpy fancy/scalar indexing by 3-10x (a
  ``row[:k].tolist()`` round-trip alone costs more than the whole scalar
  update).  ``RJob`` is a *view* over one slot — policies keep reading
  ``rj.speed`` etc.; the engine's hot loops walk the columns directly.
* **Per-GPU rows** (energy integral, accounting clock, repair deadline)
  stay as plain attributes for the single-GPU per-event path, and this
  module can gather them into fleet-wide numpy arrays at *batch barriers*
  (the end-of-run settle, rack-scale evacuations, rollout sweeps, and the
  replica-batched engine's cross-replica settle in ``core/sim/batch.py``).
  Measurement puts the scalar loop ahead of that masked vector update at
  every fleet size on the reference container (see the threshold comment
  below), so the vector path ships disabled by default — it is retained as
  the property-tested batch-semantics contract and for hosts where the
  numpy-dispatch trade flips.  All its vector arithmetic is elementwise
  (sub/mul/maximum/where), which IEEE-754 guarantees bit-identical to the
  scalar expressions in ``GPU.advance`` — the repo's golden traces are the
  proof obligation, and :func:`settle_scalar` stays behind as the
  property-test oracle.

Masked-update contract
----------------------
:func:`settle_rows` partitions its rows into three classes:

* **free** (``not g.jobs``) — the historical vector path: one masked
  energy/clock update (a resident-free GPU's wall power is exactly its
  idle floor in every phase — see the watts derivation in ``GPU.advance``):

      dt   = t - last_update
      live = dt                      if last_update >= down_until
             max(0.0, t-down_until)  otherwise
      energy += idle_w * live        when dt > 0 and live > 0

* **occupied, vector-eligible** (``g.jobs`` and ``dt > 0`` and phase in
  (MIG_RUN, MPS_PROF) and the wall-watts memo is clean
  (``g._w_key is g._spd_key``) and < 8 residents) — the progress integral
  runs as masked ``(rows, slots)`` matrix ops whose per-slot expressions
  (``done = s*dt``; the repeated-subtraction checkpoint boundary — NEVER
  fmod, whose result is not the scalar loop's) are elementwise-identical
  to ``GPU.advance``; the per-row work drain uses ``np.sum`` over < 8
  slots, which numpy reduces strictly left-to-right (its pairwise split
  starts at n == 8 — the reason for the residency cap), with trailing
  zero-padding neutral because every partial sum is non-negative.  The
  Kahan ``work_agg.shift`` calls are issued in gid order interleaved with
  the scalar rows, preserving the fleet-wide shift sequence.
* **everything else** (dt <= 0, CKPT/IDLE occupied, dirty watts memo,
  >= 8 residents) — per-GPU ``GPU.advance``, the scalar oracle.

State-for-state the result is bit-identical to :func:`settle_scalar`;
``tests/test_soa.py`` holds the property.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.sim.gpu import MIG_RUN, MPS_PROF

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.core.sim.gpu import GPU

# Scalar-fallback thresholds, re-measured for the occupied-row extension.
# (benchmarks/measure_settle.py; 1-CPU container, CPython 3.10, numpy 2.0,
# min-of-400 per point; speedup = scalar_us / vector_us, < 1 = scalar wins)
#
#   free rows      n=8: 0.21x   n=32: 0.45x   n=128: 0.69x   n=512: 0.80x
#   occupied rows  n=8: 0.24x   n=32: 0.49x   n=128: 0.65x   n=512: 0.69x
#
# The historical "break-even at 8 free rows" does NOT reproduce: the
# scalar loop wins at every measured row count, and the *marginal* per-row
# cost of the vector path is itself higher (free: ~0.36 vs ~0.30 us/row;
# occupied: ~3.3 vs ~2.3 us/row), so the speedup curve is bounded below
# 1.0 — no break-even exists on this host at any fleet size.  The reason
# is structural: simulation state lives in per-GPU Python attributes, so
# the vector path pays the same attribute reads (gather) and writes
# (apply) the scalar loop pays, plus numpy dispatch, while the arithmetic
# it absorbs is the cheap part.  This is the measurement the MS110
# suppressions cite when they keep scalar walks over the SoA columns
# (<= 7 slots per row) instead of numpy rewrites.
#
# Defaults therefore route every row through the scalar oracle; the masked
# vector path stays behind explicit per-call thresholds as the
# property-tested batch-semantics contract (tests/test_soa.py force it;
# re-run benchmarks/measure_settle.py before enabling it on a host where
# the numpy-dispatch trade might flip).  Bit-identity makes the choice
# correctness-neutral either way.
_FREE_VEC_MIN: Optional[int] = None     # no measured break-even <= 512 rows
_OCC_VEC_MIN: Optional[int] = None      # no measured break-even <= 512 rows


def settle_scalar(gpus: Sequence["GPU"], t: float) -> None:
    """Scalar reference settle: per-GPU ``advance`` in gid order.  This is
    the oracle the vectorized path is property-tested against — do not
    'optimize' it."""
    for g in gpus:
        g.advance(t)


def settle_rows(gpus: Sequence["GPU"],
                ts: Union[float, Sequence[float]],
                idle_w: Optional[np.ndarray] = None,
                free_min: Optional[int] = None,
                occ_min: Optional[int] = None) -> None:
    """Settle ``gpus[i]`` to clock ``ts[i]`` (or a shared scalar ``ts``),
    vectorizing the rows that are eligible under the masked-update contract
    above and routing the rest through the scalar ``GPU.advance``.

    This is the shared core of :meth:`FleetState.settle_all` (one replica,
    one clock) and ``BatchSim``'s cross-replica settle (``B*G`` rows, one
    clock per replica).  ``idle_w``, when given, must be the per-row idle
    floor array (callers that own the rows precompute it once).

    ``free_min`` / ``occ_min`` engage the masked vector path when at least
    that many rows of the class are eligible; ``None`` falls back to the
    module defaults — which, per the measurement above, keep everything on
    the scalar oracle.  Bit-identity holds for every threshold choice.
    """
    n = len(gpus)
    if n == 0:
        return
    if free_min is None:
        free_min = _FREE_VEC_MIN
    if occ_min is None:
        occ_min = _OCC_VEC_MIN
    if isinstance(ts, (int, float)):
        t = float(ts)
        ts_list: Optional[List[float]] = None
    else:
        ts_list = [float(x) for x in ts]
        t = 0.0
    free: List[int] = []
    occ: List[int] = []
    rest: List[int] = []
    for i, g in enumerate(gpus):
        if not g.jobs:
            free.append(i)
        elif ((t if ts_list is None else ts_list[i]) > g.last_update
                and (g.phase == MIG_RUN or g.phase == MPS_PROF)
                and g._w_key is g._spd_key and len(g._rjobs) < 8):
            occ.append(i)
        else:
            rest.append(i)
    do_free = free_min is not None and len(free) >= free_min
    do_occ = occ_min is not None and len(occ) >= occ_min
    if not do_free and not do_occ:
        # under both numpy break-even row counts: scalar is faster AND
        # trivially identical
        if ts_list is None:
            for g in gpus:
                g.advance(t)
        else:
            for i, g in enumerate(gpus):
                g.advance(ts_list[i])
        return

    if do_free:
        nf = len(free)
        lu = np.fromiter((gpus[i].last_update for i in free), np.float64, nf)
        du = np.fromiter((gpus[i].down_until for i in free), np.float64, nf)
        ej = np.fromiter((gpus[i].energy_j for i in free), np.float64, nf)
        if idle_w is not None:
            iw = idle_w[np.asarray(free, dtype=np.intp)]
        else:
            iw = np.fromiter((gpus[i]._idle_w for i in free), np.float64, nf)
        if ts_list is None:
            dt = t - lu
            tt: Union[float, np.ndarray] = t
        else:
            tt = np.fromiter((ts_list[i] for i in free), np.float64, nf)
            dt = tt - lu
        if du.any():
            # live window: repairs power the GPU off until down_until;
            # down_until only moves forward, so a window straddles at most
            # one repair boundary (same derivation as GPU.advance)
            live = np.where(lu >= du, dt, np.maximum(0.0, tt - du))
            pos = (dt > 0.0) & (live > 0.0)
        else:
            # repair-free fleet (the common case): last_update >= 0 == every
            # down_until, so live == dt exactly — three fewer array ops
            live = dt
            pos = dt > 0.0
        free_e = np.where(pos, ej + iw * live, ej).tolist()
        # free rows never touch the work aggregate, so their application
        # order is unconstrained: scatter them out of band in one zip loop
        if ts_list is None:
            for i, e in zip(free, free_e):
                g = gpus[i]
                g.energy_j = e
                g.last_update = t
        else:
            for i, e in zip(free, free_e):
                g = gpus[i]
                g.energy_j = e
                g.last_update = ts_list[i]
    elif free:
        # too few free rows to pay numpy's fixed cost: scalar, and (no
        # work-aggregate traffic) order-free like the vector scatter above
        if ts_list is None:
            for i in free:
                gpus[i].advance(t)
        else:
            for i in free:
                gpus[i].advance(ts_list[i])

    if not do_occ:
        # occupied-but-under-threshold rows join the scalar remainder; keep
        # gid order across the merge for the work-aggregate shift sequence
        if occ:
            rest = sorted(rest + occ)
        if ts_list is None:
            for i in rest:
                gpus[i].advance(t)
        else:
            for i in rest:
                gpus[i].advance(ts_list[i])
        return

    no = len(occ)
    lens = [len(gpus[i]._rjobs) for i in occ]
    s_max = max(lens)
    cnt = no * s_max
    pad = [0.0] * s_max
    sr: List[float] = []
    tr: List[float] = []
    wr: List[float] = []
    for i in occ:
        g = gpus[i]
        p = pad[len(g._rjobs):]
        sr.extend(g._spd)
        sr.extend(p)
        tr.extend(g._ckt)
        tr.extend(p)
        wr.extend(g._ckw)
        wr.extend(p)
    spd = np.array(sr).reshape(no, s_max)
    ckt = np.array(tr).reshape(no, s_max)
    ckw = np.array(wr).reshape(no, s_max)
    msk = np.arange(s_max) < np.array(lens, dtype=np.intp)[:, None]
    w = np.fromiter((gpus[i]._w_val for i in occ), np.float64, no)
    itv = np.fromiter((gpus[i].sim.cfg.ckpt_interval_s for i in occ),
                      np.float64, no)
    lu = np.fromiter((gpus[i].last_update for i in occ), np.float64, no)
    du = np.fromiter((gpus[i].down_until for i in occ), np.float64, no)
    ej = np.fromiter((gpus[i].energy_j for i in occ), np.float64, no)
    if ts_list is None:
        dt = t - lu                      # > 0 for every row by eligibility
        tt = t
    else:
        tt = np.fromiter((ts_list[i] for i in occ), np.float64, no)
        dt = tt - lu
    if du.any():
        live = np.where(lu >= du, dt, np.maximum(0.0, tt - du))
        # energy: the memoized wall watts over the live part of the window
        occ_e = np.where(live > 0.0, ej + w * live, ej).tolist()
    else:
        # repair-free: live == dt > 0 on every row, the where mask is all-on
        occ_e = (ej + w * dt).tolist()
    dtc = dt[:, None]
    done = spd * dtc                     # padded slots: 0.0 * dt == 0.0
    # per-row work drain; < 8 slots per row keeps np.sum left-to-right
    dec_l = done.sum(axis=1).tolist()
    # periodic-checkpoint bookkeeping: masked repeated subtraction — each
    # pass peels one boundary exactly like the scalar while-loop (fmod
    # would round differently and break bit-identity)
    itvc = itv[:, None]
    m = msk & (itvc > 0.0)
    ct = np.where(m, ckt + dtc, ckt)
    cw = np.where(m, ckw + done, ckw)
    bm = m & (ct >= itvc)
    while bm.any():
        ct = np.where(bm, ct - itvc, ct)
        cw = np.where(bm, spd * ct, cw)
        bm = bm & (ct >= itvc)
    dt_l = dt.tolist()
    done_l = done.tolist()
    ct_l = ct.tolist()
    cw_l = cw.tolist()
    itv_l = itv.tolist()

    def apply_occ(r: int, i: int) -> None:
        g = gpus[i]
        g.energy_j = occ_e[r]
        g.last_update = t if ts_list is None else ts_list[i]
        row_done = done_l[r]
        dt_i = dt_l[r]
        run = g.phase == MIG_RUN
        # misolint: disable=MS110 -- scatter of the vectorized progress
        # back into per-job attributes; <=7 slots, and the attribute
        # writes dominate either way (see the _OCC_VEC_MIN measurement)
        for s_i, rj in enumerate(g._rjobs):
            job = rj.job
            job.remaining -= row_done[s_i]
            if run:
                job.t_run += dt_i
            else:
                job.t_mps += dt_i
        if itv_l[r] > 0.0:
            k = lens[r]
            g._ckt[:] = ct_l[r][:k]
            g._ckw[:] = cw_l[r][:k]
        d = dec_l[r]
        if d:
            g.sim.work_agg.shift(-d)

    if not rest:
        for r, i in enumerate(occ):
            apply_occ(r, i)
        return
    # occupied scalar rows can shift the Kahan work aggregate too: a two-
    # pointer merge applies both classes in gid order, preserving the
    # fleet-wide shift sequence of settle_scalar
    oi = ri = 0
    n_occ = len(occ)
    n_rest = len(rest)
    while oi < n_occ or ri < n_rest:
        if ri >= n_rest or (oi < n_occ and occ[oi] < rest[ri]):
            apply_occ(oi, occ[oi])
            oi += 1
        else:
            i = rest[ri]
            gpus[i].advance(t if ts_list is None else ts_list[i])
            ri += 1


class FleetState:
    """Fleet-wide SoA staging buffers + the vectorized batch operations.

    The object attributes on :class:`GPU` stay canonical; ``gather()``
    snapshots them into numpy arrays, the vector ops compute on the arrays,
    and ``scatter()`` writes results back.  Gather/scatter cost O(fleet)
    attribute traffic once per *batch*, not per event — but that attribute
    traffic is most of what the scalar ``advance`` loop pays too, which is
    why the measured thresholds (see module comment) keep the scalar path
    as the default.
    """

    __slots__ = ("gpus", "n", "idle_w", "last_update", "down_until",
                 "energy_j")

    def __init__(self, gpus: Sequence["GPU"]):
        self.gpus = list(gpus)
        self.n = len(self.gpus)
        # idle floors are fixed per GPU kind: gather once
        self.idle_w = np.array([g._idle_w for g in self.gpus])
        self.last_update = np.zeros(self.n)
        self.down_until = np.zeros(self.n)
        self.energy_j = np.zeros(self.n)

    # -------------------------------------------------------- staging I/O

    def gather(self) -> None:
        """Snapshot the per-GPU scalar attributes into the arrays."""
        gpus = self.gpus
        n = self.n
        self.last_update = np.fromiter(
            (g.last_update for g in gpus), dtype=np.float64, count=n)
        self.down_until = np.fromiter(
            (g.down_until for g in gpus), dtype=np.float64, count=n)
        self.energy_j = np.fromiter(
            (g.energy_j for g in gpus), dtype=np.float64, count=n)

    def scatter(self, idx: Sequence[int]) -> None:
        """Write the arrays back to the GPU attributes for rows ``idx``."""
        gpus = self.gpus
        lu = self.last_update.tolist()
        ej = self.energy_j.tolist()
        for i in idx:
            g = gpus[i]
            g.last_update = lu[i]
            g.energy_j = ej[i]

    # -------------------------------------------------- batch operations

    def settle_all(self, t: float,
                   free_min: Optional[int] = None,
                   occ_min: Optional[int] = None) -> None:
        """Advance every GPU's accounting clock and energy integral to
        ``t`` — masked vector updates for the eligible rows (resident-free
        ones, and occupied progressing ones with a clean watts memo), the
        scalar ``advance`` for everything else.  State-for-state
        bit-identical to :func:`settle_scalar` (see the masked-update
        contract in the module docstring); thresholds as in
        :func:`settle_rows`."""
        settle_rows(self.gpus, t, idle_w=self.idle_w,
                    free_min=free_min, occ_min=occ_min)

    # ------------------------------------------------- resident snapshot

    def resident_matrix(self) -> Dict[str, np.ndarray]:
        """Export the per-resident SoA columns as fleet-wide ``(G, S)``
        arrays (``S`` = the largest resident count in the fleet; shorter
        rows zero-padded, with ``mask`` marking occupied slots).  This is
        the read-only bridge for vectorized consumers — rollout scoring,
        property tests, offline analysis — and never feeds back into
        simulation state."""
        gpus = self.gpus
        s = max((len(g._rjobs) for g in gpus), default=0)
        shape = (self.n, max(s, 1))
        speed = np.zeros(shape)
        ck_t = np.zeros(shape)
        ck_w = np.zeros(shape)
        remaining = np.zeros(shape)
        mask = np.zeros(shape, dtype=bool)
        for i, g in enumerate(gpus):
            k = len(g._rjobs)
            if not k:
                continue
            speed[i, :k] = g._spd
            ck_t[i, :k] = g._ckt
            ck_w[i, :k] = g._ckw
            # misolint: disable=MS110 -- gather into the (G, S) export is
            # itself the vectorization boundary; <=7 slots per row (the
            # measure_settle.py bound recorded above)
            remaining[i, :k] = [rj.job.remaining for rj in g._rjobs]
            mask[i, :k] = True
        return {"speed": speed, "since_ckpt_t": ck_t, "since_ckpt_work": ck_w,
                "remaining": remaining, "mask": mask}
