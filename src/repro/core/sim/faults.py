"""Pluggable fault injection: the failure-domain realism layer.

MISO's central trade-off is that MPS is lightweight but lacks the error
containment MIG provides (paper §2): a job crash during an MPS exploration
window can take down every co-located job, while MIG isolates the blast to
one slice.  Uniform Poisson GPU/rack outages (``SimConfig.gpu_mtbf_s`` /
``rack_mtbf_s``, owned by the engine) cannot express that asymmetry — this
module holds the injectors that can, behind the same registry pattern the
policies / placers / objectives layers use:

* ``mps_blast``         — crash shocks whose blast radius depends on the
  victim GPU's phase: every co-resident dies during an MPS window, exactly
  one (random) sliced job dies under MIG, nothing dies while checkpointing
  or idle.
* ``flaky_reconfig``    — a CKPT-ending MIG repartition op fails with
  probability ``reconfig_fail_p`` and is retried under bounded exponential
  backoff; the GPU is unschedulable while retrying, and exhausting
  ``reconfig_max_retries`` escalates to a hard GPU fault.
* ``straggler``         — persistent speed degradation (``straggler_factor``
  multiplier), not binary death; clears after ``straggler_recover_s`` or a
  quarantine repair.
* ``estimator_garbage`` — the U-Net occasionally emits garbage slice-speed
  estimates (NaNs / junk / all-zero); the policy layer degrades to its
  last-known-good estimate or the oracle fallback instead of crashing
  (``Policy.sanitize_estimate``).

Determinism contract: every injector draws exclusively from the engine's
dedicated ``sim.fault_rng`` stream (seeded ``(seed, 0xFA17)``), in event
order — enabling or tuning chaos never perturbs the main failure schedule
(``sim.rng``) or the MPS measurement noise (``sim.noise_rng``).  With
``SimConfig.faults=()`` no injector exists, no fault event is scheduled and
no fault RNG is drawn: golden traces stay bit-identical (the zero-overhead
guarantee, enforced by ``tests/test_faults.py``).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any, Dict, List, Sequence, Type

from repro.core.sim.gpu import GPU, MIG_RUN, MPS_PROF

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.core.sim.engine import ClusterSim

Estimate = Dict[int, float]

_REGISTRY: Dict[str, Type["FaultInjector"]] = {}


def register_fault_injector(cls: Type["FaultInjector"]
                            ) -> Type["FaultInjector"]:
    """Class decorator: make ``cls`` reachable from ``SimConfig.faults``."""
    if not getattr(cls, "name", None):
        raise ValueError(f"{cls.__name__} must define a non-empty `name`")
    if cls.name in _REGISTRY:
        raise ValueError(f"duplicate fault injector name {cls.name!r} "
                         f"({_REGISTRY[cls.name].__name__} vs {cls.__name__})")
    _REGISTRY[cls.name] = cls
    return cls


def get_fault_injector(name: str) -> Type["FaultInjector"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown fault injector {name!r}; "
            f"available: {', '.join(available_fault_injectors())}") from None


def available_fault_injectors() -> List[str]:
    return sorted(_REGISTRY)


class FaultInjector:
    """Base class for fault injectors (one instance per simulation).

    Injectors drive themselves through ``"fault"`` events on the engine's
    heap: :meth:`schedule_initial` arms the first one at construction time
    and :meth:`on_event` handles (and typically re-arms) each firing.  The
    two engine-side hooks below are dispatched only when an *enabled*
    injector overrides them, so un-hooked simulations pay a single
    empty-list check.
    """

    name: str = ""

    def __init__(self, sim: "ClusterSim"):
        self.sim = sim

    def schedule_initial(self) -> None:
        """Push this injector's first event(s); called at engine build."""

    def on_event(self, payload: Any) -> None:
        """Handle one ``"fault"`` event addressed to this injector."""

    def on_reconfig_end(self, g: GPU) -> bool:
        """A CKPT window (checkpoint + MIG reconfigure op) just expired on
        ``g``.  Return True to fail the op: the injector has rescheduled
        the retry (or escalated) and the phase end must not proceed."""
        return False

    def filter_estimates(self, g: GPU, jids: Sequence[int],
                         ests: Sequence[Estimate]) -> Sequence[Estimate]:
        """Intercept freshly-produced slice-speed estimates (corruption
        point for estimator faults)."""
        return ests


@register_fault_injector
class MpsBlastInjector(FaultInjector):
    """Phase-dependent crash shocks (paper §2's containment asymmetry).

    A Poisson stream (rate ``1 / mps_crash_mtbf_s``) of crash shocks, each
    aimed at a uniformly random GPU.  The blast radius is decided by what
    the victim GPU is doing:

    * MPS exploration window — no error containment: every co-resident on
      the GPU dies (rolled back to its last checkpoint and requeued);
    * MIG run — hardware isolation: exactly one random sliced job dies,
      its slice-mates survive untouched;
    * CKPT / idle / down — no kernels in flight, the shock is absorbed.
    """

    name = "mps_blast"

    def schedule_initial(self) -> None:
        if self.sim.cfg.mps_crash_mtbf_s > 0.0:
            self._arm()

    def _arm(self) -> None:
        sim = self.sim
        dt = float(sim.fault_rng.exponential(sim.cfg.mps_crash_mtbf_s))
        sim._push(sim.t + dt, "fault", (self.name, None))

    def on_event(self, payload: Any) -> None:
        sim = self.sim
        g = sim.gpus[int(sim.fault_rng.integers(len(sim.gpus)))]
        self._arm()
        if sim.t < g.down_until or not g.jobs:
            return
        if g.phase == MPS_PROF:
            victims = list(g.jobs)
            fs = sim.fstats
            fs["n_blasts"] += 1
            fs["blast_jobs"] += len(victims)
            if len(victims) > fs["blast_radius_max"]:
                fs["blast_radius_max"] = len(victims)
        elif g.phase == MIG_RUN:
            sliced = [jid for jid, rj in g.jobs.items() if rj.slice_size]
            if not sliced:
                return
            victims = [sliced[int(sim.fault_rng.integers(len(sliced)))]]
        else:
            return
        sim.crash_jobs(g, victims)
        sim.record_fault(g)


@register_fault_injector
class FlakyReconfigInjector(FaultInjector):
    """Transient MIG-reconfiguration failures with bounded backoff.

    Each CKPT-ending repartition op fails independently with probability
    ``reconfig_fail_p``.  A failed op keeps the GPU in its CKPT phase for a
    backoff of ``reconfig_retry_s * 2**(attempt-1)`` and pulls it out of
    the placement index (unschedulable while retrying — residents keep
    paying checkpoint time).  Exhausting ``reconfig_max_retries`` is a hard
    GPU fault: the health machinery may quarantine the GPU, otherwise it
    fails outright and pays the normal repair window.
    """

    name = "flaky_reconfig"

    def on_reconfig_end(self, g: GPU) -> bool:
        sim = self.sim
        cfg = sim.cfg
        if cfg.reconfig_fail_p <= 0.0:
            return False
        if float(sim.fault_rng.random()) >= cfg.reconfig_fail_p:
            if not g.sched_ok:
                # a retried op finally landed: back into service
                g.sched_ok = True
                g.reconfig_tries = 0
                if sim.t >= g.down_until:
                    sim._refresh_feas(g)
                    sim.index.add(g)
            return False
        g.reconfig_tries += 1
        sim.fstats["n_reconfig_retries"] += 1
        if g.reconfig_tries > cfg.reconfig_max_retries:
            # retries exhausted: escalate.  record_fault may quarantine
            # (evacuate + quarantine repair window); otherwise the GPU
            # fails outright
            if not sim.record_fault(g):
                sim._fail_gpu(g)
            return True
        g.advance(sim.t)
        if g.sched_ok:
            g.sched_ok = False
            sim.index.remove(g)
        backoff = cfg.reconfig_retry_s * (2.0 ** (g.reconfig_tries - 1))
        g.phase_end = sim.t + backoff
        sim._schedule_gpu_events(g)
        return True


@register_fault_injector
class StragglerInjector(FaultInjector):
    """Persistent stragglers: speed degradation, not binary death.

    A Poisson stream (rate ``1 / straggler_mtbf_s``) of degradation onsets,
    each hitting a uniformly random in-service GPU: its effective speed is
    multiplied by ``straggler_factor`` (health -> degraded) until
    ``straggler_recover_s`` elapses or a quarantine repair replaces the
    hardware.  Already-struck or down GPUs absorb the shock.
    """

    name = "straggler"

    def schedule_initial(self) -> None:
        if self.sim.cfg.straggler_mtbf_s > 0.0:
            self._arm()

    def _arm(self) -> None:
        sim = self.sim
        dt = float(sim.fault_rng.exponential(sim.cfg.straggler_mtbf_s))
        sim._push(sim.t + dt, "fault", (self.name, None))

    def on_event(self, payload: Any) -> None:
        sim = self.sim
        if payload is not None:
            self._recover(sim.gpus[int(payload)])
            return
        g = sim.gpus[int(sim.fault_rng.integers(len(sim.gpus)))]
        self._arm()
        if sim.t < g.down_until or g.speed_fault != 1.0:
            return
        g.advance(sim.t)                 # settle progress at healthy speed
        g.speed_fault = sim.cfg.straggler_factor
        if sim.record_fault(g):
            return                       # quarantined: evacuated, down, reset
        sim.finalize(g)                  # degraded speeds + rescheduled events
        sim._push(sim.t + sim.cfg.straggler_recover_s, "fault",
                  (self.name, g.gid))

    def _recover(self, g: GPU) -> None:
        from repro.core.sim.gpu import DEGRADED, HEALTHY
        sim = self.sim
        if g.speed_fault == 1.0 or sim.t < g.down_until:
            return                       # already repaired (e.g. quarantine)
        g.advance(sim.t)
        g.speed_fault = 1.0
        if g.health == DEGRADED:
            g.health = HEALTHY
        sim.finalize(g)


@register_fault_injector
class EstimatorFaultInjector(FaultInjector):
    """Estimator faults: the U-Net occasionally outputs garbage.

    With probability ``estimator_fault_p`` per profiling window, the whole
    window's estimates are replaced by one of three garbage modes (all-NaN
    numerical blow-up, uniform junk including negatives, silent all-zero).
    The policy layer is expected to catch this and degrade to its
    last-known-good estimate or the oracle fallback
    (``Policy.sanitize_estimate``) instead of feeding it to Algorithm 1.
    """

    name = "estimator_garbage"

    def filter_estimates(self, g: GPU, jids: Sequence[int],
                         ests: Sequence[Estimate]) -> Sequence[Estimate]:
        sim = self.sim
        p = sim.cfg.estimator_fault_p
        if p <= 0.0 or float(sim.fault_rng.random()) >= p:
            return ests
        sim.fstats["n_estimator_faults"] += 1
        mode = int(sim.fault_rng.integers(3))
        out: List[Estimate] = []
        for est in ests:
            if mode == 0:
                out.append({s: float("nan") for s in est})
            elif mode == 1:
                junk = sim.fault_rng.uniform(-10.0, 10.0, size=len(est))
                out.append({s: float(v) for s, v in zip(est, junk)})
            else:
                out.append({s: 0.0 for s in est})
        return out
