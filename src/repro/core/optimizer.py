"""MISO partition optimizer (paper Algorithm 1).

Given per-job speed functions f_i: slice-size -> normalized speed (0..1, with
0 meaning OOM/QoS-infeasible), scan every valid partition of length m and
every job->slice assignment, and return the configuration maximizing
sum_i f_i(x_i)  (system throughput, Eq. 2-4).

Assignments within a slice multiset are solved exactly by bitmask DP over
jobs (O(2^m * m) per multiset) instead of m! permutations — same optimum,
~50x fewer evaluations; ``optimize_partition_bruteforce`` keeps the literal
Algorithm 1 enumeration as the test oracle.

Repeated repartition calls in long traces mostly carry the exact same speed
vectors (a job's profile — and hence its estimate — is piecewise constant in
progress), so results are memoized on ``(space, m, rounded speed-vector
signature)``.  ``benchmarks/components.optimizer_latency`` measures the
speedup; pass ``memo=False`` to bypass.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

from repro.core.partitions import PartitionSpace

_MEMO: Dict[tuple, Optional["PartitionChoice"]] = {}
_MEMO_STATS = {"hits": 0, "misses": 0}
_MEMO_ROUND = 6      # decimals: well below any speed difference that matters
_MEMO_MAX = 65536    # FIFO-bounded: noisy estimators never repeat a key, so
                     # an unbounded dict would be a slow leak across long runs


def _memo_key(space: PartitionSpace, speeds, require_feasible: bool) -> tuple:
    sig = tuple(tuple(sorted((s, round(v, _MEMO_ROUND)) for s, v in sv.items()))
                for sv in speeds)
    return (space.name, space.sizes, space.total_compute, space.total_mem,
            require_feasible, sig)


def clear_memo() -> None:
    _MEMO.clear()
    _MEMO_STATS["hits"] = _MEMO_STATS["misses"] = 0


def memo_stats() -> Dict[str, int]:
    return dict(_MEMO_STATS, size=len(_MEMO))


@dataclass(frozen=True)
class PartitionChoice:
    partition: Tuple[int, ...]     # slice sizes, one per job (assignment order)
    objective: float               # sum of assigned speeds (predicted STP)
    feasible: bool                 # every job got a non-zero-speed slice


def _assign_dp(sizes: Tuple[int, ...], speeds: Sequence[Dict[int, float]]):
    """Best assignment of m jobs to the multiset ``sizes`` (len m).

    Returns (best_obj, perm) where perm[i] = slice size of job i.
    DP over (position in sizes, bitmask of assigned jobs).
    """
    m = len(sizes)
    full = (1 << m) - 1
    # dp[mask] = best objective having filled the first popcount(mask) slices
    dp = {0: (0.0, ())}
    for pos in range(m):
        size = sizes[pos]
        new_dp = {}
        for mask, (obj, choice) in dp.items():
            if bin(mask).count("1") != pos:
                continue
            for j in range(m):
                if mask & (1 << j):
                    continue
                nm = mask | (1 << j)
                val = obj + speeds[j].get(size, 0.0)
                cur = new_dp.get(nm)
                if cur is None or val > cur[0]:
                    new_dp[nm] = (val, choice + ((j, size),))
        dp.update(new_dp)
    best_obj, choice = dp.get(full, (0.0, ()))
    perm = [0] * m
    for j, size in choice:
        perm[j] = size
    return best_obj, tuple(perm)


def optimize_partition(space: PartitionSpace,
                       speeds: Sequence[Dict[int, float]],
                       require_feasible: bool = False,
                       memo: bool = True) -> Optional[PartitionChoice]:
    """Algorithm 1 with exact assignment.  speeds[i][size] -> f_i(size)."""
    m = len(speeds)
    if m == 0:
        return None
    if memo:
        key = _memo_key(space, speeds, require_feasible)
        cached = _MEMO.get(key, _MEMO)        # sentinel: None is a valid value
        if cached is not _MEMO:
            _MEMO_STATS["hits"] += 1
            return cached
        _MEMO_STATS["misses"] += 1
    best: Optional[PartitionChoice] = None
    for part in space.partitions_of_len(m):
        obj, perm = _assign_dp(part, speeds)
        feasible = all(speeds[j].get(perm[j], 0.0) > 0.0 for j in range(m))
        if require_feasible and not feasible:
            continue
        if best is None or obj > best.objective:
            best = PartitionChoice(perm, obj, feasible)
    if memo:
        if len(_MEMO) >= _MEMO_MAX:
            _MEMO.pop(next(iter(_MEMO)))       # evict oldest insertion
        _MEMO[key] = best
    return best


def optimize_partition_bruteforce(space: PartitionSpace,
                                  speeds: Sequence[Dict[int, float]]):
    """Literal Algorithm 1: enumerate every ordered x (partition x assignment).

    Like the DP path, an all-zero speed vector still yields a (infeasible)
    choice with objective 0.0 rather than ``None`` — the two are test oracles
    for each other, so they must agree on all-OOM job mixes.
    """
    m = len(speeds)
    best_obj, best_config = -1.0, None
    for part in space.partitions_of_len(m):
        for perm in set(itertools.permutations(part)):
            obj = sum(speeds[j].get(perm[j], 0.0) for j in range(m))
            if obj > best_obj:
                best_obj, best_config = obj, perm
    if best_config is None:
        return None
    return PartitionChoice(tuple(best_config), best_obj,
                           all(speeds[j].get(best_config[j], 0.0) > 0.0
                               for j in range(m)))
