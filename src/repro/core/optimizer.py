"""MISO partition optimizer (paper Algorithm 1), vectorized.

Given per-job speed functions f_i: slice-size -> normalized speed (0..1, with
0 meaning OOM/QoS-infeasible), scan every valid partition of length m and
every job->slice assignment, and return the configuration maximizing
sum_i f_i(x_i)  (system throughput, Eq. 2-4).

The scan is one numpy pass over *all* length-m multisets at once: the job
speeds are gathered into a ``(P, m, m)`` partition x slot x job tensor
(``space.part_cols(m)`` precomputed at :class:`PartitionSpace` construction)
and the exact assignment is solved by a bitmask DP over flat numpy arrays —
level t of the DP fills slot t for every partition and every popcount-t mask
simultaneously.  The DP visit order and first-strict-max tie-breaking
replicate the historical per-partition dict DP *exactly* (see
``_dp_schedule``), so results — objective, chosen multiset AND the job->slice
permutation — are bit-identical to the scalar implementation; the golden
traces prove it end-to-end.  ``_assign_dp`` keeps that scalar dict DP as the
single-multiset reference (and the benchmark's un-memoized comparison
point), and ``optimize_partition_bruteforce`` keeps the literal Algorithm 1
enumeration as the test oracle.

Repeated repartition calls in long traces mostly carry the exact same speed
vectors (a job's profile — and hence its estimate — is piecewise constant in
progress), so results are memoized on ``(space.uid, rounded speed-vector
signature)`` — the per-space id is interned at construction instead of
re-hashing the space's name/sizes/capacity tuple per call.
``benchmarks/components.optimizer_latency`` measures both the vectorized
speedup and the memo speedup; pass ``memo=False`` to bypass.

The *goal* of the search is pluggable (``objective=`` on every solver entry
point, see :mod:`repro.core.sim.objectives`): per-slice power is constant
across job→slice assignments, so the inner DP always solves the assignment
by maximizing additive speeds and the objective only re-ranks partition
rows from ``(throughput, watts)``.  ``objective=None`` (or ``"throughput"``)
takes the historical code path unchanged — bit-identical to the
pre-objective optimizer; non-default objectives (``"energy"``, ``"edp"``)
run the full argmax-tracked forward and score rows with the
:class:`~repro.core.fleet.PowerModel` passed as ``power=`` (the target
GPU's per-kind model; ``None`` falls back to the reference a100).  Memo
entries are keyed by objective identity and power model alongside the
speed signature, so objectives never collide in the shared cache.
"""
from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partitions import PartitionSpace

_MEMO: Dict[tuple, Optional["PartitionChoice"]] = {}
_MEMO_STATS = {"hits": 0, "misses": 0}
_MEMO_ROUND = 6      # decimals: well below any speed difference that matters
_MEMO_MAX = 65536    # FIFO-bounded: noisy estimators never repeat a key, so
                     # an unbounded dict would be a slow leak across long runs


_SIG_CACHE: Dict[int, tuple] = {}   # id(speed dict) -> (dict, space uid, sig)
_SIG_MAX = 65536


def _sig_one(sv: Dict[int, float], space: PartitionSpace) -> tuple:
    """Rounded per-dict signature fragment, cached on dict identity.

    Estimate dicts are produced once per profiling window (and the oracle
    estimator memoizes per profile), then passed to the optimizer unchanged
    on every repartition — so the id-keyed fragment is usually a hit.  The
    dict is pinned in the entry so the id cannot be recycled while cached."""
    hit = _SIG_CACHE.get(id(sv))
    if hit is not None and hit[0] is sv and hit[1] == space.uid:
        return hit[2]
    frag = tuple(round(sv.get(s, 0.0), _MEMO_ROUND) for s in space.sizes)
    if len(_SIG_CACHE) >= _SIG_MAX:
        _SIG_CACHE.pop(next(iter(_SIG_CACHE)))
    _SIG_CACHE[id(sv)] = (sv, space.uid, frag)
    return frag


def _memo_key(space: PartitionSpace, speeds, require_feasible: bool) -> tuple:
    # a missing size and an explicit 0.0 produce identical results in every
    # solver path (``.get(size, 0.0)``), so the signature may collapse them
    sig = tuple(_sig_one(sv, space) for sv in speeds)
    return (space.uid, require_feasible, sig)


def clear_memo() -> None:
    _MEMO.clear()
    _SIG_CACHE.clear()
    _MEMO_STATS["hits"] = _MEMO_STATS["misses"] = 0


def memo_stats() -> Dict[str, int]:
    return dict(_MEMO_STATS, size=len(_MEMO))


@dataclass(frozen=True)
class PartitionChoice:
    partition: Tuple[int, ...]     # slice sizes, one per job (assignment order)
    objective: float               # sum of assigned speeds (predicted STP) —
                                   # always the throughput value, whatever
                                   # objective ranked the rows
    feasible: bool                 # every job got a non-zero-speed slice


# --------------------------------------------------------------------------
# the scalar reference DP (kept verbatim: tie-break oracle + benchmark base)
# --------------------------------------------------------------------------


def _assign_dp(sizes: Tuple[int, ...], speeds: Sequence[Dict[int, float]]):
    """Best assignment of m jobs to the multiset ``sizes`` (len m).

    Returns (best_obj, perm) where perm[i] = slice size of job i.
    DP over (position in sizes, bitmask of assigned jobs).  This is the
    historical scalar implementation; ``assign_batch`` must match it
    bit-for-bit, tie-breaks included.
    """
    m = len(sizes)
    full = (1 << m) - 1
    # dp[mask] = best objective having filled the first popcount(mask) slices
    dp = {0: (0.0, ())}
    for pos in range(m):
        size = sizes[pos]
        new_dp = {}
        for mask, (obj, choice) in dp.items():
            if bin(mask).count("1") != pos:
                continue
            for j in range(m):
                if mask & (1 << j):
                    continue
                nm = mask | (1 << j)
                val = obj + speeds[j].get(size, 0.0)
                cur = new_dp.get(nm)
                if cur is None or val > cur[0]:
                    new_dp[nm] = (val, choice + ((j, size),))
        dp.update(new_dp)
    best_obj, choice = dp.get(full, (0.0, ()))
    perm = [0] * m
    for j, size in choice:
        perm[j] = size
    return best_obj, tuple(perm)


# --------------------------------------------------------------------------
# vectorized assignment: one DP over (partitions x masks) numpy arrays
# --------------------------------------------------------------------------


class _Level:
    """One DP level's static index structure (popcount-t masks)."""
    __slots__ = ("t", "n", "prev2d", "jobs2d", "prev_flat", "jobs_flat",
                 "prev_list", "jobs_list", "off")

    def __init__(self, t, prev2d, jobs2d, off):
        self.t = t
        self.n = prev2d.shape[0]
        self.prev2d = prev2d
        self.jobs2d = jobs2d
        self.prev_flat = np.ascontiguousarray(prev2d.ravel())
        self.jobs_flat = np.ascontiguousarray(jobs2d.ravel())
        self.prev_list = self.prev_flat.tolist()
        self.jobs_list = self.jobs_flat.tolist()
        self.off = off                 # flat offset into the WG weight row


@functools.lru_cache(maxsize=None)
def _dp_schedule(m: int):
    """Static DP index schedule for job count ``m``, replicating the dict
    DP's enumeration order exactly.

    Level t (1-based) holds every bitmask of popcount t, in the order the
    dict DP first inserts it; each such mask has exactly t candidate
    transitions (one per set bit j, predecessor mask ^ (1<<j)), in the order
    the dict DP enumerates them (predecessors in their own insertion order,
    then j ascending).  ``prev2d`` indexes into level t-1's mask order.
    Because replacement in the dict DP is strictly-greater, its winner is
    the *first* maximal candidate — precisely np.argmax's (and a
    first-strict-max Python scan's) tie rule over these candidate axes.

    Returns ``(levels, total)`` where ``total`` is the candidate count
    summed over levels (the WG weight-row width).
    """
    dp_keys = [0]                      # dict insertion order across levels
    index_in_level = {0: 0}
    levels = []
    off = 0
    for pos in range(m):
        new_masks: List[int] = []      # first-occurrence order = insertion
        cands: Dict[int, List[Tuple[int, int]]] = {}
        for mask in dp_keys:
            if bin(mask).count("1") != pos:
                continue
            for j in range(m):
                if mask & (1 << j):
                    continue
                nm = mask | (1 << j)
                if nm not in cands:
                    cands[nm] = []
                    new_masks.append(nm)
                cands[nm].append((index_in_level[mask], j))
        dp_keys.extend(new_masks)
        index_in_level = {nm: i for i, nm in enumerate(new_masks)}
        prev = np.asarray([[c[0] for c in cands[nm]] for nm in new_masks],
                          dtype=np.int64)
        jobs = np.asarray([[c[1] for c in cands[nm]] for nm in new_masks],
                          dtype=np.int64)
        levels.append(_Level(pos + 1, prev, jobs, off))
        off += prev.size
    return tuple(levels), off


# flat gather indices per (space uid, multiset rows): CIDX[p, k] points into
# S.ravel() at (candidate k's job, slot column of candidate k's level), so
# the whole DP's weights are fetched with a single np.take per call
_CIDX_CACHE: Dict[tuple, np.ndarray] = {}
_CIDX_MAX = 4096


def _cidx_for(key: Optional[tuple], cols: np.ndarray,
              n_sizes: int) -> np.ndarray:
    cidx = _CIDX_CACHE.get(key) if key is not None else None
    if cidx is None:
        m = cols.shape[1]
        levels, _ = _dp_schedule(m)
        blocks = [lv.jobs_flat[None, :] * n_sizes + cols[:, lv.t - 1][:, None]
                  for lv in levels]
        cidx = np.concatenate(blocks, axis=1)
        if key is not None:
            if len(_CIDX_CACHE) >= _CIDX_MAX:
                _CIDX_CACHE.pop(next(iter(_CIDX_CACHE)))
            _CIDX_CACHE[key] = cidx
    return cidx


_maximum_reduce = np.maximum.reduce


def _forward_max(P: int, m: int, S: np.ndarray, cidx: np.ndarray, levels):
    """Max-only batched DP forward pass: per level t, fill slot t for every
    partition row and every popcount-t mask at once (4 numpy ops a level; no
    argmax tracking — the winning path is re-derived from the level values
    by :func:`_backtrack_row`).  Returns ``(dps, WG)`` where ``dps[t]`` is
    the (P, n_t) value table after level t and ``WG`` the (P, total)
    candidate-weight gather."""
    WG = S.take(cidx)
    dp = WG[:, :m]                     # level 1: one candidate per mask
    dps = [None, dp]
    for lv in levels[1:]:
        t = lv.t
        cand = dp.take(lv.prev_flat, axis=1, mode="clip")
        cand += WG[:, lv.off:lv.off + lv.n * t]
        dp = _maximum_reduce(cand.reshape(P, lv.n, t), axis=2)
        dps.append(dp)
    return dps, WG


def _backtrack_row(m: int, levels, dps, WG, cols_row, r: int):
    """Re-derive one partition row's winning path from the level value
    tables: at each mask pick the first candidate attaining the stored max
    (the dict DP's strictly-greater replacement rule).  Pure Python on
    ``.tolist()`` rows — a handful of microseconds, paid only for winners.
    Returns ``(perm_cols list, feasible)``; a candidate's WG weight *is*
    S[job, col], so feasibility needs no extra gather."""
    wrow = WG[r].tolist()
    perm_cols = [0] * m
    feasible = True
    cur = 0
    for t in range(m, 1, -1):
        lv = levels[t - 1]
        dprev = dps[t - 1][r].tolist()
        pl, jl = lv.prev_list, lv.jobs_list
        base = cur * t
        base_w = lv.off + base
        best = None
        bi = 0
        for c in range(t):
            v = dprev[pl[base + c]] + wrow[base_w + c]
            if best is None or v > best:
                best, bi = v, c
        perm_cols[jl[base + bi]] = cols_row[t - 1]
        if wrow[base_w + bi] <= 0.0:
            feasible = False
        cur = pl[base + bi]
    j = levels[0].jobs_list[cur]       # level 1: single candidate
    perm_cols[j] = cols_row[0]
    if wrow[cur] <= 0.0:
        feasible = False
    return perm_cols, feasible


def _forward_full(cols: np.ndarray, S: np.ndarray, cidx: np.ndarray):
    """Forward pass with per-level argmax tracking, for consumers that need
    every row's winning assignment (fragmentation-aware scans, tests)."""
    P, m = cols.shape
    levels, _ = _dp_schedule(m)
    WG = S.take(cidx)
    dp = np.zeros((P, 1))
    cis = []
    for lv in levels:
        t = lv.t
        cand = dp.take(lv.prev_flat, axis=1, mode="clip")
        cand += WG[:, lv.off:lv.off + lv.n * t]
        cand = cand.reshape(P, lv.n, t)
        cis.append(cand.argmax(axis=2))
        dp = _maximum_reduce(cand, axis=2)
    return dp[:, 0], cis, WG


def _backtrack_all(cols: np.ndarray, WG: np.ndarray, cis, rows=None):
    """Walk winning paths at once: (perm_cols (R, m), feas (R,)).
    A chosen candidate's WG weight *is* its S[job, col] speed, so
    feasibility comes straight from the gathered weights — this also makes
    the walk independent of how rows were stacked across mixes.  ``rows``
    restricts the walk to a subset of rows (e.g. per-mix winners); default
    is every row."""
    m = cols.shape[1]
    levels, _ = _dp_schedule(m)
    if rows is None:
        rows = np.arange(cols.shape[0])
        cols_sel = cols
    else:
        cols_sel = cols[rows]
    R = rows.shape[0]
    out_rows = np.arange(R)
    cur = np.zeros(R, dtype=np.int64)
    perm_cols = np.zeros((R, m), dtype=np.int64)
    feas = np.ones(R, dtype=bool)
    for t in range(m, 0, -1):
        lv = levels[t - 1]
        ci = cis[t - 1][rows, cur]
        j = lv.jobs2d[cur, ci]
        perm_cols[out_rows, j] = cols_sel[:, t - 1]
        feas &= WG[rows, lv.off + cur * t + ci] > 0.0
        cur = lv.prev2d[cur, ci]
    return perm_cols, feas


def assign_batch(cols: np.ndarray, S: np.ndarray):
    """Exact assignment of m jobs to each of P slice multisets, batched.

    ``cols``: (P, m) — slot t's size as a column index into the size menu.
    ``S``:    (m, n_sizes) — S[j, k] = f_j(size of column k).

    Returns ``(objs (P,), perm_cols (P, m), feas (P,))``: per multiset the
    best achievable objective, the winning job->column assignment
    (perm_cols[p, j] = column of the slice job j gets) and whether every job
    in that winning assignment got a non-zero speed.  Bit-identical to
    running ``_assign_dp`` on every row, tie-breaks included.
    """
    objs, cis, WG = _forward_full(cols, S, _cidx_for(None, cols, S.shape[1]))
    perm_cols, feas = _backtrack_all(cols, WG, cis)
    return objs, perm_cols, feas


def _speed_matrix(space: PartitionSpace, speeds) -> np.ndarray:
    """(m, n_sizes) dense speed matrix in ``space.sizes`` column order."""
    sizes = space.sizes
    flat = [sv.get(s, 0.0) for sv in speeds for s in sizes]
    return np.asarray(flat, dtype=np.float64).reshape(len(speeds), len(sizes))


def solve_all_partitions(space: PartitionSpace, speeds):
    """Run the batched Algorithm-1 kernel over every valid length-m multiset.

    Returns ``(objs, perms, feas)`` with ``perms`` (P, m) in slice *sizes*
    (perm[p, j] = size job j gets under partition row p), rows in
    ``space.partitions_of_len(m)`` order — the raw material for both
    :func:`optimize_partition` and fragmentation-aware policy variants.
    """
    m = len(speeds)
    cols = space.part_cols(m)
    if cols.shape[0] == 0:
        return (np.empty(0), np.empty((0, m), dtype=np.int64),
                np.empty(0, dtype=bool))
    S = _speed_matrix(space, speeds)
    objs, cis, WG = _forward_full(
        cols, S, _cidx_for((space.uid, m), cols, len(space.sizes)))
    perm_cols, feas = _backtrack_all(cols, WG, cis)
    sizes_arr = np.asarray(space.sizes, dtype=np.int64)
    return objs, sizes_arr[perm_cols], feas


def assign_multisets(space: PartitionSpace, rows, speeds):
    """Batched exact assignment over arbitrary slice multisets ``rows``
    (each a length-m tuple of sizes from ``space``; all rows same length).
    Used by policies that scan sub-multisets (e.g. OptSta's fixed menu).
    Returns ``(objs, perms, feas)`` as :func:`solve_all_partitions` does,
    rows in the given order."""
    m = len(speeds)
    col = space.size_col
    cols = np.asarray([[col[s] for s in r] for r in rows],
                      dtype=np.int64).reshape(len(rows), m)
    S = _speed_matrix(space, speeds)
    objs, cis, WG = _forward_full(
        cols, S, _cidx_for((space.uid, tuple(rows)), cols, len(space.sizes)))
    perm_cols, feas = _backtrack_all(cols, WG, cis)
    sizes_arr = np.asarray(space.sizes, dtype=np.int64)
    return objs, sizes_arr[perm_cols], feas


def _optimize_batch(space: PartitionSpace, speeds,
                    require_feasible: bool) -> Optional[PartitionChoice]:
    """First-strict-max selection over partition rows (the historical scan
    order: rows ascend in ``partitions_of_len`` order, replacement only on
    strictly greater objective).  Feasibility is resolved lazily: only the
    winning row's path is backtracked unless the winner turns out
    infeasible under ``require_feasible`` (then the full mask is needed —
    the global first-max is also the feasible first-max whenever it is
    itself feasible)."""
    m = len(speeds)
    cols = space.part_cols(m)
    P = cols.shape[0]
    if P == 0:
        return None
    S = _speed_matrix(space, speeds)
    cidx = _cidx_for((space.uid, m), cols, len(space.sizes))
    levels, _ = _dp_schedule(m)
    dps, WG = _forward_max(P, m, S, cidx, levels)
    objs = dps[m][:, 0]
    idx = int(objs.argmax())
    perm_cols, feasible = _backtrack_row(m, levels, dps, WG,
                                         cols[idx].tolist(), idx)
    if require_feasible and not feasible:
        # rare: the global winner's own assignment is infeasible — fall back
        # to the full argmax-tracked pass to mask per-row feasibility
        _, cis, WG2 = _forward_full(cols, S, cidx)
        _, feas = _backtrack_all(cols, WG2, cis)
        if not feas.any():
            return None
        idx = int(np.argmax(np.where(feas, objs, -np.inf)))
        perm_cols, feasible = _backtrack_row(m, levels, dps, WG,
                                             cols[idx].tolist(), idx)
    sizes = space.sizes
    return PartitionChoice(tuple(sizes[c] for c in perm_cols),
                           float(objs[idx]), feasible)


def _resolve_objective(objective):
    """Objective argument (name / instance / None) -> instance, or ``None``
    for the default throughput goal (historical bit-identical path).
    Imported lazily: ``repro.core.sim`` eagerly imports the engine, which
    imports this module — a top-level import would cycle."""
    if objective is None:
        return None
    from repro.core.sim.objectives import resolve_objective
    return resolve_objective(objective)


def _optimize_objective(space: PartitionSpace, speeds, require_feasible: bool,
                        objective, power) -> Optional[PartitionChoice]:
    """Non-default-objective solve: full argmax-tracked forward over every
    length-m row, per-row feasibility from the backtrack, then the
    objective ranks rows from (throughput, watts).  The per-row assignment
    is the throughput-optimal one — exact for any row-ranking objective
    because a row's watts are assignment-invariant."""
    from repro.core.sim.objectives import partition_watts, resolve_power
    m = len(speeds)
    cols = space.part_cols(m)
    P = cols.shape[0]
    if P == 0:
        return None
    S = _speed_matrix(space, speeds)
    cidx = _cidx_for((space.uid, m), cols, len(space.sizes))
    objs, cis, WG = _forward_full(cols, S, cidx)
    perm_cols, feas = _backtrack_all(cols, WG, cis)
    if require_feasible:
        if not feas.any():
            return None
        pool = feas
    else:
        pool = np.ones(P, dtype=bool)
    watts = (partition_watts(space, resolve_power(power), m)
             if objective.needs_power else None)
    idx = objective.select(objs, watts, pool)
    sizes = space.sizes
    return PartitionChoice(tuple(sizes[c] for c in perm_cols[idx]),
                           float(objs[idx]), bool(feas[idx]))


def optimize_partition(space: PartitionSpace,
                       speeds: Sequence[Dict[int, float]],
                       require_feasible: bool = False,
                       memo: bool = True,
                       objective=None,
                       power=None) -> Optional[PartitionChoice]:
    """Algorithm 1 with exact assignment.  speeds[i][size] -> f_i(size).

    ``objective`` names (or is) the row-ranking goal — default throughput,
    the historical behavior; ``power`` is the per-kind
    :class:`~repro.core.fleet.PowerModel` energy-aware objectives score
    with (``None`` = reference a100)."""
    m = len(speeds)
    if m == 0:
        return None
    obj = _resolve_objective(objective)
    if memo:
        key = _memo_key(space, speeds, require_feasible)
        if obj is not None:
            key = key + (obj.memo_key(), power)
        cached = _MEMO.get(key, _MEMO)        # sentinel: None is a valid value
        if cached is not _MEMO:
            _MEMO_STATS["hits"] += 1
            return cached
        _MEMO_STATS["misses"] += 1
    if obj is not None:
        best = _optimize_objective(space, speeds, require_feasible, obj, power)
    elif m == 1:
        best = _optimize_single(space, speeds[0], require_feasible)
    else:
        best = _optimize_batch(space, speeds, require_feasible)
    if memo:
        if len(_MEMO) >= _MEMO_MAX:
            _MEMO.pop(next(iter(_MEMO)))       # evict oldest insertion
        _MEMO[key] = best
    return best


def optimize_partition_batch(space: PartitionSpace,
                             mixes: Sequence[Sequence[Dict[int, float]]],
                             require_feasible: bool = False,
                             memo: bool = True,
                             objective=None,
                             power=None) -> List[Optional[PartitionChoice]]:
    """Solve many repartition decisions against one space in one pass.

    ``mixes[i]`` is the per-job speed-dict list of decision i (job counts may
    differ between mixes).  Same-length mixes are stacked into a single
    ``(B*P, m)`` DP — the per-call fixed cost (speed-matrix build, weight
    gather, per-level numpy dispatch) amortizes over the batch, which is
    where the >=10x over the scalar scan comes from (see
    ``benchmarks/components.optimizer_latency``).  The engine's same-tick
    coalescing routes concurrent repartitions here.

    Element i equals ``optimize_partition(space, mixes[i], ...)`` exactly
    (bit-identical choice and objective, same memo interaction) — for the
    default throughput goal and for every registered objective.
    """
    obj = _resolve_objective(objective)
    out: List[Optional[PartitionChoice]] = [None] * len(mixes)
    pending: Dict[int, List[int]] = {}
    keys: Dict[int, tuple] = {}
    key_first: Dict[tuple, int] = {}
    alias: Dict[int, int] = {}
    for i, speeds in enumerate(mixes):
        m = len(speeds)
        if m == 0:
            continue
        if memo:
            key = _memo_key(space, speeds, require_feasible)
            if obj is not None:
                key = key + (obj.memo_key(), power)
            cached = _MEMO.get(key, _MEMO)
            if cached is not _MEMO:
                _MEMO_STATS["hits"] += 1
                out[i] = cached
                continue
            first = key_first.get(key)
            if first is not None:
                # duplicate mix within this batch: sequential singles would
                # hit the memo here, so count (and solve) it as one
                _MEMO_STATS["hits"] += 1
                alias[i] = first
                continue
            _MEMO_STATS["misses"] += 1
            keys[i] = key
            key_first[key] = i
        if obj is None and m == 1:
            out[i] = _optimize_single(space, speeds[0], require_feasible)
        else:
            pending.setdefault(m, []).append(i)
    for m, idxs in pending.items():
        group = [mixes[i] for i in idxs]
        if obj is not None:
            solved = _optimize_group_objective(space, group, require_feasible,
                                               obj, power)
        else:
            solved = _optimize_group(space, group, require_feasible)
        for i, choice in zip(idxs, solved):
            out[i] = choice
    for i, first in alias.items():
        out[i] = out[first]
    if memo:
        for i, key in keys.items():
            if len(_MEMO) >= _MEMO_MAX:
                _MEMO.pop(next(iter(_MEMO)))
            _MEMO[key] = out[i]
    return out


def _optimize_group(space: PartitionSpace, group,
                    require_feasible: bool) -> List[Optional[PartitionChoice]]:
    """Stacked solve of B same-length mixes: rows (B*P, m), one forward."""
    B = len(group)
    m = len(group[0])
    cols = space.part_cols(m)
    P = cols.shape[0]
    if P == 0:
        return [None] * B
    sizes = space.sizes
    n = len(sizes)
    flat = [sv.get(s, 0.0) for speeds in group for sv in speeds
            for s in sizes]
    S = np.asarray(flat, dtype=np.float64)
    base = _cidx_for((space.uid, m), cols, n)
    # shift each mix's gather block into its slab of S.ravel()
    cidx = (base[None, :, :]
            + (np.arange(B) * (m * n))[:, None, None]).reshape(B * P, -1)
    cols_tiled = np.broadcast_to(cols, (B,) + cols.shape).reshape(B * P, m)
    objs, cis, WG = _forward_full(cols_tiled, S, cidx)
    objs2 = objs.reshape(B, P)
    # lazily backtrack the B winner rows only; the full per-row feasibility
    # mask is needed just for mixes whose winner turns out infeasible under
    # require_feasible (the global first-max is also the feasible first-max
    # whenever it is itself feasible)
    idx = objs2.argmax(axis=1)
    rows = np.arange(B) * P + idx
    perm_sel, feas_sel = _backtrack_all(cols_tiled, WG, cis, rows=rows)
    ok = np.ones(B, dtype=bool)
    if require_feasible and not feas_sel.all():
        _, feas = _backtrack_all(cols_tiled, WG, cis)
        feas2 = feas.reshape(B, P)
        ok = feas2.any(axis=1)
        idx = np.argmax(np.where(feas2, objs2, -np.inf), axis=1)
        rows = np.arange(B) * P + idx
        perm_sel, feas_sel = _backtrack_all(cols_tiled, WG, cis, rows=rows)
    win_perms = perm_sel.tolist()
    win_objs = objs[rows].tolist()
    win_feas = feas_sel.tolist()
    results: List[Optional[PartitionChoice]] = []
    for b in range(B):
        if not ok[b]:
            results.append(None)
            continue
        results.append(PartitionChoice(
            tuple(sizes[c] for c in win_perms[b]),
            win_objs[b], win_feas[b]))
    return results


def _optimize_group_objective(space: PartitionSpace, group,
                              require_feasible: bool, objective, power
                              ) -> List[Optional[PartitionChoice]]:
    """Stacked non-default-objective solve of B same-length mixes: one
    forward over (B*P, m) rows, full backtrack (feasibility is an input to
    every objective pool), then per-mix row ranking.  Element b equals
    ``_optimize_objective(space, group[b], ...)`` exactly."""
    from repro.core.sim.objectives import partition_watts, resolve_power
    B = len(group)
    m = len(group[0])
    cols = space.part_cols(m)
    P = cols.shape[0]
    if P == 0:
        return [None] * B
    sizes = space.sizes
    n = len(sizes)
    flat = [sv.get(s, 0.0) for speeds in group for sv in speeds
            for s in sizes]
    S = np.asarray(flat, dtype=np.float64)
    base = _cidx_for((space.uid, m), cols, n)
    cidx = (base[None, :, :]
            + (np.arange(B) * (m * n))[:, None, None]).reshape(B * P, -1)
    cols_tiled = np.broadcast_to(cols, (B,) + cols.shape).reshape(B * P, m)
    objs, cis, WG = _forward_full(cols_tiled, S, cidx)
    perm_cols, feas = _backtrack_all(cols_tiled, WG, cis)
    objs2 = objs.reshape(B, P)
    feas2 = feas.reshape(B, P)
    perms2 = perm_cols.reshape(B, P, m)
    watts = (partition_watts(space, resolve_power(power), m)
             if objective.needs_power else None)
    all_rows = np.ones(P, dtype=bool)
    results: List[Optional[PartitionChoice]] = []
    for b in range(B):
        if require_feasible:
            if not feas2[b].any():
                results.append(None)
                continue
            pool = feas2[b]
        else:
            pool = all_rows
        idx = objective.select(objs2[b], watts, pool)
        results.append(PartitionChoice(
            tuple(sizes[c] for c in perms2[b, idx]),
            float(objs2[b, idx]), bool(feas2[b, idx])))
    return results


def _optimize_single(space: PartitionSpace, sv: Dict[int, float],
                     require_feasible: bool) -> Optional[PartitionChoice]:
    """m == 1 fast path (a lone job on a GPU is the most common decision):
    scan the length-1 partitions in row order, first strict max — identical
    selection to the batched kernel, no numpy round-trip."""
    best_size, best_v = None, -np.inf
    for (size,) in space.partitions_of_len(1):
        v = sv.get(size, 0.0)
        if require_feasible and v <= 0.0:
            continue
        if v > best_v:
            best_size, best_v = size, v
    if best_size is None:
        return None
    return PartitionChoice((best_size,), float(best_v), best_v > 0.0)


def optimize_partition_bruteforce(space: PartitionSpace,
                                  speeds: Sequence[Dict[int, float]],
                                  objective=None, power=None):
    """Literal Algorithm 1: enumerate every ordered x (partition x assignment).

    Like the DP path, an all-zero speed vector still yields a (infeasible)
    choice with objective 0.0 rather than ``None`` — the two are test oracles
    for each other, so they must agree on all-OOM job mixes.

    With a non-default ``objective`` this stays the independent reference:
    per multiset the best-throughput assignment is found by enumeration, the
    multiset's watts come straight from ``PowerModel.partition_w`` (not the
    optimizer's cached row vectors), and the objective ranks the multisets.
    """
    m = len(speeds)
    obj_fn = _resolve_objective(objective)
    if obj_fn is not None:
        return _bruteforce_objective(space, speeds, obj_fn, power)
    best_obj, best_config = -1.0, None
    for part in space.partitions_of_len(m):
        for perm in sorted(set(itertools.permutations(part))):
            obj = sum(speeds[j].get(perm[j], 0.0) for j in range(m))
            if obj > best_obj:
                best_obj, best_config = obj, perm
    if best_config is None:
        return None
    return PartitionChoice(tuple(best_config), best_obj,
                           all(speeds[j].get(best_config[j], 0.0) > 0.0
                               for j in range(m)))


def _bruteforce_objective(space: PartitionSpace, speeds, objective, power):
    from repro.core.sim.objectives import resolve_power
    m = len(speeds)
    rows = space.partitions_of_len(m)
    if not rows:
        return None
    pw = resolve_power(power)
    objs, watts, perms = [], [], []
    for part in rows:
        best_t, best_perm = -1.0, None
        for perm in sorted(set(itertools.permutations(part))):
            t = sum(speeds[j].get(perm[j], 0.0) for j in range(m))
            if t > best_t:
                best_t, best_perm = t, perm
        objs.append(best_t)
        watts.append(pw.partition_w(space, part))
        perms.append(best_perm)
    objs = np.asarray(objs)
    watts = np.asarray(watts) if objective.needs_power else None
    idx = objective.select(objs, watts, np.ones(len(rows), dtype=bool))
    perm = perms[idx]
    return PartitionChoice(tuple(perm), float(objs[idx]),
                           all(speeds[j].get(perm[j], 0.0) > 0.0
                               for j in range(m)))
