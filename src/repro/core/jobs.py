"""Workload pool and job model for the MISO cluster.

The paper's evaluation mixes eight single-GPU DL training workloads (Table 2)
with four batch sizes each.  Our pool is built from the assigned architecture
*families* at single-accelerator scale (the paper's jobs are 25M–1.4B-param
models): each family contributes a config whose FLOPs / HBM-bytes / footprint
per step come from the shared analytic cost model (roofline/costs.py), so the
simulator, the predictor's training data and the §Roofline tables are
mutually consistent.

Per-job ``compute_eff`` (achievable MFU) and ``cache_sens`` (sensitivity to
losing shared-L2 capacity) are deterministic functions of the job type —
they are what make the MPS->MIG mapping non-trivial but learnable.
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

from repro.configs.base import ModelConfig, MoEConfig
from repro.roofline.costs import step_costs

# ---------------------------------------------------------------------------
# single-accelerator-scale members of each assigned family (paper Table 2
# analogue: model x batch sizes)
# ---------------------------------------------------------------------------

_SEQ = 1024

_POOL_CONFIGS = {
    "smollm-360m": ModelConfig(
        name="smollm-360m", family="dense", n_layers=32, d_model=960,
        n_heads=15, n_kv_heads=5, head_dim=64, d_ff=2560, vocab_size=49152,
        tie_embeddings=True),
    "granite-dense-700m": ModelConfig(
        name="granite-dense-700m", family="dense", n_layers=24, d_model=1536,
        n_heads=12, n_kv_heads=4, head_dim=128, d_ff=5376, vocab_size=49152),
    "rwkv6-430m": ModelConfig(
        name="rwkv6-430m", family="ssm", n_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=16, d_ff=3584, vocab_size=65536,
        rwkv_head_dim=64),
    "recurrentgemma-400m": ModelConfig(
        name="recurrentgemma-400m", family="hybrid", n_layers=12, d_model=1024,
        n_heads=8, n_kv_heads=1, head_dim=128, d_ff=3072, vocab_size=65536,
        local_window=1024, block_pattern=("rglru", "rglru", "attn"),
        tie_embeddings=True),
    "qwen2-moe-1b": ModelConfig(
        name="qwen2-moe-1b", family="moe", n_layers=12, d_model=1024,
        n_heads=8, n_kv_heads=8, head_dim=128, d_ff=704, vocab_size=65536,
        moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=704,
                      n_shared_experts=2, d_ff_shared=1408)),
    "musicgen-300m": ModelConfig(
        name="musicgen-300m", family="audio", n_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=2048,
        mlp_variant="gelu"),
    "mixtral-micro-1b": ModelConfig(
        name="mixtral-micro-1b", family="moe", n_layers=12, d_model=1024,
        n_heads=16, n_kv_heads=4, head_dim=64, d_ff=2816, vocab_size=32768,
        sliding_window=1024,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=2816)),
    "chameleon-550m": ModelConfig(
        name="chameleon-550m", family="vlm", n_layers=16, d_model=1280,
        n_heads=20, n_kv_heads=4, head_dim=64, d_ff=4480, vocab_size=65536,
        qk_norm=True),
}

_BATCHES = {
    "smollm-360m": (8, 16, 32, 64),
    "granite-dense-700m": (4, 8, 16, 32),
    "rwkv6-430m": (8, 16, 32, 64),
    "recurrentgemma-400m": (8, 16, 32, 64),
    "qwen2-moe-1b": (4, 8, 16, 32),
    "musicgen-300m": (8, 16, 32, 64),
    "mixtral-micro-1b": (4, 8, 16, 32),
    "chameleon-550m": (4, 8, 16, 32),
}


def _det_unit(*keys: str) -> float:
    """Deterministic hash -> [0, 1)."""
    h = hashlib.sha256("|".join(keys).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2 ** 64


@dataclass(frozen=True)
class JobProfile:
    name: str                 # "<model>/b<batch>"
    model: str
    batch: int
    flops_per_step: float
    bytes_per_step: float
    mem_gb: float             # resident footprint (must fit the slice)
    compute_eff: float        # achievable fraction of peak FLOP/s
    cache_sens: float         # 0..1: byte inflation when shared cache shrinks
    sm_util: float            # fraction of SMs the job can keep busy alone
                              # (paper Takeaway 1: most jobs can't use a full GPU)

    @property
    def intensity(self) -> float:
        return self.flops_per_step / max(self.bytes_per_step, 1.0)

    # profiles are immutable value objects: copying a Job (the simulator
    # deep-copies its trace) must not clone them, both for speed and so the
    # perf-model's identity-keyed caches stay warm across simulations
    def __deepcopy__(self, memo) -> "JobProfile":
        return self

    def __copy__(self) -> "JobProfile":
        return self


# effective-byte multipliers by family: element-wise-heavy recurrent models
# and embedding-table-heavy models move far more HBM bytes per useful FLOP
# than the matmul-dense families (the paper's GNN/embedding jobs are the
# extreme cases).
_BYTES_MULT_BASE = {
    "dense": 2.5, "moe": 4.0, "ssm": 9.0, "hybrid": 7.0,
    "audio": 3.0, "vlm": 2.5,
}


def job_profile(model: str, batch: int) -> JobProfile:
    cfg = _POOL_CONFIGS[model]
    c = step_costs(cfg, _SEQ, batch, "train")
    u = lambda tag: _det_unit(tag, model, str(batch))
    eff = 0.35 + 0.30 * u("eff")
    # memory-boundedness: family base x small-batch penalty x jitter
    mult = _BYTES_MULT_BASE[cfg.family] * (1.0 + 8.0 / batch) * (0.7 + 0.9 * u("mult"))
    bytes_eff = c.hbm_bytes * mult
    inten = c.flops / max(bytes_eff, 1.0)
    sens = max(0.05, min(0.95, 1.1 - inten / 500.0))
    sens = 0.6 * sens + 0.4 * u("cache")
    # achievable SM occupancy: grows with batch, capped well below 1 for most
    # (paper Fig 2: typical DL jobs keep 20-60% of an A100's SMs busy)
    sm = 0.14 + 0.07 * math.log2(max(batch, 2)) + 0.22 * u("sm")
    sm = max(0.12, min(0.9, sm))
    return JobProfile(
        name=f"{model}/b{batch}", model=model, batch=batch,
        flops_per_step=c.flops, bytes_per_step=bytes_eff,
        mem_gb=min(19.0, c.mem_bytes / 1e9),   # pool fits 3g/4g (20GB) by design
        compute_eff=eff, cache_sens=sens, sm_util=sm)


WORKLOADS: Tuple[JobProfile, ...] = tuple(
    job_profile(m, b) for m in _POOL_CONFIGS for b in _BATCHES[m])

DUMMY_PROFILE = JobProfile(
    name="dummy", model="dummy", batch=1,
    flops_per_step=1e9, bytes_per_step=1e8, mem_gb=0.3,
    compute_eff=0.5, cache_sens=0.05, sm_util=0.05)


# ---------------------------------------------------------------------------
# Job: one queue entry in the cluster
# ---------------------------------------------------------------------------


@dataclass(slots=True)
class Job:
    jid: int
    profile: JobProfile
    arrival: float
    work: float                       # seconds of exclusive full-GPU execution
    min_mem_gb: float = 0.0           # user memory constraint (paper §4.3)
    qos_min_slice: int = 0            # minimum slice size for QoS (paper §4.3)
    n_instances: int = 1              # multi-instance jobs (paper §4.3)
    mi_group: Optional[int] = None    # clones share one MPS profile
    # phase changes: list of (fraction_of_work, profile) — triggers re-profiling
    phases: Tuple[Tuple[float, JobProfile], ...] = ()

    # runtime bookkeeping (filled by the simulator)
    remaining: float = field(default=0.0)
    queue_since: float = 0.0
    t_queue: float = 0.0
    t_mps: float = 0.0
    t_ckpt: float = 0.0
    t_run: float = 0.0
    start_time: Optional[float] = None
    finish_time: Optional[float] = None
    # (space, min_required_slice) memo maintained by
    # PartitionSpace.job_required_slice — placement-scan hot path
    _req_cache: Optional[tuple] = field(default=None, repr=False,
                                        compare=False)

    def __post_init__(self):
        if self.remaining == 0.0:
            self.remaining = self.work

    def profile_at(self, done_frac: float) -> JobProfile:
        if not self.phases:
            return self.profile
        prof = self.profile
        for frac, p in self.phases:
            if done_frac >= frac:
                prof = p
        return prof
