"""Training data for the MPS->MIG predictor (paper §4.1 "Model training").

400 random job mixes per job count 1..7 (2800 mixes), each a (3 x 7 MPS
input, 3 x 7 MIG target) pair with dummy-workload padding, plus 4 extra
column permutations per mix (14,000 samples), 75/25 train/validation split.
Targets for the 2g/1g linear-regression heads are generated alongside.
"""
from __future__ import annotations

import numpy as np

from repro.core.jobs import DUMMY_PROFILE, WORKLOADS
from repro.core.perfmodel import MPS_LEVELS, PerfModel

OUT_SLICES = (7, 4, 3)      # U-Net output rows
LIN_SLICES = (2, 1)         # linear-regression heads


def mix_to_matrices(pm: PerfModel, profs, jobs: int = 7):
    """One mix -> (mps 3xJ, mig 3xJ, lin 2xJ, n_real).

    Matrices include dummy padding columns; per-column max normalization as
    in the paper (all elements in (0, 1]).
    """
    m = len(profs)
    padded = list(profs) + [DUMMY_PROFILE] * (jobs - m)
    mps = np.asarray(pm.mps_matrix(padded), dtype=np.float32)   # (3, J)
    col_max = np.maximum(mps.max(axis=0, keepdims=True), 1e-9)
    mps = mps / col_max

    mig = np.zeros((len(OUT_SLICES), jobs), np.float32)
    lin = np.zeros((len(LIN_SLICES), jobs), np.float32)
    for j, p in enumerate(padded):
        sv = pm.speed_vector(p)
        for r, s in enumerate(OUT_SLICES):
            mig[r, j] = sv.get(s, 0.0)
        for r, s in enumerate(LIN_SLICES):
            lin[r, j] = sv.get(s, 0.0)
    mcol = np.maximum(mig.max(axis=0, keepdims=True), 1e-9)
    mig = mig / mcol
    return mps, mig, lin, m


def generate_dataset(pm: PerfModel, *, mixes_per_count: int = 400,
                     max_jobs: int = 7, n_perms: int = 4, seed: int = 0,
                     val_frac: float = 0.25):
    """Returns dict of train/val arrays (paper: 2800 mixes -> 14k samples)."""
    rng = np.random.default_rng(seed)
    pool = list(WORKLOADS)
    xs, ys, lins = [], [], []
    for count in range(1, max_jobs + 1):
        for _ in range(mixes_per_count):
            idx = rng.integers(0, len(pool), size=count)
            profs = [pool[i] for i in idx]
            mps, mig, lin, _ = mix_to_matrices(pm, profs, jobs=max_jobs)
            variants = [np.arange(max_jobs)]
            for _ in range(n_perms):
                variants.append(rng.permutation(max_jobs))
            for perm in variants:
                xs.append(mps[:, perm])
                ys.append(mig[:, perm])
                lins.append(lin[:, perm])
    x = np.stack(xs)
    y = np.stack(ys)
    lin = np.stack(lins)
    n = len(x)
    order = rng.permutation(n)
    x, y, lin = x[order], y[order], lin[order]
    n_val = int(n * val_frac)
    return {
        "train_x": x[n_val:], "train_y": y[n_val:], "train_lin": lin[n_val:],
        "val_x": x[:n_val], "val_y": y[:n_val], "val_lin": lin[:n_val],
    }
