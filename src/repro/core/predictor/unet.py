"""The MISO performance predictor: a lightweight U-Net convolutional
autoencoder (paper §4.1, Fig 7-8).

Input : (batch, L, J) MPS speed matrix — L sharing levels x J jobs
        (3 x 7 on A100; the TPU space uses 3 x 8), each column normalized
        by its max, dummy-padded to J columns.
Output: (batch, 3, J) predicted interference-free speeds on the three
        largest slice types (7g / 4g / 3g), per-column normalized.

Architecture per the paper: two encoder blocks with 32 and 64 filters into a
256-filter center, two decoder blocks with skip connections, 2x2 kernels,
(2,2) strides.  The 3x7 input is edge-replication-padded to 4x8 so the
stride-2 convs divide evenly (the paper does not specify its padding; we
avoid zero padding for the reason the paper cites — large zero regions hurt
training), and the output is cropped back.
Inference goes through one module-level jitted apply shared by every
:class:`UNet` instance (keyed on parameter shapes + input shape, so all
estimators in a process — and all sweep workers forked from it — reuse one
compiled executable per shape instead of recompiling per instance), and
batches are padded to power-of-two buckets so a handful of compilations
serve any batch size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.utils.tree import ParamBuilder, fan_in_init

DN = ("NHWC", "HWIO", "NHWC")


def _conv_init(k_h, k_w, c_in):
    return fan_in_init(k_h * k_w * c_in)


def init(key, levels: int = 3, jobs: int = 7, dtype=jnp.float32):
    """Returns (params, specs)."""
    pb = ParamBuilder(key, dtype=dtype)

    def conv(name, kh, kw, cin, cout):
        pb.param(f"{name}_w", (kh, kw, cin, cout),
                 ("kh", "kw", "cin", "cout"), init=_conv_init(kh, kw, cin))
        pb.param(f"{name}_b", (cout,), ("cout",),
                 init=lambda k, s, d: jnp.zeros(s, d))

    conv("stem", 2, 2, 1, 16)
    conv("enc1", 2, 2, 16, 32)     # stride 2
    conv("enc2", 2, 2, 32, 64)     # stride 2
    conv("center", 2, 2, 64, 256)
    conv("dec1_up", 2, 2, 256, 64)  # transpose, stride 2
    conv("dec1", 2, 2, 64 + 32, 64)
    conv("dec2_up", 2, 2, 64, 32)   # transpose, stride 2
    conv("dec2", 2, 2, 32 + 16, 32)
    conv("head", 1, 1, 32, 1)
    return pb.build()


def _conv(x, p, name, stride=1):
    y = lax.conv_general_dilated(
        x, p[f"{name}_w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=DN)
    return y + p[f"{name}_b"]


def _conv_t(x, p, name):
    y = lax.conv_transpose(
        x, p[f"{name}_w"], strides=(2, 2), padding="SAME",
        dimension_numbers=DN)
    return y + p[f"{name}_b"]


def _act(x):
    # leaky ReLU: the ASHA-tuned activation in the paper is unspecified; plain
    # ReLU collapses (dead units -> zero gradient) on this low-variance input
    return jax.nn.leaky_relu(x, negative_slope=0.1)


def pad_input(m, out_h: int = 4, out_w: int = 8):
    """Edge-replicate a (batch, L, J) matrix to (batch, out_h, out_w, 1)."""
    b, h, w = m.shape
    m = jnp.pad(m, ((0, 0), (0, out_h - h), (0, out_w - w)), mode="edge")
    return m[..., None]


def apply(params, mps_matrix, levels: int = 3, jobs: int = 7):
    """mps_matrix: (batch, levels, jobs) -> (batch, 3, jobs) in (0, 1]."""
    x = pad_input(mps_matrix)
    stem = _act(_conv(x, params, "stem"))          # (4, 8, 16)
    e1 = _act(_conv(stem, params, "enc1", stride=2))  # (2, 4, 32)
    e2 = _act(_conv(e1, params, "enc2", stride=2))    # (1, 2, 64)
    c = _act(_conv(e2, params, "center"))             # (1, 2, 256)
    d1 = _act(_conv_t(c, params, "dec1_up"))          # (2, 4, 64)
    d1 = _act(_conv(jnp.concatenate([d1, e1], -1), params, "dec1"))
    d2 = _act(_conv_t(d1, params, "dec2_up"))         # (4, 8, 32)
    d2 = _act(_conv(jnp.concatenate([d2, stem], -1), params, "dec2"))
    out = jax.nn.sigmoid(_conv(d2, params, "head"))[..., 0]  # (4, 8)
    return out[:, :3, :jobs]


@functools.partial(jax.jit, static_argnames=("levels", "jobs"))
def _apply_jit(params, mps_matrix, levels: int, jobs: int):
    return apply(params, mps_matrix, levels=levels, jobs=jobs)


def _bucket(b: int) -> int:
    """Next power-of-two batch bucket, so B estimator instances x arbitrary
    window batch sizes compile O(log B) executables instead of O(B)."""
    n = 1
    while n < b:
        n *= 2
    return n


def warm_jit_cache(levels: int = 3, jobs: int = 7,
                   batch_buckets=(1, 2, 4, 8)) -> None:
    """Compile the shared apply for the standard shapes ahead of time.

    Call this in a process that will fork workers (e.g. the sweep engine):
    the forked children inherit the parent's XLA compilation cache, so each
    worker skips its own multi-hundred-ms compile.  Compilation is keyed on
    parameter *shapes*, so warming with freshly-initialized params also
    covers artifact-loaded ones.
    """
    # misolint: disable=MS102 -- shape-only jit warm-up: params are discarded
    # and XLA keys its compile cache on shapes, so any constant key works
    params, _ = init(jax.random.PRNGKey(0), levels, jobs)
    for b in batch_buckets:
        m = jnp.zeros((b, levels, jobs), jnp.float32)
        _apply_jit(params, m, levels, jobs).block_until_ready()


class UNet:
    """Convenience wrapper holding params; apply is the shared jitted one."""

    def __init__(self, params, levels: int = 3, jobs: int = 7):
        self.params = params
        self.levels = levels
        self.jobs = jobs

    @classmethod
    def create(cls, key, levels: int = 3, jobs: int = 7):
        params, _ = init(key, levels, jobs)
        return cls(params, levels, jobs)

    def __call__(self, mps_matrix):
        """(levels, jobs) or (batch, levels, jobs) -> predictions of the same
        leading shape.  Batches are zero-padded up to the next power-of-two
        bucket (batch elements are independent through every conv, so padding
        rows never change real rows) and cropped back."""
        single = mps_matrix.ndim == 2
        m = mps_matrix[None] if single else mps_matrix
        b = m.shape[0]
        nb = _bucket(b)
        m = jnp.asarray(m, jnp.float32)
        if nb != b:
            m = jnp.concatenate(
                [m, jnp.zeros((nb - b,) + m.shape[1:], jnp.float32)], axis=0)
        out = _apply_jit(self.params, m, self.levels, self.jobs)[:b]
        return out[0] if single else out
