"""Linear-regression heads for the 2g/1g slices (paper §4.1 "Memory
considerations"): speeds on 2g and 1g are predicted from the (7g, 4g, 3g)
speeds by least squares.  The paper reports R^2 ~= 0.96; OOM handling is
separate (the memory monitor zeroes f_i before the optimizer runs), so the
fit uses only non-OOM samples.
"""
from __future__ import annotations

import numpy as np


def fit_linreg(mig_cols: np.ndarray, lin_cols: np.ndarray):
    """mig_cols: (N, 3) = (k7, k4, k3); lin_cols: (N, 2) = (k2, k1).

    Returns dict with weights (4, 2) incl. bias and per-target R^2.
    """
    mask = (lin_cols > 0).all(axis=1)          # exclude OOM rows
    X = mig_cols[mask]
    Y = lin_cols[mask]
    A = np.concatenate([X, np.ones((len(X), 1))], axis=1)   # (N, 4)
    W, *_ = np.linalg.lstsq(A, Y, rcond=None)
    pred = A @ W
    ss_res = ((Y - pred) ** 2).sum(axis=0)
    ss_tot = ((Y - Y.mean(axis=0)) ** 2).sum(axis=0) + 1e-12
    r2 = 1.0 - ss_res / ss_tot
    return {"w": W, "r2": r2}


def apply_linreg(model, mig_cols: np.ndarray) -> np.ndarray:
    """mig_cols: (..., 3) -> (..., 2) clipped to [0, 1]."""
    A = np.concatenate([mig_cols, np.ones(mig_cols.shape[:-1] + (1,))], axis=-1)
    out = A @ model["w"]
    return np.clip(out, 0.0, 1.0)
