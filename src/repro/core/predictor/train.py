"""Predictor training (paper §4.1): MAE loss, Adam, 50 epochs.

The paper reports validation MAE ~= 0.017 over the (0,1] speedup range and
trains in seconds per epoch; this module reproduces that loop, fits the
2g/1g linear-regression heads on the same training split, and persists
everything to an .npz artifact used by the simulator and the cluster driver.

Heterogeneous fleets need one artifact per accelerator kind — each kind's
(MPS matrix -> MIG matrix) mapping reflects its own roofline (h100's 2x
memory doubles the OOM-free region; its bandwidth ratio shifts every
memory-bound speed) — so :func:`train_and_save_kind` trains against the
kind's own partition space and hardware and writes
``artifacts/predictor_<kind>.npz``, exactly the path
``repro.core.fleet.default_artifact_path`` routes through
``GPUSpec.estimator``::

    PYTHONPATH=src python -m repro.core.predictor.train --kinds a100,h100
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.predictor import dataset as ds
from repro.core.predictor import linreg, unet
from repro.train.optim import adam_init, adam_update

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "..",
                            "artifacts")
DEFAULT_PATH = os.path.join(ARTIFACT_DIR, "predictor.npz")


def mae(pred, target):
    return jnp.mean(jnp.abs(pred - target))


def train_predictor(data, *, epochs: int = 50, batch: int = 128,
                    lr: float = 4e-4, lr_min: float = 2e-5, seed: int = 0,
                    jobs: int = 7, log_every: int = 10, verbose: bool = True):
    """Returns (params, history dict)."""
    key = jax.random.PRNGKey(seed)
    params, _ = unet.init(key, jobs=jobs)
    opt = adam_init(params)

    tx = jnp.asarray(data["train_x"])
    ty = jnp.asarray(data["train_y"])
    vx = jnp.asarray(data["val_x"])
    vy = jnp.asarray(data["val_y"])

    @jax.jit
    def step(params, opt, x, y, lr_t):
        def loss_fn(p):
            return mae(unet.apply(p, x, jobs=jobs), y)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr=lr_t)
        return params, opt, loss

    @jax.jit
    def val_loss(params):
        return mae(unet.apply(params, vx, jobs=jobs), vy)

    n = len(tx)
    steps_per_epoch = max(1, n // batch)
    rng = np.random.default_rng(seed)
    history = {"val_mae": [], "train_mae": [], "epoch_s": []}
    for epoch in range(epochs):
        t0 = time.time()
        # cosine decay
        frac = epoch / max(1, epochs - 1)
        lr_t = lr_min + 0.5 * (lr - lr_min) * (1 + np.cos(np.pi * frac))
        order = rng.permutation(n)
        losses = []
        for i in range(steps_per_epoch):
            idx = order[i * batch:(i + 1) * batch]
            params, opt, loss = step(params, opt, tx[idx], ty[idx],
                                     jnp.float32(lr_t))
            losses.append(float(loss))
        vm = float(val_loss(params))
        history["val_mae"].append(vm)
        history["train_mae"].append(float(np.mean(losses)))
        history["epoch_s"].append(time.time() - t0)
        if verbose and (epoch % log_every == 0 or epoch == epochs - 1):
            print(f"[predictor] epoch {epoch:3d} train_mae={np.mean(losses):.4f} "
                  f"val_mae={vm:.4f} ({history['epoch_s'][-1]:.1f}s)")
    return params, history


def fit_heads(data):
    """Fit 2g/1g linreg heads on the training split."""
    mig = data["train_y"].transpose(0, 2, 1).reshape(-1, 3)
    lin = data["train_lin"].transpose(0, 2, 1).reshape(-1, 2)
    return linreg.fit_linreg(mig, lin)


def save_artifact(path, params, heads, history):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    arrays = {"/".join(str(k.key) for k in kp): np.asarray(v)
              for kp, v in flat}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path,
             __head_w=heads["w"], __head_r2=heads["r2"],
             __val_mae=np.asarray(history["val_mae"]),
             **arrays)


def load_artifact(path):
    z = np.load(path)
    params = {}
    heads = {"w": z["__head_w"], "r2": z["__head_r2"]}
    for k in z.files:
        if k.startswith("__"):
            continue
        node = params
        parts = k.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(z[k])
    return params, heads, {"val_mae": z["__val_mae"].tolist()}


def train_and_save(path=DEFAULT_PATH, *, pm=None, epochs=80,
                   mixes_per_count=400, seed=0, verbose=True):
    from repro.core.partitions import a100_mig_space
    from repro.core.perfmodel import PerfModel
    pm = pm or PerfModel(a100_mig_space())
    data = ds.generate_dataset(pm, mixes_per_count=mixes_per_count, seed=seed)
    params, history = train_predictor(data, epochs=epochs, seed=seed,
                                      verbose=verbose)
    heads = fit_heads(data)
    save_artifact(path, params, heads, history)
    if verbose:
        print(f"[predictor] final val MAE {history['val_mae'][-1]:.4f}; "
              f"linreg R^2 {heads['r2']}")
    return params, heads, history


def kind_perfmodel(kind: str):
    """The ground-truth performance model a kind's predictor trains
    against (its own slice menu + roofline hardware)."""
    from repro.core.partitions import a100_mig_space, h100_mig_space
    from repro.core.perfmodel import A100, H100, PerfModel
    try:
        space_fn, hw = {"a100": (a100_mig_space, A100),
                        "h100": (h100_mig_space, H100)}[kind]
    except KeyError:
        raise ValueError(
            f"no trainable predictor for kind {kind!r} (the U-Net's output "
            f"rows are the 7g/4g/3g MIG slices; train a100 or h100)") \
            from None
    return PerfModel(space_fn(), hw)


def train_and_save_kind(kind: str, path=None, *, epochs=80,
                        mixes_per_count=400, seed=0, verbose=True):
    """Train and persist ``artifacts/predictor_<kind>.npz`` — the per-kind
    artifact ``repro.core.fleet`` auto-routes into ``GPUSpec.estimator``."""
    path = path or os.path.join(ARTIFACT_DIR, f"predictor_{kind}.npz")
    if verbose:
        print(f"[predictor] training {kind} -> {os.path.abspath(path)}")
    return train_and_save(path, pm=kind_perfmodel(kind), epochs=epochs,
                          mixes_per_count=mixes_per_count, seed=seed,
                          verbose=verbose)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="train per-kind MPS->MIG predictor artifacts")
    ap.add_argument("--kinds", default="a100,h100",
                    help="comma-separated accelerator kinds to train")
    ap.add_argument("--epochs", type=int, default=80)
    ap.add_argument("--mixes-per-count", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    for kind in [k.strip() for k in args.kinds.split(",") if k.strip()]:
        train_and_save_kind(kind, epochs=args.epochs,
                            mixes_per_count=args.mixes_per_count,
                            seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
