from repro.core.predictor.unet import UNet
from repro.core.predictor.dataset import generate_dataset, mix_to_matrices
from repro.core.predictor.linreg import fit_linreg, apply_linreg
