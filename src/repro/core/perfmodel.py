"""Ground-truth performance model: job speeds on MIG slices and under MPS.

No A100s (or TPUs) exist in this container, so measured speeds are replaced
by a roofline-analytic model (DESIGN.md §2, "what changed"):

* **MIG slice** (interference-free): the slice provides ``compute_frac`` of
  peak FLOP/s, ``mem_bw_frac`` of HBM bandwidth and ``cache_frac`` of shared
  L2.  Losing cache inflates a job's HBM bytes by its ``cache_sens``.
  ``t = max(t_compute, t_memory)``; speed = 1/t.

* **MPS level** (interference-prone): every co-located job is capped at
  ``level`` of the SMs; total compute is time-multiplexed when oversubscribed,
  HBM bandwidth is contended proportionally to demand (fixed-point
  iteration), and co-runners add cache pressure that inflates bytes.

The U-Net predictor is trained purely on (MPS-matrix -> MIG-matrix) pairs
from this model — it never sees these internals, mirroring how the paper
trains on measured pairs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.core.jobs import JobProfile
from repro.core.partitions import PartitionSpace

MPS_LEVELS = (1.00, 0.50, 0.14)       # paper §4.1: 100 / 50 / 14 %


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float                 # per accelerator (full)
    hbm_bw: float
    mem_gb: float
    cache_mps_kappa: float = 1.50     # byte inflation per unit cache pressure (MPS)
    cache_mig_kappa: float = 0.45     # byte inflation for reduced-cache slices
    mps_mux_overhead: float = 0.12    # per-co-runner time-multiplexing cost (MPS)
    mps_bw_loss: float = 0.15         # achievable-HBM-bandwidth loss per co-runner
    sched_overhead_s: float = 1e-3    # fixed per-step latency floor


A100 = Hardware("a100-40gb", peak_flops=312e12, hbm_bw=1.555e12, mem_gb=40.0)
H100 = Hardware("h100-80gb", peak_flops=989e12, hbm_bw=3.35e12, mem_gb=80.0,
                mps_bw_loss=0.12)
# one v5e pod as "one accelerator": 256 chips
TPU_V5E_POD = Hardware("tpu-v5e-pod", peak_flops=256 * 197e12,
                       hbm_bw=256 * 819e9, mem_gb=256 * 16.0,
                       cache_mps_kappa=0.15, cache_mig_kappa=0.0)


_CACHE_MAX = 65536   # FIFO bound: profiles are deepcopied per job, so keys
                     # accumulate across long sweeps without one


class PerfModel:
    """Ground-truth speeds.  All entry points are memoized on the profile
    *object* — a job's profile is piecewise constant in progress (and, since
    :class:`JobProfile` is an immutable value object that survives trace
    deep-copies, shared across simulations in one process), so the same
    vectors are asked for over and over.  Keys are ``id(profile)`` with the
    profile held in the cache entry, which pins the id for the entry's
    lifetime; this skips re-hashing nine dataclass fields per lookup.  The
    cached dicts are shared objects: callers must treat them as read-only
    (every in-repo consumer copies before mutating)."""

    def __init__(self, space: PartitionSpace, hw: Hardware = A100):
        self.space = space
        self.hw = hw
        self._time_cache: dict = {}
        self._speed_cache: dict = {}
        self._vec_cache: dict = {}
        self._mps_cache: dict = {}

    def _bound(self, cache: dict) -> None:
        if len(cache) >= _CACHE_MAX:
            cache.pop(next(iter(cache)))

    # ----------------------------------------------------------- MIG side

    def slice_time(self, prof: JobProfile, size: int) -> float:
        """Seconds per step on slice ``size`` (inf if OOM)."""
        key = (id(prof), size)
        hit = self._time_cache.get(key)
        if hit is not None:
            return hit[1]
        self._bound(self._time_cache)
        t = self._slice_time(prof, size)
        self._time_cache[key] = (prof, t)
        return t

    def _slice_time(self, prof: JobProfile, size: int) -> float:
        st = self.space.slices[size]
        if prof.mem_gb > st.memory_gb:
            return float("inf")
        # the job can only keep `sm_util` of the full GPU's SMs busy; a slice
        # smaller than that clips it (paper Takeaway 1: small jobs lose little
        # on small slices)
        usable = min(self.space.compute_frac(size), prof.sm_util)
        t_comp = prof.flops_per_step / (
            self.hw.peak_flops * usable * prof.compute_eff)
        bytes_eff = prof.bytes_per_step * (
            1.0 + self.hw.cache_mig_kappa * prof.cache_sens
            * (1.0 - self.space.cache_frac(size)))
        t_mem = bytes_eff / (self.hw.hbm_bw * self.space.mem_bw_frac(size))
        return max(t_comp, t_mem) + self.hw.sched_overhead_s

    def slice_speed(self, prof: JobProfile, size: int) -> float:
        """Execution speed on a slice normalized by full-slice speed: (0,1]."""
        key = (id(prof), size)
        hit = self._speed_cache.get(key)
        if hit is not None:
            return hit[1]
        t_full = self.slice_time(prof, self.space.full_size)
        t = self.slice_time(prof, size)
        v = 0.0 if t == float("inf") else t_full / t
        self._bound(self._speed_cache)
        self._speed_cache[key] = (prof, v)
        return v

    def speed_vector(self, prof: JobProfile) -> dict:
        hit = self._vec_cache.get(id(prof))
        if hit is not None:
            return hit[1]
        self._bound(self._vec_cache)
        sv = {s: self.slice_speed(prof, s) for s in self.space.sizes}
        self._vec_cache[id(prof)] = (prof, sv)
        return sv

    # ----------------------------------------------------------- MPS side

    def mps_speeds(self, profs: Sequence[JobProfile], level: float,
                   iters: int = 12) -> list:
        """Normalized speeds (vs. solo full-GPU) for jobs co-located in MPS at
        ``level`` active-thread fraction each.  The fixed point is memoized
        on the (profiles, level) mix — a GPU's MPS window asks for the same
        mix at every event inside it — and loop-invariant terms are hoisted
        out of the iteration; the arithmetic (and therefore every float bit)
        is unchanged from the historical per-call loop."""
        m = len(profs)
        if m == 0:
            return []
        key = (tuple(id(p) for p in profs), level, iters)
        hit = self._mps_cache.get(key)
        if hit is not None:
            return list(hit[1])
        # cache pressure from co-runners (shared L2 in MPS)
        pressures = []
        for i, p in enumerate(profs):
            others = sum(q.cache_sens for j, q in enumerate(profs) if j != i)
            pressures.append(min(2.0, others / 2.0))
        bytes_eff = [p.bytes_per_step *
                     (1.0 + self.hw.cache_mps_kappa * p.cache_sens * pr)
                     for p, pr in zip(profs, pressures)]

        # compute shares: each job is capped at min(level, its own achievable
        # occupancy); oversubscription time-multiplexes proportionally
        caps = [min(level, p.sm_util) for p in profs]
        total_cap = sum(caps)
        shares = [c / max(1.0, total_cap) for c in caps]

        # contended DRAM loses efficiency (row-buffer conflicts etc.)
        bw_total = self.hw.hbm_bw * max(0.4, 1.0 - self.hw.mps_bw_loss * (m - 1))
        solo = [1.0 / self.slice_time(p, self.space.full_size) for p in profs]
        # per-job compute time and the multiplexing factor are invariant
        # across fixed-point iterations
        t_comps = [p.flops_per_step / (self.hw.peak_flops * shares[i]
                                       * p.compute_eff)
                   for i, p in enumerate(profs)]
        mux = 1.0 + self.hw.mps_mux_overhead * (m - 1)
        overhead = self.hw.sched_overhead_s
        rates = list(solo)
        for _ in range(iters):
            demand = [r * b for r, b in zip(rates, bytes_eff)]
            total_d = sum(demand)
            new_rates = []
            for i in range(m):
                if total_d > bw_total and total_d > 0:
                    bw_i = bw_total * demand[i] / total_d
                else:
                    bw_i = bw_total
                t_mem = bytes_eff[i] / max(bw_i, 1e-6)
                new_rates.append(1.0 / (max(t_comps[i], t_mem) * mux
                                        + overhead))
            rates = [0.5 * a + 0.5 * b for a, b in zip(rates, new_rates)]

        out = [r / s for r, s in zip(rates, solo)]
        self._bound(self._mps_cache)
        self._mps_cache[key] = (tuple(profs), out)
        return list(out)

    def mps_matrix(self, profs: Sequence[JobProfile]) -> list:
        """3 x m matrix of MPS speeds (rows = MPS_LEVELS)."""
        return [self.mps_speeds(profs, lv) for lv in MPS_LEVELS]
