"""Accelerator partition spaces.

``A100MIGSpace`` models the paper's Table 1 exactly: slice profiles
{1g.5gb, 2g.10gb, 3g.20gb, 4g.20gb, 7g.40gb} over 7 compute (GPC) slots and
8 memory slots (3g occupies 4 memory slots — the A100 quirk that makes
(3g,3g) a full configuration), per-type max counts, and the paper's explicit
placement exclusion (4g and 3g cannot coexist).  The paper's appendix figure
shows the 18 placement-maximal rows; scheduling per Eq. (4) needs exactly one
slice per job, so the optimizer searches *all* valid multisets (including
non-maximal ones such as (4g, 2g) for a 2-job mix) — ``maximal_partitions``
reproduces the appendix-figure semantics.

``TPUPodSpace`` is the TPU adaptation (DESIGN.md §2): a 16x16 v5e pod is
sliced into contiguous row-range sub-meshes in units of 2 rows (32 chips).
Memory is per-chip, so memory slots == compute units and there is no 4+3
exclusion; up to 8 co-located jobs per pod.
"""
from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple


@dataclass(frozen=True)
class SliceType:
    size: int            # compute units (GPCs / row-pairs); the f_i(x) key
    name: str
    compute_slots: int
    mem_slots: int
    memory_gb: float
    max_count: int
    cache_frac: float    # fraction of shared cache (A100 L2); 1.0 on TPU
    chips: int = 0       # TPU: chips in the sub-mesh
    mesh_shape: Optional[Tuple[int, int]] = None


class PartitionSpace:
    """Enumerates valid slice multisets (partitions) of one accelerator."""

    def __init__(self, slice_types: Sequence[SliceType], total_compute: int,
                 total_mem: int, exclusions: Sequence[frozenset] = (),
                 name: str = "space"):
        self.name = name
        self.slices: Dict[int, SliceType] = {s.size: s for s in slice_types}
        self.sizes = tuple(sorted(self.slices, reverse=True))
        self.total_compute = total_compute
        self.total_mem = total_mem
        self.exclusions = tuple(frozenset(e) for e in exclusions)
        self.partitions = self._enumerate()
        self.max_jobs = max(len(p) for p in self.partitions)
        self.full_size = max(self.sizes)

    # -------------------------------------------------------- enumeration

    def _enumerate(self) -> Tuple[Tuple[int, ...], ...]:
        found = set()

        def rec(idx, current, compute, mem):
            if current:
                found.add(tuple(sorted(current, reverse=True)))
            if idx >= len(self.sizes):
                return
            size = self.sizes[idx]
            st = self.slices[size]
            max_n = min(st.max_count,
                        (self.total_compute - compute) // st.compute_slots if st.compute_slots else 0,
                        (self.total_mem - mem) // st.mem_slots if st.mem_slots else 0)
            for n in range(max_n, -1, -1):
                nxt = current + [size] * n
                if n and any(e <= set(nxt) for e in self.exclusions):
                    continue
                rec(idx + 1, nxt, compute + n * st.compute_slots,
                    mem + n * st.mem_slots)

        rec(0, [], 0, 0)
        return tuple(sorted(found, key=lambda p: (len(p), [-x for x in p])))

    def is_valid(self, partition: Sequence[int]) -> bool:
        return tuple(sorted(partition, reverse=True)) in set(self.partitions)

    @functools.lru_cache(maxsize=None)
    def partitions_of_len(self, m: int) -> Tuple[Tuple[int, ...], ...]:
        return tuple(p for p in self.partitions if len(p) == m)

    @property
    def maximal_partitions(self) -> Tuple[Tuple[int, ...], ...]:
        """Partitions to which no further slice can be added (the appendix
        figure's rows, multiset-level)."""
        return tuple(p for p in self.partitions
                     if self.largest_free_slice(p) == 0)

    def largest_free_slice(self, partition: Sequence[int]) -> int:
        """Largest slice size still addable next to ``partition`` (0 if the
        accelerator is fully packed) — the fragmentation score used by
        space-aware policies."""
        compute = sum(self.slices[s].compute_slots for s in partition)
        mem = sum(self.slices[s].mem_slots for s in partition)
        best = 0
        for size, st in self.slices.items():
            if (compute + st.compute_slots <= self.total_compute
                    and mem + st.mem_slots <= self.total_mem
                    and list(partition).count(size) < st.max_count
                    and not any(e <= set(partition) | {size}
                                for e in self.exclusions)
                    and size > best):
                best = size
        return best

    def slice_mem_gb(self, size: int) -> float:
        return self.slices[size].memory_gb

    def compute_frac(self, size: int) -> float:
        return self.slices[size].compute_slots / self.total_compute

    def mem_bw_frac(self, size: int) -> float:
        return self.slices[size].mem_slots / self.total_mem

    def cache_frac(self, size: int) -> float:
        return self.slices[size].cache_frac


def a100_mig_space() -> PartitionSpace:
    """Paper Table 1. 4g+3g cannot coexist (paper §2.2)."""
    slices = [
        SliceType(7, "7g.40gb", 7, 8, 40.0, 1, 1.0),
        SliceType(4, "4g.20gb", 4, 4, 20.0, 1, 0.5),
        SliceType(3, "3g.20gb", 3, 4, 20.0, 2, 0.5),
        SliceType(2, "2g.10gb", 2, 2, 10.0, 3, 0.25),
        SliceType(1, "1g.5gb", 1, 1, 5.0, 7, 0.125),
    ]
    return PartitionSpace(slices, total_compute=7, total_mem=8,
                          exclusions=[frozenset({4, 3})], name="a100-mig")


def h100_mig_space() -> PartitionSpace:
    """H100-80GB MIG menu: same GPC topology and 4g/3g exclusion as the A100
    (7 compute slots over 8 memory slots), but every slice carries twice the
    memory — the heterogeneity that makes a mixed fleet interesting, since a
    job OOM-ing on a100 1g.5gb fits h100 1g.10gb."""
    slices = [
        SliceType(7, "7g.80gb", 7, 8, 80.0, 1, 1.0),
        SliceType(4, "4g.40gb", 4, 4, 40.0, 1, 0.5),
        SliceType(3, "3g.40gb", 3, 4, 40.0, 2, 0.5),
        SliceType(2, "2g.20gb", 2, 2, 20.0, 3, 0.25),
        SliceType(1, "1g.10gb", 1, 1, 10.0, 7, 0.125),
    ]
    return PartitionSpace(slices, total_compute=7, total_mem=8,
                          exclusions=[frozenset({4, 3})], name="h100-mig")


def tpu_pod_space(rows: int = 16, cols: int = 16,
                  hbm_per_chip_gb: float = 16.0) -> PartitionSpace:
    """16x16 v5e pod sliced into contiguous row ranges, 2 rows per unit."""
    unit_chips = 2 * cols
    total_units = rows // 2
    defs = [(1, 4 * total_units), (2, total_units // 2), (3, 2),
            (4, 2), (total_units, 1)]
    slices = []
    for units, max_count in defs:
        chips = units * unit_chips
        slices.append(SliceType(
            size=units,
            name=f"{units}u.{int(chips * hbm_per_chip_gb)}gb",
            compute_slots=units, mem_slots=units,
            memory_gb=chips * hbm_per_chip_gb,
            max_count=min(max_count, total_units // units),
            cache_frac=1.0,           # per-chip VMEM/HBM: no shared cache
            chips=chips, mesh_shape=(2 * units, cols)))
    return PartitionSpace(slices, total_compute=total_units,
                          total_mem=total_units, name="tpu-pod")
