"""Accelerator partition spaces.

``A100MIGSpace`` models the paper's Table 1 exactly: slice profiles
{1g.5gb, 2g.10gb, 3g.20gb, 4g.20gb, 7g.40gb} over 7 compute (GPC) slots and
8 memory slots (3g occupies 4 memory slots — the A100 quirk that makes
(3g,3g) a full configuration), per-type max counts, and the paper's explicit
placement exclusion (4g and 3g cannot coexist).  The paper's appendix figure
shows the 18 placement-maximal rows; scheduling per Eq. (4) needs exactly one
slice per job, so the optimizer searches *all* valid multisets (including
non-maximal ones such as (4g, 2g) for a 2-job mix) — ``maximal_partitions``
reproduces the appendix-figure semantics.

``TPUPodSpace`` is the TPU adaptation (DESIGN.md §2): a 16x16 v5e pod is
sliced into contiguous row-range sub-meshes in units of 2 rows (32 chips).
Memory is per-chip, so memory slots == compute units and there is no 4+3
exclusion; up to 8 co-located jobs per pod.

Everything the scheduler's hot path needs per decision is precomputed at
construction time (partition spaces are tiny and immutable):

* dense per-length arrays — ``part_sizes(m)`` is the ``(P, m)`` slice-size
  matrix of every valid length-``m`` multiset (rows sorted descending) and
  ``part_cols(m)`` maps each slot to its column in ``self.sizes``; both feed
  the vectorized Algorithm-1 kernel in :mod:`repro.core.optimizer`;
* fragmentation scores — ``part_spare(m)`` carries ``largest_free_slice``
  for every row, and per-tuple lookups are cached;
* admission feasibility — slice memory is non-decreasing in slice size on
  every menu we model, so "does some partition give every job a slice with
  enough memory *and* above its QoS floor" collapses to one scalar
  requirement per job (``min_required_slice``) and one vectorized
  comparison against the sorted size matrix (``placeable``).  This is the
  per-space precomputation the fragmentation-aware MIG scheduling line of
  work (PAPERS.md) argues for, and it is *exact* — unlike the former
  biggest-memory-first greedy, which missed feasible placements when QoS
  floors conflicted with the memory order.
"""
from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

_SPACE_UIDS = itertools.count()


@dataclass(frozen=True)
class SliceType:
    size: int            # compute units (GPCs / row-pairs); the f_i(x) key
    name: str
    compute_slots: int
    mem_slots: int
    memory_gb: float
    max_count: int
    cache_frac: float    # fraction of shared cache (A100 L2); 1.0 on TPU
    chips: int = 0       # TPU: chips in the sub-mesh
    mesh_shape: Optional[Tuple[int, int]] = None


class PartitionSpace:
    """Enumerates valid slice multisets (partitions) of one accelerator."""

    def __init__(self, slice_types: Sequence[SliceType], total_compute: int,
                 total_mem: int, exclusions: Sequence[frozenset] = (),
                 name: str = "space"):
        self.name = name
        self.slices: Dict[int, SliceType] = {s.size: s for s in slice_types}
        self.sizes = tuple(sorted(self.slices, reverse=True))
        self.total_compute = total_compute
        self.total_mem = total_mem
        self.exclusions = tuple(frozenset(e) for e in exclusions)
        self.partitions = self._enumerate()
        self.max_jobs = max(len(p) for p in self.partitions)
        self.full_size = max(self.sizes)
        # process-unique id: memo keys intern this instead of re-hashing
        # (name, sizes, total_compute, total_mem) on every optimizer call
        self.uid = next(_SPACE_UIDS)
        self._partition_set = frozenset(self.partitions)
        self.size_col = {s: k for k, s in enumerate(self.sizes)}
        self._spare_cache: Dict[Tuple[int, ...], int] = {}
        self._by_len = self._build_dense()
        # memory per slice must be non-decreasing in slice size for the
        # scalar-requirement feasibility collapse; every menu we model
        # satisfies this (A100/H100 MIG tables, per-chip TPU memory)
        asc = sorted(self.sizes)
        self._mem_by_size_asc = [(s, self.slices[s].memory_gb) for s in asc]
        self._mem_monotone = all(
            a[1] <= b[1] for a, b in zip(self._mem_by_size_asc,
                                         self._mem_by_size_asc[1:]))
        # admission-path memos (pure functions of this immutable space, so
        # they are safe to share across simulations): (mem, qos) -> scalar
        # requirement, sorted requirement tuple -> placeable verdict, and
        # sorted requirement tuple -> largest addable slice (the fleet
        # index's ``_max_add``).  Job populations draw from bounded profile
        # pools, so these saturate quickly; bounded FIFO as a leak guard.
        self._mrs_cache: Dict[Tuple[float, int], Optional[int]] = {}
        self._placeable_cache: Dict[Tuple[int, ...], bool] = {}
        self._max_add_cache: Dict[Tuple[int, ...], int] = {}

    # -------------------------------------------------------- enumeration

    def _enumerate(self) -> Tuple[Tuple[int, ...], ...]:
        found = set()

        def rec(idx, current, compute, mem):
            if current:
                found.add(tuple(sorted(current, reverse=True)))
            if idx >= len(self.sizes):
                return
            size = self.sizes[idx]
            st = self.slices[size]
            max_n = min(st.max_count,
                        (self.total_compute - compute) // st.compute_slots if st.compute_slots else 0,
                        (self.total_mem - mem) // st.mem_slots if st.mem_slots else 0)
            for n in range(max_n, -1, -1):
                nxt = current + [size] * n
                if n and any(e <= set(nxt) for e in self.exclusions):
                    continue
                rec(idx + 1, nxt, compute + n * st.compute_slots,
                    mem + n * st.mem_slots)

        rec(0, [], 0, 0)
        return tuple(sorted(found, key=lambda p: (len(p), [-x for x in p])))

    def _build_dense(self):
        """Per length m: (sizes (P,m), col-index (P,m), spare (P,),
        compute-slots-used (P,)) over all valid length-m multisets, rows in
        ``partitions`` order (selection tie-breaks depend on it)."""
        by_len = {}
        self._pareto_by_len: Dict[int, Tuple[Tuple[int, ...], ...]] = {}
        for m in range(1, self.max_jobs + 1):
            rows = [p for p in self.partitions if len(p) == m]
            sizes = np.asarray(rows, dtype=np.int64).reshape(len(rows), m)
            cols = np.asarray([[self.size_col[s] for s in p] for p in rows],
                              dtype=np.int64).reshape(len(rows), m)
            spare = np.asarray([self._largest_free(p) for p in rows],
                               dtype=np.int64)
            used = np.asarray([sum(self.slices[s].compute_slots for s in p)
                               for p in rows], dtype=np.int64)
            by_len[m] = (sizes, cols, spare, used)
            # Pareto-maximal rows (sorted descending): a row dominated
            # elementwise by another can never be the only feasible
            # placement, so admission checks scan just the frontier
            frontier = [p for p in rows
                        if not any(q != p and all(a >= b for a, b
                                                  in zip(q, p))
                                   for q in rows)]
            self._pareto_by_len[m] = tuple(frontier)
        return by_len

    # ----------------------------------------------------- dense accessors

    def part_sizes(self, m: int) -> np.ndarray:
        """(P, m) slice sizes of every valid length-m partition (rows sorted
        descending, ``partitions`` order)."""
        return self._by_len[m][0] if m in self._by_len else \
            np.empty((0, max(m, 1)), dtype=np.int64)

    def part_cols(self, m: int) -> np.ndarray:
        """(P, m) column index of each slot's size in ``self.sizes``."""
        return self._by_len[m][1] if m in self._by_len else \
            np.empty((0, max(m, 1)), dtype=np.int64)

    def part_spare(self, m: int) -> np.ndarray:
        """(P,) ``largest_free_slice`` of every length-m partition."""
        return self._by_len[m][2] if m in self._by_len else \
            np.empty((0,), dtype=np.int64)

    def part_compute(self, m: int) -> np.ndarray:
        """(P,) compute slots used by every length-m partition."""
        return self._by_len[m][3] if m in self._by_len else \
            np.empty((0,), dtype=np.int64)

    def is_valid(self, partition: Sequence[int]) -> bool:
        return tuple(sorted(partition, reverse=True)) in self._partition_set

    @functools.lru_cache(maxsize=None)
    def partitions_of_len(self, m: int) -> Tuple[Tuple[int, ...], ...]:
        return tuple(p for p in self.partitions if len(p) == m)

    @property
    def maximal_partitions(self) -> Tuple[Tuple[int, ...], ...]:
        """Partitions to which no further slice can be added (the appendix
        figure's rows, multiset-level)."""
        return tuple(p for p in self.partitions
                     if self.largest_free_slice(p) == 0)

    def largest_free_slice(self, partition: Sequence[int]) -> int:
        """Largest slice size still addable next to ``partition`` (0 if the
        accelerator is fully packed) — the fragmentation score used by
        space-aware policies.  Cached per multiset."""
        key = tuple(partition)
        best = self._spare_cache.get(key)
        if best is None:
            best = self._spare_cache[key] = self._largest_free(key)
        return best

    def _largest_free(self, partition: Tuple[int, ...]) -> int:
        compute = sum(self.slices[s].compute_slots for s in partition)
        mem = sum(self.slices[s].mem_slots for s in partition)
        best = 0
        for size, st in self.slices.items():
            if (compute + st.compute_slots <= self.total_compute
                    and mem + st.mem_slots <= self.total_mem
                    and list(partition).count(size) < st.max_count
                    and not any(e <= set(partition) | {size}
                                for e in self.exclusions)
                    and size > best):
                best = size
        return best

    # --------------------------------------------- admission feasibility

    def min_required_slice(self, mem_gb: float,
                           qos_min_slice: int = 0) -> Optional[int]:
        """Smallest slice size satisfying both the memory footprint and the
        QoS floor, or None when no slice on the menu does.  Because slice
        memory is non-decreasing in slice size, a slice satisfies a job iff
        ``size >= min_required_slice(job)`` — the whole 2-D (memory, QoS)
        constraint collapses to this one scalar."""
        key = (mem_gb, qos_min_slice)
        try:
            return self._mrs_cache[key]
        except KeyError:
            pass
        out = None
        for size, sz_mem in self._mem_by_size_asc:
            if sz_mem >= mem_gb and size >= qos_min_slice:
                out = size
                break
        if len(self._mrs_cache) >= 65536:
            self._mrs_cache.pop(next(iter(self._mrs_cache)))
        self._mrs_cache[key] = out
        return out

    def job_required_slice(self, job) -> Optional[int]:
        """``min_required_slice`` of a :class:`~repro.core.jobs.Job`'s
        effective footprint ``(max(mem_gb, min_mem_gb), qos_min_slice)``,
        cached on the job (a job's requirement against one space never
        changes; the space object is pinned in the cache entry so a
        heterogeneous fleet re-resolves per space)."""
        c = job._req_cache
        if c is not None and c[0] is self:
            return c[1]
        r = self.min_required_slice(
            max(job.profile.mem_gb, job.min_mem_gb), job.qos_min_slice)
        job._req_cache = (self, r)
        return r

    def placeable(self, required_sizes: Sequence[int]) -> bool:
        """Exact feasibility: does *some* valid partition of length
        ``len(required_sizes)`` give every job a slice of at least its
        required size?  Requirements and rows are both sorted descending, so
        slot r must cover the r-th most demanding job — exact for scalar
        requirements by an exchange argument — and only the precomputed
        Pareto-maximal rows need scanning."""
        m = len(required_sizes)
        if m not in self._pareto_by_len:
            return False
        req = tuple(sorted(required_sizes, reverse=True))
        cached = self._placeable_cache.get(req)
        if cached is not None:
            return cached
        out = False
        for row in self._pareto_by_len[m]:
            ok = True
            for a, b in zip(row, req):
                if a < b:
                    ok = False
                    break
            if ok:
                out = True
                break
        if len(self._placeable_cache) >= 65536:
            self._placeable_cache.pop(next(iter(self._placeable_cache)))
        self._placeable_cache[req] = out
        return out

    def required_sizes(self, mems: Sequence[float],
                       qoss: Sequence[int]) -> Optional[Sequence[int]]:
        """Per-job scalar slice requirements for a (memory, QoS) job set, or
        None when some job fits no slice on the menu — or when slice memory
        is not monotone in slice size, where the scalar collapse is inexact
        (no shipped menu; callers needing exactness there use
        :meth:`feasible_exact`'s matching fallback)."""
        if not self._mem_monotone:
            return None
        reqs = []
        for mem, q in zip(mems, qoss):
            r = self.min_required_slice(mem, q)
            if r is None:
                return None
            reqs.append(r)
        return reqs

    def feasible_exact(self, mems: Sequence[float],
                       qoss: Sequence[int]) -> bool:
        """Exact admission check for arbitrary (memory, QoS) pairs.  Uses the
        scalar-requirement fast path when slice memory is monotone in size
        (all shipped menus); falls back to per-partition bitmask matching
        otherwise, so correctness never depends on the menu shape."""
        if self._mem_monotone:
            reqs = self.required_sizes(mems, qoss)
            return reqs is not None and self.placeable(reqs)
        return self._feasible_matching(list(mems), list(qoss))

    def _feasible_matching(self, mems, qoss) -> bool:
        """Bitmask-DP perfect matching over every partition (non-monotone
        menus only; exponential in m but m <= max_jobs <= 8)."""
        m = len(mems)
        for part in self.partitions_of_len(m):
            ok_mask = []
            for size in part:
                st = self.slices[size]
                bits = 0
                for j in range(m):
                    if st.memory_gb >= mems[j] and size >= qoss[j]:
                        bits |= 1 << j
                ok_mask.append(bits)
            reach = {0}
            for bits in ok_mask:
                nxt = set()
                for mask in reach:
                    free = bits & ~mask
                    while free:
                        low = free & -free
                        nxt.add(mask | low)
                        free ^= low
                reach = nxt
                if not reach:
                    break
            if (1 << m) - 1 in reach:
                return True
        return False

    # ------------------------------------------------------------- misc

    def slice_mem_gb(self, size: int) -> float:
        return self.slices[size].memory_gb

    def compute_frac(self, size: int) -> float:
        return self.slices[size].compute_slots / self.total_compute

    def mem_bw_frac(self, size: int) -> float:
        return self.slices[size].mem_slots / self.total_mem

    def cache_frac(self, size: int) -> float:
        return self.slices[size].cache_frac


def a100_mig_space() -> PartitionSpace:
    """Paper Table 1. 4g+3g cannot coexist (paper §2.2)."""
    slices = [
        SliceType(7, "7g.40gb", 7, 8, 40.0, 1, 1.0),
        SliceType(4, "4g.20gb", 4, 4, 20.0, 1, 0.5),
        SliceType(3, "3g.20gb", 3, 4, 20.0, 2, 0.5),
        SliceType(2, "2g.10gb", 2, 2, 10.0, 3, 0.25),
        SliceType(1, "1g.5gb", 1, 1, 5.0, 7, 0.125),
    ]
    return PartitionSpace(slices, total_compute=7, total_mem=8,
                          exclusions=[frozenset({4, 3})], name="a100-mig")


def h100_mig_space() -> PartitionSpace:
    """H100-80GB MIG menu: same GPC topology and 4g/3g exclusion as the A100
    (7 compute slots over 8 memory slots), but every slice carries twice the
    memory — the heterogeneity that makes a mixed fleet interesting, since a
    job OOM-ing on a100 1g.5gb fits h100 1g.10gb."""
    slices = [
        SliceType(7, "7g.80gb", 7, 8, 80.0, 1, 1.0),
        SliceType(4, "4g.40gb", 4, 4, 40.0, 1, 0.5),
        SliceType(3, "3g.40gb", 3, 4, 40.0, 2, 0.5),
        SliceType(2, "2g.20gb", 2, 2, 20.0, 3, 0.25),
        SliceType(1, "1g.10gb", 1, 1, 10.0, 7, 0.125),
    ]
    return PartitionSpace(slices, total_compute=7, total_mem=8,
                          exclusions=[frozenset({4, 3})], name="h100-mig")


def tpu_pod_space(rows: int = 16, cols: int = 16,
                  hbm_per_chip_gb: float = 16.0) -> PartitionSpace:
    """16x16 v5e pod sliced into contiguous row ranges, 2 rows per unit."""
    unit_chips = 2 * cols
    total_units = rows // 2
    defs = [(1, 4 * total_units), (2, total_units // 2), (3, 2),
            (4, 2), (total_units, 1)]
    slices = []
    for units, max_count in defs:
        chips = units * unit_chips
        slices.append(SliceType(
            size=units,
            name=f"{units}u.{int(chips * hbm_per_chip_gb)}gb",
            compute_slots=units, mem_slots=units,
            memory_gb=chips * hbm_per_chip_gb,
            max_count=min(max_count, total_units // units),
            cache_frac=1.0,           # per-chip VMEM/HBM: no shared cache
            chips=chips, mesh_shape=(2 * units, cols)))
    return PartitionSpace(slices, total_compute=total_units,
                          total_mem=total_units, name="tpu-pod")
