"""Heterogeneous accelerator fleets.

A fleet is a list of :class:`GPUSpec`, one per accelerator in the cluster:
each carries its own partition space (slice menu), performance model,
slice-speed estimator and a ``speed_scale`` converting that accelerator's
normalized speeds into reference work-seconds (``Job.work`` is denominated
in exclusive *A100* seconds, so an h100 with ``speed_scale=2.0`` burns two
work-seconds per wall-second on its full slice).

The engine, GPU state machine and every policy route all space/perf lookups
through the resident GPU's spec — ``sim.space`` / ``sim.pm`` remain only as
the homogeneous-compat default (the first spec).

Fleet spec strings compose kinds with counts::

    parse_fleet("a100:4+h100:4")   # 8 accelerators, two slice menus
    parse_fleet("h100:2")
    parse_fleet("a100:2+h100:2+tpu:1")

All GPUs of one kind share a single spec object — across ``parse_fleet``
calls too (the per-kind factories are memoized): specs are read-only and
their default estimator is stateless, so partition-space precomputation,
the perf-model caches and the optimizer memo stay warm across every
simulation in the process instead of being rebuilt per sweep cell.  (The
memoization also means a predictor artifact dropped into ``artifacts/``
mid-process is only picked up by the *first* factory call.)

Per-kind estimators: each factory looks for a trained predictor artifact for
its kind (``artifacts/predictor_<kind>.npz``, with the legacy un-suffixed
``artifacts/predictor.npz`` accepted for a100) and routes it through
``GPUSpec.estimator`` as a :class:`~repro.core.estimators.UNetEstimator`;
without one the spec falls back to the oracle estimator.  An *explicitly*
passed estimator always wins — ``__post_init__`` never clobbers it — and an
explicit ``artifact=`` path that does not exist raises instead of silently
degrading to the oracle.

Per-kind power: every spec carries a :class:`PowerModel` (idle watts plus
per-slice active watts), the electrical side of the accelerator that the
energy-aware objectives (:mod:`repro.core.sim.objectives`) and the engine's
energy accounting consume.  The shapes follow the power-partitioning
measurements of Vamja et al. (PAPERS.md, arXiv 2501.17752): idle draw is a
substantial fixed floor, and active draw grows *sublinearly* in the slice's
compute fraction — a 1g slice pulls clearly more than 1/7 of the full-GPU
active power, which is exactly why packing work onto few large slices is
more energy-efficient than scattering it across many small ones.
"""
from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.estimators import OracleEstimator, UNetEstimator
from repro.core.partitions import (PartitionSpace, a100_mig_space,
                                   h100_mig_space, tpu_pod_space)
from repro.core.perfmodel import A100, H100, TPU_V5E_POD, PerfModel

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts")


def default_artifact_path(kind: str) -> Optional[str]:
    """The trained predictor artifact shipped for ``kind``
    (``artifacts/predictor_<kind>.npz``; a100 also accepts the legacy
    un-suffixed ``artifacts/predictor.npz``), or None when none exists."""
    per_kind = os.path.join(ARTIFACT_DIR, f"predictor_{kind}.npz")
    if os.path.exists(per_kind):
        return per_kind
    if kind == "a100":
        legacy = os.path.join(ARTIFACT_DIR, "predictor.npz")
        if os.path.exists(legacy):
            return legacy
    return None


@dataclass(frozen=True)
class PowerModel:
    """Per-kind electrical model: wall power as a function of what runs.

    ``idle_w`` is the always-on floor (HBM refresh, fans, static leakage);
    an active slice adds ``active_w(compute_frac)`` on top.  The exponent
    ``gamma < 1`` encodes the sublinear per-slice power the
    power-partitioning paper measures: small MIG instances draw
    disproportionately more watts per GPC than large ones (uncore
    structures — L2 banks, memory controllers — power up per instance), so
    ``active_w(1/7) > max_active_w / 7``.  ``mps_active_frac`` scales the
    full-GPU active draw during an MPS co-run window (the whole chip is
    powered, partitioned or not).
    """
    idle_w: float                     # wall draw with no active compute
    max_active_w: float               # full-slice active draw above idle
    gamma: float = 0.8                # sublinearity of active_w in compute frac
    mps_active_frac: float = 1.0      # active fraction during MPS co-location

    def active_w(self, compute_frac: float) -> float:
        """Active watts of one slice spanning ``compute_frac`` of the chip."""
        if compute_frac <= 0.0:
            return 0.0
        return self.max_active_w * compute_frac ** self.gamma

    def partition_w(self, space: PartitionSpace, sizes) -> float:
        """Wall watts with slices ``sizes`` (a multiset from ``space``) all
        busy: idle floor + per-slice active draw."""
        return self.idle_w + sum(self.active_w(space.compute_frac(s))
                                 for s in sizes)


# TDP splits: a100 400 W (≈62 W idle), h100 SXM 700 W (≈88 W idle); the v5e
# pod is 256 chips at a ~170 W chip envelope with a near-linear profile
# (per-chip power gangs, no shared uncore across the pod).
A100_POWER = PowerModel(idle_w=62.0, max_active_w=338.0, gamma=0.80)
H100_POWER = PowerModel(idle_w=88.0, max_active_w=612.0, gamma=0.80)
TPU_V5E_POD_POWER = PowerModel(idle_w=256 * 45.0, max_active_w=256 * 125.0,
                               gamma=0.97)

_KIND_POWER: Dict[str, PowerModel] = {
    "a100": A100_POWER,
    "h100": H100_POWER,
    "tpu": TPU_V5E_POD_POWER,
}

#: fallback for specs of unknown kind (homogeneous_fleet with a custom space)
DEFAULT_POWER = A100_POWER


@dataclass
class GPUSpec:
    """Everything accelerator-type-specific about one cluster slot."""
    kind: str
    space: PartitionSpace
    pm: PerfModel
    estimator: object = None          # slice-speed estimator
    speed_scale: float = 1.0          # full-slice speed vs. the reference GPU
    artifact: Optional[str] = None    # predictor artifact backing `estimator`
    power: Optional[PowerModel] = None  # per-kind electrical model

    def __post_init__(self):
        if self.power is None:
            # exact kind first; legacy homogeneous specs carry the space
            # name as their kind ("a100-mig", "tpu-pod"), so fall back to
            # a known-kind prefix before the generic default
            self.power = _KIND_POWER.get(self.kind) or next(
                (p for k, p in _KIND_POWER.items()
                 if self.kind.startswith(k)), DEFAULT_POWER)
        if self.estimator is not None:
            # an explicit estimator always wins; never clobber it with the
            # artifact/oracle defaulting below (dataclasses.replace re-runs
            # __post_init__, so this guard is what keeps copies intact)
            return
        if self.artifact is not None:
            if not os.path.exists(self.artifact):
                raise FileNotFoundError(
                    f"predictor artifact for {self.kind!r} not found: "
                    f"{self.artifact!r} (train one with "
                    f"repro.core.predictor.train, or drop the artifact= "
                    f"argument to fall back to the oracle estimator)")
            self.estimator = UNetEstimator.from_artifact(self.pm, self.artifact)
        else:
            self.estimator = OracleEstimator(self.pm)


@functools.lru_cache(maxsize=None)
def _a100_spec() -> GPUSpec:
    space = a100_mig_space()
    return GPUSpec("a100", space, PerfModel(space, A100), speed_scale=1.0,
                   artifact=default_artifact_path("a100"))


@functools.lru_cache(maxsize=None)
def _h100_spec() -> GPUSpec:
    space = h100_mig_space()
    # ~2x achievable training throughput vs. A100 (memory-bound jobs track
    # the ~2.2x HBM-bandwidth ratio, compute-bound ones land higher)
    return GPUSpec("h100", space, PerfModel(space, H100), speed_scale=2.0,
                   artifact=default_artifact_path("h100"))


@functools.lru_cache(maxsize=None)
def _tpu_spec() -> GPUSpec:
    space = tpu_pod_space()
    # one v5e pod counts as one "accelerator"; its full slice dwarfs a GPU
    return GPUSpec("tpu", space, PerfModel(space, TPU_V5E_POD),
                   speed_scale=32.0, artifact=default_artifact_path("tpu"))


FLEET_KINDS: Dict[str, Callable[[], GPUSpec]] = {
    "a100": _a100_spec,
    "h100": _h100_spec,
    "tpu": _tpu_spec,
}


def available_kinds() -> List[str]:
    return sorted(FLEET_KINDS)


def parse_fleet(spec: str) -> List[GPUSpec]:
    """``"a100:4+h100:4"`` -> list of 8 GPUSpecs (one shared spec per kind)."""
    out: List[GPUSpec] = []
    cache: Dict[str, GPUSpec] = {}
    for part in str(spec).replace(",", "+").split("+"):
        part = part.strip()
        if not part:
            continue
        kind, _, count = part.partition(":")
        kind = kind.strip().lower()
        if kind not in FLEET_KINDS:
            raise ValueError(f"unknown accelerator kind {kind!r}; "
                             f"available: {', '.join(available_kinds())}")
        try:
            n = int(count) if count else 1
        except ValueError:
            raise ValueError(f"bad count in fleet spec segment {part!r}") from None
        if n <= 0:
            raise ValueError(f"fleet spec segment {part!r} must have count >= 1")
        if kind not in cache:
            cache[kind] = FLEET_KINDS[kind]()
        out.extend([cache[kind]] * n)
    if not out:
        raise ValueError(f"empty fleet spec {spec!r}")
    return out


def homogeneous_fleet(space: PartitionSpace, pm: PerfModel, estimator,
                      n: int) -> List[GPUSpec]:
    """The legacy single-space cluster as a fleet (shared spec, scale 1)."""
    spec = GPUSpec(space.name, space, pm, estimator)
    return [spec] * n


def describe_fleet(fleet: Sequence[GPUSpec]) -> str:
    """Stable compact rendering, e.g. ``"a100:4+h100:4"`` (insertion order)."""
    runs: List[List] = []
    for s in fleet:
        if runs and runs[-1][0] == s.kind:
            runs[-1][1] += 1
        else:
            runs.append([s.kind, 1])
    return "+".join(f"{k}:{n}" for k, n in runs)
