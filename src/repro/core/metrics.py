"""Figures of merit (paper §2.3): average JCT, makespan, system throughput."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.jobs import Job


@dataclass(frozen=True)
class TraceMetrics:
    avg_jct: float
    makespan: float
    stp: float                   # time-averaged aggregate progress rate / GPU
    p50_jct: float
    p90_jct: float
    jcts: tuple
    relative_jcts: tuple         # JCT / exclusive-execution time (Fig 11)
    breakdown: dict              # mean seconds in queue / mps / ckpt / run


def compute_metrics(jobs: Sequence[Job], n_gpus: int) -> TraceMetrics:
    done = [j for j in jobs if j.finish_time is not None]
    if not done:
        raise ValueError("no completed jobs")
    jcts = np.array([j.finish_time - j.arrival for j in done])
    rel = np.array([(j.finish_time - j.arrival) / j.work for j in done])
    t0 = min(j.arrival for j in done)
    t1 = max(j.finish_time for j in done)
    makespan = t1 - t0
    total_work = sum(j.work for j in done)
    stp = total_work / makespan / n_gpus if makespan > 0 else 0.0
    breakdown = {
        "queue": float(np.mean([j.t_queue for j in done])),
        "mps": float(np.mean([j.t_mps for j in done])),
        "ckpt": float(np.mean([j.t_ckpt for j in done])),
        "run": float(np.mean([j.t_run for j in done])),
    }
    return TraceMetrics(
        avg_jct=float(jcts.mean()), makespan=float(makespan), stp=float(stp),
        p50_jct=float(np.percentile(jcts, 50)),
        p90_jct=float(np.percentile(jcts, 90)),
        jcts=tuple(float(x) for x in jcts),
        relative_jcts=tuple(float(x) for x in rel),
        breakdown=breakdown)
