"""Figures of merit (paper §2.3): average JCT, makespan, system throughput —
plus the energy dimension (fleet-integrated joules and derived efficiency
ratios) that the pluggable objective layer optimizes for."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.jobs import Job


@dataclass(frozen=True)
class TraceMetrics:
    avg_jct: float
    makespan: float
    stp: float                   # time-averaged aggregate progress rate / GPU
    p50_jct: float
    p90_jct: float
    jcts: tuple
    relative_jcts: tuple         # JCT / exclusive-execution time (Fig 11)
    breakdown: dict              # mean seconds in queue / mps / ckpt / run
    # energy accounting (0.0 on legacy callers that pass no energy)
    energy_j: float = 0.0        # fleet-integrated wall energy over the run
    avg_power_w: float = 0.0     # energy_j / the span it was integrated over
    energy_per_job_j: float = 0.0
    jct_per_joule: float = 0.0   # avg_jct / energy_j (s/J).  A raw ratio of
                                 # the two headline metrics, NOT a figure of
                                 # merit on its own (more joules at equal JCT
                                 # *lowers* it); rank efficiency with
                                 # energy_per_job_j / energy_j instead


def compute_metrics(jobs: Sequence[Job], n_gpus: int,
                    energy_j: float = 0.0,
                    energy_span_s: float = 0.0) -> TraceMetrics:
    """``energy_span_s`` is the wall-clock span ``energy_j`` was integrated
    over (the engine's final clock); it defaults to the makespan, which
    undercounts the pre-first-arrival idle window."""
    done = [j for j in jobs if j.finish_time is not None]
    if not done:
        raise ValueError("no completed jobs")
    jcts = np.array([j.finish_time - j.arrival for j in done])
    rel = np.array([(j.finish_time - j.arrival) / j.work for j in done])
    t0 = min(j.arrival for j in done)
    t1 = max(j.finish_time for j in done)
    makespan = t1 - t0
    total_work = sum(j.work for j in done)
    stp = total_work / makespan / n_gpus if makespan > 0 else 0.0
    breakdown = {
        "queue": float(np.mean([j.t_queue for j in done])),
        "mps": float(np.mean([j.t_mps for j in done])),
        "ckpt": float(np.mean([j.t_ckpt for j in done])),
        "run": float(np.mean([j.t_run for j in done])),
    }
    avg_jct = float(jcts.mean())
    span = energy_span_s if energy_span_s > 0 else makespan
    return TraceMetrics(
        avg_jct=avg_jct, makespan=float(makespan), stp=float(stp),
        p50_jct=float(np.percentile(jcts, 50)),
        p90_jct=float(np.percentile(jcts, 90)),
        jcts=tuple(float(x) for x in jcts),
        relative_jcts=tuple(float(x) for x in rel),
        breakdown=breakdown,
        energy_j=float(energy_j),
        avg_power_w=float(energy_j / span) if span > 0 else 0.0,
        energy_per_job_j=float(energy_j / len(done)),
        jct_per_joule=float(avg_jct / energy_j) if energy_j > 0 else 0.0)
