"""Figures of merit (paper §2.3): average JCT, makespan, system throughput —
plus the energy dimension (fleet-integrated joules and derived efficiency
ratios) that the pluggable objective layer optimizes for."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.core.jobs import Job


@dataclass(frozen=True)
class TraceMetrics:
    avg_jct: float
    makespan: float
    stp: float                   # time-averaged aggregate progress rate / GPU
    p50_jct: float
    p90_jct: float
    jcts: tuple
    relative_jcts: tuple         # JCT / exclusive-execution time (Fig 11)
    breakdown: dict              # mean seconds in queue / mps / ckpt / run
    # energy accounting (0.0 on legacy callers that pass no energy)
    energy_j: float = 0.0        # fleet-integrated wall energy over the run
    avg_power_w: float = 0.0     # energy_j / the span it was integrated over
    energy_per_job_j: float = 0.0
    jct_per_joule: float = 0.0   # avg_jct / energy_j (s/J).  A raw ratio of
                                 # the two headline metrics, NOT a figure of
                                 # merit on its own (more joules at equal JCT
                                 # *lowers* it); rank efficiency with
                                 # energy_per_job_j / energy_j instead
    # robustness accounting (all zero when no fault model is enabled).
    # ``stp`` only ever counted committed work — rolled-back progress is
    # re-added to job.remaining and redone — so goodput aliases stp and
    # gross_stp adds back the fault-destroyed work for the classic
    # goodput-vs-throughput split.
    goodput: float = 0.0         # committed work rate per GPU (== stp)
    gross_stp: float = 0.0       # goodput + fault-destroyed work rate
    work_lost_s: float = 0.0     # work-seconds destroyed by faults/migrations
    n_fault_events: int = 0      # injector + hard (GPU/rack outage) faults
    blast_jobs: int = 0          # jobs killed by MPS blast-radius faults
    blast_radius_max: int = 0    # largest single-fault co-resident kill
    mean_recover_s: float = 0.0  # eviction -> re-placement, per victim
    quarantine_occupancy: float = 0.0  # quarantined GPU-time / fleet-time
    n_quarantines: int = 0
    n_migrations: int = 0        # residents evacuated via the primitive


def compute_metrics(jobs: Sequence[Job], n_gpus: int,
                    energy_j: float = 0.0,
                    energy_span_s: float = 0.0,
                    fault_stats: Optional[Mapping] = None) -> TraceMetrics:
    """``energy_span_s`` is the wall-clock span ``energy_j`` was integrated
    over (the engine's final clock); it defaults to the makespan, which
    undercounts the pre-first-arrival idle window.  ``fault_stats`` is the
    engine's robustness counter map (``ClusterSim.fstats`` plus the lost /
    recover aggregates); ``None`` leaves every robustness field zero."""
    done = [j for j in jobs if j.finish_time is not None]
    if not done:
        raise ValueError("no completed jobs")
    jcts = np.array([j.finish_time - j.arrival for j in done])
    rel = np.array([(j.finish_time - j.arrival) / j.work for j in done])
    t0 = min(j.arrival for j in done)
    t1 = max(j.finish_time for j in done)
    makespan = t1 - t0
    total_work = sum(j.work for j in done)
    stp = total_work / makespan / n_gpus if makespan > 0 else 0.0
    breakdown = {
        "queue": float(np.mean([j.t_queue for j in done])),
        "mps": float(np.mean([j.t_mps for j in done])),
        "ckpt": float(np.mean([j.t_ckpt for j in done])),
        "run": float(np.mean([j.t_run for j in done])),
    }
    avg_jct = float(jcts.mean())
    span = energy_span_s if energy_span_s > 0 else makespan
    robust = {}
    if fault_stats is not None:
        fs = fault_stats
        lost = float(fs.get("work_lost_s", 0.0))
        n_rec = int(fs.get("n_recovered", 0))
        robust = dict(
            goodput=float(stp),
            gross_stp=float(stp + (lost / makespan / n_gpus
                                   if makespan > 0 else 0.0)),
            work_lost_s=lost,
            n_fault_events=int(fs.get("n_faults", 0)),
            blast_jobs=int(fs.get("blast_jobs", 0)),
            blast_radius_max=int(fs.get("blast_radius_max", 0)),
            mean_recover_s=(float(fs.get("recover_s_total", 0.0)) / n_rec
                            if n_rec else 0.0),
            quarantine_occupancy=(float(fs.get("quarantine_gpu_s", 0.0))
                                  / (n_gpus * span) if span > 0 else 0.0),
            n_quarantines=int(fs.get("n_quarantines", 0)),
            n_migrations=int(fs.get("n_migrations", 0)))
    return TraceMetrics(
        avg_jct=avg_jct, makespan=float(makespan), stp=float(stp),
        p50_jct=float(np.percentile(jcts, 50)),
        p90_jct=float(np.percentile(jcts, 90)),
        jcts=tuple(float(x) for x in jcts),
        relative_jcts=tuple(float(x) for x in rel),
        breakdown=breakdown,
        energy_j=float(energy_j),
        avg_power_w=float(energy_j / span) if span > 0 else 0.0,
        energy_per_job_j=float(energy_j / len(done)),
        jct_per_joule=float(avg_jct / energy_j) if energy_j > 0 else 0.0,
        **robust)
