"""Job-trace generation modeled after the paper's methodology (§5):
Helios-like execution-time distribution capped at 2h (~p90 of the original
trace), Poisson arrivals with configurable mean inter-arrival time (``lam_s``
is seconds between arrivals, not a rate), jobs uniformly sampled from the
workload pool (model x batch size).  Non-Poisson arrival processes live in
:mod:`repro.core.scenarios` and are injected via ``arrival_times``.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.jobs import Job, JobProfile, WORKLOADS


def generate_trace(n_jobs: int, *, lam_s: float = 60.0, seed: int = 0,
                   max_duration_s: float = 7200.0, min_duration_s: float = 60.0,
                   pool: Optional[Sequence[JobProfile]] = None,
                   qos_frac: float = 0.0, multi_instance_frac: float = 0.0,
                   mem_constraint_frac: float = 0.0,
                   arrival_times: Optional[Sequence[float]] = None,
                   duration_sigma: float = 1.1) -> List[Job]:
    """Returns jobs sorted by arrival time.

    ``lam_s`` is the **mean inter-arrival time in seconds** (i.e. the scale
    ``1/λ`` of the exponential, *not* the Poisson rate λ itself) — smaller
    values mean heavier load.  Pass ``arrival_times`` (sorted, one per job)
    to replace the default Poisson process with an arbitrary arrival pattern
    (see :mod:`repro.core.scenarios` for bursty / diurnal / heavy-tail /
    flash-crowd generators); ``lam_s`` is then ignored.  ``duration_sigma``
    is the lognormal shape of the work distribution (raise it for
    heavier-tailed job sizes).
    """
    rng = np.random.default_rng(seed)
    pool = list(pool or WORKLOADS)
    if arrival_times is None:
        arrivals = np.cumsum(rng.exponential(lam_s, size=n_jobs))
    else:
        arrivals = np.asarray(list(arrival_times), dtype=float)
        if len(arrivals) != n_jobs:
            raise ValueError(f"arrival_times has {len(arrivals)} entries "
                             f"for n_jobs={n_jobs}")
    jobs = []
    for i in range(n_jobs):
        prof = pool[rng.integers(0, len(pool))]
        # lognormal work duration (median ~12 min), clipped like the paper
        work = float(np.clip(rng.lognormal(mean=6.6, sigma=duration_sigma),
                             min_duration_s, max_duration_s))
        qos = 0
        if qos_frac and rng.random() < qos_frac:
            qos = int(rng.choice([2, 3]))
        n_inst = 1
        if multi_instance_frac and rng.random() < multi_instance_frac:
            n_inst = int(rng.integers(2, 5))
        min_mem = 0.0
        if mem_constraint_frac and rng.random() < mem_constraint_frac:
            min_mem = prof.mem_gb  # user declares the true footprint
        jobs.append(Job(jid=i, profile=prof, arrival=float(arrivals[i]),
                        work=work, qos_min_slice=qos, n_instances=n_inst,
                        min_mem_gb=min_mem))
    return expand_multi_instance(jobs)


def expand_multi_instance(jobs: Sequence[Job]) -> List[Job]:
    """Expand n_instances > 1 into clone Jobs sharing an mi_group, so the
    scheduler profiles once and spawns the rest (paper §4.3)."""
    out: List[Job] = []
    next_id = max((j.jid for j in jobs), default=-1) + 1
    for j in jobs:
        if j.n_instances <= 1:
            out.append(j)
            continue
        j.mi_group = j.jid
        n = j.n_instances
        j.n_instances = 1
        out.append(j)
        for _ in range(n - 1):
            out.append(Job(jid=next_id, profile=j.profile, arrival=j.arrival,
                           work=j.work, qos_min_slice=j.qos_min_slice,
                           min_mem_gb=j.min_mem_gb, mi_group=j.mi_group))
            next_id += 1
    return out
