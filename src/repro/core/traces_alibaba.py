"""Alibaba ``cluster-trace-gpu-v2020`` replay: CSV loader + synthetic twin.

The PAI trace (https://github.com/alibaba/clusterdata, ``cluster-trace-gpu
-v2020``) records ~100K GPU jobs over two months on a ~6,500-GPU production
cluster — the workload the fragmentation-aware MIG scheduling line of work
(PAPERS.md: Ting et al.; Zambianco et al.) evaluates against, and the one
the related litosly repo drives at full scale.  This module maps its rows
onto the simulator's :class:`~repro.core.jobs.Job` model so every policy /
placer / objective in the registry can be replayed against production-shaped
load:

* **submission time** — the trace's ``start_time`` column (seconds; the
  public per-job file does not carry a separate submit column, so queueing
  inside the original cluster is not replayed — our simulator re-queues
  under its own schedulers).  An optional 11th ``submit_time`` column wins
  when present.  Times are normalized so the first kept row arrives at 0.
* **work** — ``(end_time - start_time) * min(plan_gpu/100, 1)``: the wall
  duration scaled by the requested GPU share, i.e. seconds of *exclusive
  full-GPU* execution, which is what ``Job.work`` means.  Zero/negative
  durations (unfinished rows, clock skew) are dropped and counted.
* **QoS tier** — ``plan_gpu`` (percent of a GPU, 25/50/100/200...) maps to
  the smallest slice covering that compute share; latency-ish task classes
  (``chief`` / ``evaluator`` / ``ps``) carry a slice floor on top.  Shares
  above 100% either clamp to the full slice (default) or reject with a
  clear error (``oversize="error"``).
* **workload profile** — the trace has no model identity, so each job draws
  a pool profile (:data:`repro.core.jobs.WORKLOADS`) by a deterministic
  hash of its ``job_name``: stable across runs, processes and machines.
* **instances** — ``inst_num`` expands into co-scheduled clones sharing an
  ``mi_group`` (capped: the trace's CPU-worker counts reach the hundreds).

:func:`synthesize_alibaba_trace` bootstraps the committed sample's joint
(duration, gpu-share, task-class, instance-count) rows and its empirical
inter-arrival distribution into arbitrarily long traces with the same
shape — the offline stand-in for the real CSV (which is too large to
commit) and the load generator for the engine scaling benchmark.
"""
from __future__ import annotations

import csv
import hashlib
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.jobs import Job, JobProfile, WORKLOADS
from repro.core.partitions import PartitionSpace, a100_mig_space
from repro.core.traces import expand_multi_instance

#: the public per-job schema of ``pai_job_duration_estimate_100K.csv``-style
#: exports (litosly / related work); an optional trailing ``submit_time``
#: column is honored when present.
ALIBABA_COLUMNS = ("job_name", "task_name", "inst_num", "status",
                   "start_time", "end_time", "plan_cpu", "plan_mem",
                   "plan_gpu", "gpu_type")

#: slice floors by task class: coordination/serving roles need
#: responsiveness, so they carry a QoS floor beyond their compute share
TASK_QOS_FLOOR = {"chief": 2, "evaluator": 2, "ps": 1}

#: committed ~200-row sample (see ``tools/make_alibaba_sample.py``)
SAMPLE_CSV = os.path.join(os.path.dirname(__file__), "..", "data",
                          "alibaba_v2020_sample.csv")

_INSTANCE_CAP = 4          # trace inst_num counts CPU workers, often 100s
_MIN_WORK_S = 1.0


@dataclass
class TraceStats:
    """Row accounting for one :func:`load_alibaba_trace` pass."""
    rows_total: int = 0            # data rows seen (header excluded)
    rows_used: int = 0             # rows that became jobs
    rows_malformed: int = 0        # short rows / unparseable numbers
    rows_zero_duration: int = 0    # end <= start (unfinished / skewed)
    rows_no_gpu: int = 0           # plan_gpu missing or 0 (CPU-only)
    rows_clamped: int = 0          # plan_gpu > 100 clamped to the full slice
    t0: float = 0.0                # raw submit time mapped to arrival 0
    span_s: float = 0.0            # arrival span of the kept rows


@dataclass(frozen=True)
class TraceRow:
    """One parsed trace row (before the Job mapping)."""
    job_name: str
    task_name: str
    inst_num: int
    status: str
    submit: float                  # raw trace time (seconds)
    duration: float                # end - start wall seconds
    gpu_share: float               # plan_gpu / 100 (1.0 = one full GPU)
    gpu_type: str


def _det_index(key: str, n: int) -> int:
    """Deterministic ``job_name -> [0, n)`` (stable across processes;
    ``hash()`` is salted per interpreter and must not leak into traces)."""
    h = hashlib.sha256(key.encode()).digest()
    return int.from_bytes(h[:8], "big") % n


def parse_alibaba_csv(path: str, *, strict: bool = False
                      ) -> Tuple[List[TraceRow], TraceStats]:
    """Parse the CSV into :class:`TraceRow` records + accounting.

    Malformed rows (too few columns, unparseable numbers) are skipped and
    counted unless ``strict=True``, which raises with the offending line
    number.  Zero/negative-duration and GPU-less rows are dropped and
    counted; rows are **not** yet time-sorted (the trace interleaves
    out-of-order submissions; :func:`load_alibaba_trace` sorts)."""
    rows: List[TraceRow] = []
    stats = TraceStats()
    with open(path, newline="") as f:
        for lineno, rec in enumerate(csv.reader(f), start=1):
            if not rec or (lineno == 1 and rec[0].strip() == "job_name"):
                continue                       # blank line / header
            stats.rows_total += 1
            try:
                if len(rec) < len(ALIBABA_COLUMNS):
                    raise ValueError(f"{len(rec)} columns, "
                                     f"need {len(ALIBABA_COLUMNS)}")
                start = float(rec[4])
                end = float(rec[5])
                plan_gpu = float(rec[8]) if rec[8].strip() else 0.0
                inst = int(float(rec[2])) if rec[2].strip() else 1
                submit = float(rec[10]) if len(rec) > 10 and rec[10].strip() \
                    else start
            except ValueError as e:
                if strict:
                    raise ValueError(
                        f"{path}:{lineno}: malformed trace row {rec!r} "
                        f"({e})") from None
                stats.rows_malformed += 1
                continue
            if end <= start:
                stats.rows_zero_duration += 1
                continue
            if plan_gpu <= 0.0:
                stats.rows_no_gpu += 1
                continue
            rows.append(TraceRow(
                job_name=rec[0].strip(), task_name=rec[1].strip().lower(),
                inst_num=max(1, inst), status=rec[3].strip(),
                submit=submit, duration=end - start,
                gpu_share=plan_gpu / 100.0, gpu_type=rec[9].strip()))
    return rows, stats


def _qos_for(space: PartitionSpace, gpu_share: float, task_name: str) -> int:
    """Smallest slice covering the requested compute share, lifted by the
    task-class floor.  ``gpu_share`` is pre-capped at 1.0 by the caller."""
    qos = 0
    for size in sorted(space.sizes):
        if space.compute_frac(size) >= gpu_share:
            qos = size
            break
    else:                                     # pragma: no cover - cap'd
        qos = space.full_size
    floor = TASK_QOS_FLOOR.get(task_name, 0)
    if floor and floor in space.slices:
        qos = max(qos, floor)
    return qos


def rows_to_jobs(rows: Sequence[TraceRow], *,
                 space: Optional[PartitionSpace] = None,
                 pool: Optional[Sequence[JobProfile]] = None,
                 oversize: str = "clamp",
                 max_duration_s: Optional[float] = None,
                 stats: Optional[TraceStats] = None) -> List[Job]:
    """Map parsed rows (already time-ordered, arrivals relative to 0) onto
    simulator Jobs; shared by the CSV loader and the synthetic generator.

    ``oversize`` controls ``plan_gpu > 100`` (multi-GPU requests, which no
    MIG slice can serve): ``"clamp"`` caps the request at the full slice,
    ``"error"`` raises with the row identity."""
    if oversize not in ("clamp", "error"):
        raise ValueError(f"oversize={oversize!r}: expected 'clamp' or "
                         f"'error'")
    space = space or a100_mig_space()
    pool = list(pool or WORKLOADS)
    jobs: List[Job] = []
    for i, r in enumerate(rows):
        share = r.gpu_share
        if share > 1.0:
            if oversize == "error":
                raise ValueError(
                    f"job {r.job_name!r}: plan_gpu={share * 100:.0f}% "
                    f"exceeds the largest MIG slice "
                    f"({space.full_size}g = 100%); pass oversize='clamp' "
                    f"to cap multi-GPU requests at one full slice")
            share = 1.0
            if stats is not None:
                stats.rows_clamped += 1
        duration = r.duration
        if max_duration_s is not None:
            duration = min(duration, max_duration_s)
        prof = pool[_det_index(r.job_name, len(pool))]
        jobs.append(Job(
            jid=i, profile=prof, arrival=r.submit,
            work=max(_MIN_WORK_S, duration * share),
            qos_min_slice=_qos_for(space, share, r.task_name),
            n_instances=min(r.inst_num, _INSTANCE_CAP)))
    return expand_multi_instance(jobs)


def load_alibaba_trace(path: str = SAMPLE_CSV, *,
                       limit_jobs: Optional[int] = None,
                       t_start: Optional[float] = None,
                       t_end: Optional[float] = None,
                       space: Optional[PartitionSpace] = None,
                       pool: Optional[Sequence[JobProfile]] = None,
                       oversize: str = "clamp", strict: bool = False,
                       max_duration_s: Optional[float] = None,
                       stats_out: Optional[TraceStats] = None) -> List[Job]:
    """Load an Alibaba v2020 CSV as a replayable job trace.

    Rows are sorted by submission time (the raw trace interleaves
    out-of-order submissions) and normalized so the first kept row arrives
    at t=0.  ``t_start`` / ``t_end`` slice a window *after* normalization
    (window jobs are re-based to arrive at ``t - t_start``); ``limit_jobs``
    then keeps the first N of the slice — both deterministic, so two loads
    of the same window are identical.  Pass ``stats_out`` (a fresh
    :class:`TraceStats`) to receive the row accounting."""
    rows, stats = parse_alibaba_csv(path, strict=strict)
    rows.sort(key=lambda r: (r.submit, r.job_name, r.task_name))
    if rows:
        t0 = rows[0].submit
        stats.t0 = t0
        rows = [TraceRow(r.job_name, r.task_name, r.inst_num, r.status,
                         r.submit - t0, r.duration, r.gpu_share, r.gpu_type)
                for r in rows]
    if t_start is not None or t_end is not None:
        lo = t_start or 0.0
        hi = t_end if t_end is not None else float("inf")
        rows = [TraceRow(r.job_name, r.task_name, r.inst_num, r.status,
                         r.submit - lo, r.duration, r.gpu_share, r.gpu_type)
                for r in rows if lo <= r.submit < hi]
    if limit_jobs is not None:
        rows = rows[:limit_jobs]
    stats.rows_used = len(rows)
    stats.span_s = rows[-1].submit - rows[0].submit if len(rows) > 1 else 0.0
    jobs = rows_to_jobs(rows, space=space, pool=pool, oversize=oversize,
                        max_duration_s=max_duration_s, stats=stats)
    if stats_out is not None:
        stats_out.__dict__.update(stats.__dict__)
    return jobs


# ------------------------------------------------------------- synthesis


def synthesize_alibaba_trace(n_jobs: int, *, seed: int = 0,
                             sample_path: str = SAMPLE_CSV,
                             load_scale: float = 1.0,
                             space: Optional[PartitionSpace] = None,
                             pool: Optional[Sequence[JobProfile]] = None,
                             max_duration_s: Optional[float] = None
                             ) -> List[Job]:
    """Synthetic trace with the sample's empirical distributions.

    Bootstraps whole rows — the joint (duration, gpu-share, task-class,
    instance-count) tuple is resampled together, preserving the trace's
    correlations (big requests run longer) — and draws inter-arrivals from
    the sample's empirical gaps, scaled down by ``load_scale`` (2.0 = twice
    the arrival rate; scale it with fleet size to keep utilization
    constant).  Seeded and deterministic; shares the row->Job mapping with
    the CSV loader, so QoS / oversize / instance semantics are identical."""
    if n_jobs <= 0:
        return []
    if load_scale <= 0:
        raise ValueError(f"load_scale must be > 0, got {load_scale}")
    base, _ = parse_alibaba_csv(sample_path)
    if not base:
        raise ValueError(f"{sample_path}: no usable rows to bootstrap from")
    base.sort(key=lambda r: (r.submit, r.job_name, r.task_name))
    submits = np.asarray([r.submit for r in base], dtype=float)
    iats = np.diff(submits)
    iats = iats[iats > 0]
    if iats.size == 0:
        iats = np.asarray([1.0])
    rng = np.random.default_rng((seed, 0xA11BABA))
    picks = rng.integers(0, len(base), size=n_jobs)
    gaps = rng.choice(iats, size=n_jobs) / load_scale
    arrivals = np.cumsum(gaps) - gaps[0]          # first arrival at 0
    rows = [TraceRow(job_name=f"synth-{seed}-{i}",
                     task_name=base[k].task_name,
                     inst_num=base[k].inst_num, status="Synthesized",
                     submit=float(arrivals[i]),
                     duration=base[k].duration,
                     gpu_share=base[k].gpu_share,
                     gpu_type=base[k].gpu_type)
            for i, k in enumerate(picks)]
    return rows_to_jobs(rows, space=space, pool=pool, oversize="clamp",
                        max_duration_s=max_duration_s)
