"""MS106: fork-safety — worker pools must use the spawn context.

The PR 5 deadlock class: once a process has initialized JAX/XLA (thread
pools, locked allocators), ``fork()`` clones held locks into children that
can never release them — the sweep hung forever the first time workers ran
real U-Net inference.  The repo contract is therefore *always spawn*:

* ``ProcessPoolExecutor(...)`` must pass an explicit ``mp_context=`` (and
  not a fork one);
* ``multiprocessing.Pool`` / ``Process`` must come from
  ``get_context("spawn")``;
* ``get_context("fork")`` / ``set_start_method("fork")`` are flagged
  outright.

The check applies everywhere (any module can be imported after jax is
live); the message notes when the file itself imports jax, which makes the
fork hazard a certainty rather than a latency.
"""
from __future__ import annotations

import ast
from typing import List

from misolint.context import ModuleContext
from misolint.rules.base import Finding, Rule, register_rule


def _kwarg(call: ast.Call, name: str):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _is_fork_const(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and node.value == "fork"


@register_rule
class ForkSafetyRule(Rule):
    id = "MS106"
    title = "process pool without explicit spawn context (fork-after-jax)"

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        jax_note = (" — this file imports jax, so a forked child inherits "
                    "XLA's held locks and deadlocks"
                    if ctx.imports_module("jax") else "")
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = ctx.resolve(node.func) or ""
            tail = dotted.split(".")[-1]
            if tail == "ProcessPoolExecutor":
                mpc = _kwarg(node, "mp_context")
                if mpc is None:
                    out.append(self.finding(
                        ctx, node,
                        f"ProcessPoolExecutor without explicit mp_context=: "
                        f"the platform default (fork on Linux) deadlocks "
                        f"under live XLA; pass multiprocessing.get_context"
                        f"(\"spawn\"){jax_note}"))
                elif (isinstance(mpc, ast.Call)
                        and (ctx.resolve(mpc.func) or "").endswith(
                            "get_context")
                        and mpc.args and _is_fork_const(mpc.args[0])):
                    out.append(self.finding(
                        ctx, node,
                        f"ProcessPoolExecutor with a fork context: use "
                        f"get_context(\"spawn\"){jax_note}"))
            elif tail in ("Pool", "Process") and dotted.startswith(
                    "multiprocessing."):
                out.append(self.finding(
                    ctx, node,
                    f"bare multiprocessing.{tail}: derive workers from "
                    f"multiprocessing.get_context(\"spawn\") so the start "
                    f"method is explicit{jax_note}"))
            elif tail == "get_context" and node.args \
                    and _is_fork_const(node.args[0]):
                out.append(self.finding(
                    ctx, node,
                    f"get_context(\"fork\") requested: forking a "
                    f"jax-initialized process deadlocks; use spawn"
                    f"{jax_note}"))
            elif tail == "set_start_method" and node.args \
                    and _is_fork_const(node.args[0]):
                out.append(self.finding(
                    ctx, node,
                    f"set_start_method(\"fork\"): the repo contract is "
                    f"spawn everywhere{jax_note}"))
        return out
