"""Rule registry: one module per rule, mirroring the simulator's
policy/placer/objective registries.

A rule is a class with a unique ``id`` (``MS1xx``), a one-line ``title``,
an optional ``scope`` (repo-relative path prefixes it applies to; empty =
everywhere) and a ``check(ctx) -> List[Finding]`` method.  Rules that can
rewrite code mechanically also implement ``fix(ctx, finding) -> edits``
(see ``misolint.fixes``).

Register with the decorator::

    @register_rule
    class MyRule(Rule):
        id = "MS1xx"
        ...
"""
from __future__ import annotations

from typing import Dict, List, Type

from misolint.rules.base import Rule, register_rule, all_rules, get_rule

# importing the modules registers the built-ins (kept in id order)
from misolint.rules import (ms101_global_rng, ms102_reseed,  # noqa: F401
                            ms103_set_iteration, ms104_registry,
                            ms105_mutable_default, ms106_fork_safety,
                            ms107_float_accumulation, ms108_wall_clock,
                            ms109_swallowed_exceptions,
                            ms110_soa_scalar_loop)

__all__ = ["Rule", "register_rule", "all_rules", "get_rule"]
