from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Type

from misolint.context import ModuleContext


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    # set post-check by the driver, never by rules:
    suppressed: bool = False
    suppress_reason: Optional[str] = None
    baselined: bool = False

    @property
    def sort_key(self) -> Tuple[str, int, int, str]:
        return (self.path, self.line, self.col, self.rule)


_REGISTRY: Dict[str, Type["Rule"]] = {}


def register_rule(cls: Type["Rule"]) -> Type["Rule"]:
    if not getattr(cls, "id", None):
        raise ValueError(f"{cls.__name__} must define a non-empty `id`")
    if cls.id in _REGISTRY:
        raise ValueError(f"duplicate rule id {cls.id!r}")
    _REGISTRY[cls.id] = cls
    return cls


def all_rules() -> List[Type["Rule"]]:
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def get_rule(rule_id: str) -> Type["Rule"]:
    try:
        return _REGISTRY[rule_id]
    except KeyError:
        raise ValueError(f"unknown rule {rule_id!r}; "
                         f"available: {', '.join(sorted(_REGISTRY))}") from None


class Rule:
    """Base class. Subclasses set ``id``/``title``/``scope`` and implement
    ``check``; ``scope`` is a tuple of path prefixes (repo-relative,
    forward slashes) — empty means every linted file."""

    id: str = ""
    title: str = ""
    scope: Tuple[str, ...] = ()
    fixable: bool = False

    def applies_to(self, path: str) -> bool:
        if not self.scope:
            return True
        return any(p in path for p in self.scope)

    def check(self, ctx: ModuleContext) -> List[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, ctx: ModuleContext, node: ast.AST,
                message: str) -> Finding:
        return Finding(rule=self.id, path=ctx.path,
                       line=getattr(node, "lineno", 0),
                       col=getattr(node, "col_offset", 0),
                       message=message)
