"""MS104: registry hygiene for the policy/placer/objective plugin layers.

The simulator's pluggable layers all follow one convention: a module under
``policies/`` holds exactly one ``@register_policy`` class whose literal
``name`` matches the module (underscores become hyphens — ``miso_frag.py``
registers ``"miso-frag"``), so ``SimConfig.policy`` strings, file names and
sweep-report columns never drift apart.  Placers and objectives share the
decorator convention: every ``@register_placer`` / ``@register_objective``
class must carry a unique, non-empty literal ``name``.

Violations here are how registries rot: a module registering two policies
under one file, a class whose name is computed at runtime (unfindable by
grep), or a copy-pasted duplicate name that silently shadows at import.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from misolint.context import ModuleContext
from misolint.rules.base import Finding, Rule, register_rule

_DECORATORS = ("register_policy", "register_placer", "register_objective")
_EXEMPT_MODULES = {"__init__", "base"}


def _decorator_name(dec: ast.AST) -> Optional[str]:
    if isinstance(dec, ast.Name):
        return dec.id
    if isinstance(dec, ast.Attribute):
        return dec.attr
    if isinstance(dec, ast.Call):
        return _decorator_name(dec.func)
    return None


def _literal_name_attr(cls: ast.ClassDef) -> Optional[str]:
    """The class's literal `name = "..."` assignment, if any."""
    for stmt in cls.body:
        if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
                and stmt.targets[0].id == "name"
                and isinstance(stmt.value, ast.Constant)
                and isinstance(stmt.value.value, str)):
            return stmt.value.value
        if (isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
                and stmt.target.id == "name"
                and isinstance(stmt.value, ast.Constant)
                and isinstance(stmt.value.value, str)):
            return stmt.value.value
    return None


@register_rule
class RegistryHygieneRule(Rule):
    id = "MS104"
    title = "plugin registry hygiene (one policy per module, literal names)"
    scope = ("src/",)

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        registered: List[tuple] = []   # (class node, decorator, name|None)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            decs = [d for d in (_decorator_name(x) for x in node.decorator_list)
                    if d in _DECORATORS]
            if not decs:
                continue
            registered.append((node, decs[0], _literal_name_attr(node)))

        seen: Dict[str, str] = {}
        for cls, dec, name in registered:
            if not name:
                out.append(self.finding(
                    ctx, cls,
                    f"@{dec} class `{cls.name}` has no literal string "
                    f"`name = \"...\"` attribute — registry names must be "
                    f"grep-able constants"))
            elif name in seen:
                out.append(self.finding(
                    ctx, cls,
                    f"@{dec} name {name!r} on `{cls.name}` duplicates "
                    f"`{seen[name]}` in the same module — the second "
                    f"registration raises (or shadows) at import"))
            else:
                seen[name] = cls.name

        # policies/ package: one registered policy per module, file name
        # and registry name must agree
        if "/policies/" in ctx.path:
            module = ctx.path.rsplit("/", 1)[-1].removesuffix(".py")
            if module not in _EXEMPT_MODULES:
                policies = [(c, d, n) for c, d, n in registered
                            if d == "register_policy"]
                if len(policies) != 1:
                    out.append(Finding(
                        rule=self.id, path=ctx.path, line=1, col=0,
                        message=(f"module `{module}.py` registers "
                                 f"{len(policies)} policies; the convention "
                                 f"is exactly one @register_policy class "
                                 f"per module")))
                for cls, _, name in policies:
                    if name and name != module.replace("_", "-"):
                        out.append(self.finding(
                            ctx, cls,
                            f"policy name {name!r} does not match module "
                            f"`{module}.py` (expected "
                            f"{module.replace('_', '-')!r}) — keep file "
                            f"names and SimConfig.policy strings aligned"))
        return out
