"""MS105: mutable default arguments.

``def f(jobs=[])`` evaluates the list once at definition time; every call
then shares (and mutates) the same object.  In a simulator that replays
traces across seeds and worker processes this is state leaking between
runs — the canonical fix is ``=None`` plus a guard in the body, which
``misolint --fix`` applies mechanically.
"""
from __future__ import annotations

import ast
from typing import List

from misolint.context import ModuleContext
from misolint.rules.base import Finding, Rule, register_rule

_MUTABLE_CALLS = {"list", "dict", "set", "bytearray", "defaultdict",
                  "OrderedDict", "Counter", "deque"}


def is_mutable_default(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        f = node.func
        name = f.id if isinstance(f, ast.Name) else (
            f.attr if isinstance(f, ast.Attribute) else None)
        return name in _MUTABLE_CALLS
    return False


@register_rule
class MutableDefaultRule(Rule):
    id = "MS105"
    title = "mutable default argument"
    fixable = True      # default -> None + `if x is None:` guard

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                continue
            args = node.args
            pos = args.posonlyargs + args.args
            for arg, default in zip(pos[len(pos) - len(args.defaults):],
                                    args.defaults):
                if is_mutable_default(default):
                    out.append(self.finding(
                        ctx, default,
                        f"mutable default `{arg.arg}="
                        f"{ast.unparse(default)}`: shared across calls; "
                        f"use None and rebuild inside the body"))
            for arg, default in zip(args.kwonlyargs, args.kw_defaults):
                if default is not None and is_mutable_default(default):
                    out.append(self.finding(
                        ctx, default,
                        f"mutable default `{arg.arg}="
                        f"{ast.unparse(default)}`: shared across calls; "
                        f"use None and rebuild inside the body"))
        return out
