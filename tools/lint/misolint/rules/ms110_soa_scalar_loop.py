"""MS110: per-resident Python ``for`` loop over SoA-backed columns.

The simulator's per-resident hot state lives in slot-aligned
struct-of-arrays columns (``GPU._rjobs`` / ``_spd`` / ``_ckt`` / ``_ckw``;
layout rationale in ``core/sim/soa.py``).  A Python-level loop over those
columns inside ``core/sim/`` is one of two things:

* a **sanctioned scalar column walk** — measured faster than any numpy
  round-trip at the <=7-resident row lengths a GPU can hold, and bit-pinned
  by the golden traces — which must carry an inline suppression citing that
  measurement, or
* an **accidental reintroduction** of per-object iteration on a path that
  should go through the vectorized ``soa.FleetState`` batch operations.

Either way the loop must be deliberate, so this rule flags every one:
plain ``for`` statements and comprehensions, through ``enumerate`` /
``zip`` / ``list`` / ``reversed`` / ``sorted`` wrappers, subscripted
column slices (``self._rjobs[i:]``), and simple local aliases bound from a
column in the same function (``rjobs = self._rjobs``).

One pattern is recognized rather than flagged: the **replica-major
gather** in ``core/sim/batch.py`` — a comprehension over a column whose
value is stored straight into a subscripted destination row
(``out[b, g, :k] = [... for rj in g._rjobs]``).  That scatter builds the
``(B, G, S)`` export arrays that ARE the vectorization boundary: each row
is one GPU's <=7-slot column (the same length bound that sanctions the
scalar walks), and there is no ``FleetState`` batch op left to route it
through — the gather is how rows become batch-shaped in the first place.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from misolint.context import ModuleContext
from misolint.rules.base import Finding, Rule, register_rule

#: the SoA column attributes (kept in sync with GPU.__init__ / soa.py)
COLUMNS = ("_rjobs", "_spd", "_ckt", "_ckw")

#: builtins that forward iteration to their argument(s)
_WRAPPERS = ("enumerate", "zip", "list", "tuple", "reversed", "sorted")

#: the one module whose subscript-store gathers are replica-major exports
BATCH_MODULE = "src/repro/core/sim/batch.py"


def _is_replica_major_gather(ctx: ModuleContext, node: ast.AST) -> bool:
    """A comprehension in ``core/sim/batch.py`` whose value lands directly
    in a subscripted store — ``out[b, g, :k] = [... for rj in col]`` — is
    the replica-major export gather, not a scalar walk to vectorize."""
    if ctx.path != BATCH_MODULE:
        return False
    if not isinstance(node, (ast.ListComp, ast.GeneratorExp)):
        return False
    parent = ctx.parent(node)
    return (isinstance(parent, ast.Assign)
            and len(parent.targets) == 1
            and isinstance(parent.targets[0], ast.Subscript))


def _column_of(node: ast.AST,
               aliases: Dict[str, str]) -> Optional[str]:
    """The SoA column ``node`` refers to, unwrapping subscripts
    (``self._rjobs[i:]`` iterates the column), or None."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute) and node.attr in COLUMNS:
        return node.attr
    if isinstance(node, ast.Name):
        return aliases.get(node.id)
    return None


def _iter_columns(iter_node: ast.AST,
                  aliases: Dict[str, str]) -> List[str]:
    """Columns iterated by a loop's ``iter`` expression, looking through
    one level of wrapper call (``enumerate(self._rjobs)``)."""
    cands = [iter_node]
    if (isinstance(iter_node, ast.Call)
            and isinstance(iter_node.func, ast.Name)
            and iter_node.func.id in _WRAPPERS):
        cands = list(iter_node.args)
    out = []
    for c in cands:
        col = _column_of(c, aliases)
        if col is not None:
            out.append(col)
    return out


def _function_aliases(fn: ast.AST) -> Dict[str, str]:
    """Simple local aliases of SoA columns inside ``fn``:
    ``spd = self._spd`` binds ``spd`` for the rest of the function."""
    out: Dict[str, str] = {}
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign):
            continue
        col = _column_of(node.value, {})
        if col is None:
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                out[tgt.id] = col
    return out


@register_rule
class SoaScalarLoopRule(Rule):
    id = "MS110"
    title = "per-resident Python loop over an SoA-backed column"
    scope = ("src/repro/core/sim/",)

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        alias_cache: Dict[int, Dict[str, str]] = {}

        def aliases_for(node: ast.AST) -> Dict[str, str]:
            fn = ctx.enclosing(node, ast.FunctionDef, ast.AsyncFunctionDef)
            if fn is None:
                return {}
            key = id(fn)
            if key not in alias_cache:
                alias_cache[key] = _function_aliases(fn)
            return alias_cache[key]

        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.For):
                iters = [node.iter]
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters = [g.iter for g in node.generators]
            else:
                continue
            aliases = aliases_for(node)
            cols = []
            for it in iters:
                cols.extend(_iter_columns(it, aliases))
            if not cols:
                continue
            if _is_replica_major_gather(ctx, node):
                continue
            names = ", ".join(f"`{c}`" for c in dict.fromkeys(cols))
            out.append(self.finding(
                ctx, node,
                f"Python-level loop over SoA column(s) {names}; vectorize "
                f"through soa.FleetState batch ops, or suppress citing the "
                f"<=7-slot scalar-walk measurement (see soa.py)"))
        return out
