"""MS101: global / unseeded RNG inside the simulator core.

``src/repro/core/`` must thread explicit ``numpy.random.Generator``
objects (or JAX keys) through every stochastic path — the module-level
``np.random.*`` / ``random.*`` functions share hidden global state, so one
stray call desynchronizes every seeded stream in the process and the
golden traces stop being golden.

Allowed: ``np.random.default_rng``, ``Generator`` / ``SeedSequence`` /
``BitGenerator`` constructors (``PCG64``, ``Philox``, ...), and any
attribute *reference* (annotations like ``np.random.Generator``).  Flagged:
*calls* to the stateful module-level API (``np.random.rand``, ``np.random
.seed``, ``random.random``, ``random.shuffle``, ...).
"""
from __future__ import annotations

import ast
from typing import List

from misolint.context import ModuleContext
from misolint.rules.base import Finding, Rule, register_rule

_NP_ALLOWED = {"default_rng", "Generator", "SeedSequence", "BitGenerator",
               "PCG64", "PCG64DXSM", "Philox", "SFC64", "MT19937",
               "RandomState"}  # RandomState(seed) is explicit-stream too
# stdlib random: the Random class is an explicit stream; everything else
# module-level mutates the hidden global instance
_STDLIB_ALLOWED = {"Random", "SystemRandom"}


@register_rule
class GlobalRngRule(Rule):
    id = "MS101"
    title = "global/unseeded RNG in simulator core (thread a Generator)"
    scope = ("src/repro/core/",)

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = ctx.resolve(node.func)
            if not dotted:
                continue
            parts = dotted.split(".")
            if (len(parts) >= 3 and parts[0] == "numpy"
                    and parts[1] == "random"
                    and parts[2] not in _NP_ALLOWED):
                out.append(self.finding(
                    ctx, node,
                    f"call to global numpy RNG `{'.'.join(parts[1:])}`: "
                    f"thread an explicit np.random.Generator instead"))
            elif (len(parts) == 2 and parts[0] == "random"
                    and parts[1] not in _STDLIB_ALLOWED
                    and ctx.imports_module("random")):
                out.append(self.finding(
                    ctx, node,
                    f"call to stdlib global RNG `{dotted}`: thread an "
                    f"explicit random.Random or np.random.Generator"))
        return out
