"""MS103: iterating a set where the order can feed decisions.

Set iteration order is a hash-table artifact: stable enough to *look*
deterministic in one interpreter, free to change across platforms, Python
versions and (for str elements) ``PYTHONHASHSEED``.  Any set iteration
whose element order can reach ordering-sensitive code — placement
candidate lists, first-strict-max argmax scans, heap pushes — must go
through an explicit ``sorted(...)``.

Flagged consumption sites: ``for x in <set>``, comprehensions over a set,
``list/tuple/enumerate/iter/reversed(<set>)``, ``*<set>`` unpacking and
``heapq`` calls.  Order-insensitive sinks are allowed: ``sorted``, ``len``,
``sum``, ``min``, ``max``, ``any``, ``all``, ``set``, ``frozenset``,
membership tests and comparisons.  ``dict.keys()`` iteration is flagged in
the same way when written explicitly — iterate the dict itself, or wrap in
``sorted(...)`` when the order feeds a decision.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from misolint.context import ModuleContext
from misolint.rules.base import Finding, Rule, register_rule

_ORDER_FREE_SINKS = {"sorted", "len", "sum", "min", "max", "any", "all",
                     "set", "frozenset", "bool"}
_ORDERED_WRAPPERS = {"list", "tuple", "enumerate", "iter", "reversed"}
_SET_BINOPS = (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)
_SET_METHODS = {"union", "intersection", "difference", "symmetric_difference"}


def is_set_expr(node: ast.AST) -> bool:
    """Syntactically set-valued: literals, comprehensions, set()/frozenset()
    calls, .keys() views, set algebra on any of those."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Name) and f.id in ("set", "frozenset"):
            return True
        if isinstance(f, ast.Attribute):
            if f.attr == "keys":
                return True
            if f.attr in _SET_METHODS and is_set_expr(f.value):
                return True
    if isinstance(node, ast.BinOp) and isinstance(node.op, _SET_BINOPS):
        return is_set_expr(node.left) or is_set_expr(node.right)
    return False


@register_rule
class SetIterationRule(Rule):
    id = "MS103"
    title = "unordered set iteration on a potential decision path"
    fixable = True      # wrap the iterable in sorted(...)

    def _sink_name(self, ctx: ModuleContext,
                   consumer: ast.AST) -> Optional[str]:
        """Name of the call directly consuming ``consumer``'s result, for
        the order-insensitive allowance (e.g. sorted(x for x in s))."""
        parent = ctx.parent(consumer)
        if isinstance(parent, ast.Call) and isinstance(parent.func, ast.Name):
            return parent.func.id
        return None

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []

        def flag(node: ast.AST, how: str) -> None:
            out.append(self.finding(
                ctx, node,
                f"{how} iterates a set in hash order; wrap the iterable in "
                f"sorted(...) (or restructure) so downstream decisions "
                f"cannot depend on hash-table layout"))

        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.For) and is_set_expr(node.iter):
                flag(node.iter, "for-loop")
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                for gen in node.generators:
                    if is_set_expr(gen.iter):
                        # a genexp feeding straight into an order-free sink
                        # (sorted(...), sum(...)) is fine
                        if (isinstance(node, ast.GeneratorExp)
                                and self._sink_name(ctx, node)
                                in _ORDER_FREE_SINKS):
                            continue
                        flag(gen.iter, "comprehension")
            elif isinstance(node, ast.Call):
                f = node.func
                name = f.id if isinstance(f, ast.Name) else (
                    ctx.resolve(f) or "")
                if (isinstance(f, ast.Name) and name in _ORDERED_WRAPPERS
                        and node.args and is_set_expr(node.args[0])):
                    flag(node.args[0], f"{name}(...)")
                elif (name.startswith("heapq.") and node.args
                        and any(is_set_expr(a) for a in node.args)):
                    flag(node, f"{name}(...)")
            elif isinstance(node, ast.Starred) and is_set_expr(node.value):
                flag(node.value, "starred unpacking")
        return out
